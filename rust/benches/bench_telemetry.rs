//! Bench: live-telemetry overhead — the telemetry tier's performance bar.
//!
//! Measures saturated-server throughput (the `bench_obs` Q/K/V pattern:
//! 2 workers, rotating shared input) with telemetry off vs fully on — a
//! 20 ms sampler plus a live scraper thread hitting `/metrics` throughout
//! the run — plus a `sample_tick` micro-benchmark (ticks/s through the
//! full snapshot → derive → ring-store path). Emitted as
//! `BENCH_telemetry.json` for CI trend tracking.
//!
//! Gate (soft-retried to ride out scheduler noise, then hard): telemetry
//! fully on costs ≤ 2% of saturated throughput, best-of-N compared.

#[path = "common.rs"]
mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use adip::arch::Architecture;
use adip::coordinator::{Coordinator, CoordinatorConfig, MatmulRequest, Metrics, SubmitOptions};
use adip::dataflow::Mat;
use adip::telemetry::sampler::{sample_tick, PrevCounters, SampleSet};
use adip::telemetry::TelemetryConfig;
use adip::testutil::Rng;

const REQS: usize = 96;
const DIM: usize = 64;

/// One `/metrics` scrape over a throwaway connection (the tier is
/// one-request-per-connection); returns the body length as a liveness
/// check.
fn scrape(addr: SocketAddr) -> usize {
    let mut stream = TcpStream::connect(addr).expect("connect telemetry");
    stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: bench\r\n\r\n").expect("send scrape");
    let mut text = String::new();
    stream.read_to_string(&mut text).expect("read scrape");
    assert!(text.starts_with("HTTP/1.1 200"), "scrape failed: {text:.40}");
    text.len()
}

/// One saturated serving run; with telemetry enabled a scraper thread
/// polls `/metrics` for the whole run. Returns host seconds.
fn saturated_serve(telemetry: TelemetryConfig) -> f64 {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 2 * REQS,
        batch_window: 8,
        telemetry,
        ..Default::default()
    });
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = coord.telemetry_addr().map(|addr| {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut scrapes = 0usize;
            while !stop.load(Ordering::Acquire) {
                assert!(scrape(addr) > 0);
                scrapes += 1;
                std::thread::sleep(Duration::from_millis(50));
            }
            scrapes
        })
    });
    let client = coord.client();
    let mut rng = Rng::seeded(41);
    let t0 = std::time::Instant::now();
    let mut shared = Arc::new(Mat::random(&mut rng, DIM, DIM, 8));
    let tickets: Vec<_> = (0..REQS)
        .map(|i| {
            if i % 3 == 0 {
                shared = Arc::new(Mat::random(&mut rng, DIM, DIM, 8));
            }
            let req = MatmulRequest {
                id: 0,
                input_id: (i / 3) as u64,
                a: shared.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, DIM, 32, 2))],
                weight_bits: 2,
                act_act: false,
                tag: String::new(),
            };
            client.submit(SubmitOptions::new(req)).expect("queue sized")
        })
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Release);
    if let Some(s) = scraper {
        assert!(s.join().expect("scraper clean") > 0, "scraper never landed a scrape");
    }
    coord.shutdown();
    dt
}

/// Best observed throughput (req/s) over `reps` runs.
fn best_req_per_s(telemetry: TelemetryConfig, reps: usize) -> f64 {
    let stat = common::bench(reps, || saturated_serve(telemetry));
    REQS as f64 / stat.min_s
}

fn main() {
    // Sampler micro-bench: full snapshot → derive → ring-store ticks.
    // The sampler runs one of these every interval (default 250 ms), so
    // ticks costing microseconds means its steady-state duty cycle is
    // negligible — that, not the 2% gate, is why the tier is cheap.
    const TICKS: usize = 20_000;
    let metrics = Metrics::default();
    metrics.record_completion(1024, 1e-6, 4096, 4);
    metrics.record_cache(3, 1, 2, 1);
    let tick = common::bench(5, || {
        let series = SampleSet::default();
        let mut prev = PrevCounters::new(&metrics);
        for _ in 0..TICKS {
            std::hint::black_box(sample_tick(&metrics, &series, &mut prev));
        }
        assert_eq!(series.ticks.load(Ordering::Acquire) as usize, TICKS);
    });
    println!("== sampler micro-bench ({TICKS} ticks/iter) ==");
    common::report("sample_tick (snapshot+derive+store)", tick, TICKS as f64, "tick");

    let on_cfg = TelemetryConfig {
        listen: Some("127.0.0.1:0".parse().expect("addr")),
        sample_interval: Duration::from_millis(20),
    };

    // Saturated-throughput differential: telemetry off vs on-with-live-
    // scraper. Retried on gate failure — a saturated 2-worker serve has
    // real scheduler noise and the 2% gate is tighter than one cold
    // run's variance; the best observation across attempts is the honest
    // estimate of each mode's capability.
    println!("\n== saturated server telemetry overhead ({REQS} requests, 2 workers) ==");
    let mut base = 0f64;
    let mut on = 0f64;
    let mut overhead = f64::INFINITY;
    for attempt in 0..3 {
        base = base.max(best_req_per_s(TelemetryConfig::default(), 5));
        on = on.max(best_req_per_s(on_cfg, 5));
        overhead = (base / on - 1.0).max(0.0);
        println!(
            "  attempt {attempt}: off {base:.1} req/s | on {on:.1} req/s ({:+.2}%)",
            overhead * 100.0
        );
        if overhead <= 0.02 {
            break;
        }
    }
    assert!(overhead <= 0.02, "telemetry overhead {:.2}% exceeds the 2% gate", overhead * 100.0);

    let json = format!(
        "{{\n  \"bench\": \"bench_telemetry\",\n  \"sampler\": {{\"ticks_per_iter\": {TICKS}, \"ticks_per_s\": {:.0}}},\n  \"saturated_server\": {{\"requests\": {REQS}, \"off_req_per_s\": {base:.2}, \"on_req_per_s\": {on:.2}, \"overhead_on\": {overhead:.4}}}\n}}\n",
        TICKS as f64 / tick.min_s
    );
    let path = std::env::var("BENCH_TELEMETRY_JSON")
        .unwrap_or_else(|_| "BENCH_telemetry.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
