//! Bench: execution-backend comparison — `Backend::Functional` (direct
//! whole-GEMM + analytical timing, the serving path) vs
//! `Backend::CycleAccurate` (register-level golden reference) — at the
//! GEMM level and end-to-end through the coordinator at n = 32.
//!
//! The acceptance bar for the functional backend is ≥ 5× end-to-end
//! coordinator throughput at n = 32; in practice it lands around two
//! orders of magnitude because the cycle path steps every PE every beat.
//!
//! Also gates the served host kernel: `Mat::matmul_blocked` (the
//! `--kernel=blocked` tile loop) must beat the naive reference GEMM by
//! ≥ 3× single-threaded at 1024³ — the size where `B` (4 MiB) no longer
//! fits in L2, so the naive row-streaming loop pays full memory latency
//! while the blocked loop keeps its working tile cache-resident. Gated
//! on min-of-reps (co-tenant noise only ever inflates a rep).

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use adip::arch::{build_array, ArchConfig, Architecture, Backend};
use adip::coordinator::{Coordinator, CoordinatorConfig, MatmulRequest, SubmitOptions};
use adip::dataflow::Mat;
use adip::quant::PrecisionMode;
use adip::sim::CoSim;
use adip::testutil::Rng;

fn gemm_once(backend: Backend, a: &Mat, b: &Mat, mode: PrecisionMode) -> u64 {
    let cfg = ArchConfig::with_n(32).with_backend(backend);
    let mut sim = CoSim::new(build_array(Architecture::Adip, cfg));
    sim.run_gemm(a, b, mode, false).unwrap().cycles
}

fn serve_stream(backend: Backend, requests: usize, dim: usize) -> f64 {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 1024,
        batch_window: 8,
        backend,
        ..Default::default()
    });
    let client = coord.client();
    let mut rng = Rng::seeded(23);
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::new();
    let mut shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
    for i in 0..requests {
        if i % 3 == 0 {
            shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
        }
        let req = MatmulRequest {
            id: 0,
            input_id: (i / 3) as u64,
            a: shared.clone(),
            bs: vec![Arc::new(Mat::random(&mut rng, dim, 32, 2))],
            weight_bits: 2,
            act_act: false,
            tag: String::new(),
        };
        tickets.push(client.submit(SubmitOptions::new(req)).expect("queue sized"));
    }
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    coord.shutdown();
    dt
}

fn main() {
    let mut rng = Rng::seeded(11);

    println!("== GEMM-level backend comparison (ADiP 32x32, 128x128x128) ==");
    let a = Mat::random(&mut rng, 128, 128, 8);
    for mode in PrecisionMode::ALL {
        let b = Mat::random(&mut rng, 128, 128, mode.weight_bits());
        let cf = gemm_once(Backend::Functional, &a, &b, mode);
        let cg = gemm_once(Backend::CycleAccurate, &a, &b, mode);
        assert_eq!(cf, cg, "backends disagree on simulated cycles");
        let macs = (128usize * 128 * 128) as f64;
        let fast = common::bench(8, || gemm_once(Backend::Functional, &a, &b, mode));
        common::report(&format!("functional gemm {mode}"), fast, macs, "MAC");
        let slow = common::bench(3, || gemm_once(Backend::CycleAccurate, &a, &b, mode));
        common::report(&format!("cycle-accurate gemm {mode}"), slow, macs, "MAC");
        println!(
            "  -> functional speedup {mode}: {:.1}x (identical outputs + cycles)",
            slow.median_s / fast.median_s
        );
    }

    println!("\n== host kernel: blocked vs naive GEMM (1024x1024x1024, i32) ==");
    const KDIM: usize = 1024;
    let ka = Mat::random(&mut rng, KDIM, KDIM, 8);
    let kb = Mat::random(&mut rng, KDIM, KDIM, 8);
    let macs = (KDIM * KDIM * KDIM) as f64;
    let naive = common::bench(3, || ka.matmul(&kb));
    common::report("naive kernel (reference)", naive, macs, "MAC");
    let blocked1 = common::bench(3, || ka.matmul_blocked(&kb, 1));
    common::report("blocked kernel (1 thread)", blocked1, macs, "MAC");
    let blockedn = common::bench(3, || ka.matmul_blocked(&kb, 0));
    common::report("blocked kernel (all threads)", blockedn, macs, "MAC");
    assert_eq!(ka.matmul(&kb), ka.matmul_blocked(&kb, 0), "kernels must be bit-exact");
    let kernel_gain = naive.min_s / blocked1.min_s;
    println!(
        "  -> blocked speedup: {kernel_gain:.2}x single-thread (bar: >= 3x), {:.2}x threaded",
        naive.min_s / blockedn.min_s
    );
    assert!(
        kernel_gain >= 3.0,
        "blocked kernel must beat naive by >= 3x single-threaded at 1024^3 (got {kernel_gain:.2}x)"
    );

    println!("\n== end-to-end coordinator throughput (n=32, 2 workers, Q/K/V stream) ==");
    const REQS: usize = 48;
    const DIM: usize = 128;
    let t_fast = serve_stream(Backend::Functional, REQS, DIM);
    let t_slow = serve_stream(Backend::CycleAccurate, REQS, DIM);
    println!(
        "  functional:     {REQS} requests in {t_fast:.3}s = {:.0} req/s",
        REQS as f64 / t_fast
    );
    println!(
        "  cycle-accurate: {REQS} requests in {t_slow:.3}s = {:.0} req/s",
        REQS as f64 / t_slow
    );
    let speedup = t_slow / t_fast;
    println!("  end-to-end speedup: {speedup:.1}x (acceptance bar: >= 5x)");
    assert!(
        speedup >= 5.0,
        "functional backend must be at least 5x faster end-to-end (got {speedup:.1}x)"
    );
}
