//! Bench: array tile paths — fast functional vs register-level simulation
//! — plus the Fig. 4 analytical series.
//!
//! The fast tile path is the coordinator's hot loop; the register-level
//! simulator is the validation path. Reported in simulated MACs per host
//! second.

#[path = "common.rs"]
mod common;

use adip::analytical::fig4_series;
use adip::arch::{AdipArray, ArchConfig, SystolicArray};
use adip::dataflow::{interleave_tiles, Mat};
use adip::quant::PrecisionMode;
use adip::testutil::Rng;

fn main() {
    println!("== Fig. 4 (Eqs. 2–3): ADiP latency/throughput across sizes ==");
    for row in fig4_series() {
        println!(
            "  N={:<3} {:<6} latency={:<5} throughput={:>9.1} ops/cycle ({:.3} TOPS @1GHz)",
            row.n,
            row.mode.to_string(),
            row.latency,
            row.throughput_ops_per_cycle,
            row.throughput_tops_at_1ghz
        );
    }

    let mut rng = Rng::seeded(3);
    println!("\n== functional tile pass (coordinator hot path) ==");
    for n in [16usize, 32, 64] {
        for mode in PrecisionMode::ALL {
            let arr = AdipArray::new(ArchConfig::with_n(n));
            let k = mode.interleave_factor();
            let a = Mat::random(&mut rng, n, n, 8);
            let tiles: Vec<Mat> =
                (0..k).map(|_| Mat::random(&mut rng, n, n, mode.weight_bits())).collect();
            let refs: Vec<&Mat> = tiles.iter().collect();
            let it = interleave_tiles(&refs, mode).unwrap();
            let macs = (n * n * n * k) as f64;
            let stat = common::bench(32, || arr.tile_pass(&a, &it).unwrap());
            common::report(&format!("tile_pass fast n={n} {mode}"), stat, macs, "MAC");
        }
    }

    println!("\n== register-level cycle simulation (validation path) ==");
    for n in [8usize, 16, 32] {
        let arr = AdipArray::new(ArchConfig::with_n(n));
        let a = Mat::random(&mut rng, n, n, 8);
        let tiles: Vec<Mat> = (0..4).map(|_| Mat::random(&mut rng, n, n, 2)).collect();
        let refs: Vec<&Mat> = tiles.iter().collect();
        let it = interleave_tiles(&refs, PrecisionMode::W2).unwrap();
        let macs = (n * n * n * 4) as f64;
        let stat = common::bench(8, || arr.tile_pass_cycle_accurate(&a, &it).unwrap());
        common::report(&format!("tile_pass cycle-accurate n={n} 8b×2b"), stat, macs, "MAC");
    }
}
