//! Bench: L3 coordinator serving throughput and the batching ablation.
//!
//! Measures end-to-end request throughput through the full stack (bounded
//! queue → router/batcher → worker cores → co-sim execution) and isolates
//! the shared-input batching benefit by comparing a fusable Q/K/V stream
//! against the same stream with fusion-defeating input ids.

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use adip::arch::Architecture;
use adip::coordinator::{Coordinator, CoordinatorConfig, MatmulRequest};
use adip::dataflow::Mat;
use adip::testutil::Rng;

fn stream(fusable: bool, requests: usize, dim: usize) -> (usize, f64, u64) {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 1024,
        batch_window: 12,
        ..Default::default()
    });
    let mut rng = Rng::seeded(17);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
    for i in 0..requests {
        if i % 3 == 0 {
            shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
        }
        let input_id = if fusable { (i / 3) as u64 } else { i as u64 };
        let a = if fusable { shared.clone() } else { Arc::new(Mat::random(&mut rng, dim, dim, 8)) };
        let req = MatmulRequest {
            id: 0,
            input_id,
            a,
            // narrow (head-size) outputs: solo requests cannot j-fuse
            bs: vec![Arc::new(Mat::random(&mut rng, dim, 32, 2))],
            weight_bits: 2,
            act_act: false,
            tag: String::new(),
        };
        rxs.push(coord.try_submit(req).expect("queue sized").1);
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().unwrap().result.is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let cycles = coord.metrics().sim_cycles.load(std::sync::atomic::Ordering::Relaxed);
    coord.shutdown();
    (ok, dt, cycles)
}

fn main() {
    const REQS: usize = 96;
    const DIM: usize = 128;

    println!("== coordinator serving throughput (ADiP 32x32, 2 workers) ==");
    let stat = common::bench(5, || stream(true, REQS, DIM));
    common::report("serve fusable Q/K/V stream", stat, REQS as f64, "req");

    println!("\n== batching ablation (same stream, fusion on/off) ==");
    let (_, t_fused, cyc_fused) = stream(true, REQS, DIM);
    let (_, t_solo, cyc_solo) = stream(false, REQS, DIM);
    println!("  fused:   {t_fused:.3}s host, {cyc_fused} simulated cycles");
    println!("  unfused: {t_solo:.3}s host, {cyc_solo} simulated cycles");
    println!(
        "  simulated-cycle reduction from shared-input batching: {:.1}% (paper's multi-matrix mode)",
        (1.0 - cyc_fused as f64 / cyc_solo as f64) * 100.0
    );
}
