//! Bench: L3 coordinator serving — throughput, the batching ablation,
//! per-class queue waits on a saturated mixed-priority trace, and the
//! pipelined-vs-inline prepare gate — emitted as `BENCH_coordinator.json`
//! for CI trend tracking (uploaded alongside `BENCH_cluster.json`).
//!
//! Acceptance gates:
//!
//! 1. **Prepare overlap ≥ 1.1×**: on a decode-shaped stream (skinny
//!    activations, wide weights — the serving case where host-side
//!    preparation is a double-digit fraction of execution) with the
//!    weight cache on (fingerprints are mandatory work), the pipelined
//!    prepare stage must beat inline preparation by ≥ 1.1× host
//!    wall-clock. Gated on the min of repeated runs (co-tenant stalls on
//!    shared CI runners only ever inflate a rep, never deflate it).
//!    Simulated accounting is asserted identical across the two modes, so
//!    the gate isolates pure host pipelining.
//! 2. **Priority order**: on the saturated mixed-priority trace,
//!    Interactive mean queue wait must not exceed Background's.

#[path = "common.rs"]
mod common;

use std::sync::atomic::Ordering;
use std::sync::Arc;

use adip::arch::Architecture;
use adip::cluster::ClusterConfig;
use adip::coordinator::{
    Coordinator, CoordinatorConfig, MatmulRequest, PrepareMode, Priority, SubmitOptions,
};
use adip::dataflow::Mat;
use adip::testutil::Rng;
use adip::workload::{repeated_attention_trace, TraceConfig, TransformerModel};

fn stream(fusable: bool, requests: usize, dim: usize) -> (usize, f64, u64) {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 1024,
        batch_window: 12,
        ..Default::default()
    });
    let client = coord.client();
    let mut rng = Rng::seeded(17);
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::new();
    let mut shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
    for i in 0..requests {
        if i % 3 == 0 {
            shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
        }
        let input_id = if fusable { (i / 3) as u64 } else { i as u64 };
        let a = if fusable { shared.clone() } else { Arc::new(Mat::random(&mut rng, dim, dim, 8)) };
        let req = MatmulRequest {
            id: 0,
            input_id,
            a,
            // narrow (head-size) outputs: solo requests cannot j-fuse
            bs: vec![Arc::new(Mat::random(&mut rng, dim, 32, 2))],
            weight_bits: 2,
            act_act: false,
            tag: String::new(),
        };
        tickets.push(client.submit(SubmitOptions::new(req)).expect("queue sized"));
    }
    let mut ok = 0;
    for t in tickets {
        if t.wait().unwrap().result.is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let cycles = coord.metrics().sim_cycles.load(Ordering::Relaxed);
    coord.shutdown();
    (ok, dt, cycles)
}

/// Decode-shaped prepare-heavy stream: skinny activations (`m` rows)
/// against wide `k×nc` weights, unique weights per request (every cache
/// probe misses, so fingerprinting is mandatory work on every batch).
/// Returns (host seconds, total simulated cycles).
fn prepare_stream(prepare: PrepareMode, requests: usize) -> (f64, u64) {
    const M: usize = 2;
    const K: usize = 256;
    const NC: usize = 256;
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 16,
        workers: 1, // inline mode is then truly serial prepare->execute
        queue_capacity: 2 * requests,
        batch_window: 1,
        cluster: ClusterConfig::with_cores(1).with_cache(32),
        prepare,
        ..Default::default()
    });
    let client = coord.client();
    let mut rng = Rng::seeded(29);
    // operands built up front: the measured region is pure serving
    let reqs: Vec<MatmulRequest> = (0..requests)
        .map(|i| MatmulRequest {
            id: 0,
            input_id: i as u64,
            a: Arc::new(Mat::random(&mut rng, M, K, 8)),
            bs: (0..2).map(|_| Arc::new(Mat::random(&mut rng, K, NC, 2))).collect(),
            weight_bits: 2,
            act_act: false,
            tag: String::new(),
        })
        .collect();
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = reqs
        .into_iter()
        .map(|r| client.submit(SubmitOptions::new(r)).expect("queue sized"))
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    let cycles = coord.metrics().sim_cycles.load(Ordering::Relaxed);
    coord.shutdown();
    (dt, cycles)
}

fn main() {
    const REQS: usize = 96;
    const DIM: usize = 128;

    println!("== coordinator serving throughput (ADiP 32x32, 2 workers, Client/Ticket API) ==");
    let stat = common::bench(5, || stream(true, REQS, DIM));
    common::report("serve fusable Q/K/V stream", stat, REQS as f64, "req");
    let throughput_req_s = REQS as f64 / stat.median_s;

    println!("\n== batching ablation (same stream, fusion on/off) ==");
    let (_, t_fused, cyc_fused) = stream(true, REQS, DIM);
    let (_, t_solo, cyc_solo) = stream(false, REQS, DIM);
    println!("  fused:   {t_fused:.3}s host, {cyc_fused} simulated cycles");
    println!("  unfused: {t_solo:.3}s host, {cyc_solo} simulated cycles");
    println!(
        "  simulated-cycle reduction from shared-input batching: {:.1}% (paper's multi-matrix mode)",
        (1.0 - cyc_fused as f64 / cyc_solo as f64) * 100.0
    );

    // -- saturated mixed-priority trace: per-class queue waits ------------
    println!("\n== saturated mixed-priority trace (2 workers, all classes) ==");
    let model = TransformerModel::by_name("bitnet").expect("bitnet model");
    let tcfg = TraceConfig { dim: 64, head_cols: 16, layers: 4, heads: 2, rate_per_s: 1e9 };
    // 3 invocations: scores are Interactive, first-invocation projections
    // Batch, replayed projections Background — all three classes live.
    // Classes are then round-robin interleaved across the arrival order:
    // in the raw trace every Background request is a late-invocation
    // replay at the back of the stream, so plain FIFO would already give
    // it the longest waits and the mi <= mb gate below could not detect
    // a priority regression.
    let trace = {
        let mut by_class: Vec<Vec<_>> = (0..Priority::COUNT).map(|_| Vec::new()).collect();
        for t in repeated_attention_trace(&model, &tcfg, 19, 3) {
            by_class[t.priority.index()].push(t);
        }
        let mut mixed = Vec::new();
        while by_class.iter().any(|v| !v.is_empty()) {
            for v in by_class.iter_mut() {
                if !v.is_empty() {
                    mixed.push(v.remove(0));
                }
            }
        }
        mixed
    };
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 2 * trace.len(),
        batch_window: 12,
        // aging off for the gate: everything queues at once under
        // saturation, so default aging would (correctly) promote aged
        // Background work ahead of fresh Interactive and blur the
        // base-class ordering this section measures
        aging: std::time::Duration::from_secs(3600),
        ..Default::default()
    });
    let client = coord.client();
    let total = trace.len();
    let tickets: Vec<_> = trace
        .into_iter()
        .map(|t| {
            client
                .submit(SubmitOptions::new(t.request).priority(t.priority))
                .expect("queue sized")
        })
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let m = coord.metrics();
    // human-readable table comes from the shared summary (single source
    // with serve/trace); the raw values below feed the JSON artifact
    print!("{}", m.class_queue_summary());
    let mut class_rows = Vec::new();
    for class in Priority::ALL {
        let completed = m.class_completed[class.index()].load(Ordering::Relaxed);
        let mean = m.mean_class_queue_seconds(class).unwrap_or(0.0);
        let p50 = m.class_queue_percentile(class, 50.0).unwrap_or(0.0);
        let p95 = m.class_queue_percentile(class, 95.0).unwrap_or(0.0);
        class_rows.push(format!(
            "    {{\"class\": \"{}\", \"completed\": {completed}, \"queue_mean_s\": {mean:.6}, \"queue_p50_s\": {p50:.6}, \"queue_p95_s\": {p95:.6}}}",
            class.name()
        ));
    }
    let mi = m.mean_class_queue_seconds(Priority::Interactive).expect("interactive completed");
    let mb = m.mean_class_queue_seconds(Priority::Background).expect("background completed");
    println!(
        "  {total} requests | interactive/background mean wait ratio {:.3}",
        mi / mb.max(1e-12)
    );
    assert!(
        mi <= mb,
        "interactive mean queue wait {mi:.6}s must not exceed background {mb:.6}s under saturation"
    );
    coord.shutdown();

    // -- pipelined vs inline prepare: the overlap gate --------------------
    println!(
        "\n== prepare pipeline: pipelined stage vs inline (decode-shaped stream, 1 worker) =="
    );
    const PREP_REQS: usize = 160;
    // The gate uses the pure-serving duration `prepare_stream` returns
    // (submit -> last completion), NOT a wall-clock around the whole
    // call: operand generation (~21M random entries per rep) and
    // coordinator startup/shutdown are constant in both modes and would
    // squeeze the measured ratio toward 1.0.
    let run_reps = |mode: PrepareMode| -> (f64, f64, u64) {
        let _ = prepare_stream(mode, PREP_REQS); // warmup
        let mut times = Vec::new();
        let mut cycles = 0u64;
        for _ in 0..3 {
            let (dt, cyc) = prepare_stream(mode, PREP_REQS);
            times.push(dt);
            cycles = cyc;
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (times[0], times[times.len() / 2], cycles)
    };
    let (inline_min, inline_median, sim_inline) = run_reps(PrepareMode::Inline);
    let (pipe_min, pipe_median, sim_pipe) = run_reps(PrepareMode::Pipelined);
    assert_eq!(
        sim_pipe, sim_inline,
        "prepare modes must be accounting-identical (only host time may differ)"
    );
    // min-of-reps: co-tenant stalls on shared CI runners only ever
    // inflate a rep, never deflate it
    let gain = inline_min / pipe_min;
    println!(
        "  {PREP_REQS} requests: inline {:.1} ms | pipelined {:.1} ms (serving medians) | overlap speedup {gain:.2}x on min (bar: >= 1.1x)",
        inline_median * 1e3,
        pipe_median * 1e3
    );
    assert!(
        gain >= 1.1,
        "pipelined prepare must beat inline by >= 1.1x on the decode-shaped stream (got {gain:.2}x)"
    );

    // -- machine-readable results for the CI artifact ---------------------
    let json = format!(
        "{{\n  \"bench\": \"bench_coordinator\",\n  \"throughput\": {{\"requests\": {REQS}, \"req_per_s\": {throughput_req_s:.2}}},\n  \"batching\": {{\"fused_cycles\": {cyc_fused}, \"unfused_cycles\": {cyc_solo}, \"cycle_reduction\": {:.4}}},\n  \"per_class\": [\n{}\n  ],\n  \"prepare_pipeline\": {{\"requests\": {PREP_REQS}, \"inline_min_s\": {:.6}, \"pipelined_min_s\": {:.6}, \"speedup\": {gain:.4}, \"gate\": 1.1}}\n}}\n",
        1.0 - cyc_fused as f64 / cyc_solo as f64,
        class_rows.join(",\n"),
        inline_min,
        pipe_min
    );
    let path =
        std::env::var("BENCH_COORD_JSON").unwrap_or_else(|_| "BENCH_coordinator.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
