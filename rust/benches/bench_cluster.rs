//! Bench: cluster scaling sweep + weight-cache serving gain.
//!
//! Sweeps cores ∈ {1, 2, 4, 8} at n = 32 on the functional backend over an
//! M-split GEMM large enough to shard 8 ways, reporting simulated cluster
//! latency (the metric the subsystem models: max over cores at 1 GHz) and
//! host wall-clock per run.
//!
//! Acceptance gate: ≥ 2× end-to-end speedup (simulated cluster latency) at
//! 4 cores vs 1 core. The simulated gate is deterministic by construction
//! — cluster cycles equal the analytical estimate exactly (enforced here
//! and in `integration_cluster.rs`) — while host wall-clock scaling is
//! reported for reference (it saturates at the machine's CPU count; CI
//! runners commonly expose only 2 vCPUs).
//!
//! A second section replays a repeated-weights Transformer trace through a
//! weight-cached cluster and asserts the cache reports hits.

#[path = "common.rs"]
mod common;

use adip::analytical::gemm::MemoryPolicy;
use adip::analytical::{estimate_cluster, estimate_gemm, GemmShape};
use adip::arch::{ArchConfig, Architecture, Backend};
use adip::cluster::{ClusterConfig, ClusterScheduler};
use adip::dataflow::Mat;
use adip::quant::PrecisionMode;
use adip::testutil::Rng;

const M: usize = 1024;
const K: usize = 256;
const NC: usize = 256;
const N: usize = 32;
const MODE: PrecisionMode = PrecisionMode::W2;

fn main() {
    let mut rng = Rng::seeded(31);
    let a = Mat::random(&mut rng, M, K, 8);
    let b = Mat::random(&mut rng, K, NC, MODE.weight_bits());
    let want = a.matmul(&b);
    let shape = GemmShape::new(M, K, NC);
    let acfg = ArchConfig::with_n(N);
    let single_est = estimate_gemm(Architecture::Adip, &acfg, shape, MODE, MemoryPolicy::default());

    println!("== cluster scaling sweep (ADiP {N}x{N}, {M}x{K}x{NC} {MODE}, M-split, functional) ==");
    let mut cycles_at = std::collections::BTreeMap::new();
    for cores in [1usize, 2, 4, 8] {
        let cluster = ClusterConfig::with_cores(cores);
        let mut mesh = ClusterScheduler::new(Architecture::Adip, N, Backend::Functional, cluster);
        let run = mesh.run_gemm(&a, &b, MODE, false).expect("cluster run");
        assert_eq!(run.result.outputs[0], want, "cores={cores}: outputs must stay bit-exact");
        let est =
            estimate_cluster(Architecture::Adip, &acfg, shape, 1, MODE, &cluster, MemoryPolicy::default());
        assert_eq!(
            run.result.cycles, est.cycles,
            "cores={cores}: cluster cycles must equal the analytical estimate"
        );
        cycles_at.insert(cores, run.result.cycles);
        let stat = common::bench(5, || {
            let mut m = ClusterScheduler::new(Architecture::Adip, N, Backend::Functional, cluster);
            m.run_gemm(&a, &b, MODE, false).unwrap().result.cycles
        });
        let macs = (M * K * NC) as f64;
        common::report(&format!("cluster {cores} core(s)"), stat, macs, "MAC");
        println!(
            "    simulated: {:>9} cycles = {:.3} ms @ 1 GHz | speedup {:.2}x | efficiency {:.0}% | shards {}",
            run.result.cycles,
            run.result.cycles as f64 / 1e6,
            est.speedup_vs(&single_est),
            est.parallel_efficiency(&single_est) * 100.0,
            run.shards
        );
    }

    let speedup4 = cycles_at[&1] as f64 / cycles_at[&4] as f64;
    println!("\n  end-to-end simulated speedup at 4 cores: {speedup4:.2}x (acceptance bar: >= 2x)");
    assert!(
        speedup4 >= 2.0,
        "cluster must deliver >= 2x end-to-end speedup at 4 cores (got {speedup4:.2}x)"
    );

    println!("\n== weight cache on a repeated-weights Transformer trace (BitNet-shaped) ==");
    use adip::workload::{repeated_attention_trace, TraceConfig, TransformerModel};
    let model = TransformerModel::by_name("bitnet").expect("bitnet model");
    let tcfg = TraceConfig { dim: 96, head_cols: 32, layers: 6, heads: 1, rate_per_s: 1e9 };
    let trace = repeated_attention_trace(&model, &tcfg, 13, 4);
    let run_trace = |cache_entries: usize| {
        let cluster = ClusterConfig::with_cores(2).with_cache(cache_entries);
        let mut mesh = ClusterScheduler::new(Architecture::Adip, N, Backend::Functional, cluster);
        let t0 = std::time::Instant::now();
        for t in &trace {
            let bs: Vec<&Mat> = t.request.bs.iter().map(|b| b.as_ref()).collect();
            let mode = PrecisionMode::for_weight_bits(t.request.weight_bits);
            mesh.run_gemm_set(&t.request.a, &bs, mode, t.request.act_act).expect("trace run");
        }
        (t0.elapsed().as_secs_f64(), mesh.cache_stats())
    };
    let (t_cold, _) = run_trace(0);
    let (t_cached, stats) = run_trace(512);
    println!(
        "  {} requests: uncached {:.3}s | cached {:.3}s ({:.2}x) | {} hits / {} misses / {} evictions",
        trace.len(),
        t_cold,
        t_cached,
        t_cold / t_cached,
        stats.hits,
        stats.misses,
        stats.evictions
    );
    assert!(stats.hits > 0, "repeated-weights trace must produce cache hits");
    let projections_per_inv = (tcfg.layers * 3) as u64;
    assert!(
        stats.hits >= 3 * projections_per_inv,
        "every replayed projection shard should hit (hits {}, expected >= {})",
        stats.hits,
        3 * projections_per_inv
    );
}
