//! Bench: cluster scaling sweep, warm-pool vs spawn-per-run serving, and
//! the weight-cache gain — emitted as `BENCH_cluster.json` for CI trend
//! tracking.
//!
//! Sweeps cores ∈ {1, 2, 4, 8} at n = 32 on the functional backend over an
//! M-split GEMM large enough to shard 8 ways, reporting simulated cluster
//! latency (the metric the subsystem models: max over cores at 1 GHz) and
//! host wall-clock per run.
//!
//! Acceptance gates:
//!
//! 1. ≥ 2× end-to-end speedup (simulated cluster latency) at 4 cores vs
//!    1 core. Deterministic by construction — cluster cycles equal the
//!    analytical estimate exactly (enforced here and in
//!    `integration_cluster.rs`) — while host wall-clock scaling is
//!    reported for reference (it saturates at the machine's CPU count; CI
//!    runners commonly expose only 2 vCPUs).
//! 2. ≥ 1.1× host wall-clock speedup of the **persistent worker pool**
//!    over the legacy spawn-per-run engine on a repeated attention trace
//!    at 4 cores (warm workers + pipelined ingress vs a thread
//!    spawn/join barrier per GEMM). Simulated accounting is asserted
//!    identical across the two engines, so the gate isolates pure host
//!    dispatch overhead.
//!
//! A final section replays a repeated-weights Transformer trace through a
//! weight-cached cluster and asserts the cache reports hits.
//!
//! Results land in `BENCH_cluster.json` (override the path with the
//! `BENCH_JSON` env var): cores-sweep cycles/speedups, the warm-pool
//! ratio, and shared-cache hit rates — uploaded as a CI artifact so the
//! perf trajectory is tracked across PRs.

#[path = "common.rs"]
mod common;

use adip::analytical::gemm::MemoryPolicy;
use adip::analytical::{estimate_cluster, estimate_gemm, GemmShape};
use adip::arch::{ArchConfig, Architecture, Backend};
use adip::cluster::{CacheConfig, ClusterConfig, ClusterScheduler, PoolMode, SharedWeightCache};
use adip::dataflow::Mat;
use adip::quant::PrecisionMode;
use adip::testutil::Rng;
use adip::workload::{repeated_attention_trace, TraceConfig, TransformerModel};

const M: usize = 1024;
const K: usize = 256;
const NC: usize = 256;
const N: usize = 32;
const MODE: PrecisionMode = PrecisionMode::W2;

fn main() {
    let mut rng = Rng::seeded(31);
    let a = Mat::random(&mut rng, M, K, 8);
    let b = Mat::random(&mut rng, K, NC, MODE.weight_bits());
    let want = a.matmul(&b);
    let shape = GemmShape::new(M, K, NC);
    let acfg = ArchConfig::with_n(N);
    let single_est = estimate_gemm(Architecture::Adip, &acfg, shape, MODE, MemoryPolicy::default());

    println!(
        "== cluster scaling sweep (ADiP {N}x{N}, {M}x{K}x{NC} {MODE}, M-split, functional) =="
    );
    let mut cycles_at = std::collections::BTreeMap::new();
    let mut sweep_rows = Vec::new();
    for cores in [1usize, 2, 4, 8] {
        let cluster = ClusterConfig::with_cores(cores);
        let mut mesh = ClusterScheduler::new(Architecture::Adip, N, Backend::Functional, cluster);
        let run = mesh.run_gemm(&a, &b, MODE, false).expect("cluster run");
        assert_eq!(run.result.outputs[0], want, "cores={cores}: outputs must stay bit-exact");
        let est = estimate_cluster(
            Architecture::Adip,
            &acfg,
            shape,
            1,
            MODE,
            &cluster,
            MemoryPolicy::default(),
        );
        assert_eq!(
            run.result.cycles, est.cycles,
            "cores={cores}: cluster cycles must equal the analytical estimate"
        );
        cycles_at.insert(cores, run.result.cycles);
        // warm-pool steady state: reuse one scheduler across iterations
        let stat = common::bench(5, || mesh.run_gemm(&a, &b, MODE, false).unwrap().result.cycles);
        let macs = (M * K * NC) as f64;
        common::report(&format!("cluster {cores} core(s)"), stat, macs, "MAC");
        println!(
            "    simulated: {:>9} cycles = {:.3} ms @ 1 GHz | speedup {:.2}x | efficiency {:.0}% | shards {}",
            run.result.cycles,
            run.result.cycles as f64 / 1e6,
            est.speedup_vs(&single_est),
            est.parallel_efficiency(&single_est) * 100.0,
            run.shards
        );
        sweep_rows.push(format!(
            "    {{\"cores\": {cores}, \"shards\": {}, \"simulated_cycles\": {}, \"simulated_speedup\": {:.4}, \"host_median_s\": {:.6}}}",
            run.shards,
            run.result.cycles,
            est.speedup_vs(&single_est),
            stat.median_s
        ));
    }

    let speedup4 = cycles_at[&1] as f64 / cycles_at[&4] as f64;
    println!("\n  end-to-end simulated speedup at 4 cores: {speedup4:.2}x (acceptance bar: >= 2x)");
    assert!(
        speedup4 >= 2.0,
        "cluster must deliver >= 2x end-to-end speedup at 4 cores (got {speedup4:.2}x)"
    );

    // -- warm persistent pool vs legacy spawn-per-run on a repeated trace --
    println!("\n== warm pool vs spawn-per-run (repeated attention trace, 4 cores, n=8) ==");
    let model = TransformerModel::by_name("bitnet").expect("bitnet model");
    // Small per-request GEMMs on purpose: this section measures *dispatch*
    // overhead (spawn/join barrier vs warm queue), which the compute of a
    // big GEMM would simply hide. 48/8 = 6 M-tiles shard 4 ways per run.
    let pool_tcfg = TraceConfig { dim: 48, head_cols: 16, layers: 4, heads: 1, rate_per_s: 1e9 };
    let pool_trace = repeated_attention_trace(&model, &pool_tcfg, 17, 8);
    // cache off: every invocation executes, isolating dispatch overhead
    let run_trace_on = |pool: PoolMode| -> u64 {
        let cluster = ClusterConfig::with_cores(4).with_pool(pool);
        let mut mesh = ClusterScheduler::new(Architecture::Adip, 8, Backend::Functional, cluster);
        let mut sim_cycles = 0u64;
        for t in &pool_trace {
            let bs: Vec<&Mat> = t.request.bs.iter().map(|b| b.as_ref()).collect();
            let mode = PrecisionMode::for_weight_bits(t.request.weight_bits);
            sim_cycles += mesh
                .run_gemm_set(&t.request.a, &bs, mode, t.request.act_act)
                .expect("trace run")
                .result
                .cycles;
        }
        sim_cycles
    };
    // Simulated cycle totals are captured from the benched iterations
    // themselves (deterministic, identical every rep) — no extra replays.
    let (mut sim_spawn, mut sim_pool) = (0u64, 0u64);
    let spawn_stat = common::bench(3, || {
        sim_spawn = run_trace_on(PoolMode::PerRun);
        sim_spawn
    });
    let pool_stat = common::bench(3, || {
        sim_pool = run_trace_on(PoolMode::Persistent);
        sim_pool
    });
    assert_eq!(
        sim_pool, sim_spawn,
        "pool engines must be accounting-identical (only host time may differ)"
    );
    // Gate on the fastest observed iteration: min is noise-resistant
    // (co-tenant stalls on shared 2-vCPU CI runners only ever inflate a
    // rep, never deflate it), while medians are reported for context.
    let pool_gain = spawn_stat.min_s / pool_stat.min_s;
    println!(
        "  {} requests: spawn-per-run {:.1} ms | persistent pool {:.1} ms (medians) | warm-pool speedup {pool_gain:.2}x on min (bar: >= 1.1x)",
        pool_trace.len(),
        spawn_stat.median_s * 1e3,
        pool_stat.median_s * 1e3
    );
    assert!(
        pool_gain >= 1.1,
        "warm pool must beat spawn-per-run by >= 1.1x on the repeated trace (got {pool_gain:.2}x)"
    );

    println!("\n== shared weight cache on a repeated-weights Transformer trace (2 workers) ==");
    let tcfg = TraceConfig { dim: 96, head_cols: 32, layers: 6, heads: 1, rate_per_s: 1e9 };
    const INVOCATIONS: usize = 4;
    let trace = repeated_attention_trace(&model, &tcfg, 13, INVOCATIONS);
    // Two schedulers over ONE shared store, alternating requests with the
    // parity shifted every invocation — the coordinator's cross-worker
    // shape, so `shared_hits` in the JSON is a live metric, not a dead 0.
    let run_trace = |cache_entries: usize| {
        let store =
            SharedWeightCache::new(CacheConfig { capacity: cache_entries, ..Default::default() });
        let cluster = ClusterConfig::with_cores(2).with_cache(cache_entries);
        let mut workers: Vec<ClusterScheduler> = (0..2)
            .map(|_| {
                ClusterScheduler::with_shared_cache(
                    Architecture::Adip,
                    N,
                    Backend::Functional,
                    cluster,
                    store.clone(),
                )
            })
            .collect();
        let per_inv = trace.len() / INVOCATIONS;
        let t0 = std::time::Instant::now();
        for (i, t) in trace.iter().enumerate() {
            let mesh = &mut workers[(i + i / per_inv) % 2];
            let bs: Vec<&Mat> = t.request.bs.iter().map(|b| b.as_ref()).collect();
            let mode = PrecisionMode::for_weight_bits(t.request.weight_bits);
            mesh.run_gemm_set(&t.request.a, &bs, mode, t.request.act_act).expect("trace run");
        }
        (t0.elapsed().as_secs_f64(), store.stats())
    };
    let (t_cold, _) = run_trace(0);
    let (t_cached, stats) = run_trace(512);
    let hit_rate = stats.hits as f64 / (stats.hits + stats.misses).max(1) as f64;
    println!(
        "  {} requests: uncached {:.3}s | cached {:.3}s ({:.2}x) | {} hits ({} cross-worker) / {} misses / {} evictions (hit rate {:.1}%)",
        trace.len(),
        t_cold,
        t_cached,
        t_cold / t_cached,
        stats.hits,
        stats.shared_hits,
        stats.misses,
        stats.evictions,
        hit_rate * 100.0
    );
    assert!(stats.hits > 0, "repeated-weights trace must produce cache hits");
    assert!(
        stats.shared_hits > 0,
        "parity-shifted replays must hit entries the sibling worker inserted"
    );
    let projections_per_inv = (tcfg.layers * 3) as u64;
    assert!(
        stats.hits >= 3 * projections_per_inv,
        "every replayed projection shard should hit (hits {}, expected >= {})",
        stats.hits,
        3 * projections_per_inv
    );

    // -- machine-readable results for the CI artifact --
    let json = format!(
        "{{\n  \"bench\": \"bench_cluster\",\n  \"array_n\": {N},\n  \"gemm\": {{\"m\": {M}, \"k\": {K}, \"n\": {NC}, \"mode\": \"{MODE}\"}},\n  \"cores_sweep\": [\n{}\n  ],\n  \"speedup_at_4_cores\": {{\"value\": {speedup4:.4}, \"gate\": 2.0}},\n  \"warm_pool\": {{\"cores\": 4, \"requests\": {}, \"spawn_per_run_min_s\": {:.6}, \"persistent_pool_min_s\": {:.6}, \"speedup\": {pool_gain:.4}, \"gate\": 1.1}},\n  \"weight_cache\": {{\"requests\": {}, \"hits\": {}, \"shared_hits\": {}, \"misses\": {}, \"evictions\": {}, \"hit_rate\": {hit_rate:.4}, \"uncached_s\": {t_cold:.6}, \"cached_s\": {t_cached:.6}, \"speedup\": {:.4}}}\n}}\n",
        sweep_rows.join(",\n"),
        pool_trace.len(),
        spawn_stat.min_s,
        pool_stat.min_s,
        trace.len(),
        stats.hits,
        stats.shared_hits,
        stats.misses,
        stats.evictions,
        t_cold / t_cached
    );
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
