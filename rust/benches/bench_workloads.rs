//! Bench: full workload evaluation (the engine behind Figs. 9/10/11) and
//! end-to-end functional co-simulation of real GEMMs.
//!
//! The first section regenerates the paper's per-model totals and prints
//! the improvement annotations; the second measures the co-simulator's
//! sustained functional throughput (simulated MACs per host second) — the
//! §Perf L3 metric.

#[path = "common.rs"]
mod common;

use adip::arch::{build_array, ArchConfig, Architecture};
use adip::dataflow::Mat;
use adip::quant::PrecisionMode;
use adip::sim::{evaluate_model, CoSim, SimConfig};
use adip::testutil::Rng;
use adip::workload::TransformerModel;

fn main() {
    println!("== Figs. 9/10/11: per-model totals (WS / DiP / ADiP, 32x32) ==");
    let cfg = SimConfig::default();
    for model in TransformerModel::evaluated() {
        let dip = evaluate_model(Architecture::Dip, &model, &cfg);
        let adip_r = evaluate_model(Architecture::Adip, &model, &cfg);
        let ws = evaluate_model(Architecture::Ws, &model, &cfg);
        println!(
            "  {:<14} latency(ms) WS={:>9.1} DiP={:>9.1} ADiP={:>9.1}  | imp {:+.1}% | energy {:+.1}% | mem {:+.1}%",
            model.name,
            ws.total_seconds() * 1e3,
            dip.total_seconds() * 1e3,
            adip_r.total_seconds() * 1e3,
            (1.0 - adip_r.total_cycles() as f64 / dip.total_cycles() as f64) * 100.0,
            (1.0 - adip_r.total_energy_j() / dip.total_energy_j()) * 100.0,
            (1.0 - adip_r.total_memory_bytes() as f64 / dip.total_memory_bytes() as f64) * 100.0,
        );
    }

    println!("\n== evaluation-engine speed (all 3 models × 3 archs per iter) ==");
    let stat = common::bench(16, || {
        let mut acc = 0u64;
        for model in TransformerModel::evaluated() {
            for arch in Architecture::ALL {
                acc ^= evaluate_model(arch, &model, &cfg).total_cycles();
            }
        }
        acc
    });
    common::report("evaluate_model x9", stat, 9.0, "eval");

    println!("\n== functional co-simulation throughput (simulated MACs/s) ==");
    let mut rng = Rng::seeded(9);
    for (m, k, n, mode) in [
        (256usize, 256usize, 256usize, PrecisionMode::W8),
        (256, 256, 256, PrecisionMode::W2),
        (512, 512, 512, PrecisionMode::W2),
    ] {
        let a = Mat::random(&mut rng, m, k, 8);
        let b = Mat::random(&mut rng, k, n, mode.weight_bits());
        let macs = (m * k * n) as f64;
        let stat = common::bench(8, || {
            let mut sim = CoSim::new(build_array(Architecture::Adip, ArchConfig::with_n(32)));
            sim.run_gemm(&a, &b, mode, false).unwrap()
        });
        common::report(&format!("cosim gemm {m}x{k}x{n} {mode}"), stat, macs, "MAC");
    }
}
