//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Multiplier count `M`** — why the paper selects 16 (Fig. 2's
//!    "optimal design" claim): throughput per PE vs area proxy.
//! 2. **Shared column unit `E` stages** — sensitivity of Eq. (2) latency
//!    to the per-mode pipeline depth.
//! 3. **Multi-bank runtime interleaving** — stall overhead vs bank count
//!    for activation-to-activation workloads (the "almost zero overhead"
//!    claim holds iff banks ≥ interleave factor).
//! 4. **Fusion policy** — slot utilization across head sizes (Fig. 5(d)).

#[path = "common.rs"]
mod common;

use adip::analytical::{adip_latency, pe_latency, qkv_sweep, slot_utilization, FusionPolicy};
use adip::arch::SharedColumnUnit;
use adip::quant::PrecisionMode;
use adip::sim::MemorySystem;

fn main() {
    println!("== ablation 1: multiplier count M (selected design point: 16) ==");
    println!(
        "{:<6} {:>14} {:>14} {:>14} {:>18}",
        "M", "lat 8b×8b", "lat 8b×4b", "lat 8b×2b", "thr/area proxy"
    );
    for m in [2u32, 4, 8, 16, 32] {
        let l8 = pe_latency(m, 2, 8, 8);
        let l4 = pe_latency(m, 2, 8, 4);
        let l2 = pe_latency(m, 2, 8, 2);
        // throughput per multiplier-area: ops/cycle at 8b×8b per M
        let proxy = 2.0 / (l8 as f64 * m as f64);
        println!("{:<6} {:>14} {:>14} {:>14} {:>18.4}", m, l8, l4, l2, proxy);
    }
    println!(
        "-> M=16 is the smallest M with 1-cycle 8b×8b (Fig. 2); beyond 16 adds\n\
         area with zero latency gain (M=32 proxy halves).\n"
    );

    println!("== ablation 2: column-unit pipeline depth E (Eq. 2, N = 32) ==");
    let unit = SharedColumnUnit;
    for mode in PrecisionMode::ALL {
        let e_sel = unit.pipeline_stages(mode);
        print!("{:<7}", mode.to_string());
        for e in 0..=4u64 {
            let lat = adip_latency(32, 16, 2, 8, mode.weight_bits(), 1, e);
            let marker = if e == e_sel { "*" } else { " " };
            print!("  E={e}:{lat}{marker}");
        }
        println!();
    }
    println!("-> latency impact of E is ≤4 cycles on a 63-cycle tile (≤6%), amortized\n\
              to <0.1% over streamed tiles — sharing the unit per column is free.\n");

    println!("== ablation 3: runtime-interleave stalls vs bank count (8b×2b, tile=32c) ==");
    for banks in [1usize, 2, 4, 8] {
        let mut mem = MemorySystem::new(banks);
        let stall = mem.runtime_interleave(4, 32);
        println!("  banks={banks}: stall={stall} cycles per stationary group ({}%)",
            100 * stall / 32 / 4);
    }
    println!("-> ≥4 banks ⇒ zero overhead: the paper's multi-bank claim.\n");

    println!("== ablation 4: fusion policy slot utilization (8b×2b, N = 32) ==");
    println!("{:<8} {:>8} {:>10} {:>10}", "d_k", "solo", "col-fuse", "qkv-fuse");
    for row in qkv_sweep(32, &[16, 32, 64, 128, 256]) {
        println!(
            "{:<8} {:>7.0}% {:>9.0}% {:>9.0}%",
            row.d_k,
            row.solo * 100.0,
            row.column * 100.0,
            row.qkv * 100.0
        );
    }
    let wide = slot_utilization(PrecisionMode::W2, 32, 2560, FusionPolicy::ColumnTiles);
    println!("-> head-limited (d_k ≤ N) projections need the Fig. 5(d) multi-matrix\n\
              mode; wide projections (d_model = 2560: {:.0}%) saturate by column\n\
              fusion alone.", wide * 100.0);

    // timing: the whole ablation suite is analytical — confirm it's instant
    let stat = common::bench(5, || {
        let mut acc = 0u64;
        for m in [2u32, 4, 8, 16, 32] {
            acc += pe_latency(m, 2, 8, 8);
        }
        acc
    });
    common::report("\nanalytical ablation sweep", stat, 5.0, "sweep");
}
