//! Bench: reconfigurable PE hot path + Fig. 2 regeneration.
//!
//! Regenerates the Fig. 2 latency series from Eq. (1) and measures the
//! host-side throughput of the bit-exact PE model (the arithmetic the
//! cycle-accurate simulator runs per PE per beat).

#[path = "common.rs"]
mod common;

use adip::analytical::fig2_series;
use adip::arch::{PeConfig, ReconfigurablePe, SharedColumnUnit};
use adip::quant::PrecisionMode;
use adip::testutil::Rng;

fn main() {
    println!("== Fig. 2 (Eq. 1): PE latency in cycles ==");
    for row in fig2_series() {
        println!(
            "  M={:<3} {:<6} -> {} cycle(s)",
            row.multipliers,
            row.mode.to_string(),
            row.latency
        );
    }

    println!("\n== bit-exact PE model throughput (host) ==");
    let mut rng = Rng::seeded(1);
    let unit = SharedColumnUnit;
    for mode in PrecisionMode::ALL {
        let mut pe = ReconfigurablePe::new(PeConfig::default(), mode);
        let weights: Vec<u8> = (0..1024).map(|_| rng.next_u32() as u8).collect();
        let acts: Vec<i32> = (0..1024).map(|_| rng.int_of_bits(8)).collect();
        const MACS: usize = 1 << 16;
        let stat = common::bench(16, || {
            let mut acc = 0i64;
            for i in 0..MACS {
                pe.load_weight(weights[i & 1023], mode);
                let groups = pe.compute(acts[i & 1023]);
                let outs = unit.combine(mode, groups);
                acc += outs[0];
            }
            acc
        });
        common::report(&format!("pe_compute+column_combine {mode}"), stat, MACS as f64, "MAC");
    }
}
