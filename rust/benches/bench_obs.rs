//! Bench: lifecycle-tracing overhead — the tentpole's performance bar.
//!
//! Measures saturated-server throughput (the `bench_hotpath` Q/K/V
//! pattern: 2 workers, rotating shared input) with tracing off, fully on,
//! and sampled at 1/16, plus a recorder micro-benchmark (events/s into
//! the sharded rings and the cost of the disabled fast path). Emitted as
//! `BENCH_obs.json` for CI trend tracking.
//!
//! Gates (soft-retried to ride out scheduler noise, then hard):
//! * full tracing costs ≤ 5% of saturated throughput,
//! * 1/16 sampling costs ≤ 1%.
//! Both compare best-of-N wall clock, the most noise-robust statistic the
//! tiny harness offers.

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use adip::arch::Architecture;
use adip::coordinator::{
    Coordinator, CoordinatorConfig, MatmulRequest, SubmitOptions, TraceMode,
};
use adip::dataflow::Mat;
use adip::obs::{Recorder, SpanKind, LANE_CLIENT};
use adip::testutil::Rng;

const REQS: usize = 96;
const DIM: usize = 64;

/// One saturated serving run under the given trace mode; returns host
/// seconds for the whole stream.
fn saturated_serve(trace: TraceMode) -> f64 {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 2 * REQS,
        batch_window: 8,
        trace,
        ..Default::default()
    });
    let client = coord.client();
    let mut rng = Rng::seeded(41);
    let t0 = std::time::Instant::now();
    let mut shared = Arc::new(Mat::random(&mut rng, DIM, DIM, 8));
    let tickets: Vec<_> = (0..REQS)
        .map(|i| {
            if i % 3 == 0 {
                shared = Arc::new(Mat::random(&mut rng, DIM, DIM, 8));
            }
            let req = MatmulRequest {
                id: 0,
                input_id: (i / 3) as u64,
                a: shared.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, DIM, 32, 2))],
                weight_bits: 2,
                act_act: false,
                tag: String::new(),
            };
            client.submit(SubmitOptions::new(req)).expect("queue sized")
        })
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    coord.shutdown();
    dt
}

/// Best observed throughput (req/s) over `reps` runs.
fn best_req_per_s(trace: TraceMode, reps: usize) -> f64 {
    let stat = common::bench(reps, || saturated_serve(trace));
    REQS as f64 / stat.min_s
}

fn main() {
    // Recorder micro-bench: raw event ingest (single writer, enabled)
    // and the disabled fast path (one relaxed load + branch per call).
    // EVENTS stays under the rings' aggregate capacity so the enabled
    // case measures real slot-claim stores, not the overflow path.
    const EVENTS: usize = 60_000;
    let on = common::bench(5, || {
        let r = Recorder::default();
        r.enable(TraceMode::On);
        for i in 0..EVENTS {
            r.event(SpanKind::Queue, i as u64, LANE_CLIENT, 0);
        }
        assert_eq!(r.dropped(), 0, "sized under capacity");
    });
    let disabled = Recorder::default();
    let off = common::bench(5, || {
        for i in 0..EVENTS {
            disabled.event(SpanKind::Queue, i as u64, LANE_CLIENT, 0);
        }
    });
    println!("== recorder micro-bench ({EVENTS} events/iter) ==");
    common::report("event ingest (enabled)", on, EVENTS as f64, "ev");
    common::report("event ingest (disabled path)", off, EVENTS as f64, "ev");
    assert_eq!(disabled.snapshot().len(), 0, "disabled recorder must store nothing");
    assert_eq!(disabled.dropped(), 0);

    // Saturated-throughput differential: off vs on vs sample=16. The
    // comparison is retried on gate failure — a saturated 2-worker serve
    // has real scheduler noise, and the 1% gate is tighter than one
    // cold run's variance; the best observation across attempts is the
    // honest estimate of each mode's capability.
    println!("\n== saturated server tracing overhead ({REQS} requests, 2 workers) ==");
    let mut base = 0f64;
    let mut full = 0f64;
    let mut sampled = 0f64;
    let (mut over_full, mut over_sampled) = (f64::INFINITY, f64::INFINITY);
    for attempt in 0..3 {
        base = base.max(best_req_per_s(TraceMode::Off, 5));
        full = full.max(best_req_per_s(TraceMode::On, 5));
        sampled = sampled.max(best_req_per_s(TraceMode::Sample(16), 5));
        over_full = (base / full - 1.0).max(0.0);
        over_sampled = (base / sampled - 1.0).max(0.0);
        println!(
            "  attempt {attempt}: off {base:.1} req/s | on {full:.1} ({:+.2}%) | sample=16 {sampled:.1} ({:+.2}%)",
            over_full * 100.0,
            over_sampled * 100.0
        );
        if over_full <= 0.05 && over_sampled <= 0.01 {
            break;
        }
    }
    assert!(
        over_full <= 0.05,
        "full tracing overhead {:.2}% exceeds the 5% gate",
        over_full * 100.0
    );
    assert!(
        over_sampled <= 0.01,
        "sample=16 tracing overhead {:.2}% exceeds the 1% gate",
        over_sampled * 100.0
    );

    let json = format!(
        "{{\n  \"bench\": \"bench_obs\",\n  \"recorder\": {{\"events_per_iter\": {EVENTS}, \"enabled_ev_per_s\": {:.0}, \"disabled_ev_per_s\": {:.0}}},\n  \"saturated_server\": {{\"requests\": {REQS}, \"off_req_per_s\": {base:.2}, \"on_req_per_s\": {full:.2}, \"sample16_req_per_s\": {sampled:.2}, \"overhead_on\": {over_full:.4}, \"overhead_sample16\": {over_sampled:.4}}}\n}}\n",
        EVENTS as f64 / on.min_s,
        EVENTS as f64 / off.min_s
    );
    let path = std::env::var("BENCH_OBS_JSON").unwrap_or_else(|_| "BENCH_obs.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
