//! Bench: served hot-path contention — the sharded atomic latency
//! reservoir (default) vs the legacy single-mutex reservoir
//! (`Metrics::legacy()`), plus saturated-server throughput with a
//! concurrent Prometheus scraper — emitted as `BENCH_hotpath.json` for
//! CI trend tracking (uploaded alongside the other bench artifacts).
//!
//! The contention microbench is deliberately worst-case: every thread
//! does nothing but `record_latency`, so the reservoir synchronization
//! is the entire measured cost. Both modes share the same summary
//! atomics (queue/service sums, per-class counters); only the sample
//! storage differs, which is exactly the delta the sharding removed.
//! No hard speed gate here — the numbers feed the JSON artifact and the
//! correctness asserts (full sample retention, identical percentile
//! readers) are what must hold; `bench_backends` carries the kernel
//! speed gate.

#[path = "common.rs"]
mod common;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use adip::arch::Architecture;
use adip::coordinator::{
    Coordinator, CoordinatorConfig, MatmulRequest, Metrics, Priority, SubmitOptions,
};
use adip::dataflow::Mat;
use adip::testutil::Rng;

const THREADS: usize = 8;
const PER_THREAD: usize = 20_000;

/// All `THREADS` writers hammer `record_latency` on one `Metrics`
/// instance with zero think time; returns wall seconds for the storm.
fn hammer(m: &Metrics) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            scope.spawn(move || {
                for i in 0..PER_THREAD {
                    let q = ((t * PER_THREAD + i) % 1000) as f64 * 1e-6;
                    m.record_latency(q, q * 0.5, Priority::ALL[i % Priority::COUNT]);
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// Saturated mixed stream through the coordinator while a scraper thread
/// reads `render()` + percentiles in a tight loop (the serving scrape
/// pattern the sharded reservoir exists for). Returns (host seconds,
/// completed scrapes).
fn saturated_serve(requests: usize, dim: usize) -> (f64, u64) {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 32,
        workers: 2,
        queue_capacity: 2 * requests,
        batch_window: 8,
        ..Default::default()
    });
    let metrics = coord.metrics();
    let stop = Arc::new(AtomicBool::new(false));
    let scrapes = Arc::new(AtomicU64::new(0));
    let scraper = {
        let (m, stop, scrapes) = (metrics, stop.clone(), scrapes.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::hint::black_box(m.render());
                std::hint::black_box(m.queue_percentile(95.0));
                std::hint::black_box(m.class_queue_summary());
                scrapes.fetch_add(1, Ordering::Relaxed);
            }
        })
    };
    let client = coord.client();
    let mut rng = Rng::seeded(41);
    let t0 = std::time::Instant::now();
    let mut shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            if i % 3 == 0 {
                shared = Arc::new(Mat::random(&mut rng, dim, dim, 8));
            }
            let req = MatmulRequest {
                id: 0,
                input_id: (i / 3) as u64,
                a: shared.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, dim, 32, 2))],
                weight_bits: 2,
                act_act: false,
                tag: String::new(),
            };
            client.submit(SubmitOptions::new(req)).expect("queue sized")
        })
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    scraper.join().unwrap();
    coord.shutdown();
    (dt, scrapes.load(Ordering::Relaxed))
}

fn main() {
    let total = (THREADS * PER_THREAD) as f64;

    // Correctness under the storm (untimed): both reservoirs keep every
    // summary counter, and the percentile readers stay available.
    for m in [Metrics::default(), Metrics::legacy()] {
        hammer(&m);
        let completed: u64 = Priority::ALL
            .iter()
            .map(|c| m.class_completed[c.index()].load(Ordering::Relaxed))
            .sum();
        assert_eq!(
            completed,
            (THREADS * PER_THREAD) as u64,
            "summary counters must not drop records"
        );
        assert!(m.queue_percentile(50.0).is_some(), "reservoir must have samples");
    }

    println!(
        "== metrics reservoir under max contention ({THREADS} writers x {PER_THREAD} records) =="
    );
    let sharded = common::bench(5, || hammer(&Metrics::default()));
    common::report("sharded reservoir (default)", sharded, total, "rec");
    let legacy_metrics = Metrics::legacy();
    let legacy = common::bench(5, || hammer(&legacy_metrics));
    common::report("legacy mutex reservoir", legacy, total, "rec");
    let lock_waits = legacy_metrics.metrics_lock_waits.load(Ordering::Relaxed);
    let speedup = legacy.min_s / sharded.min_s;
    println!(
        "  -> sharded/legacy record throughput: {speedup:.2}x (legacy contended lock acquisitions: {lock_waits})"
    );

    println!("\n== saturated server with concurrent scraper (2 workers, Q/K/V stream) ==");
    const REQS: usize = 96;
    const DIM: usize = 64;
    let (dt, scrapes) = saturated_serve(REQS, DIM);
    let req_per_s = REQS as f64 / dt;
    println!(
        "  {REQS} requests in {dt:.3}s = {req_per_s:.0} req/s with {scrapes} scrapes in flight"
    );
    assert!(scrapes > 0, "scraper thread must have completed at least one scrape");

    let json = format!(
        "{{\n  \"bench\": \"bench_hotpath\",\n  \"metrics_contention\": {{\"threads\": {THREADS}, \"records_per_thread\": {PER_THREAD}, \"sharded_rec_per_s\": {:.0}, \"legacy_rec_per_s\": {:.0}, \"speedup\": {speedup:.4}, \"legacy_lock_waits\": {lock_waits}}},\n  \"saturated_server\": {{\"requests\": {REQS}, \"req_per_s\": {req_per_s:.2}, \"scrapes\": {scrapes}}}\n}}\n",
        total / sharded.min_s,
        total / legacy.min_s
    );
    let path =
        std::env::var("BENCH_HOTPATH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
