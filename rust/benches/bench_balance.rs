//! Bench: the global balance subsystem — work-stealing throughput on a
//! skewed mixed-priority trace, plus the cross-request coalescing hit
//! rate and its simulated-cycle win — emitted as `BENCH_balance.json` for
//! CI trend tracking (uploaded alongside the cluster/coordinator JSONs).
//!
//! Acceptance gates:
//!
//! 1. **Idle stealing ≥ 1.15×** over `StealPolicy::Off` host wall-clock
//!    on the skewed trace. The skew is adversarial by construction: with
//!    2 workers and `batch_window = 1`, round-robin dispatch parks every
//!    heavy batch on worker 0 (heavy requests sit at even submission
//!    indices), so the static baseline serializes all heavy work on one
//!    worker while worker 1 idles — exactly the pathology the ROADMAP
//!    names. Gated on min-of-reps (co-tenant stalls on shared CI runners
//!    only ever inflate a rep, never deflate it).
//! 2. **Coalescing fires**: the same-weights multi-client stream must
//!    report `coalesced_passes_total > 0` and strictly fewer simulated
//!    cycles than the uncoalesced run of the identical stream (weight
//!    tiles loaded once per stacked pass instead of once per request).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use adip::arch::Architecture;
use adip::balance::{CoalesceConfig, StealPolicy};
use adip::coordinator::{
    Coordinator, CoordinatorConfig, MatmulRequest, Priority, SubmitOptions, Ticket,
};
use adip::dataflow::Mat;
use adip::testutil::Rng;

const WORKERS: usize = 2;

/// Build the skewed mixed-priority trace: heavy Batch-class requests at
/// even indices (→ all land on worker 0 under round-robin), light
/// Interactive requests at odd indices. Distinct inputs and weights:
/// nothing fuses, nothing coalesces — the gate isolates pure stealing.
fn skewed_requests(n_requests: usize) -> Vec<(MatmulRequest, Priority)> {
    let mut rng = Rng::seeded(41);
    (0..n_requests as u64)
        .map(|i| {
            if i % WORKERS as u64 == 0 {
                (
                    MatmulRequest {
                        id: 0,
                        input_id: i,
                        a: Arc::new(Mat::random(&mut rng, 192, 192, 8)),
                        bs: vec![Arc::new(Mat::random(&mut rng, 192, 192, 2))],
                        weight_bits: 2,
                        act_act: false,
                        tag: format!("heavy-{i}"),
                    },
                    Priority::Batch,
                )
            } else {
                (
                    MatmulRequest {
                        id: 0,
                        input_id: i,
                        a: Arc::new(Mat::random(&mut rng, 16, 16, 8)),
                        bs: vec![Arc::new(Mat::random(&mut rng, 16, 16, 2))],
                        weight_bits: 2,
                        act_act: false,
                        tag: format!("light-{i}"),
                    },
                    Priority::Interactive,
                )
            }
        })
        .collect()
}

/// Serve the skewed trace under one steal policy; returns (host seconds,
/// steals, steal failures).
fn run_skewed(reqs: &[(MatmulRequest, Priority)], steal: StealPolicy) -> (f64, u64, u64) {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 16,
        workers: WORKERS,
        queue_capacity: 4 * reqs.len(),
        batch_window: 1, // one batch per request: round-robin skew holds
        steal,
        ..Default::default()
    });
    let client = coord.client();
    let t0 = std::time::Instant::now();
    let tickets: Vec<Ticket> = reqs
        .iter()
        .map(|(r, p)| client.submit(SubmitOptions::new(r.clone()).priority(*p)).unwrap())
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    let steals = m.steals.load(Ordering::Relaxed);
    let failures = m.steal_failures.load(Ordering::Relaxed);
    coord.shutdown();
    (dt, steals, failures)
}

/// Serve a same-weights multi-client stream (skinny decode-shaped
/// activations against one shared projection set) with coalescing on or
/// off; returns (host s, simulated cycles, coalesced passes, members).
fn run_same_weights(n_requests: usize, coalesce_on: bool) -> (f64, u64, u64, u64) {
    let mut rng = Rng::seeded(43);
    let b = Arc::new(Mat::random(&mut rng, 256, 256, 2));
    let reqs: Vec<MatmulRequest> = (0..n_requests as u64)
        .map(|i| MatmulRequest {
            id: 0,
            input_id: 1_000 * (i % 4) + i, // 4 interleaved clients
            a: Arc::new(Mat::random(&mut rng, 8, 256, 8)),
            bs: vec![b.clone()],
            weight_bits: 2,
            act_act: false,
            tag: format!("client{}/r{i}", i % 4),
        })
        .collect();
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 16,
        workers: WORKERS,
        queue_capacity: 4 * reqs.len(),
        batch_window: 1,
        steal: StealPolicy::Idle,
        coalesce: CoalesceConfig {
            enabled: coalesce_on,
            window: Duration::from_millis(2),
            max_members: 8,
        },
        ..Default::default()
    });
    let client = coord.client();
    let t0 = std::time::Instant::now();
    let tickets: Vec<Ticket> = reqs
        .iter()
        .map(|r| client.submit(SubmitOptions::new(r.clone())).unwrap())
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().result.is_ok());
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    let out = (
        dt,
        m.sim_cycles.load(Ordering::Relaxed),
        m.coalesced_passes.load(Ordering::Relaxed),
        m.coalesced_members.load(Ordering::Relaxed),
    );
    coord.shutdown();
    out
}

fn main() {
    const SKEW_REQS: usize = 48;
    const REPS: usize = 3;

    println!(
        "== balance fabric: skewed mixed-priority trace ({WORKERS} workers, heavy on worker 0) =="
    );
    let reqs = skewed_requests(SKEW_REQS);
    let run_reps = |steal: StealPolicy| -> (f64, u64, u64) {
        let _ = run_skewed(&reqs, steal); // warmup
        let (mut best, mut steals, mut failures) = (f64::INFINITY, 0, 0);
        for _ in 0..REPS {
            let (dt, s, f) = run_skewed(&reqs, steal);
            if dt < best {
                best = dt;
            }
            steals = s;
            failures = f;
        }
        (best, steals, failures)
    };
    let (off_s, _, _) = run_reps(StealPolicy::Off);
    let (idle_s, idle_steals, idle_failures) = run_reps(StealPolicy::Idle);
    let (aggr_s, aggr_steals, _) = run_reps(StealPolicy::Aggressive);
    let gain = off_s / idle_s;
    println!(
        "  off {:.1} ms | idle {:.1} ms ({idle_steals} steals, {idle_failures} empty idle scans) | aggressive {:.1} ms ({aggr_steals} steals)",
        off_s * 1e3,
        idle_s * 1e3,
        aggr_s * 1e3
    );
    println!("  idle-vs-off speedup {gain:.2}x on min-of-{REPS} (bar: >= 1.15x)");
    assert!(idle_steals > 0, "the skewed trace must provoke steals");
    assert!(
        gain >= 1.15,
        "Idle stealing must beat static ownership by >= 1.15x on the skewed trace (got {gain:.2}x)"
    );

    println!("\n== cross-request coalescing: same-weights multi-client stream ==");
    const CO_REQS: usize = 64;
    let (solo_s, solo_cycles, _, _) = run_same_weights(CO_REQS, false);
    let (co_s, co_cycles, passes, members) = run_same_weights(CO_REQS, true);
    let cycle_reduction = 1.0 - co_cycles as f64 / solo_cycles as f64;
    println!(
        "  uncoalesced: {:.1} ms host, {solo_cycles} simulated cycles",
        solo_s * 1e3
    );
    println!(
        "  coalesced:   {:.1} ms host, {co_cycles} simulated cycles | {passes} passes, {members} members | cycle reduction {:.1}%",
        co_s * 1e3,
        cycle_reduction * 100.0
    );
    assert!(passes > 0, "the same-weights stream must coalesce");
    assert!(
        co_cycles < solo_cycles,
        "coalescing must reduce simulated cycles (weights loaded once per stacked pass): {co_cycles} vs {solo_cycles}"
    );

    let json = format!(
        "{{\n  \"bench\": \"bench_balance\",\n  \"skew\": {{\"requests\": {SKEW_REQS}, \"workers\": {WORKERS}, \"off_min_s\": {off_s:.6}, \"idle_min_s\": {idle_s:.6}, \"aggressive_min_s\": {aggr_s:.6}, \"idle_speedup\": {gain:.4}, \"gate\": 1.15, \"idle_steals\": {idle_steals}, \"idle_steal_failures\": {idle_failures}}},\n  \"coalesce\": {{\"requests\": {CO_REQS}, \"uncoalesced_cycles\": {solo_cycles}, \"coalesced_cycles\": {co_cycles}, \"cycle_reduction\": {cycle_reduction:.4}, \"coalesced_passes\": {passes}, \"coalesced_members\": {members}}}\n}}\n"
    );
    let path =
        std::env::var("BENCH_BALANCE_JSON").unwrap_or_else(|_| "BENCH_balance.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
