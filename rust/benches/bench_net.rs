//! Bench: the network serving tier — loopback TCP round-trip latency by
//! priority class under a concurrent-connection sweep, plus the row-band
//! streaming throughput of a large output and the Cancel-frame ack RTT —
//! emitted as `BENCH_net.json` for CI trend tracking (uploaded alongside
//! the balance/cluster/coordinator JSONs).
//!
//! Acceptance gates (correctness, not wall-clock — loopback timing on a
//! shared CI runner is noise):
//!
//! 1. Every request of the sweep completes `Ok` and **bit-exact** versus
//!    the host matmul — the wire tier never corrupts a result under
//!    connection concurrency.
//! 2. The large streamed output crosses the socket in more than one
//!    row-band chunk and reassembles bit-exactly.
//! 3. Cancel acks round-trip (idempotent no-op on unknown ids).

use std::sync::Arc;
use std::time::Instant;

use adip::arch::{Architecture, Backend};
use adip::coordinator::{Coordinator, CoordinatorConfig, MatmulRequest, Priority};
use adip::dataflow::Mat;
use adip::net::{NetClient, NetServer, SubmitReply};
use adip::testutil::Rng;

const REQS_PER_CONN: usize = 16;
const CLASS_NAMES: [&str; 3] = ["interactive", "batch", "background"];

/// Per-class request shapes: interactive small (latency-bound), batch
/// large (throughput), background medium.
fn class_request(rng: &mut Rng, class: usize, seq: u64) -> (MatmulRequest, Priority) {
    let (d, bits, prio) = match class {
        0 => (24, 8, Priority::Interactive),
        1 => (96, 2, Priority::Batch),
        _ => (48, 4, Priority::Background),
    };
    (
        MatmulRequest {
            id: 0,
            input_id: seq,
            a: Arc::new(Mat::random(rng, d, d, 8)),
            bs: vec![Arc::new(Mat::random(rng, d, d, bits))],
            weight_bits: bits,
            act_act: false,
            tag: format!("{}-{seq}", CLASS_NAMES[class]),
        },
        prio,
    )
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One sweep point: `conns` closed-loop connections, each its own
/// `NetClient` + thread, each submitting a class-rotating trace and
/// verifying every output. Returns (elapsed_s, per-class latency lists).
fn sweep_point(addr: std::net::SocketAddr, conns: usize) -> (f64, [Vec<f64>; 3]) {
    let t0 = Instant::now();
    let per_thread: Vec<Vec<(usize, f64)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                s.spawn(move || {
                    let mut rng = Rng::seeded(1000 + c as u64);
                    let mut net = NetClient::connect(addr).expect("connect");
                    let mut lat = Vec::with_capacity(REQS_PER_CONN);
                    for i in 0..REQS_PER_CONN {
                        let class = i % 3;
                        let (req, prio) = class_request(&mut rng, class, i as u64);
                        let want = req.a.matmul(&req.bs[0]);
                        let wire_id = i as u64 + 1;
                        let t = Instant::now();
                        match net.submit(wire_id, &req, prio, None).expect("submit") {
                            SubmitReply::Accepted { .. } => {}
                            other => panic!("conn {c} req {i} refused: {other:?}"),
                        }
                        let out = net.wait(wire_id).expect("wait");
                        lat.push((class, t.elapsed().as_secs_f64()));
                        assert_eq!(
                            out.result.expect("request failed"),
                            vec![want],
                            "conn {c} req {i}: wire output not bit-exact"
                        );
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("conn thread")).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let mut classes: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for lat in per_thread {
        for (class, secs) in lat {
            classes[class].push(secs);
        }
    }
    for c in classes.iter_mut() {
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    (elapsed, classes)
}

fn main() {
    let coord = Coordinator::start(CoordinatorConfig {
        arch: Architecture::Adip,
        n: 16,
        workers: 2,
        queue_capacity: 1024,
        batch_window: 4,
        backend: Backend::Functional,
        ..Default::default()
    });
    let server = NetServer::bind("127.0.0.1:0", coord.client(), coord.metrics())
        .expect("bind loopback server");
    let addr = server.local_addr();

    println!("== net serving: closed-loop connection sweep ({REQS_PER_CONN} reqs/conn) ==");
    let mut sweep_json = Vec::new();
    for &conns in &[1usize, 2, 4] {
        let (elapsed, classes) = sweep_point(addr, conns);
        let total = conns * REQS_PER_CONN;
        let rps = total as f64 / elapsed;
        print!("  conns={conns}: {total} reqs in {:.1} ms ({rps:.0} req/s)", elapsed * 1e3);
        let mut class_json = Vec::new();
        for (ci, name) in CLASS_NAMES.iter().enumerate() {
            let p50 = percentile(&classes[ci], 0.50) * 1e3;
            let p95 = percentile(&classes[ci], 0.95) * 1e3;
            print!(" | {name} p50 {p50:.2} ms p95 {p95:.2} ms");
            class_json.push(format!(
                "\"{name}\": {{\"p50_ms\": {p50:.4}, \"p95_ms\": {p95:.4}, \"n\": {}}}",
                classes[ci].len()
            ));
        }
        println!();
        sweep_json.push(format!(
            "{{\"connections\": {conns}, \"requests\": {total}, \"elapsed_s\": {elapsed:.6}, \"rps\": {rps:.1}, \"classes\": {{{}}}}}",
            class_json.join(", ")
        ));
    }

    println!("\n== row-band streaming: one large output over the socket ==");
    let mut rng = Rng::seeded(7);
    let (rows, cols) = (512usize, 512usize);
    let big = MatmulRequest {
        id: 0,
        input_id: 9000,
        a: Arc::new(Mat::random(&mut rng, rows, cols, 8)),
        bs: vec![Arc::new(Mat::random(&mut rng, cols, cols, 2))],
        weight_bits: 2,
        act_act: false,
        tag: "stream".into(),
    };
    let want = big.a.matmul(&big.bs[0]);
    let band = adip::net::wire::chunk_rows(cols);
    let chunks = rows.div_ceil(band);
    assert!(chunks > 1, "the streaming figure must cover multiple chunks (got {chunks})");
    let mut net = NetClient::connect(addr).expect("connect");
    let t = Instant::now();
    assert!(matches!(
        net.submit(1, &big, Priority::Batch, None).expect("submit big"),
        SubmitReply::Accepted { .. }
    ));
    let out = net.wait(1).expect("wait big");
    let stream_s = t.elapsed().as_secs_f64();
    assert_eq!(out.result.expect("big request failed"), vec![want], "streamed reassembly");
    let payload_mib = (rows * cols * 4) as f64 / (1 << 20) as f64;
    println!(
        "  {rows}x{cols} output: {chunks} chunks of {band} rows, {:.1} ms round-trip ({:.1} MiB payload)",
        stream_s * 1e3,
        payload_mib
    );

    // Cancel-frame ack RTT: unknown ids are idempotent no-ops, so this
    // measures the pure frame round-trip on a warm session.
    let mut rtts: Vec<f64> = (0..64)
        .map(|i| {
            let t = Instant::now();
            assert!(!net.cancel(50_000 + i).expect("cancel ack"));
            t.elapsed().as_secs_f64()
        })
        .collect();
    rtts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cancel_p50_us = percentile(&rtts, 0.50) * 1e6;
    println!("  cancel-ack RTT p50 {cancel_p50_us:.0} us");

    server.shutdown();
    coord.shutdown();

    let json = format!(
        "{{\n  \"bench\": \"bench_net\",\n  \"sweep\": [\n    {}\n  ],\n  \"stream\": {{\"rows\": {rows}, \"cols\": {cols}, \"chunks\": {chunks}, \"band_rows\": {band}, \"elapsed_s\": {stream_s:.6}, \"payload_mib\": {payload_mib:.2}}},\n  \"cancel_ack_rtt_us_p50\": {cancel_p50_us:.1}\n}}\n",
        sweep_json.join(",\n    ")
    );
    let path = std::env::var("BENCH_NET_JSON").unwrap_or_else(|_| "BENCH_net.json".to_string());
    std::fs::write(&path, &json).expect("write bench json");
    println!("\n  wrote {path}");
}
