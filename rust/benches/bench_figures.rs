//! Bench: regenerate EVERY paper table and figure (the deliverable-(d)
//! harness) and time each regeneration.
//!
//! `cargo bench --bench bench_figures` prints the full set of artifacts —
//! the same output as `adip all` — with per-artifact wall-clock, proving
//! the entire evaluation section regenerates in seconds.

#[path = "common.rs"]
mod common;

use adip::report;

fn main() {
    for name in report::ALL_ARTIFACTS {
        let stat = common::bench(3, || report::render(name).unwrap());
        let r = report::render(name).unwrap();
        println!("{}", r.text);
        println!(
            "[regenerated {name} in {:.1} ms median]\n",
            stat.median_s * 1e3
        );
    }
}
