//! Shared timing harness for the benches (criterion is not in the offline
//! crate snapshot; this is a deliberately small warmup+repeat timer with
//! median/min reporting).

use std::time::Instant;

/// Benchmark result for one case.
#[derive(Debug, Clone, Copy)]
pub struct BenchStat {
    /// Median wall-clock seconds per iteration.
    pub median_s: f64,
    /// Fastest observed iteration.
    pub min_s: f64,
    /// Iterations measured.
    pub iters: usize,
}

/// Time `f` (excluding one warmup call): `reps` measured iterations,
/// median + min reported.
pub fn bench<T>(reps: usize, mut f: impl FnMut() -> T) -> BenchStat {
    std::hint::black_box(f()); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStat { median_s: times[times.len() / 2], min_s: times[0], iters: reps }
}

/// Print a standard row: name, median, throughput (unit/s given per-iter
/// work `units`).
pub fn report(name: &str, stat: BenchStat, units: f64, unit_name: &str) {
    println!(
        "{name:<44} {:>10.3} ms/iter  {:>14.3e} {unit_name}/s  (min {:.3} ms, n={})",
        stat.median_s * 1e3,
        units / stat.median_s,
        stat.min_s * 1e3,
        stat.iters
    );
}
