//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! The build environment for this repository has no registry access, so the
//! crate ships the small slice of `anyhow` the codebase actually uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait. Error values carry a message plus a
//! context chain; `{:#}` renders the chain inline exactly like upstream.
//!
//! Swap this for the real `anyhow` (same public surface) when online.

use std::fmt;

/// A string-backed error with a chain of context messages.
///
/// Like `anyhow::Error`, this type deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    /// Outermost description first (most recent `.context()` call).
    chain: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push an outer context message (most recent first).
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // Fold the source chain into the message chain.
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and missing `Option` values).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_on_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("key {:?}", "n")).unwrap_err();
        assert_eq!(e.to_string(), "key \"n\"");

        // context on an already-anyhow Result composes
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert_eq!(check(true).unwrap(), 1);
        assert_eq!(check(false).unwrap_err().to_string(), "flag was false");

        fn always_bails() -> Result<()> {
            bail!("code {}", 7);
        }
        assert_eq!(always_bails().unwrap_err().to_string(), "code 7");
        assert_eq!(anyhow!("x = {}", 3).to_string(), "x = 3");
        let msg = String::from("wrapped");
        assert_eq!(anyhow!(msg).to_string(), "wrapped");
    }
}
