//! Weight-tile interleaving for multi-matrix multiplication (Fig. 5).
//!
//! In the 8b×4b / 8b×2b modes, ADiP stores 2 / 3 / 4 *distinct* weight
//! tiles in one stationary tile: element `(r, c)` of the interleaved tile
//! packs element `(r, c)` of each source tile into adjacent subword fields
//! of one 8-bit carrier (source 0 in the least-significant field). Each PE
//! multiplies the shared 8-bit activation against every field in the same
//! cycle, producing one psum stream per source matrix — the “asymmetric
//! multi-matrix multiplication with a shared input matrix” mode.
//!
//! Fig. 5 variants covered:
//! * (a) 8b×8b — single tile, no interleaving (`k = 1`).
//! * (b) 8b×4b — 2 tiles, 4-bit fields.
//! * (c) 8b×2b — 4 tiles, 2-bit fields.
//! * (d) 8b×2b Q/K/V — 3 tiles, 2-bit fields (the 4th field unused);
//!   used when `d_k / N` would otherwise leave the array under-utilized.

use anyhow::{bail, ensure, Result};

use super::matrix::Mat;
use crate::quant::{types::value_range, PrecisionMode};

/// An interleaved stationary weight tile: `k` source tiles packed into one
/// 8-bit-carrier tile.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterleavedTile {
    /// Packed carrier tile; each element is one byte (stored `0..=255`).
    pub packed: Mat,
    /// Precision mode the tile was packed for.
    pub mode: PrecisionMode,
    /// Number of source matrices actually packed (may be less than the
    /// mode's capacity, e.g. 3 Q/K/V tiles in 8b×2b).
    pub k: usize,
    /// Source tiles retained at pack time (§Perf iteration 5: the
    /// functional fast path reads these instead of re-extracting subword
    /// fields on every activation pass; bit-equality with the packed
    /// fields is asserted by the round-trip tests). Empty for tiles built
    /// by hand from a raw carrier.
    pub sources: Vec<Mat>,
}

impl InterleavedTile {
    /// Weight value of source matrix `s` at `(r, c)` (sign-extended).
    pub fn source_value(&self, s: usize, r: usize, c: usize) -> i32 {
        assert!(s < self.k);
        let byte = self.packed.get(r, c) as u32;
        let w = self.mode.weight_bits();
        let field = (byte >> (w * s as u32)) & ((1 << w) - 1);
        crate::quant::packing::sign_extend(field as i32, w)
    }
}

/// Interleave `tiles` (all the same shape, values in range for
/// `mode.weight_bits()`) into one stationary tile. `tiles.len()` must be
/// `1..=mode.interleave_factor()`.
pub fn interleave_tiles(tiles: &[&Mat], mode: PrecisionMode) -> Result<InterleavedTile> {
    let k = tiles.len();
    ensure!(k >= 1, "need at least one tile");
    ensure!(
        k <= mode.interleave_factor(),
        "{k} tiles exceed the {} capacity of {mode}",
        mode.interleave_factor()
    );
    let (rows, cols) = (tiles[0].rows(), tiles[0].cols());
    let w = mode.weight_bits();
    let (lo, hi) = value_range(w);
    for (s, t) in tiles.iter().enumerate() {
        ensure!(
            t.rows() == rows && t.cols() == cols,
            "tile {s} shape {}x{} != {}x{}",
            t.rows(),
            t.cols(),
            rows,
            cols
        );
        if let Some(bad) = t.as_slice().iter().find(|&&v| !(lo..=hi).contains(&v)) {
            bail!("tile {s} value {bad} out of {w}-bit range {lo}..={hi}");
        }
    }
    let mask = (1u32 << w) - 1;
    let packed = Mat::from_fn(rows, cols, |r, c| {
        let mut byte = 0u32;
        for (s, t) in tiles.iter().enumerate() {
            byte |= ((t.get(r, c) as u32) & mask) << (w * s as u32);
        }
        byte as i32
    });
    let sources = tiles.iter().map(|t| (*t).clone()).collect();
    Ok(InterleavedTile { packed, mode, k, sources })
}

/// Recover the `k` source tiles from an interleaved tile; inverse of
/// [`interleave_tiles`].
pub fn deinterleave_tile(t: &InterleavedTile) -> Vec<Mat> {
    (0..t.k)
        .map(|s| Mat::from_fn(t.packed.rows(), t.packed.cols(), |r, c| t.source_value(s, r, c)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{check, Rng};

    #[test]
    fn single_tile_8x8_is_identity_bytes() {
        let mut rng = Rng::seeded(21);
        let t = Mat::random(&mut rng, 4, 4, 8);
        let it = interleave_tiles(&[&t], PrecisionMode::W8).unwrap();
        assert_eq!(it.k, 1);
        let back = deinterleave_tile(&it);
        assert_eq!(back[0], t);
    }

    #[test]
    fn two_tiles_4bit_fig5b() {
        let a = Mat::from_vec(1, 2, vec![-8, 7]);
        let b = Mat::from_vec(1, 2, vec![3, -1]);
        let it = interleave_tiles(&[&a, &b], PrecisionMode::W4).unwrap();
        // low nibble = a, high nibble = b
        assert_eq!(it.packed.get(0, 0), ((3u32 << 4) | 0x8) as i32);
        assert_eq!(it.source_value(0, 0, 0), -8);
        assert_eq!(it.source_value(1, 0, 1), -1);
        let back = deinterleave_tile(&it);
        assert_eq!(back, vec![a, b]);
    }

    #[test]
    fn four_tiles_2bit_fig5c() {
        let tiles: Vec<Mat> =
            (0..4).map(|s| Mat::from_fn(3, 3, |r, c| ((r + c + s) % 4) as i32 - 2)).collect();
        let refs: Vec<&Mat> = tiles.iter().collect();
        let it = interleave_tiles(&refs, PrecisionMode::W2).unwrap();
        assert_eq!(it.k, 4);
        assert_eq!(deinterleave_tile(&it), tiles);
    }

    #[test]
    fn three_tiles_qkv_fig5d() {
        // Q/K/V variant: 3 tiles in the 4-slot 2-bit mode.
        let q = Mat::from_vec(2, 2, vec![1, -1, 0, 1]);
        let k = Mat::from_vec(2, 2, vec![-2, 0, 1, -1]);
        let v = Mat::from_vec(2, 2, vec![0, 1, -2, 0]);
        let it = interleave_tiles(&[&q, &k, &v], PrecisionMode::W2).unwrap();
        assert_eq!(it.k, 3);
        assert_eq!(deinterleave_tile(&it), vec![q, k, v]);
    }

    #[test]
    fn rejects_capacity_and_range_violations() {
        let t = Mat::zeros(2, 2);
        let too_many: Vec<&Mat> = vec![&t, &t];
        assert!(interleave_tiles(&too_many, PrecisionMode::W8).is_err());
        let wide = Mat::from_vec(1, 1, vec![5]);
        assert!(interleave_tiles(&[&wide], PrecisionMode::W2).is_err());
        let a = Mat::zeros(2, 2);
        let b = Mat::zeros(3, 2);
        assert!(interleave_tiles(&[&a, &b], PrecisionMode::W4).is_err());
    }

    #[test]
    fn roundtrip_property_all_modes() {
        check(
            "interleave-roundtrip",
            31,
            60,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let k = 1 + rng.below(mode.interleave_factor());
                let n = 1 + rng.below(8);
                let tiles: Vec<Mat> =
                    (0..k).map(|_| Mat::random(rng, n, n, mode.weight_bits())).collect();
                (mode, tiles)
            },
            |(mode, tiles)| {
                let refs: Vec<&Mat> = tiles.iter().collect();
                let it = interleave_tiles(&refs, *mode).map_err(|e| e.to_string())?;
                if deinterleave_tile(&it) == *tiles {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
