//! DiP weight-tile permutation (paper §IV-B, Fig. 6 step 1).
//!
//! The DiP dataflow [34] loads the stationary weight tile *permuted*: every
//! column `c` is rotated **upward** by `c` positions. Combined with the
//! diagonal movement of activations (row-to-row with wraparound at the
//! array boundary), each activation then meets exactly the weights of the
//! original column-aligned GEMM without the input/output skew FIFOs that a
//! conventional weight-stationary array needs.

use super::matrix::Mat;

/// Rotate every column of `tile` upward by its column index:
/// `out[r][c] = tile[(r + c) mod R][c]`.
pub fn permute_dip(tile: &Mat) -> Mat {
    let rows = tile.rows();
    Mat::from_fn(rows, tile.cols(), |r, c| tile.get((r + c) % rows, c))
}

/// Inverse of [`permute_dip`]: rotate every column downward by its index.
pub fn unpermute_dip(tile: &Mat) -> Mat {
    let rows = tile.rows();
    Mat::from_fn(rows, tile.cols(), |r, c| tile.get((r + rows - (c % rows)) % rows, c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{check, Rng};

    #[test]
    fn known_4x4_example() {
        // Column c rotated up by c: matches the worked example of Fig. 6.
        #[rustfmt::skip]
        let tile = Mat::from_vec(4, 4, vec![
            11, 12, 13, 14,
            21, 22, 23, 24,
            31, 32, 33, 34,
            41, 42, 43, 44,
        ]);
        #[rustfmt::skip]
        let want = Mat::from_vec(4, 4, vec![
            11, 22, 33, 44,
            21, 32, 43, 14,
            31, 42, 13, 24,
            41, 12, 23, 34,
        ]);
        assert_eq!(permute_dip(&tile), want);
    }

    #[test]
    fn first_column_unchanged() {
        let mut rng = Rng::seeded(2);
        let tile = Mat::random(&mut rng, 8, 8, 8);
        let p = permute_dip(&tile);
        for r in 0..8 {
            assert_eq!(p.get(r, 0), tile.get(r, 0));
        }
    }

    #[test]
    fn permute_is_row_permutation_per_column() {
        // each column keeps exactly the same multiset of values
        let mut rng = Rng::seeded(3);
        let tile = Mat::random(&mut rng, 6, 6, 8);
        let p = permute_dip(&tile);
        for c in 0..6 {
            let mut a: Vec<i32> = (0..6).map(|r| tile.get(r, c)).collect();
            let mut b: Vec<i32> = (0..6).map(|r| p.get(r, c)).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "column {c}");
        }
    }

    #[test]
    fn roundtrip_property() {
        check(
            "dip-permute-roundtrip",
            17,
            50,
            |rng| {
                let n = 1 + rng.below(16);
                let m = 1 + rng.below(16);
                Mat::random(rng, n, m, 8)
            },
            |tile| {
                if unpermute_dip(&permute_dip(tile)) == *tile
                    && permute_dip(&unpermute_dip(tile)) == *tile
                {
                    Ok(())
                } else {
                    Err("permute/unpermute not inverse".into())
                }
            },
        );
    }
}
