//! Block (tiled) matrix multiplication — paper Algorithm 1.
//!
//! Large GEMMs are decomposed into `T×T` tiles matching the array size; the
//! innermost loops multiply tile pairs and accumulate psums into the output
//! block. The loop order follows the paper (j → k → i) so a stationary
//! weight tile `(k, j)` is reused across all `i` blocks — the weight reuse
//! the stationary dataflow is built around.

use super::matrix::Mat;

/// Coordinates of one tile-level multiply: output block `(i, j)`,
/// reduction index `k`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileCoord {
    /// Output block row (in tiles).
    pub i: usize,
    /// Output block column (in tiles).
    pub j: usize,
    /// Reduction step (in tiles).
    pub k: usize,
}

/// The tile decomposition of a `m×k_dim · k_dim×n` GEMM with tile size `t`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGrid {
    /// GEMM M dimension (rows of A / C).
    pub m: usize,
    /// GEMM K dimension (cols of A / rows of B).
    pub k_dim: usize,
    /// GEMM N dimension (cols of B / C).
    pub n: usize,
    /// Tile edge (array size).
    pub t: usize,
}

impl TileGrid {
    /// Tiles along M.
    pub fn tiles_m(&self) -> usize {
        self.m.div_ceil(self.t)
    }

    /// Tiles along K.
    pub fn tiles_k(&self) -> usize {
        self.k_dim.div_ceil(self.t)
    }

    /// Tiles along N.
    pub fn tiles_n(&self) -> usize {
        self.n.div_ceil(self.t)
    }

    /// Total tile-level multiplications.
    pub fn total_tiles(&self) -> usize {
        self.tiles_m() * self.tiles_k() * self.tiles_n()
    }

    /// Iterate tile coordinates in the paper's j → k → i order.
    pub fn coords(&self) -> impl Iterator<Item = TileCoord> + '_ {
        let (tm, tk, tn) = (self.tiles_m(), self.tiles_k(), self.tiles_n());
        (0..tn).flat_map(move |j| {
            (0..tk).flat_map(move |k| (0..tm).map(move |i| TileCoord { i, j, k }))
        })
    }
}

/// Build the tile grid for a GEMM.
pub fn tile_grid(m: usize, k_dim: usize, n: usize, t: usize) -> TileGrid {
    assert!(t > 0, "tile size must be positive");
    assert!(m > 0 && k_dim > 0 && n > 0, "GEMM dims must be positive");
    TileGrid { m, k_dim, n, t }
}

/// Algorithm 1: compute `a · b` via `t×t` tiles, calling `tile_mm` for each
/// tile pair (defaults to the reference tile GEMM — the hardware models
/// substitute their own functional path) and accumulating psums.
pub fn blocked_matmul_with(
    a: &Mat,
    b: &Mat,
    t: usize,
    mut tile_mm: impl FnMut(TileCoord, &Mat, &Mat) -> Mat,
) -> Mat {
    let grid = tile_grid(a.rows(), a.cols(), b.cols(), t);
    let mut c = Mat::zeros(a.rows(), b.cols());
    for coord in grid.coords() {
        let a_tile = a.tile(coord.i * t, coord.k * t, t, t);
        let b_tile = b.tile(coord.k * t, coord.j * t, t, t);
        let p = tile_mm(coord, &a_tile, &b_tile);
        assert_eq!(p.rows(), t, "tile_mm must return a {t}x{t} psum tile");
        assert_eq!(p.cols(), t);
        c.accumulate(coord.i * t, coord.j * t, &p);
    }
    c
}

/// Algorithm 1 with the reference tile GEMM.
pub fn blocked_matmul(a: &Mat, b: &Mat, t: usize) -> Mat {
    blocked_matmul_with(a, b, t, |_, at, bt| at.matmul(bt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{check, Rng};

    #[test]
    fn grid_counts() {
        let g = tile_grid(10, 7, 5, 4);
        assert_eq!((g.tiles_m(), g.tiles_k(), g.tiles_n()), (3, 2, 2));
        assert_eq!(g.total_tiles(), 12);
        assert_eq!(g.coords().count(), 12);
    }

    #[test]
    fn coords_follow_paper_loop_order() {
        let g = tile_grid(4, 4, 4, 2); // 2x2x2 tiles
        let got: Vec<TileCoord> = g.coords().collect();
        // j outermost, then k, then i
        assert_eq!(got[0], TileCoord { i: 0, j: 0, k: 0 });
        assert_eq!(got[1], TileCoord { i: 1, j: 0, k: 0 });
        assert_eq!(got[2], TileCoord { i: 0, j: 0, k: 1 });
        assert_eq!(got[4], TileCoord { i: 0, j: 1, k: 0 });
    }

    #[test]
    fn every_tile_visited_exactly_once() {
        let g = tile_grid(9, 9, 9, 4);
        let mut seen = std::collections::HashSet::new();
        for c in g.coords() {
            assert!(seen.insert(c), "tile {c:?} visited twice");
        }
        assert_eq!(seen.len(), g.total_tiles());
    }

    #[test]
    fn blocked_equals_reference_exact_divisible() {
        let mut rng = Rng::seeded(41);
        let a = Mat::random(&mut rng, 8, 8, 8);
        let b = Mat::random(&mut rng, 8, 8, 8);
        assert_eq!(blocked_matmul(&a, &b, 4), a.matmul(&b));
    }

    #[test]
    fn blocked_equals_reference_ragged_property() {
        check(
            "blocked-matmul-ref",
            43,
            40,
            |rng| {
                let (m, k, n) = (1 + rng.below(20), 1 + rng.below(20), 1 + rng.below(20));
                let t = 1 + rng.below(8);
                (Mat::random(rng, m, k, 8), Mat::random(rng, k, n, 4), t)
            },
            |(a, b, t)| {
                if blocked_matmul(a, b, *t) == a.matmul(b) {
                    Ok(())
                } else {
                    Err("blocked != reference".into())
                }
            },
        );
    }

    #[test]
    fn custom_tile_mm_sees_padded_tiles() {
        let a = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as i32);
        let b = Mat::from_fn(3, 3, |r, c| (r == c) as i32);
        let mut calls = 0;
        let c = blocked_matmul_with(&a, &b, 2, |_, at, bt| {
            calls += 1;
            assert_eq!((at.rows(), at.cols()), (2, 2));
            at.matmul(bt)
        });
        assert_eq!(calls, 2 * 2 * 2);
        assert_eq!(c, a);
    }
}
