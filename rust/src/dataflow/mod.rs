//! The ADiP dataflow preprocessing pipeline (paper §IV-B, Figs. 5–6) and
//! block matrix multiplication (paper Algorithm 1).
//!
//! Order of operations for a stationary weight tile:
//!
//! 1. **Permutation** ([`permute`]) — the DiP dataflow rotates every column
//!    of the tile upward by its column index so that diagonally-moving
//!    activations meet the right weights without input/output
//!    synchronization FIFOs.
//! 2. **Interleaving** ([`interleave`]) — for 8b×4b / 8b×2b modes, 2 / 3 / 4
//!    distinct weight tiles are merged element-wise into one 8-bit carrier
//!    tile (Fig. 5(b)–(d)), enabling multi-matrix multiplication with a
//!    shared input matrix.
//! 3. **Tiling** ([`tiling`]) — large GEMMs are decomposed into array-sized
//!    tiles with psum accumulation over the K dimension (Algorithm 1).
//!
//! [`matrix`] provides the dense integer matrix type these stages operate
//! on, together with the reference GEMM used as the correctness oracle.

pub mod interleave;
pub mod matrix;
pub mod permute;
pub mod tiling;

pub use interleave::{deinterleave_tile, interleave_tiles, InterleavedTile};
pub use matrix::Mat;
pub use permute::{permute_dip, unpermute_dip};
pub use tiling::{blocked_matmul, tile_grid, TileCoord, TileGrid};

use crate::quant::PrecisionMode;

/// The complete Fig. 6 offline weight preparation: DiP column-rotation
/// permutation of each source tile, then interleaving into the packed
/// stationary carrier. The result is what the weight memory actually
/// stores — an array loading it needs no further transformation.
///
/// (The register-level simulators take *unpermuted* tiles and permute on
/// load, modeling the same preprocessing; `prepared` round-trips to the
/// identical stationary bytes — asserted in tests.)
pub fn prepare_stationary_tile(
    tiles: &[&Mat],
    mode: PrecisionMode,
) -> anyhow::Result<InterleavedTile> {
    let permuted: Vec<Mat> = tiles.iter().map(|t| permute_dip(t)).collect();
    let refs: Vec<&Mat> = permuted.iter().collect();
    interleave_tiles(&refs, mode)
}

#[cfg(test)]
mod prepare_tests {
    use super::*;
    use crate::testutil::{check, Rng};

    #[test]
    fn prepare_equals_permute_then_interleave_and_commutes() {
        // Permutation (element movement) commutes with interleaving
        // (element-wise packing): preparing the tiles equals permuting the
        // packed carrier. This is the property that lets the hardware run
        // the two preprocessing steps in either order (Fig. 6).
        check(
            "fig6-prepare",
            1401,
            40,
            |rng: &mut Rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let k = 1 + rng.below(mode.interleave_factor());
                let n = 1 + rng.below(12);
                let tiles: Vec<Mat> =
                    (0..k).map(|_| Mat::random(rng, n, n, mode.weight_bits())).collect();
                (mode, tiles)
            },
            |(mode, tiles)| {
                let refs: Vec<&Mat> = tiles.iter().collect();
                let prepared = prepare_stationary_tile(&refs, *mode).map_err(|e| e.to_string())?;
                let packed_first = interleave_tiles(&refs, *mode).map_err(|e| e.to_string())?;
                if prepared.packed != permute_dip(&packed_first.packed) {
                    return Err("permute/interleave do not commute".into());
                }
                // and the sources recover as the permuted originals
                let back = deinterleave_tile(&prepared);
                for (orig, got) in tiles.iter().zip(&back) {
                    if *got != permute_dip(orig) {
                        return Err("prepared sources mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
