//! Dense row-major integer matrices + the reference GEMM oracle.
//!
//! All functional modeling in the crate (PE, arrays, simulator, coordinator)
//! works on `i32` matrices: activations/weights are small integers
//! (8/4/2-bit) and psums fit comfortably in `i32` for the tile sizes ADiP
//! supports (worst case `127·127·64·4 < 2^31`).

use std::fmt;

use crate::testutil::Rng;

/// Dense row-major `i32` matrix.
#[derive(Clone, PartialEq, Eq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<i32>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0; rows * cols] }
    }

    /// Build from row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<i32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch: {rows}x{cols} vs {}", data.len());
        Mat { rows, cols, data }
    }

    /// Build from a closure of `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Random matrix with entries fitting `bits` bits (signed).
    pub fn random(rng: &mut Rng, rows: usize, cols: usize, bits: u32) -> Mat {
        Mat::from_vec(rows, cols, rng.int_vec(rows * cols, bits))
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element access.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element access.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Add `v` into `(r, c)`.
    #[inline]
    pub fn add_assign(&mut self, r: usize, c: usize, v: i32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] += v;
    }

    /// Borrow the row-major backing slice.
    pub fn as_slice(&self) -> &[i32] {
        &self.data
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// Extract the sub-matrix starting at `(r0, c0)` with shape
    /// `rows × cols`, zero-padding past the edges (tiles at matrix borders).
    pub fn tile(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |r, c| {
            let (rr, cc) = (r0 + r, c0 + c);
            if rr < self.rows && cc < self.cols {
                self.get(rr, cc)
            } else {
                0
            }
        })
    }

    /// Write `tile` into `self` at `(r0, c0)`, ignoring parts past the edge
    /// (inverse of the zero-padding in [`Mat::tile`]).
    pub fn place(&mut self, r0: usize, c0: usize, tile: &Mat) {
        for r in 0..tile.rows {
            for c in 0..tile.cols {
                let (rr, cc) = (r0 + r, c0 + c);
                if rr < self.rows && cc < self.cols {
                    self.set(rr, cc, tile.get(r, c));
                }
            }
        }
    }

    /// Accumulate `tile` into `self` at `(r0, c0)` (psum accumulation).
    pub fn accumulate(&mut self, r0: usize, c0: usize, tile: &Mat) {
        for r in 0..tile.rows {
            for c in 0..tile.cols {
                let (rr, cc) = (r0 + r, c0 + c);
                if rr < self.rows && cc < self.cols {
                    self.add_assign(rr, cc, tile.get(r, c));
                }
            }
        }
    }

    /// Reference GEMM: `self (m×k) · other (k×n)` in `i32`. The correctness
    /// oracle every hardware model is tested against.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "inner dims: {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for kk in 0..self.cols {
                let a = self.get(i, kk);
                if a == 0 {
                    continue;
                }
                let brow = other.row(kk);
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Blocked GEMM: `self (m×k) · other (k×n)`, bit-exact with
    /// [`Mat::matmul`] (`i32` accumulation is exact in any order, and each
    /// output element still accumulates in ascending `k`). `other` is
    /// transposed once so the inner loop reduces two contiguous slices,
    /// the loops are blocked so a `KERNEL_BLOCK`-sized patch of it stays
    /// cache-resident, and the output is split into row bands executed on
    /// `threads` scoped threads (0 = one per available CPU). This is the
    /// `KernelMode::Blocked` serving kernel; [`Mat::matmul`] remains the
    /// reference oracle and differential baseline.
    pub fn matmul_blocked(&self, other: &Mat, threads: usize) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "inner dims: {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut data = vec![0i32; m * n];
        if m == 0 || k == 0 || n == 0 {
            return Mat { rows: m, cols: n, data };
        }
        let threads = match threads {
            0 => std::thread::available_parallelism().map_or(1, |t| t.get()),
            t => t,
        }
        .min(m);
        let bt = other.transpose();
        let (a, btd) = (self.data.as_slice(), bt.data.as_slice());
        let band = m.div_ceil(threads);
        std::thread::scope(|scope| {
            for (bi, out) in data.chunks_mut(band * n).enumerate() {
                let work = move || matmul_band(a, btd, out, bi * band, k, n);
                if threads == 1 {
                    work();
                } else {
                    scope.spawn(work);
                }
            }
        });
        Mat { rows: m, cols: n, data }
    }

    /// Max absolute element (for quick sanity checks).
    pub fn abs_max(&self) -> i32 {
        self.data.iter().map(|v| v.abs()).max().unwrap_or(0)
    }
}

/// Cache block edge for [`Mat::matmul_blocked`]: a `64×64` `i32` patch of
/// the transposed weight matrix is 16 KiB — comfortably L1-resident while
/// every row of the band streams over it.
const KERNEL_BLOCK: usize = 64;

/// One row band of the blocked GEMM: `out = A[r0..r0+rows] · Bᵀᵀ`, with
/// `bt` the k-contiguous transposed `B`. Blocking order is `k` outer then
/// `j`, so each `(kb, jb)` patch of `bt` is reused by every row of the
/// band before the next patch is touched; per output element the partial
/// products still accumulate in ascending `k`.
fn matmul_band(a: &[i32], bt: &[i32], out: &mut [i32], r0: usize, k: usize, n: usize) {
    for kb in (0..k).step_by(KERNEL_BLOCK) {
        let kend = (kb + KERNEL_BLOCK).min(k);
        for jb in (0..n).step_by(KERNEL_BLOCK) {
            let jend = (jb + KERNEL_BLOCK).min(n);
            for (ri, orow) in out.chunks_mut(n).enumerate() {
                let arow = &a[(r0 + ri) * k + kb..(r0 + ri) * k + kend];
                for j in jb..jend {
                    let brow = &bt[j * k + kb..j * k + kend];
                    let mut acc = orow[j];
                    for (&av, &bv) in arow.iter().zip(brow) {
                        acc += av * bv;
                    }
                    orow[j] = acc;
                }
            }
        }
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(8);
        for r in 0..show_rows {
            let row: Vec<String> =
                self.row(r).iter().take(8).map(|v| format!("{v:4}")).collect();
            let ell = if self.cols > 8 { " …" } else { "" };
            writeln!(f, "  [{}{}]", row.join(", "), ell)?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 3 + c) as i32);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.get(1, 2), 5);
        assert_eq!(m.row(1), &[3, 4, 5]);
        assert_eq!(m.as_slice(), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seeded(5);
        let m = Mat::random(&mut rng, 7, 3, 8);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(2, 6), m.get(6, 2));
    }

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_vec(2, 2, vec![1, 2, 3, 4]);
        let b = Mat::from_vec(2, 2, vec![5, 6, 7, 8]);
        assert_eq!(a.matmul(&b), Mat::from_vec(2, 2, vec![19, 22, 43, 50]));
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seeded(6);
        let a = Mat::random(&mut rng, 5, 5, 8);
        let id = Mat::from_fn(5, 5, |r, c| (r == c) as i32);
        assert_eq!(a.matmul(&id), a);
        assert_eq!(id.matmul(&a), a);
    }

    #[test]
    fn tile_pads_with_zeros_and_place_restores() {
        let m = Mat::from_fn(5, 5, |r, c| (r * 5 + c) as i32 + 1);
        let t = m.tile(3, 3, 4, 4);
        assert_eq!(t.get(0, 0), m.get(3, 3));
        assert_eq!(t.get(2, 0), 0); // past the bottom edge
        assert_eq!(t.get(0, 3), 0); // past the right edge
        let mut out = Mat::zeros(5, 5);
        for r0 in [0, 4] {
            for c0 in [0, 4] {
                out.place(r0, c0, &m.tile(r0, c0, 4, 4));
            }
        }
        // every element covered by at least one tile
        assert_eq!(out, m);
    }

    #[test]
    fn accumulate_adds() {
        let mut acc = Mat::zeros(2, 2);
        let t = Mat::from_vec(2, 2, vec![1, 2, 3, 4]);
        acc.accumulate(0, 0, &t);
        acc.accumulate(0, 0, &t);
        assert_eq!(acc, Mat::from_vec(2, 2, vec![2, 4, 6, 8]));
    }

    #[test]
    fn blocked_matches_naive_on_ragged_shapes_and_thread_counts() {
        crate::testutil::check(
            "matmul-blocked-vs-naive",
            15,
            40,
            |rng| {
                // ragged shapes straddling the 64-wide block edge
                let (m, k, n) = (1 + rng.below(97), 1 + rng.below(97), 1 + rng.below(97));
                let threads = *rng.choose(&[0usize, 1, 2, 4]);
                (Mat::random(rng, m, k, 8), Mat::random(rng, k, n, 4), threads)
            },
            |(a, b, threads)| {
                if a.matmul_blocked(b, *threads) == a.matmul(b) {
                    Ok(())
                } else {
                    Err(format!("blocked != naive at {threads} threads"))
                }
            },
        );
    }

    #[test]
    fn blocked_handles_degenerate_and_multi_band_shapes() {
        // empty output / empty inner dimension
        let e = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        assert_eq!(e.matmul_blocked(&b, 4), e.matmul(&b));
        let a = Mat::zeros(4, 0);
        let b0 = Mat::zeros(0, 3);
        assert_eq!(a.matmul_blocked(&b0, 2), a.matmul(&b0));
        // more threads than rows: one band per row
        let mut rng = Rng::seeded(16);
        let a = Mat::random(&mut rng, 3, 70, 8);
        let b = Mat::random(&mut rng, 70, 66, 8);
        assert_eq!(a.matmul_blocked(&b, 16), a.matmul(&b));
    }

    #[test]
    fn matmul_associativity_property() {
        crate::testutil::check(
            "matmul-assoc",
            13,
            25,
            |rng| {
                let (m, k, n, p) =
                    (1 + rng.below(6), 1 + rng.below(6), 1 + rng.below(6), 1 + rng.below(6));
                (
                    Mat::random(rng, m, k, 4),
                    Mat::random(rng, k, n, 4),
                    Mat::random(rng, n, p, 4),
                )
            },
            |(a, b, c)| {
                if a.matmul(b).matmul(c) == a.matmul(&b.matmul(c)) {
                    Ok(())
                } else {
                    Err("not associative".into())
                }
            },
        );
    }
}
