//! The ADiP array model (paper §IV).

use anyhow::{ensure, Result};

use super::array::{ArchConfig, Architecture, SystolicArray, TilePass};
use super::column_unit::SharedColumnUnit;
use super::cycle_sim::simulate_adip_tile;
use super::pe::PeConfig;
use crate::dataflow::{deinterleave_tile, InterleavedTile, Mat};
use crate::quant::PrecisionMode;

/// `N×N` reconfigurable PEs + shared column units, diagonal dataflow.
#[derive(Debug, Clone)]
pub struct AdipArray {
    cfg: ArchConfig,
    pe_cfg: PeConfig,
    unit: SharedColumnUnit,
}

impl AdipArray {
    /// Build an ADiP array from a configuration.
    pub fn new(cfg: ArchConfig) -> AdipArray {
        AdipArray {
            cfg,
            pe_cfg: PeConfig { multipliers: cfg.multipliers, mult_width: 2 },
            unit: SharedColumnUnit,
        }
    }

    /// The paper's evaluation instance (32×32, M = 16, S = 1).
    pub fn paper_eval() -> AdipArray {
        AdipArray::new(ArchConfig::default())
    }

    /// PE configuration in use.
    pub fn pe_config(&self) -> PeConfig {
        self.pe_cfg
    }

    /// Run one tile pass through the register-level cycle simulator
    /// instead of the fast functional path (slow; used for validation and
    /// the `--cycle-accurate` CLI flag).
    pub fn tile_pass_cycle_accurate(
        &self,
        activations: &Mat,
        weights: &InterleavedTile,
    ) -> Result<TilePass> {
        let res = simulate_adip_tile(activations, weights, self.pe_cfg, self.cfg.mac_stages)?;
        Ok(TilePass {
            outputs: res.outputs,
            latency_cycles: res.cycles,
            steady_cycles: self.steady_tile_cycles(weights.mode),
        })
    }
}

impl SystolicArray for AdipArray {
    fn architecture(&self) -> Architecture {
        Architecture::Adip
    }

    fn config(&self) -> &ArchConfig {
        &self.cfg
    }

    fn supports(&self, _mode: PrecisionMode) -> bool {
        true
    }

    /// Paper Eq. (2): `N·ceil((1/M)(OW₁·OW₂/MW²)) + N + S + E − 2`.
    fn tile_latency(&self, mode: PrecisionMode) -> u64 {
        let n = self.cfg.n as u64;
        n * self.pe_cfg.mode_latency(mode) + n + self.cfg.mac_stages
            + self.unit.pipeline_stages(mode)
            - 2
    }

    /// Steady-state initiation interval: the array accepts a new
    /// stationary-tile pass every `N × Latency_PE` cycles (fill/drain and
    /// the column-unit stages overlap with the next pass).
    fn steady_tile_cycles(&self, mode: PrecisionMode) -> u64 {
        self.cfg.n as u64 * self.pe_cfg.mode_latency(mode)
    }

    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass> {
        let n = self.cfg.n;
        ensure!(
            activations.rows() == n && activations.cols() == n,
            "activation tile {}x{} != array {n}x{n}",
            activations.rows(),
            activations.cols()
        );
        ensure!(
            weights.packed.rows() == n && weights.packed.cols() == n,
            "weight tile shape mismatch"
        );
        if self.cfg.backend == super::Backend::CycleAccurate {
            return self.tile_pass_cycle_accurate(activations, weights);
        }
        // Fast functional path: mathematically identical to the PE +
        // column-unit + diagonal-dataflow pipeline (cross-checked against
        // the cycle simulator in tests and by `tile_pass_cycle_accurate`).
        // §Perf iteration 5: reuse the source tiles retained at pack time
        // (the stationary tile is reused across all activation passes of a
        // group; re-extracting subword fields per pass cost ~20%).
        let computed;
        let sources: &[Mat] = if weights.sources.len() == weights.k {
            &weights.sources
        } else {
            computed = deinterleave_tile(weights);
            &computed
        };
        let outputs = sources.iter().map(|w| activations.matmul(w)).collect();
        Ok(TilePass {
            outputs,
            latency_cycles: self.tile_latency(weights.mode),
            steady_cycles: self.steady_tile_cycles(weights.mode),
        })
    }

    /// `2 · k · N²` ops per cycle at the selected design point (the Eq. (3)
    /// numerator per steady-state cycle).
    fn peak_ops_per_cycle(&self, mode: PrecisionMode) -> u64 {
        let n = self.cfg.n as u64;
        2 * mode.interleave_factor() as u64 * n * n / self.pe_cfg.mode_latency(mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::interleave_tiles;
    use crate::testutil::{check, Rng};

    fn arr(n: usize) -> AdipArray {
        AdipArray::new(ArchConfig::with_n(n))
    }

    #[test]
    fn eq2_latencies_at_design_point() {
        // N=32, M=16, S=1: E = 3/2/0 for 8b/4b/2b.
        let a = arr(32);
        assert_eq!(a.tile_latency(PrecisionMode::W8), 32 + 32 + 1 + 3 - 2);
        assert_eq!(a.tile_latency(PrecisionMode::W4), 32 + 32 + 1 + 2 - 2);
        assert_eq!(a.tile_latency(PrecisionMode::W2), 32 + 32 + 1 - 2);
        assert_eq!(a.steady_tile_cycles(PrecisionMode::W8), 32);
    }

    #[test]
    fn peak_ops_scale_with_mode() {
        let a = arr(64);
        assert_eq!(a.peak_ops_per_cycle(PrecisionMode::W8), 2 * 64 * 64);
        assert_eq!(a.peak_ops_per_cycle(PrecisionMode::W4), 4 * 64 * 64);
        assert_eq!(a.peak_ops_per_cycle(PrecisionMode::W2), 8 * 64 * 64);
        // 64×64 @ 1 GHz ⇒ 8.192 / 16.384 / 32.768 TOPS (paper abstract).
        assert_eq!(a.peak_ops_per_cycle(PrecisionMode::W8) * 1_000_000_000, 8_192_000_000_000);
    }

    #[test]
    fn fast_path_equals_cycle_simulator() {
        check(
            "adip-fast-vs-cycle",
            301,
            8,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let k = 1 + rng.below(mode.interleave_factor());
                let n = 2 + rng.below(7);
                let a = Mat::random(rng, n, n, 8);
                let tiles: Vec<Mat> =
                    (0..k).map(|_| Mat::random(rng, n, n, mode.weight_bits())).collect();
                let refs: Vec<&Mat> = tiles.iter().collect();
                let it = interleave_tiles(&refs, mode).unwrap();
                (n, a, it)
            },
            |(n, a, it)| {
                let array = arr(*n);
                let fast = array.tile_pass(a, it).map_err(|e| e.to_string())?;
                let slow = array.tile_pass_cycle_accurate(a, it).map_err(|e| e.to_string())?;
                if fast.outputs != slow.outputs {
                    return Err("functional path != cycle simulator".into());
                }
                if fast.latency_cycles != slow.latency_cycles {
                    return Err(format!(
                        "latency mismatch: eq2 {} vs simulated {}",
                        fast.latency_cycles, slow.latency_cycles
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn rejects_wrong_tile_shapes() {
        let array = arr(8);
        let a = Mat::zeros(4, 4);
        let w = Mat::zeros(4, 4);
        let it = interleave_tiles(&[&w], PrecisionMode::W8).unwrap();
        assert!(array.tile_pass(&a, &it).is_err());
    }

    #[test]
    fn cycle_accurate_backend_routes_tile_pass_through_register_sim() {
        let mut rng = Rng::seeded(303);
        let n = 8;
        let golden = AdipArray::new(ArchConfig::cycle_accurate(n));
        let fast = arr(n);
        let a = Mat::random(&mut rng, n, n, 8);
        let tiles: Vec<Mat> = (0..2).map(|_| Mat::random(&mut rng, n, n, 4)).collect();
        let refs: Vec<&Mat> = tiles.iter().collect();
        let it = interleave_tiles(&refs, PrecisionMode::W4).unwrap();
        let g = golden.tile_pass(&a, &it).unwrap();
        let f = fast.tile_pass(&a, &it).unwrap();
        assert_eq!(g.outputs, f.outputs);
        assert_eq!(g.latency_cycles, f.latency_cycles);
        assert_eq!(g.steady_cycles, f.steady_cycles);
    }
}
