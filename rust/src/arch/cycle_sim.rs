//! Register-level cycle simulation of the three dataflows.
//!
//! These simulators move values through explicit per-cycle registers —
//! input registers, psum buses, stationary weight registers — exactly as
//! the RTL would, and are the ground truth the fast functional tile path
//! and the analytical latency models are validated against:
//!
//! * **DiP/ADiP** ([`simulate_adip_tile`], [`simulate_dip_tile`]):
//!   activations enter the top row *unskewed* (no input FIFOs), move
//!   diagonally (down one row, **left one column**, wrapping at the
//!   boundary: the leftmost column feeds the rightmost column of the next
//!   row — Fig. 3(c)); weights are stationary and column-rotation
//!   *permuted* ([`crate::dataflow::permute_dip`]); psums travel down the
//!   columns. Output row `w` of every result tile leaves the bottom of the
//!   array — already de-skewed, eliminating output FIFOs.
//! * **WS** ([`simulate_ws_tile`]): the conventional weight-stationary
//!   baseline — activations enter from the left edge *skewed by their row
//!   index* (the input sync FIFOs), move right; psums move down; outputs
//!   drain skewed (the output sync FIFOs).
//!
//! Measured latencies reproduce Eq. (2) (and the WS/DiP equivalents in
//! [`crate::analytical`]) cycle-for-cycle — asserted in the tests.

use anyhow::{ensure, Result};

use super::column_unit::SharedColumnUnit;
use super::pe::{DipPe, PeConfig, ReconfigurablePe};
use crate::dataflow::{permute_dip, InterleavedTile, Mat};

/// Outputs + measured cycle count of one simulated tile pass.
#[derive(Debug, Clone)]
pub struct CycleSimResult {
    /// One `N×N` output tile per interleaved weight matrix.
    pub outputs: Vec<Mat>,
    /// Cycles from the first activation row entering to the last result
    /// leaving (including MAC pipeline and column-unit stages).
    pub cycles: u64,
}

/// Simulate one ADiP stationary-tile pass at register level.
///
/// `activations` is the `N×N` int8 tile (row `w` enters at cycle `w`);
/// `weights` is the *unpermuted* interleaved tile — the simulator applies
/// the DiP permutation while loading, as the preprocessing step would.
/// `mac_stages` is `S` of Eq. (2) (modeled as a constant pipeline delay).
pub fn simulate_adip_tile(
    activations: &Mat,
    weights: &InterleavedTile,
    pe_cfg: PeConfig,
    mac_stages: u64,
) -> Result<CycleSimResult> {
    let n = activations.rows();
    ensure!(n == activations.cols(), "activation tile must be square");
    ensure!(
        weights.packed.rows() == n && weights.packed.cols() == n,
        "weight tile {}x{} != activation {n}x{n}",
        weights.packed.rows(),
        weights.packed.cols()
    );
    let mode = weights.mode;
    ensure!(
        pe_cfg.mode_latency(mode) == 1,
        "cycle simulator models the selected design point (PE latency 1); \
         M={} gives latency {}",
        pe_cfg.multipliers,
        pe_cfg.mode_latency(mode)
    );

    // Load stationary weights (permuted, as the dataflow preprocessing does).
    let permuted = permute_dip(&weights.packed);
    let mut pes: Vec<ReconfigurablePe> = Vec::with_capacity(n * n);
    for r in 0..n {
        for c in 0..n {
            let mut pe = ReconfigurablePe::new(pe_cfg, mode);
            pe.load_weight(permuted.get(r, c) as u8, mode);
            pes.push(pe);
        }
    }
    let unit = SharedColumnUnit;

    // Registers. `in_reg[r][c]`: activation being used by PE (r,c) this
    // cycle. `psum_reg[r][c]`: 4-lane psum leaving row r at column c.
    let mut in_reg = vec![0i32; n * n];
    let mut in_valid = vec![false; n * n];
    let mut psum_reg = vec![[0i64; 4]; n * n];

    let k = weights.k;
    let mut outputs = vec![Mat::zeros(n, n); k];
    let total_beats = 2 * n - 1;

    // §Perf iteration 3: double-buffered register files allocated once
    // (no per-beat Vec allocation) and swapped each beat.
    let mut next_in = vec![0i32; n * n];
    let mut next_valid = vec![false; n * n];
    let mut next_psum = vec![[0i64; 4]; n * n];

    for t in 0..total_beats {
        // Next-state input registers: diagonal movement (down-left, wrap).
        for c in 0..n {
            if t < n {
                next_in[c] = activations.get(t, c);
                next_valid[c] = true;
            } else {
                next_valid[c] = false;
            }
        }
        for r in 1..n {
            for c in 0..n {
                let src = (r - 1) * n + (c + 1) % n;
                next_in[r * n + c] = in_reg[src];
                next_valid[r * n + c] = in_valid[src];
            }
        }

        // Next-state psum registers: each PE adds its contribution to the
        // psum arriving from the row above (wavefront-consistent: both were
        // registered last cycle).
        for r in 0..n {
            for c in 0..n {
                let idx = r * n + c;
                let above = if r > 0 { psum_reg[(r - 1) * n + c] } else { [0i64; 4] };
                let contrib = if next_valid[idx] {
                    pes[idx].compute(next_in[idx])
                } else {
                    [0i64; 4]
                };
                for lane in 0..4 {
                    next_psum[idx][lane] = above[lane] + contrib[lane];
                }
            }
        }

        std::mem::swap(&mut in_reg, &mut next_in);
        std::mem::swap(&mut in_valid, &mut next_valid);
        std::mem::swap(&mut psum_reg, &mut next_psum);

        // Bottom-row psums completed wavefront `w = t - (n-1)` this cycle:
        // feed the shared column units.
        if t + 1 >= n {
            let w = t + 1 - n;
            if w < n {
                for c in 0..n {
                    let outs = unit.combine(mode, psum_reg[(n - 1) * n + c]);
                    for (s, &v) in outs.iter().enumerate().take(k) {
                        outputs[s].set(
                            w,
                            c,
                            i32::try_from(v).expect("psum overflow beyond i32"),
                        );
                    }
                }
            }
        }
    }

    // Constant pipeline delays: extra MAC stages + the shared column unit.
    let cycles = total_beats as u64 + (mac_stages - 1) + unit.pipeline_stages(mode);
    Ok(CycleSimResult { outputs, cycles })
}

/// Simulate one DiP stationary-tile pass (INT8 PEs, single psum lane).
pub fn simulate_dip_tile(
    activations: &Mat,
    weights: &Mat,
    mac_stages: u64,
) -> Result<CycleSimResult> {
    let n = activations.rows();
    ensure!(n == activations.cols(), "activation tile must be square");
    ensure!(weights.rows() == n && weights.cols() == n, "weight tile shape mismatch");

    let permuted = permute_dip(weights);
    let mut pes: Vec<DipPe> = vec![DipPe::default(); n * n];
    for r in 0..n {
        for c in 0..n {
            pes[r * n + c].load_weight(permuted.get(r, c));
        }
    }

    let mut in_reg = vec![0i32; n * n];
    let mut in_valid = vec![false; n * n];
    let mut psum_reg = vec![0i64; n * n];
    let mut output = Mat::zeros(n, n);
    let total_beats = 2 * n - 1;

    for t in 0..total_beats {
        let mut next_in = vec![0i32; n * n];
        let mut next_valid = vec![false; n * n];
        for c in 0..n {
            if t < n {
                next_in[c] = activations.get(t, c);
                next_valid[c] = true;
            }
        }
        for r in 1..n {
            for c in 0..n {
                let src = (r - 1) * n + (c + 1) % n;
                next_in[r * n + c] = in_reg[src];
                next_valid[r * n + c] = in_valid[src];
            }
        }
        let mut next_psum = vec![0i64; n * n];
        for r in 0..n {
            for c in 0..n {
                let idx = r * n + c;
                let above = if r > 0 { psum_reg[(r - 1) * n + c] } else { 0 };
                let contrib =
                    if next_valid[idx] { pes[idx].compute(next_in[idx]) } else { 0 };
                next_psum[idx] = above + contrib;
            }
        }
        in_reg = next_in;
        in_valid = next_valid;
        psum_reg = next_psum;

        if t + 1 >= n {
            let w = t + 1 - n;
            if w < n {
                for c in 0..n {
                    output.set(
                        w,
                        c,
                        i32::try_from(psum_reg[(n - 1) * n + c]).expect("psum overflow"),
                    );
                }
            }
        }
    }

    let cycles = total_beats as u64 + (mac_stages - 1);
    Ok(CycleSimResult { outputs: vec![output], cycles })
}

/// Simulate one conventional weight-stationary tile pass, including the
/// input-skew and output-deskew behaviour the sync FIFOs provide.
pub fn simulate_ws_tile(
    activations: &Mat,
    weights: &Mat,
    mac_stages: u64,
) -> Result<CycleSimResult> {
    let n = activations.rows();
    ensure!(n == activations.cols(), "activation tile must be square");
    ensure!(weights.rows() == n && weights.cols() == n, "weight tile shape mismatch");

    // Weights stationary, unpermuted: PE (r, c) holds W[r][c].
    let mut in_reg = vec![0i32; n * n];
    let mut in_valid = vec![false; n * n];
    let mut psum_reg = vec![0i64; n * n];
    let mut output = Mat::zeros(n, n);
    // A[i][r] enters row r (left edge) at cycle i + r (input FIFO skew);
    // C[i][c] leaves the bottom of column c at cycle i + c + n - 1.
    let total_beats = 3 * n - 2;

    for t in 0..total_beats {
        let mut next_in = vec![0i32; n * n];
        let mut next_valid = vec![false; n * n];
        for r in 0..n {
            // left-edge injection, skewed by row index
            if t >= r && t - r < n {
                next_in[r * n] = activations.get(t - r, r);
                next_valid[r * n] = true;
            }
            for c in 1..n {
                next_in[r * n + c] = in_reg[r * n + c - 1];
                next_valid[r * n + c] = in_valid[r * n + c - 1];
            }
        }
        let mut next_psum = vec![0i64; n * n];
        for r in 0..n {
            for c in 0..n {
                let idx = r * n + c;
                let above = if r > 0 { psum_reg[(r - 1) * n + c] } else { 0 };
                let contrib = if next_valid[idx] {
                    next_in[idx] as i64 * weights.get(r, c) as i64
                } else {
                    0
                };
                next_psum[idx] = above + contrib;
            }
        }
        in_reg = next_in;
        in_valid = next_valid;
        psum_reg = next_psum;

        // C[i][c] completes at the bottom of column c at cycle i + c + n - 1
        // (0-based beat t = i + c + n - 1).
        if t + 1 >= n {
            for c in 0..n {
                let stamp = t + 1 - n; // i + c
                if stamp >= c && stamp - c < n {
                    let i = stamp - c;
                    output.set(
                        i,
                        c,
                        i32::try_from(psum_reg[(n - 1) * n + c]).expect("psum overflow"),
                    );
                }
            }
        }
    }

    let cycles = total_beats as u64 + (mac_stages - 1);
    Ok(CycleSimResult { outputs: vec![output], cycles })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::interleave_tiles;
    use crate::testutil::{check, Rng};
    use crate::quant::PrecisionMode;

    fn random_interleaved(
        rng: &mut Rng,
        n: usize,
        mode: PrecisionMode,
        k: usize,
    ) -> (Vec<Mat>, InterleavedTile) {
        let tiles: Vec<Mat> = (0..k).map(|_| Mat::random(rng, n, n, mode.weight_bits())).collect();
        let refs: Vec<&Mat> = tiles.iter().collect();
        let it = interleave_tiles(&refs, mode).unwrap();
        (tiles, it)
    }

    #[test]
    fn adip_8x8_matches_reference_gemm() {
        let mut rng = Rng::seeded(201);
        let n = 8;
        let a = Mat::random(&mut rng, n, n, 8);
        let (tiles, it) = random_interleaved(&mut rng, n, PrecisionMode::W8, 1);
        let res = simulate_adip_tile(&a, &it, PeConfig::default(), 1).unwrap();
        assert_eq!(res.outputs.len(), 1);
        assert_eq!(res.outputs[0], a.matmul(&tiles[0]));
    }

    #[test]
    fn adip_multi_matrix_modes_match_reference() {
        check(
            "cycle-sim-adip",
            203,
            12,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let k = 1 + rng.below(mode.interleave_factor());
                let n = 2 + rng.below(7);
                let a = Mat::random(rng, n, n, 8);
                let (tiles, it) = random_interleaved(rng, n, mode, k);
                (a, tiles, it)
            },
            |(a, tiles, it)| {
                let res = simulate_adip_tile(a, it, PeConfig::default(), 1)
                    .map_err(|e| e.to_string())?;
                for (s, t) in tiles.iter().enumerate() {
                    if res.outputs[s] != a.matmul(t) {
                        return Err(format!("source {s} mismatch"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn adip_measured_cycles_match_eq2() {
        // Eq. (2) with PE latency 1: N + N + S + E − 2.
        let mut rng = Rng::seeded(205);
        for n in [2usize, 4, 8, 16] {
            for mode in PrecisionMode::ALL {
                let a = Mat::random(&mut rng, n, n, 8);
                let (_, it) = random_interleaved(&mut rng, n, mode, mode.interleave_factor());
                let s = 1u64;
                let res = simulate_adip_tile(&a, &it, PeConfig::default(), s).unwrap();
                let e = SharedColumnUnit.pipeline_stages(mode);
                let eq2 = n as u64 + n as u64 + s + e - 2;
                assert_eq!(res.cycles, eq2, "n={n} mode={mode}");
            }
        }
    }

    #[test]
    fn dip_matches_reference_and_latency() {
        let mut rng = Rng::seeded(207);
        for n in [3usize, 8, 16] {
            let a = Mat::random(&mut rng, n, n, 8);
            let w = Mat::random(&mut rng, n, n, 8);
            let res = simulate_dip_tile(&a, &w, 1).unwrap();
            assert_eq!(res.outputs[0], a.matmul(&w), "n={n}");
            assert_eq!(res.cycles, 2 * n as u64 - 1, "n={n}");
        }
    }

    #[test]
    fn ws_matches_reference_and_latency() {
        let mut rng = Rng::seeded(209);
        for n in [2usize, 5, 8, 16] {
            let a = Mat::random(&mut rng, n, n, 8);
            let w = Mat::random(&mut rng, n, n, 8);
            let res = simulate_ws_tile(&a, &w, 1).unwrap();
            assert_eq!(res.outputs[0], a.matmul(&w), "n={n}");
            assert_eq!(res.cycles, 3 * n as u64 - 2, "n={n}");
        }
    }

    #[test]
    fn ws_needs_more_cycles_than_dip() {
        // The FIFO-less diagonal dataflow saves N−1 cycles per tile.
        let mut rng = Rng::seeded(211);
        let n = 16;
        let a = Mat::random(&mut rng, n, n, 8);
        let w = Mat::random(&mut rng, n, n, 8);
        let dip = simulate_dip_tile(&a, &w, 1).unwrap();
        let ws = simulate_ws_tile(&a, &w, 1).unwrap();
        assert_eq!(ws.cycles - dip.cycles, n as u64 - 1);
        assert_eq!(dip.outputs[0], ws.outputs[0]);
    }

    #[test]
    fn rejects_bad_shapes_and_slow_pe() {
        let a = Mat::zeros(4, 4);
        let (_, it) = random_interleaved(&mut Rng::seeded(1), 4, PrecisionMode::W8, 1);
        let bad = Mat::zeros(4, 5);
        assert!(simulate_dip_tile(&bad, &a, 1).is_err());
        let slow = PeConfig { multipliers: 2, mult_width: 2 };
        assert!(simulate_adip_tile(&a, &it, slow, 1).is_err());
    }
}
