//! Conventional weight-stationary (WS) baseline array.
//!
//! The TPU-style WS array the paper (and the DiP work [34]) compares
//! against: stationary INT8 weights, activations entering from the left
//! edge through **input skew FIFOs**, psums accumulated down the columns,
//! outputs drained through **output de-skew FIFOs**. The FIFOs cost area,
//! power and latency — the `N−1` skew and `N−1` de-skew cycles around each
//! stationary tile, plus a drain between back-to-back stationary tiles
//! (the skewed output wavefront occupies the array while the next tile's
//! weights load).

use anyhow::{ensure, Result};

use super::array::{ArchConfig, Architecture, SystolicArray, TilePass};
use super::cycle_sim::simulate_ws_tile;
use crate::dataflow::{InterleavedTile, Mat};
use crate::quant::PrecisionMode;

/// `N×N` INT8 weight-stationary array with sync FIFOs.
#[derive(Debug, Clone)]
pub struct WsArray {
    cfg: ArchConfig,
}

impl WsArray {
    /// Build a WS array.
    pub fn new(cfg: ArchConfig) -> WsArray {
        WsArray { cfg }
    }

    /// Register-level simulation of a tile pass, including the skewed
    /// input/output movement (validation path).
    pub fn tile_pass_cycle_accurate(&self, activations: &Mat, weights: &Mat) -> Result<TilePass> {
        let res = simulate_ws_tile(activations, weights, self.cfg.mac_stages)?;
        Ok(TilePass {
            outputs: res.outputs,
            latency_cycles: res.cycles,
            steady_cycles: self.steady_tile_cycles(PrecisionMode::W8),
        })
    }

    /// Depth of input + output synchronization FIFO registers the array
    /// needs (`Σ r + Σ (N−1−c)` = `N(N−1)` total stages) — the hardware
    /// ADiP/DiP eliminate. Consumed by the power/area model.
    pub fn sync_fifo_registers(&self) -> usize {
        self.cfg.n * (self.cfg.n - 1)
    }
}

impl SystolicArray for WsArray {
    fn architecture(&self) -> Architecture {
        Architecture::Ws
    }

    fn config(&self) -> &ArchConfig {
        &self.cfg
    }

    /// WS executes everything as 8b×8b.
    fn supports(&self, mode: PrecisionMode) -> bool {
        mode == PrecisionMode::W8
    }

    /// Single-tile latency `3N + S − 3`: input skew fill (N−1) + N
    /// streaming rows + output de-skew drain (N−1), plus extra MAC stages.
    /// Matches the register-level simulator cycle-for-cycle.
    fn tile_latency(&self, _mode: PrecisionMode) -> u64 {
        3 * self.cfg.n as u64 + self.cfg.mac_stages - 3
    }

    /// Between stationary tiles the skewed drain cannot overlap the next
    /// tile's skewed fill: `2N − 1` cycles per pass in steady state.
    fn steady_tile_cycles(&self, _mode: PrecisionMode) -> u64 {
        2 * self.cfg.n as u64 - 1
    }

    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass> {
        let n = self.cfg.n;
        ensure!(
            weights.mode == PrecisionMode::W8 && weights.k == 1,
            "WS holds a single 8-bit weight matrix"
        );
        ensure!(
            activations.rows() == n && activations.cols() == n,
            "activation tile {}x{} != array {n}x{n}",
            activations.rows(),
            activations.cols()
        );
        let w = Mat::from_fn(n, n, |r, c| (weights.packed.get(r, c) as u8) as i8 as i32);
        if self.cfg.backend == super::Backend::CycleAccurate {
            return self.tile_pass_cycle_accurate(activations, &w);
        }
        Ok(TilePass {
            outputs: vec![activations.matmul(&w)],
            latency_cycles: self.tile_latency(PrecisionMode::W8),
            steady_cycles: self.steady_tile_cycles(PrecisionMode::W8),
        })
    }

    fn peak_ops_per_cycle(&self, _mode: PrecisionMode) -> u64 {
        let n = self.cfg.n as u64;
        2 * n * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::interleave_tiles;
    use crate::testutil::Rng;

    #[test]
    fn latency_and_fifo_counts() {
        let w = WsArray::new(ArchConfig::with_n(32));
        assert_eq!(w.tile_latency(PrecisionMode::W8), 3 * 32 + 1 - 3);
        assert_eq!(w.steady_tile_cycles(PrecisionMode::W8), 63);
        assert_eq!(w.sync_fifo_registers(), 32 * 31);
    }

    #[test]
    fn dip_single_tile_advantage_is_1p49x_at_32() {
        // The DiP paper's headline: WS(3N−2) / DiP(2N−1) ≈ 1.49 at N = 32.
        let ws = WsArray::new(ArchConfig::with_n(32));
        let dip = super::super::DipArray::new(ArchConfig::with_n(32));
        let ratio =
            ws.tile_latency(PrecisionMode::W8) as f64 / dip.tile_latency(PrecisionMode::W8) as f64;
        assert!((ratio - 1.49).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn functional_matches_cycle_sim() {
        let mut rng = Rng::seeded(501);
        let n = 8;
        let ws = WsArray::new(ArchConfig::with_n(n));
        let a = Mat::random(&mut rng, n, n, 8);
        let w = Mat::random(&mut rng, n, n, 8);
        let it = interleave_tiles(&[&w], PrecisionMode::W8).unwrap();
        let fast = ws.tile_pass(&a, &it).unwrap();
        let slow = ws.tile_pass_cycle_accurate(&a, &w).unwrap();
        assert_eq!(fast.outputs, slow.outputs);
        assert_eq!(fast.latency_cycles, slow.latency_cycles);
    }

    #[test]
    fn only_w8_supported() {
        let ws = WsArray::new(ArchConfig::with_n(4));
        assert!(ws.supports(PrecisionMode::W8));
        assert!(!ws.supports(PrecisionMode::W2));
    }
}
