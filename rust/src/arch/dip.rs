//! The DiP baseline array model [34]: diagonal dataflow, INT8 PEs.
//!
//! DiP is ADiP's starting point — same FIFO-less diagonal input movement
//! and stationary weights, but with conventional INT8 MAC PEs: every mode
//! runs at 8b×8b cost and only one weight matrix can be stationary at a
//! time (no interleaving, no shared shifters needed).

use anyhow::{ensure, Result};

use super::array::{ArchConfig, Architecture, SystolicArray, TilePass};
use super::cycle_sim::simulate_dip_tile;
use crate::dataflow::{InterleavedTile, Mat};
use crate::quant::PrecisionMode;

/// `N×N` INT8 PEs with the DiP dataflow.
#[derive(Debug, Clone)]
pub struct DipArray {
    cfg: ArchConfig,
}

impl DipArray {
    /// Build a DiP array.
    pub fn new(cfg: ArchConfig) -> DipArray {
        DipArray { cfg }
    }

    /// Register-level simulation of a tile pass (validation path).
    pub fn tile_pass_cycle_accurate(&self, activations: &Mat, weights: &Mat) -> Result<TilePass> {
        let res = simulate_dip_tile(activations, weights, self.cfg.mac_stages)?;
        Ok(TilePass {
            outputs: res.outputs,
            latency_cycles: res.cycles,
            steady_cycles: self.steady_tile_cycles(PrecisionMode::W8),
        })
    }
}

impl SystolicArray for DipArray {
    fn architecture(&self) -> Architecture {
        Architecture::Dip
    }

    fn config(&self) -> &ArchConfig {
        &self.cfg
    }

    /// DiP executes everything as 8b×8b; narrower weights gain nothing.
    fn supports(&self, mode: PrecisionMode) -> bool {
        mode == PrecisionMode::W8
    }

    /// DiP-paper single-tile latency: `2N + S − 2` (N compute rows + N
    /// streaming rows, no external shift/add unit).
    fn tile_latency(&self, _mode: PrecisionMode) -> u64 {
        2 * self.cfg.n as u64 + self.cfg.mac_stages - 2
    }

    /// One new tile pass every `N` cycles in steady state.
    fn steady_tile_cycles(&self, _mode: PrecisionMode) -> u64 {
        self.cfg.n as u64
    }

    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass> {
        let n = self.cfg.n;
        ensure!(
            weights.mode == PrecisionMode::W8 && weights.k == 1,
            "DiP holds a single 8-bit weight matrix (got {} × {})",
            weights.k,
            weights.mode
        );
        ensure!(
            activations.rows() == n && activations.cols() == n,
            "activation tile {}x{} != array {n}x{n}",
            activations.rows(),
            activations.cols()
        );
        ensure!(
            weights.packed.rows() == n && weights.packed.cols() == n,
            "weight tile shape mismatch"
        );
        // In W8/k=1 the packed tile stores the raw bytes of the weight
        // matrix; reinterpret as signed.
        let w = Mat::from_fn(n, n, |r, c| (weights.packed.get(r, c) as u8) as i8 as i32);
        if self.cfg.backend == super::Backend::CycleAccurate {
            return self.tile_pass_cycle_accurate(activations, &w);
        }
        Ok(TilePass {
            outputs: vec![activations.matmul(&w)],
            latency_cycles: self.tile_latency(PrecisionMode::W8),
            steady_cycles: self.steady_tile_cycles(PrecisionMode::W8),
        })
    }

    fn peak_ops_per_cycle(&self, _mode: PrecisionMode) -> u64 {
        let n = self.cfg.n as u64;
        2 * n * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::interleave_tiles;
    use crate::testutil::Rng;

    #[test]
    fn latencies() {
        let d = DipArray::new(ArchConfig::with_n(32));
        assert_eq!(d.tile_latency(PrecisionMode::W8), 63);
        assert_eq!(d.steady_tile_cycles(PrecisionMode::W8), 32);
        // 64×64 DiP @ 1 GHz = 8.192 TOPS (Table II).
        let big = DipArray::new(ArchConfig::with_n(64));
        assert_eq!(big.peak_ops_per_cycle(PrecisionMode::W8), 8192);
    }

    #[test]
    fn functional_matches_cycle_sim() {
        let mut rng = Rng::seeded(401);
        let n = 8;
        let d = DipArray::new(ArchConfig::with_n(n));
        let a = Mat::random(&mut rng, n, n, 8);
        let w = Mat::random(&mut rng, n, n, 8);
        let it = interleave_tiles(&[&w], PrecisionMode::W8).unwrap();
        let fast = d.tile_pass(&a, &it).unwrap();
        let slow = d.tile_pass_cycle_accurate(&a, &w).unwrap();
        assert_eq!(fast.outputs, slow.outputs);
        assert_eq!(fast.latency_cycles, slow.latency_cycles);
        assert_eq!(fast.outputs[0], a.matmul(&w));
    }

    #[test]
    fn rejects_multi_matrix_tiles() {
        let n = 4;
        let d = DipArray::new(ArchConfig::with_n(n));
        let a = Mat::zeros(n, n);
        let w0 = Mat::zeros(n, n);
        let w1 = Mat::zeros(n, n);
        let it = interleave_tiles(&[&w0, &w1], PrecisionMode::W4).unwrap();
        assert!(d.tile_pass(&a, &it).is_err());
        assert!(!d.supports(PrecisionMode::W4));
        assert!(d.supports(PrecisionMode::W8));
    }
}
