//! Shared shifter/accumulator unit — one per PE column (paper Fig. 3(b)).
//!
//! ADiP hoists the shift/add recombination of weight-subword partial
//! products out of every PE into a single reconfigurable unit per column,
//! saving the area/power of per-PE shifters. The unit receives the four
//! psum-bus values leaving the last PE row and produces the final outputs
//! for the column's precision mode:
//!
//! * **8b×2b** — bypass: each of the four psums *is* a final result
//!   (output taken “directly from the last PE output”).
//! * **8b×4b** — first accumulator stage: `out_s = p_{2s} + (p_{2s+1} ≪ 2)`.
//! * **8b×8b** — second accumulator stage on top of the first:
//!   `out = stage1_0 + (stage1_1 ≪ 4)`.
//!
//! The per-mode pipeline depth (`E` of Eq. (2)) follows the selection
//! point: 0 extra stages for 8b×2b, shifter + stage 1 for 8b×4b, plus
//! stage 2 for 8b×8b.

use crate::quant::PrecisionMode;

/// Reconfigurable shared shifter + two-stage accumulator model.
#[derive(Debug, Clone, Copy, Default)]
pub struct SharedColumnUnit;

impl SharedColumnUnit {
    /// Combine the four psum-bus values into the column's final outputs
    /// (one per interleaved weight matrix). Bit-exact shift-add.
    pub fn combine(&self, mode: PrecisionMode, psums: [i64; 4]) -> Vec<i64> {
        match mode {
            PrecisionMode::W2 => psums.to_vec(),
            PrecisionMode::W4 => {
                // shifter + first accumulator stage
                vec![psums[0] + (psums[1] << 2), psums[2] + (psums[3] << 2)]
            }
            PrecisionMode::W8 => {
                let s1_lo = psums[0] + (psums[1] << 2);
                let s1_hi = psums[2] + (psums[3] << 2);
                // second accumulator stage (weight subwords 2,3 sit 4 bits up)
                vec![s1_lo + (s1_hi << 4)]
            }
        }
    }

    /// Extra pipeline stages the unit adds for a mode — the `E` term of
    /// Eq. (2). Derived from the output-selection point of Fig. 3(b):
    /// shifter (1) + stage 1 (1) + stage 2 (1).
    pub fn pipeline_stages(&self, mode: PrecisionMode) -> u64 {
        match mode {
            PrecisionMode::W2 => 0,
            PrecisionMode::W4 => 2,
            PrecisionMode::W8 => 3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::pe::{PeConfig, ReconfigurablePe};
    use crate::quant::{pack_int2, pack_int4};
    use crate::testutil::{check, Rng};

    #[test]
    fn bypass_for_2bit() {
        let u = SharedColumnUnit;
        assert_eq!(u.combine(PrecisionMode::W2, [1, -2, 3, -4]), vec![1, -2, 3, -4]);
        assert_eq!(u.pipeline_stages(PrecisionMode::W2), 0);
    }

    #[test]
    fn stage1_for_4bit() {
        let u = SharedColumnUnit;
        assert_eq!(u.combine(PrecisionMode::W4, [1, 1, 2, -1]), vec![1 + 4, 2 - 4]);
        assert_eq!(u.pipeline_stages(PrecisionMode::W4), 2);
    }

    #[test]
    fn stage2_for_8bit() {
        let u = SharedColumnUnit;
        // 1 + 2<<2 + 3<<4 + 4<<6 = 1 + 8 + 48 + 256
        assert_eq!(u.combine(PrecisionMode::W8, [1, 2, 3, 4]), vec![313]);
        assert_eq!(u.pipeline_stages(PrecisionMode::W8), 3);
    }

    #[test]
    fn pe_plus_column_unit_equals_products_property() {
        // End-to-end PE → column unit equals the plain integer products for
        // random operands in every mode.
        check(
            "pe+column-unit",
            101,
            200,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let a = rng.int_of_bits(8);
                let ws: Vec<i32> = (0..mode.interleave_factor())
                    .map(|_| rng.int_of_bits(mode.weight_bits()))
                    .collect();
                (mode, a, ws)
            },
            |(mode, a, ws)| {
                let packed = match mode {
                    PrecisionMode::W8 => ws[0] as u8,
                    PrecisionMode::W4 => pack_int4([ws[0], ws[1]]),
                    PrecisionMode::W2 => pack_int2([ws[0], ws[1], ws[2], ws[3]]),
                };
                let mut pe = ReconfigurablePe::new(PeConfig::default(), *mode);
                pe.load_weight(packed, *mode);
                let outs = SharedColumnUnit.combine(*mode, pe.compute(*a));
                for (s, &w) in ws.iter().enumerate() {
                    let want = *a as i64 * w as i64;
                    if outs[s] != want {
                        return Err(format!("matrix {s}: got {} want {want}", outs[s]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sharing_saves_units_vs_per_pe() {
        // Structural sanity: a column of N PEs uses 1 shared unit instead
        // of N — the Fig. 3(b) motivation. (Counted, not simulated.)
        let n = 32;
        let per_pe_units = n * n; // dedicated unit in every PE
        let shared_units = n; // one per column
        assert!(shared_units * n == per_pe_units);
    }
}
