//! Hardware models of the reconfigurable PE, the shared shifter/accumulator
//! column unit, and the three systolic arrays the paper evaluates
//! (ADiP, DiP, conventional weight-stationary).
//!
//! # Execution backends — "cycle sim is golden, functional is served"
//!
//! Every array model runs behind a [`Backend`] selector threaded through
//! [`ArchConfig`], [`build_array`], the co-simulator
//! ([`crate::sim::cosim::CoSim`]), the core scheduler
//! ([`crate::coordinator::CoreScheduler`]) and the coordinator
//! ([`crate::coordinator::CoordinatorConfig`]):
//!
//! * [`Backend::CycleAccurate`] — the **golden reference**. Tile passes
//!   step the register-level simulators in [`cycle_sim`]: explicit
//!   per-cycle registers for the diagonal input movement, stationary
//!   weights, psum buses and shared column units. It demonstrates that the
//!   FIFO-less dataflow really produces the GEMM and that measured cycle
//!   counts equal the paper's Eq. (2). Use it for validation, calibration
//!   runs and whenever a timing model changes.
//! * [`Backend::Functional`] — the **serving path** (default).
//!   [`FunctionalArray`] computes batched shared-input multi-matrix GEMMs
//!   directly in `O(M·K·N)` integer arithmetic (bit-exact with the 2-bit
//!   subword decomposition the PE hardware performs) and reports latency,
//!   energy and memory figures from the analytical models
//!   ([`crate::analytical`]) instead of cycle stepping. *How* that integer
//!   arithmetic runs on the host is a second, orthogonal selector:
//!   [`KernelMode::Naive`] (the reference triple loop, default) or
//!   [`KernelMode::Blocked`] (cache-blocked, B-transposed, multithreaded —
//!   `--kernel=blocked`). The kernel changes host wall-clock only; outputs
//!   are bit-exact across kernels (`i32` accumulation is order-exact) and
//!   all simulated accounting is analytical, hence kernel-independent.
//!
//! **Differential-testing policy:** the functional backend is only allowed
//! to exist because `rust/tests/integration_backends.rs` proves, for
//! randomized shapes × precisions × batch modes × architectures, that its
//! outputs are bit-exact with the cycle simulator and its reported cycles
//! equal [`crate::analytical::estimate_gemm`]. Any change to either
//! backend must keep that suite green; when the two disagree, the cycle
//! simulator wins and the functional model is the bug. The same suite
//! carries a Naive-vs-Blocked kernel axis: the blocked kernel is only
//! allowed to serve because it is bit-exact with the naive triple loop
//! (with identical cycles/passes/memory) across that matrix too. The
//! cluster execution path ([`crate::cluster`]) extends the same policy:
//! `rust/tests/integration_cluster.rs` holds sharded runs (splits × core
//! counts) to bit-exactness and to the closed-form cluster estimates on
//! both backends.
//!
//! Two modeling depths are provided and cross-checked against each other:
//!
//! * **Functional tile path** — [`SystolicArray::tile_pass`]: the exact
//!   integer arithmetic of one stationary-tile pass (bit-exact with the
//!   2-bit subword decomposition the PE hardware performs).
//! * **Register-level cycle simulation** — [`cycle_sim`]: a per-cycle
//!   register-transfer model of the diagonal dataflow (input movement,
//!   stationary weights, psum buses, shared column units).

pub mod adip;
pub mod array;
pub mod column_unit;
pub mod cycle_sim;
pub mod dip;
pub mod functional;
pub mod pe;
pub mod ws;

pub use adip::AdipArray;
pub use array::{
    build_array, ArchConfig, Architecture, Backend, KernelMode, SystolicArray, TilePass,
};
pub use column_unit::SharedColumnUnit;
pub use dip::DipArray;
pub use functional::{FunctionalArray, FunctionalRun};
pub use pe::{DipPe, PeConfig, ReconfigurablePe};
pub use ws::WsArray;
