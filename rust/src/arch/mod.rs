//! Hardware models of the reconfigurable PE, the shared shifter/accumulator
//! column unit, and the three systolic arrays the paper evaluates
//! (ADiP, DiP, conventional weight-stationary).
//!
//! Two modeling depths are provided and cross-checked against each other:
//!
//! * **Functional tile path** — [`SystolicArray::tile_matmul`]: the exact
//!   integer arithmetic of one stationary-tile pass (bit-exact with the
//!   2-bit subword decomposition the PE hardware performs). This is the
//!   hot path used by the coordinator and simulator.
//! * **Register-level cycle simulation** — [`cycle_sim`]: a per-cycle
//!   register-transfer model of the diagonal dataflow (input movement,
//!   stationary weights, psum buses, shared column units). It demonstrates
//!   that the FIFO-less dataflow really produces the GEMM, and that the
//!   measured cycle counts equal the paper's Eq. (2).

pub mod adip;
pub mod array;
pub mod column_unit;
pub mod cycle_sim;
pub mod dip;
pub mod pe;
pub mod ws;

pub use adip::AdipArray;
pub use array::{build_array, ArchConfig, Architecture, SystolicArray, TilePass};
pub use column_unit::SharedColumnUnit;
pub use dip::DipArray;
pub use pe::{DipPe, PeConfig, ReconfigurablePe};
pub use ws::WsArray;
