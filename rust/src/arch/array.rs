//! The common systolic-array abstraction shared by ADiP / DiP / WS.

use anyhow::Result;

use crate::dataflow::{InterleavedTile, Mat};
use crate::quant::PrecisionMode;

/// Which architecture a model instance represents (used by reports,
/// the power model and the simulator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Architecture {
    /// Conventional weight-stationary array with input/output sync FIFOs.
    Ws,
    /// DiP: diagonal-input-movement array, INT8 PEs [34].
    Dip,
    /// ADiP: DiP dataflow + reconfigurable adaptive-precision PEs.
    Adip,
}

impl Architecture {
    /// All architectures, in the paper's comparison order.
    pub const ALL: [Architecture; 3] = [Architecture::Ws, Architecture::Dip, Architecture::Adip];

    /// Display name used in tables.
    pub const fn name(self) -> &'static str {
        match self {
            Architecture::Ws => "WS",
            Architecture::Dip => "DiP",
            Architecture::Adip => "ADiP",
        }
    }
}

impl std::fmt::Display for Architecture {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Execution backend of an array model.
///
/// The two backends are **bit-exact equivalents** — the differential
/// conformance suite (`rust/tests/integration_backends.rs`) asserts output
/// and cycle equality across architectures, precisions and batch modes:
///
/// * [`Backend::CycleAccurate`] — every tile pass steps the register-level
///   simulators in [`super::cycle_sim`]. Slow (per-PE, per-beat); the
///   golden reference for validation and calibration runs.
/// * [`Backend::Functional`] — GEMMs are computed directly in `O(M·K·N)`
///   integer arithmetic while cycles/energy/memory come from the
///   analytical models the cycle simulators validate. The serving path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Backend {
    /// Register-level cycle simulation (golden reference).
    CycleAccurate,
    /// Direct functional GEMM + analytical timing (fast serving path).
    #[default]
    Functional,
}

impl Backend {
    /// Both backends, functional first (the default).
    pub const ALL: [Backend; 2] = [Backend::Functional, Backend::CycleAccurate];

    /// Display name used by the CLI / config files.
    pub const fn name(self) -> &'static str {
        match self {
            Backend::CycleAccurate => "cycle",
            Backend::Functional => "functional",
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Backend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cycle" | "cycle-accurate" | "cycle_accurate" | "golden" => Ok(Backend::CycleAccurate),
            "functional" | "fast" | "func" => Ok(Backend::Functional),
            other => Err(format!(
                "unknown backend {other:?} (expected `functional` or `cycle`)"
            )),
        }
    }
}

/// Arithmetic kernel the functional backend computes GEMMs with.
///
/// The kernel choice affects **host wall-clock only**: accounting
/// (passes / cycles / energy / memory) is analytical and outputs are
/// bit-exact across kernels — `i32` accumulation is exact in any order, so
/// the blocked kernel's reordered loops produce the identical matrix. The
/// cycle-accurate backend ignores this field (it steps PEs, not GEMMs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelMode {
    /// Straightforward triple loop ([`Mat::matmul`]) — the reference
    /// oracle and the differential baseline for the blocked kernel.
    #[default]
    Naive,
    /// Cache-blocked, B-transposed tile loop with `std::thread` row-band
    /// parallelism ([`Mat::matmul_blocked`]) — the serving fast path.
    Blocked,
}

impl KernelMode {
    /// Both kernels, naive (the default / baseline) first.
    pub const ALL: [KernelMode; 2] = [KernelMode::Naive, KernelMode::Blocked];

    /// Display name used by the CLI / config files.
    pub const fn name(self) -> &'static str {
        match self {
            KernelMode::Naive => "naive",
            KernelMode::Blocked => "blocked",
        }
    }
}

impl std::fmt::Display for KernelMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "naive" | "reference" | "simple" => Ok(KernelMode::Naive),
            "blocked" | "block" | "tiled" => Ok(KernelMode::Blocked),
            other => Err(format!(
                "unknown kernel {other:?} (expected `naive` or `blocked`)"
            )),
        }
    }
}

/// Array-level static configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArchConfig {
    /// PEs per row/column (`N`).
    pub n: usize,
    /// 2-bit multipliers per reconfigurable PE (`M`, ADiP only).
    pub multipliers: u32,
    /// MAC pipeline stages (`S` of Eq. (2)).
    pub mac_stages: u64,
    /// Execution backend for tile passes / GEMMs.
    pub backend: Backend,
    /// Arithmetic kernel the functional backend computes with (host speed
    /// only — accounting and outputs are kernel-independent).
    pub kernel: KernelMode,
    /// Worker threads for [`KernelMode::Blocked`]; 0 = one per available
    /// CPU. Ignored by [`KernelMode::Naive`].
    pub kernel_threads: usize,
}

impl Default for ArchConfig {
    fn default() -> Self {
        // The paper's workload evaluation point is 32×32 with the selected
        // 16-multiplier PE and single-stage MACs, served functionally.
        ArchConfig {
            n: 32,
            multipliers: 16,
            mac_stages: 1,
            backend: Backend::Functional,
            kernel: KernelMode::Naive,
            kernel_threads: 0,
        }
    }
}

impl ArchConfig {
    /// Convenience constructor for an `n × n` array.
    pub fn with_n(n: usize) -> ArchConfig {
        ArchConfig { n, ..ArchConfig::default() }
    }

    /// The same configuration with a different backend.
    pub fn with_backend(self, backend: Backend) -> ArchConfig {
        ArchConfig { backend, ..self }
    }

    /// The same configuration with a different functional kernel.
    pub fn with_kernel(self, kernel: KernelMode) -> ArchConfig {
        ArchConfig { kernel, ..self }
    }

    /// The same configuration with a blocked-kernel thread budget
    /// (0 = one thread per available CPU).
    pub fn with_kernel_threads(self, kernel_threads: usize) -> ArchConfig {
        ArchConfig { kernel_threads, ..self }
    }

    /// Convenience constructor for an `n × n` cycle-accurate array.
    pub fn cycle_accurate(n: usize) -> ArchConfig {
        ArchConfig::with_n(n).with_backend(Backend::CycleAccurate)
    }
}

/// Result of one stationary-tile pass: `k` output psum tiles (one per
/// interleaved weight matrix) plus the cycle cost of the pass.
#[derive(Debug, Clone)]
pub struct TilePass {
    /// One `N×N` psum tile per source weight matrix.
    pub outputs: Vec<Mat>,
    /// Total latency of the pass in cycles (fill + stream + drain).
    pub latency_cycles: u64,
    /// Cycles between back-to-back passes in steady state (initiation
    /// interval; fill/drain amortized).
    pub steady_cycles: u64,
}

/// Common interface of the three array models.
pub trait SystolicArray {
    /// Which architecture this is.
    fn architecture(&self) -> Architecture;

    /// Static configuration.
    fn config(&self) -> &ArchConfig;

    /// `N` (PEs per row/column).
    fn n(&self) -> usize {
        self.config().n
    }

    /// Whether the array can execute a mode natively. DiP/WS only run
    /// 8b×8b (narrower weights are zero-extended to 8-bit with no gain).
    fn supports(&self, mode: PrecisionMode) -> bool;

    /// Single-tile latency in cycles for a mode — the paper's Eq. (2) for
    /// ADiP and the DiP-paper equivalents for DiP/WS.
    fn tile_latency(&self, mode: PrecisionMode) -> u64;

    /// Steady-state initiation interval between tile passes (cycles).
    fn steady_tile_cycles(&self, mode: PrecisionMode) -> u64;

    /// Functional + timed execution of one stationary-tile pass:
    /// `activations (N×N, int8)` × `stationary interleaved tile` → `k`
    /// psum tiles. Must be bit-exact with the reference GEMM per source.
    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass>;

    /// Peak throughput in ops/cycle (2 ops per MAC) at a mode.
    fn peak_ops_per_cycle(&self, mode: PrecisionMode) -> u64;

    /// Downcast hook for the whole-GEMM fast path: the functional backend
    /// ([`super::FunctionalArray`]) returns itself so the co-simulator can
    /// skip tile-level scheduling entirely; cycle-level models return
    /// `None` and execute tile by tile.
    fn as_functional(&self) -> Option<&super::FunctionalArray> {
        None
    }
}

impl<T: SystolicArray + ?Sized> SystolicArray for Box<T> {
    fn architecture(&self) -> Architecture {
        (**self).architecture()
    }
    fn config(&self) -> &ArchConfig {
        (**self).config()
    }
    fn supports(&self, mode: PrecisionMode) -> bool {
        (**self).supports(mode)
    }
    fn tile_latency(&self, mode: PrecisionMode) -> u64 {
        (**self).tile_latency(mode)
    }
    fn steady_tile_cycles(&self, mode: PrecisionMode) -> u64 {
        (**self).steady_tile_cycles(mode)
    }
    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass> {
        (**self).tile_pass(activations, weights)
    }
    fn peak_ops_per_cycle(&self, mode: PrecisionMode) -> u64 {
        (**self).peak_ops_per_cycle(mode)
    }
    fn as_functional(&self) -> Option<&super::FunctionalArray> {
        (**self).as_functional()
    }
}

/// Build an array model by architecture tag and backend selector.
///
/// `Backend::Functional` (the [`ArchConfig`] default) returns the
/// whole-GEMM [`super::FunctionalArray`]; `Backend::CycleAccurate` returns
/// the per-architecture model whose tile passes step the register-level
/// simulators.
pub fn build_array(arch: Architecture, cfg: ArchConfig) -> Box<dyn SystolicArray + Send> {
    match cfg.backend {
        Backend::Functional => Box::new(super::FunctionalArray::new(arch, cfg)),
        Backend::CycleAccurate => match arch {
            Architecture::Ws => Box::new(super::WsArray::new(cfg)),
            Architecture::Dip => Box::new(super::DipArray::new(cfg)),
            Architecture::Adip => Box::new(super::AdipArray::new(cfg)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boxed_arrays_dispatch() {
        for backend in Backend::ALL {
            for arch in Architecture::ALL {
                let arr = build_array(arch, ArchConfig::with_n(8).with_backend(backend));
                assert_eq!(arr.architecture(), arch);
                assert_eq!(arr.n(), 8);
                assert!(arr.peak_ops_per_cycle(PrecisionMode::W8) > 0);
                assert_eq!(
                    arr.as_functional().is_some(),
                    backend == Backend::Functional,
                    "{arch} {backend}"
                );
            }
        }
    }

    #[test]
    fn backend_parsing_and_defaults() {
        assert_eq!(Backend::default(), Backend::Functional);
        assert_eq!("cycle".parse::<Backend>().unwrap(), Backend::CycleAccurate);
        assert_eq!("cycle-accurate".parse::<Backend>().unwrap(), Backend::CycleAccurate);
        assert_eq!("functional".parse::<Backend>().unwrap(), Backend::Functional);
        assert!("quantum".parse::<Backend>().is_err());
        for b in Backend::ALL {
            assert_eq!(b.name().parse::<Backend>().unwrap(), b);
            assert_eq!(b.to_string(), b.name());
        }
        assert_eq!(ArchConfig::cycle_accurate(16).backend, Backend::CycleAccurate);
        assert_eq!(ArchConfig::cycle_accurate(16).n, 16);
        assert_eq!(ArchConfig::with_n(16).backend, Backend::Functional);
    }

    #[test]
    fn kernel_parsing_and_builders() {
        assert_eq!(KernelMode::default(), KernelMode::Naive);
        assert_eq!("naive".parse::<KernelMode>().unwrap(), KernelMode::Naive);
        assert_eq!("blocked".parse::<KernelMode>().unwrap(), KernelMode::Blocked);
        assert_eq!("tiled".parse::<KernelMode>().unwrap(), KernelMode::Blocked);
        assert!("warp".parse::<KernelMode>().is_err());
        for k in KernelMode::ALL {
            assert_eq!(k.name().parse::<KernelMode>().unwrap(), k);
            assert_eq!(k.to_string(), k.name());
        }
        let c = ArchConfig::with_n(16).with_kernel(KernelMode::Blocked).with_kernel_threads(4);
        assert_eq!(c.kernel, KernelMode::Blocked);
        assert_eq!(c.kernel_threads, 4);
        assert_eq!(c.n, 16);
        // builders compose without resetting each other
        assert_eq!(c.with_backend(Backend::CycleAccurate).kernel, KernelMode::Blocked);
    }

    #[test]
    fn architecture_names() {
        assert_eq!(Architecture::Ws.name(), "WS");
        assert_eq!(Architecture::Dip.to_string(), "DiP");
        assert_eq!(Architecture::Adip.to_string(), "ADiP");
        assert_eq!(Architecture::ALL.len(), 3);
    }

    #[test]
    fn default_config_is_paper_eval_point() {
        let c = ArchConfig::default();
        assert_eq!(c.n, 32);
        assert_eq!(c.multipliers, 16);
        assert_eq!(c.mac_stages, 1);
        assert_eq!(c.backend, Backend::Functional);
        assert_eq!(c.kernel, KernelMode::Naive);
        assert_eq!(c.kernel_threads, 0);
        assert_eq!(ArchConfig::with_n(64).n, 64);
        assert_eq!(ArchConfig::with_n(64).multipliers, 16);
    }
}
