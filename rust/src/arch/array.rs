//! The common systolic-array abstraction shared by ADiP / DiP / WS.

use anyhow::Result;

use crate::dataflow::{InterleavedTile, Mat};
use crate::quant::PrecisionMode;

/// Which architecture a model instance represents (used by reports,
/// the power model and the simulator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Architecture {
    /// Conventional weight-stationary array with input/output sync FIFOs.
    Ws,
    /// DiP: diagonal-input-movement array, INT8 PEs [34].
    Dip,
    /// ADiP: DiP dataflow + reconfigurable adaptive-precision PEs.
    Adip,
}

impl Architecture {
    /// All architectures, in the paper's comparison order.
    pub const ALL: [Architecture; 3] = [Architecture::Ws, Architecture::Dip, Architecture::Adip];

    /// Display name used in tables.
    pub const fn name(self) -> &'static str {
        match self {
            Architecture::Ws => "WS",
            Architecture::Dip => "DiP",
            Architecture::Adip => "ADiP",
        }
    }
}

impl std::fmt::Display for Architecture {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Array-level static configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArchConfig {
    /// PEs per row/column (`N`).
    pub n: usize,
    /// 2-bit multipliers per reconfigurable PE (`M`, ADiP only).
    pub multipliers: u32,
    /// MAC pipeline stages (`S` of Eq. (2)).
    pub mac_stages: u64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        // The paper's workload evaluation point is 32×32 with the selected
        // 16-multiplier PE and single-stage MACs.
        ArchConfig { n: 32, multipliers: 16, mac_stages: 1 }
    }
}

impl ArchConfig {
    /// Convenience constructor for an `n × n` array.
    pub fn with_n(n: usize) -> ArchConfig {
        ArchConfig { n, ..ArchConfig::default() }
    }
}

/// Result of one stationary-tile pass: `k` output psum tiles (one per
/// interleaved weight matrix) plus the cycle cost of the pass.
#[derive(Debug, Clone)]
pub struct TilePass {
    /// One `N×N` psum tile per source weight matrix.
    pub outputs: Vec<Mat>,
    /// Total latency of the pass in cycles (fill + stream + drain).
    pub latency_cycles: u64,
    /// Cycles between back-to-back passes in steady state (initiation
    /// interval; fill/drain amortized).
    pub steady_cycles: u64,
}

/// Common interface of the three array models.
pub trait SystolicArray {
    /// Which architecture this is.
    fn architecture(&self) -> Architecture;

    /// Static configuration.
    fn config(&self) -> &ArchConfig;

    /// `N` (PEs per row/column).
    fn n(&self) -> usize {
        self.config().n
    }

    /// Whether the array can execute a mode natively. DiP/WS only run
    /// 8b×8b (narrower weights are zero-extended to 8-bit with no gain).
    fn supports(&self, mode: PrecisionMode) -> bool;

    /// Single-tile latency in cycles for a mode — the paper's Eq. (2) for
    /// ADiP and the DiP-paper equivalents for DiP/WS.
    fn tile_latency(&self, mode: PrecisionMode) -> u64;

    /// Steady-state initiation interval between tile passes (cycles).
    fn steady_tile_cycles(&self, mode: PrecisionMode) -> u64;

    /// Functional + timed execution of one stationary-tile pass:
    /// `activations (N×N, int8)` × `stationary interleaved tile` → `k`
    /// psum tiles. Must be bit-exact with the reference GEMM per source.
    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass>;

    /// Peak throughput in ops/cycle (2 ops per MAC) at a mode.
    fn peak_ops_per_cycle(&self, mode: PrecisionMode) -> u64;
}

impl<T: SystolicArray + ?Sized> SystolicArray for Box<T> {
    fn architecture(&self) -> Architecture {
        (**self).architecture()
    }
    fn config(&self) -> &ArchConfig {
        (**self).config()
    }
    fn supports(&self, mode: PrecisionMode) -> bool {
        (**self).supports(mode)
    }
    fn tile_latency(&self, mode: PrecisionMode) -> u64 {
        (**self).tile_latency(mode)
    }
    fn steady_tile_cycles(&self, mode: PrecisionMode) -> u64 {
        (**self).steady_tile_cycles(mode)
    }
    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass> {
        (**self).tile_pass(activations, weights)
    }
    fn peak_ops_per_cycle(&self, mode: PrecisionMode) -> u64 {
        (**self).peak_ops_per_cycle(mode)
    }
}

/// Build an array model by architecture tag.
pub fn build_array(arch: Architecture, cfg: ArchConfig) -> Box<dyn SystolicArray + Send> {
    match arch {
        Architecture::Ws => Box::new(super::WsArray::new(cfg)),
        Architecture::Dip => Box::new(super::DipArray::new(cfg)),
        Architecture::Adip => Box::new(super::AdipArray::new(cfg)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boxed_arrays_dispatch() {
        for arch in Architecture::ALL {
            let arr = build_array(arch, ArchConfig::with_n(8));
            assert_eq!(arr.architecture(), arch);
            assert_eq!(arr.n(), 8);
            assert!(arr.peak_ops_per_cycle(PrecisionMode::W8) > 0);
        }
    }

    #[test]
    fn architecture_names() {
        assert_eq!(Architecture::Ws.name(), "WS");
        assert_eq!(Architecture::Dip.to_string(), "DiP");
        assert_eq!(Architecture::Adip.to_string(), "ADiP");
        assert_eq!(Architecture::ALL.len(), 3);
    }

    #[test]
    fn default_config_is_paper_eval_point() {
        let c = ArchConfig::default();
        assert_eq!(c.n, 32);
        assert_eq!(c.multipliers, 16);
        assert_eq!(c.mac_stages, 1);
        assert_eq!(ArchConfig::with_n(64).n, 64);
        assert_eq!(ArchConfig::with_n(64).multipliers, 16);
    }
}
