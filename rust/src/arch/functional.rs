//! The functional execution backend — whole-GEMM direct computation with
//! analytical timing.
//!
//! [`FunctionalArray`] emulates any of the three architectures
//! (WS / DiP / ADiP) at the *GEMM* level instead of the tile level: outputs
//! are computed in one `O(M·K·N)` integer pass (bit-exact with the PE +
//! shared-column-unit arithmetic — integer matmul over range-validated
//! operands *is* that arithmetic), while passes, cycles and memory traffic
//! come from the same closed forms the register-level simulators validate
//! cycle-for-cycle ([`crate::arch::cycle_sim`]).
//!
//! The struct still implements [`SystolicArray`], so anything scheduling
//! tile-by-tile keeps working; the co-simulator additionally detects it via
//! [`SystolicArray::as_functional`] and short-circuits to
//! [`FunctionalArray::run_gemm_set`], skipping tile extraction and
//! interleave packing entirely. That fast path is what the coordinator
//! serves from; `Backend::CycleAccurate` remains the golden reference
//! (see the differential suite in `rust/tests/integration_backends.rs`).

use anyhow::{bail, ensure, Result};

use super::array::{ArchConfig, Architecture, Backend, KernelMode, SystolicArray, TilePass};
use super::{AdipArray, DipArray, WsArray};
use crate::dataflow::tiling::tile_grid;
use crate::dataflow::{InterleavedTile, Mat};
use crate::quant::{value_range, PrecisionMode};

/// Concrete per-architecture model the functional array delegates latency
/// formulas and the tile-level path to (always with the functional tile
/// path — the cycle simulators are never stepped from here).
#[derive(Debug, Clone)]
enum Inner {
    Ws(WsArray),
    Dip(DipArray),
    Adip(AdipArray),
}

impl Inner {
    fn as_dyn(&self) -> &dyn SystolicArray {
        match self {
            Inner::Ws(a) => a,
            Inner::Dip(a) => a,
            Inner::Adip(a) => a,
        }
    }
}

/// Result of a whole-GEMM (set) functional execution, before the
/// co-simulator layers memory-bank stalls and energy on top.
#[derive(Debug, Clone)]
pub struct FunctionalRun {
    /// One output matrix per weight matrix, exact integer psums.
    pub outputs: Vec<Mat>,
    /// Precision mode actually executed (WS/DiP degrade to 8b×8b).
    pub mode: PrecisionMode,
    /// Stationary-tile passes the tile schedule would execute.
    pub passes: u64,
    /// Stationary (packed weight) tile fetches.
    pub stationary_fetches: u64,
    /// Output tiles written back.
    pub output_tiles: u64,
    /// Total cycles: one pipeline fill/drain + steady streaming
    /// (excluding runtime-interleave bank stalls, which depend on the
    /// memory system and are added by the caller).
    pub cycles: u64,
    /// Steady-state initiation interval used by the schedule.
    pub steady_cycles: u64,
    /// Interleave groups as `(stationary fetches, group size)` pairs —
    /// enough for the caller to replay the multi-bank runtime-interleave
    /// accounting of the tile-level schedule exactly.
    pub interleave_groups: Vec<(u64, usize)>,
}

impl FunctionalRun {
    fn merge(&mut self, other: FunctionalRun) {
        self.outputs.extend(other.outputs);
        self.passes += other.passes;
        self.stationary_fetches += other.stationary_fetches;
        self.output_tiles += other.output_tiles;
        self.cycles += other.cycles;
        self.interleave_groups.extend(other.interleave_groups);
    }
}

/// Functional whole-GEMM model of one architecture (see module docs).
///
/// The slot-packing / pass-count / fill+steady arithmetic below is
/// intentionally a second, independent statement of the schedule that
/// `sim::cosim` executes tile-by-tile and `analytical::estimate_gemm(_set)`
/// states in closed form (`arch` cannot depend on `analytical` — the
/// dependency points the other way). The redundancy is load-bearing:
/// `rust/tests/integration_backends.rs` asserts all three agree on every
/// randomized case, so any schedule change that misses one copy fails CI
/// instead of drifting silently.
#[derive(Debug, Clone)]
pub struct FunctionalArray {
    arch: Architecture,
    cfg: ArchConfig,
    inner: Inner,
}

impl FunctionalArray {
    /// Build a functional model emulating `arch` at configuration `cfg`
    /// (the stored configuration always reports `Backend::Functional`).
    pub fn new(arch: Architecture, cfg: ArchConfig) -> FunctionalArray {
        let cfg = cfg.with_backend(Backend::Functional);
        let inner = match arch {
            Architecture::Ws => Inner::Ws(WsArray::new(cfg)),
            Architecture::Dip => Inner::Dip(DipArray::new(cfg)),
            Architecture::Adip => Inner::Adip(AdipArray::new(cfg)),
        };
        FunctionalArray { arch, cfg, inner }
    }

    /// The mode this architecture actually executes for a request
    /// (WS/DiP degrade everything to 8b×8b).
    pub fn exec_mode(&self, requested: PrecisionMode) -> PrecisionMode {
        if self.supports(requested) {
            requested
        } else {
            PrecisionMode::W8
        }
    }

    /// Validate that every weight entry fits the executed mode — the same
    /// range check `interleave_tiles` performs when packing the stationary
    /// carrier on the tile-level path.
    fn check_weight_range(&self, b: &Mat, mode: PrecisionMode, which: usize) -> Result<()> {
        let w = mode.weight_bits();
        let (lo, hi) = value_range(w);
        if let Some(bad) = b.as_slice().iter().find(|&&v| !(lo..=hi).contains(&v)) {
            bail!("weight matrix {which} value {bad} out of {w}-bit range {lo}..={hi}");
        }
        Ok(())
    }

    /// The configured arithmetic kernel: naive reference triple loop or
    /// the blocked multithreaded fast path. Bit-exact either way (`i32`
    /// accumulation is order-exact), and all accounting in this module is
    /// analytical, so the kernel choice affects host wall-clock only.
    fn compute(&self, a: &Mat, b: &Mat) -> Mat {
        match self.cfg.kernel {
            KernelMode::Naive => a.matmul(b),
            KernelMode::Blocked => a.matmul_blocked(b, self.cfg.kernel_threads),
        }
    }

    /// Execute `C = A · B` directly, with the tile schedule's analytical
    /// pass/cycle accounting. Mirrors `CoSim::run_gemm`'s schedule: on ADiP
    /// groups of `interleave_factor` adjacent output-column tiles share one
    /// stationary pass.
    pub fn run_gemm(&self, a: &Mat, b: &Mat, mode: PrecisionMode) -> Result<FunctionalRun> {
        self.run_gemm_indexed(a, b, mode, 0)
    }

    /// [`FunctionalArray::run_gemm`] with the weight matrix's position in
    /// its originating set, so a range violation reports the offending
    /// matrix index instead of a hardcoded 0 (the non-fused set fallback
    /// used to lose it).
    fn run_gemm_indexed(
        &self,
        a: &Mat,
        b: &Mat,
        mode: PrecisionMode,
        which: usize,
    ) -> Result<FunctionalRun> {
        ensure!(a.cols() == b.rows(), "inner dimension mismatch");
        let exec_mode = self.exec_mode(mode);
        self.check_weight_range(b, exec_mode, which)?;

        let n = self.n();
        let grid = tile_grid(a.rows(), a.cols(), b.cols(), n);
        let (tiles_m, tiles_k, tiles_n) =
            (grid.tiles_m() as u64, grid.tiles_k() as u64, grid.tiles_n() as u64);
        let kf = if self.arch == Architecture::Adip {
            exec_mode.interleave_factor() as u64
        } else {
            1
        };
        let full_groups = tiles_n / kf;
        let rem = (tiles_n % kf) as usize;
        let groups = full_groups + (rem > 0) as u64;

        let passes = groups * tiles_k * tiles_m;
        let latency = self.tile_latency(exec_mode);
        let steady = self.steady_tile_cycles(exec_mode);
        let mut interleave_groups = Vec::new();
        if full_groups > 0 {
            interleave_groups.push((full_groups * tiles_k, kf as usize));
        }
        if rem > 0 {
            interleave_groups.push((tiles_k, rem));
        }
        Ok(FunctionalRun {
            outputs: vec![self.compute(a, b)],
            mode: exec_mode,
            passes,
            stationary_fetches: groups * tiles_k,
            output_tiles: tiles_m * tiles_n,
            cycles: (latency - steady) + passes * steady,
            steady_cycles: steady,
            interleave_groups,
        })
    }

    /// Execute a shared-input GEMM set `C_s = A · B_s` directly. Mirrors
    /// `CoSim::run_gemm_set`'s generalized slot packing: on ADiP every
    /// (source matrix, output-column tile) pair is one interleave slot and
    /// slots are chunked into capacity-sized stationary groups; other
    /// architectures (or singleton sets) fall back to per-matrix runs.
    pub fn run_gemm_set(&self, a: &Mat, bs: &[&Mat], mode: PrecisionMode) -> Result<FunctionalRun> {
        ensure!(!bs.is_empty(), "need at least one weight matrix");
        for b in bs {
            ensure!(
                b.rows() == bs[0].rows() && b.cols() == bs[0].cols(),
                "weight matrices must share a shape"
            );
            ensure!(a.cols() == b.rows(), "inner dimension mismatch");
        }
        let exec_mode = self.exec_mode(mode);
        let adip = self.arch == Architecture::Adip;
        if !adip || bs.len() == 1 {
            // No set fusion available: independent runs, accounting summed
            // (each run pays its own pipeline fill, as the tile schedule does).
            let mut combined: Option<FunctionalRun> = None;
            for (s, b) in bs.iter().enumerate() {
                let run = self.run_gemm_indexed(a, b, mode, s)?;
                combined = Some(match combined.take() {
                    None => run,
                    Some(mut c) => {
                        c.merge(run);
                        c
                    }
                });
            }
            return Ok(combined.expect("non-empty set"));
        }

        for (s, b) in bs.iter().enumerate() {
            self.check_weight_range(b, exec_mode, s)?;
        }
        let n = self.n();
        let grid = tile_grid(a.rows(), a.cols(), bs[0].cols(), n);
        let (tiles_m, tiles_k, tiles_n) =
            (grid.tiles_m() as u64, grid.tiles_k() as u64, grid.tiles_n() as u64);
        let cap = exec_mode.interleave_factor() as u64;
        let slots = tiles_n * bs.len() as u64;
        let full_groups = slots / cap;
        let rem = (slots % cap) as usize;
        let groups = full_groups + (rem > 0) as u64;

        let passes = groups * tiles_k * tiles_m;
        let latency = self.tile_latency(exec_mode);
        let steady = self.steady_tile_cycles(exec_mode);
        let mut interleave_groups = Vec::new();
        if full_groups > 0 {
            interleave_groups.push((full_groups * tiles_k, cap as usize));
        }
        if rem > 0 {
            interleave_groups.push((tiles_k, rem));
        }
        Ok(FunctionalRun {
            outputs: bs.iter().map(|b| self.compute(a, b)).collect(),
            mode: exec_mode,
            passes,
            stationary_fetches: groups * tiles_k,
            output_tiles: tiles_m * slots,
            cycles: (latency - steady) + passes * steady,
            steady_cycles: steady,
            interleave_groups,
        })
    }
}

impl SystolicArray for FunctionalArray {
    fn architecture(&self) -> Architecture {
        self.arch
    }

    fn config(&self) -> &ArchConfig {
        &self.cfg
    }

    fn supports(&self, mode: PrecisionMode) -> bool {
        self.inner.as_dyn().supports(mode)
    }

    fn tile_latency(&self, mode: PrecisionMode) -> u64 {
        self.inner.as_dyn().tile_latency(mode)
    }

    fn steady_tile_cycles(&self, mode: PrecisionMode) -> u64 {
        self.inner.as_dyn().steady_tile_cycles(mode)
    }

    fn tile_pass(&self, activations: &Mat, weights: &InterleavedTile) -> Result<TilePass> {
        // Tile-level compatibility path (the inner model's fast functional
        // pass); schedulers that want whole-GEMM speed use `run_gemm_set`.
        self.inner.as_dyn().tile_pass(activations, weights)
    }

    fn peak_ops_per_cycle(&self, mode: PrecisionMode) -> u64 {
        self.inner.as_dyn().peak_ops_per_cycle(mode)
    }

    fn as_functional(&self) -> Option<&FunctionalArray> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::interleave_tiles;
    use crate::testutil::{check, Rng};

    fn arr(arch: Architecture, n: usize) -> FunctionalArray {
        FunctionalArray::new(arch, ArchConfig::with_n(n))
    }

    #[test]
    fn emulates_architecture_metadata() {
        for arch in Architecture::ALL {
            let f = arr(arch, 16);
            assert_eq!(f.architecture(), arch);
            assert_eq!(f.config().backend, Backend::Functional);
            assert_eq!(f.n(), 16);
            for mode in PrecisionMode::ALL {
                assert_eq!(
                    f.supports(mode),
                    arch == Architecture::Adip || mode == PrecisionMode::W8
                );
            }
        }
        // latency formulas match the concrete models
        let f = arr(Architecture::Adip, 32);
        assert_eq!(f.tile_latency(PrecisionMode::W8), 32 + 32 + 1 + 3 - 2);
        assert_eq!(arr(Architecture::Dip, 32).tile_latency(PrecisionMode::W8), 63);
        assert_eq!(arr(Architecture::Ws, 32).tile_latency(PrecisionMode::W8), 3 * 32 - 2);
    }

    #[test]
    fn run_gemm_outputs_exact_and_counts_match_tile_schedule() {
        check(
            "functional-run-gemm",
            2101,
            30,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let (m, k, n) = (1 + rng.below(30), 1 + rng.below(30), 1 + rng.below(50));
                (mode, Mat::random(rng, m, k, 8), Mat::random(rng, k, n, mode.weight_bits()))
            },
            |(mode, a, b)| {
                let f = arr(Architecture::Adip, 8);
                let run = f.run_gemm(a, b, *mode).map_err(|e| e.to_string())?;
                if run.outputs[0] != a.matmul(b) {
                    return Err("functional output != reference".into());
                }
                // pass count equals the fused tile schedule
                let grid = tile_grid(a.rows(), a.cols(), b.cols(), 8);
                let kf = mode.interleave_factor();
                let want =
                    (grid.tiles_n().div_ceil(kf) * grid.tiles_k() * grid.tiles_m()) as u64;
                if run.passes != want {
                    return Err(format!("passes {} != {want}", run.passes));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn qkv_set_packs_slots_like_the_scheduler() {
        let mut rng = Rng::seeded(2103);
        let x = Mat::random(&mut rng, 32, 32, 8);
        let ws: Vec<Mat> = (0..3).map(|_| Mat::random(&mut rng, 32, 32, 2)).collect();
        let refs: Vec<&Mat> = ws.iter().collect();
        let f = arr(Architecture::Adip, 8);
        let run = f.run_gemm_set(&x, &refs, PrecisionMode::W2).unwrap();
        // 3 matrices × 4 j-tiles = 12 slots → 3 groups × 4 k × 4 m = 48
        assert_eq!(run.passes, 48);
        assert_eq!(run.outputs.len(), 3);
        for (out, w) in run.outputs.iter().zip(&ws) {
            assert_eq!(*out, x.matmul(w));
        }
        // DiP runs them separately at 8b×8b
        let d = arr(Architecture::Dip, 8);
        let run_d = d.run_gemm_set(&x, &refs, PrecisionMode::W2).unwrap();
        assert_eq!(run_d.mode, PrecisionMode::W8);
        assert_eq!(run_d.passes, 3 * 16 * 4);
        assert_eq!(run_d.outputs, run.outputs);
    }

    #[test]
    fn rejects_out_of_range_weights_like_interleave() {
        let f = arr(Architecture::Adip, 4);
        let a = Mat::zeros(4, 4);
        let wide = Mat::from_fn(4, 4, |_, _| 3);
        assert!(f.run_gemm(&a, &wide, PrecisionMode::W2).is_err());
        assert!(f.run_gemm(&a, &wide, PrecisionMode::W4).is_ok());
        let short = Mat::zeros(3, 4);
        assert!(f.run_gemm(&a, &short, PrecisionMode::W8).is_err());
        let none: Vec<&Mat> = vec![];
        assert!(f.run_gemm_set(&a, &none, PrecisionMode::W8).is_err());
    }

    #[test]
    fn range_violation_reports_the_offending_set_index() {
        // regression: the non-fused set fallback used to hardcode index 0,
        // so a violation in matrix 2 of a WS/DiP set reported "matrix 0"
        let a = Mat::zeros(4, 4);
        let ok = Mat::zeros(4, 4);
        let wide = Mat::from_fn(4, 4, |_, _| 3);
        for arch in [Architecture::Ws, Architecture::Dip, Architecture::Adip] {
            let f = arr(arch, 4);
            // WS/DiP take the non-fused fallback; ADiP the fused path —
            // both must name matrix 2 (W2 on WS/DiP degrades to 8-bit and
            // accepts value 3, so give WS/DiP a genuinely 8-bit violation)
            let bad = if arch == Architecture::Adip {
                wide.clone()
            } else {
                Mat::from_fn(4, 4, |_, _| 300)
            };
            let err = f
                .run_gemm_set(&a, &[&ok, &ok, &bad], PrecisionMode::W2)
                .unwrap_err()
                .to_string();
            assert!(err.contains("weight matrix 2"), "{arch}: {err}");
        }
    }

    #[test]
    fn kernels_are_bit_exact_with_identical_accounting() {
        check(
            "functional-kernel-diff",
            2107,
            30,
            |rng| {
                let arch = *rng.choose(&Architecture::ALL);
                let mode = *rng.choose(&PrecisionMode::ALL);
                let threads = *rng.choose(&[0usize, 1, 2, 4]);
                let (m, k, n) = (1 + rng.below(40), 1 + rng.below(40), 1 + rng.below(40));
                let s = 1 + rng.below(4);
                let a = Mat::random(rng, m, k, 8);
                let bs: Vec<Mat> =
                    (0..s).map(|_| Mat::random(rng, k, n, mode.weight_bits())).collect();
                (arch, mode, threads, a, bs)
            },
            |(arch, mode, threads, a, bs)| {
                let refs: Vec<&Mat> = bs.iter().collect();
                let naive = FunctionalArray::new(*arch, ArchConfig::with_n(8));
                let blocked = FunctionalArray::new(
                    *arch,
                    ArchConfig::with_n(8)
                        .with_kernel(KernelMode::Blocked)
                        .with_kernel_threads(*threads),
                );
                let rn = naive.run_gemm_set(a, &refs, *mode).map_err(|e| e.to_string())?;
                let rb = blocked.run_gemm_set(a, &refs, *mode).map_err(|e| e.to_string())?;
                if rb.outputs != rn.outputs {
                    return Err(format!("{arch} {mode}: blocked outputs != naive"));
                }
                if (rb.passes, rb.cycles, rb.stationary_fetches, rb.output_tiles)
                    != (rn.passes, rn.cycles, rn.stationary_fetches, rn.output_tiles)
                {
                    return Err(format!("{arch} {mode}: accounting differs across kernels"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tile_pass_compatibility_path_matches_inner_model() {
        let mut rng = Rng::seeded(2105);
        let n = 8;
        let f = arr(Architecture::Adip, n);
        let g = AdipArray::new(ArchConfig::with_n(n));
        let a = Mat::random(&mut rng, n, n, 8);
        let tiles: Vec<Mat> = (0..4).map(|_| Mat::random(&mut rng, n, n, 2)).collect();
        let refs: Vec<&Mat> = tiles.iter().collect();
        let it = interleave_tiles(&refs, PrecisionMode::W2).unwrap();
        let fp = f.tile_pass(&a, &it).unwrap();
        let gp = g.tile_pass(&a, &it).unwrap();
        assert_eq!(fp.outputs, gp.outputs);
        assert_eq!(fp.latency_cycles, gp.latency_cycles);
        assert_eq!(fp.steady_cycles, gp.steady_cycles);
    }
}
