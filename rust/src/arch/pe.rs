//! Processing-element models.
//!
//! [`ReconfigurablePe`] is the ADiP PE of paper §III / Fig. 3(a): sixteen
//! 2-bit multipliers arranged in four groups, four group (psum)
//! accumulators, and enabled registers for the stationary weight, the
//! input activation and the psums. The shifters and final accumulators are
//! **not** in the PE — they are shared per column ([`super::column_unit`]).
//!
//! Group `g` multiplies the full 8-bit activation (as four radix-4
//! subwords) by 2-bit weight subword `g` of the packed stationary byte.
//! Which subwords belong to which logical weight matrix depends on the
//! precision mode:
//!
//! * 8b×8b — all four groups hold one 8-bit weight; column unit combines
//!   `g0 + (g1≪2) + (g2≪4) + (g3≪6)`.
//! * 8b×4b — groups {0,1} = matrix 0, groups {2,3} = matrix 1; the column
//!   unit combines each pair with one shift.
//! * 8b×2b — group `g` = matrix `g`; psums pass through unshifted.
//!
//! [`DipPe`] is the DiP baseline PE [34]: a plain INT8 MAC.

use crate::quant::{types::value_range, PrecisionMode};

/// Static PE configuration: number of 2-bit multipliers `M` and multiplier
/// operand width `MW` (paper Eq. (1)). The selected ADiP design point is
/// `M = 16, MW = 2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeConfig {
    /// Number of 2-bit multipliers instantiated (`M`).
    pub multipliers: u32,
    /// Operand width of each multiplier in bits (`MW`).
    pub mult_width: u32,
}

impl Default for PeConfig {
    fn default() -> Self {
        PeConfig { multipliers: 16, mult_width: 2 }
    }
}

impl PeConfig {
    /// Paper Eq. (1): cycles for one MAC at the given operand widths.
    ///
    /// `Latency_PE = ceil( (1/M) · (OW₁·OW₂ / MW²) )`
    pub fn latency_cycles(&self, ow1: u32, ow2: u32) -> u64 {
        let subword_products = (ow1 * ow2) as u64;
        let per_cycle = (self.multipliers * self.mult_width * self.mult_width) as u64;
        subword_products.div_ceil(per_cycle)
    }

    /// Eq. (1) specialized to a precision mode (activations 8-bit).
    pub fn mode_latency(&self, mode: PrecisionMode) -> u64 {
        self.latency_cycles(mode.act_bits(), mode.weight_bits())
    }
}

/// One cycle's worth of PE output: the four group psum contributions
/// (before column shifting/accumulation).
pub type GroupPsums = [i64; 4];

/// The ADiP reconfigurable PE (bit-exact functional model).
#[derive(Debug, Clone)]
pub struct ReconfigurablePe {
    cfg: PeConfig,
    /// Stationary packed weight byte (the “weight register”).
    weight: u8,
    mode: PrecisionMode,
    /// Effective multiplier-group operands, resolved at weight load
    /// (§Perf iteration 4): signed for the top subword of each logical
    /// weight, unsigned otherwise — exactly the wiring of Fig. 3(a).
    w_subs: [i8; 4],
}

impl ReconfigurablePe {
    /// New PE with an all-zero stationary weight.
    pub fn new(cfg: PeConfig, mode: PrecisionMode) -> ReconfigurablePe {
        ReconfigurablePe { cfg, weight: 0, mode, w_subs: [0; 4] }
    }

    /// Static configuration.
    pub fn config(&self) -> PeConfig {
        self.cfg
    }

    /// Current precision mode.
    pub fn mode(&self) -> PrecisionMode {
        self.mode
    }

    /// Load the stationary weight register with a packed byte (1 × 8-bit,
    /// 2 × 4-bit or 4 × 2-bit fields, element 0 in the low bits) and set
    /// the mode.
    pub fn load_weight(&mut self, packed: u8, mode: PrecisionMode) {
        self.weight = packed;
        self.mode = mode;
        for g in 0..4 {
            let raw = self.weight_subword(g);
            self.w_subs[g] = if self.group_is_top(g) { raw as i8 } else { (raw & 0b11) as i8 };
        }
    }

    /// Weight subword (signed 2-bit) feeding multiplier group `g`.
    fn weight_subword(&self, g: usize) -> i32 {
        let field = ((self.weight >> (2 * g)) & 0b11) as i32;
        crate::quant::packing::sign_extend(field, 2)
    }

    /// Whether group `g`'s subword is the *top* (signed) subword of its
    /// logical weight value in the current mode.
    fn group_is_top(&self, g: usize) -> bool {
        match self.mode {
            PrecisionMode::W8 => g == 3,
            PrecisionMode::W4 => g % 2 == 1,
            PrecisionMode::W2 => true,
        }
    }

    /// Compute one MAC term: multiply the 8-bit activation against the
    /// packed stationary weight, producing the four group psums. Bit-exact
    /// with the hardware: each group result is built from four 2-bit × 2-bit
    /// subword products, shift-added over the activation subwords only
    /// (weight-subword shifts happen in the shared column unit).
    ///
    /// Signedness note: the raw 2-bit field of a *non-top* subword is
    /// unsigned (0..3); the top subword of each logical weight is signed
    /// (−2..1). `weight_subword` always sign-extends, so non-top groups
    /// correct by `+4` when the raw field was ≥ 2 — equivalent to reading
    /// the field unsigned, which is what the hardware does.
    pub fn compute(&self, activation: i32) -> GroupPsums {
        let (lo, hi) = value_range(8);
        assert!((lo..=hi).contains(&activation), "activation {activation} out of int8 range");
        // §Perf iteration 2: table-driven radix-4 decomposition (no Vec
        // allocation on the per-MAC hot path; exhaustively checked against
        // `decompose_radix4` in quant::subword tests).
        let a_subs = crate::quant::subword::RADIX4_I8[(activation as u8) as usize];
        let mut out = [0i64; 4];
        for g in 0..4 {
            // group operand resolved at load time (signed top subword,
            // unsigned lower subwords — see `load_weight`)
            let w_sub = self.w_subs[g] as i32;
            let mut acc = 0i64;
            for (j, &aj) in a_subs.iter().enumerate() {
                acc += (crate::quant::subword_product(aj as i32, w_sub) as i64) << (2 * j);
            }
            out[g] = acc;
        }
        out
    }

    /// Cycles this PE needs per MAC in the current mode (Eq. (1)).
    pub fn latency(&self) -> u64 {
        self.cfg.mode_latency(self.mode)
    }
}

/// DiP baseline PE: one INT8 × INT8 MAC per cycle, dedicated accumulator.
#[derive(Debug, Clone, Default)]
pub struct DipPe {
    weight: i32,
}

impl DipPe {
    /// Load the stationary 8-bit weight.
    pub fn load_weight(&mut self, w: i32) {
        let (lo, hi) = value_range(8);
        assert!((lo..=hi).contains(&w), "weight {w} out of int8 range");
        self.weight = w;
    }

    /// One MAC term.
    pub fn compute(&self, activation: i32) -> i64 {
        let (lo, hi) = value_range(8);
        assert!((lo..=hi).contains(&activation), "activation {activation} out of int8 range");
        activation as i64 * self.weight as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::interleave_tiles;
    use crate::dataflow::Mat;
    use crate::quant::{pack_int2, pack_int4};
    use crate::testutil::{check, Rng};

    /// Reference: combine group psums exactly as the shared column unit
    /// would, returning one value per logical weight matrix.
    fn combine(mode: PrecisionMode, g: GroupPsums) -> Vec<i64> {
        match mode {
            PrecisionMode::W8 => vec![g[0] + (g[1] << 2) + (g[2] << 4) + (g[3] << 6)],
            PrecisionMode::W4 => vec![g[0] + (g[1] << 2), g[2] + (g[3] << 2)],
            PrecisionMode::W2 => vec![g[0], g[1], g[2], g[3]],
        }
    }

    #[test]
    fn eq1_latency_reproduces_fig2() {
        // Fig. 2: latency vs number of multipliers for the three modes.
        let cases: &[(u32, PrecisionMode, u64)] = &[
            (2, PrecisionMode::W8, 8),
            (4, PrecisionMode::W8, 4),
            (8, PrecisionMode::W8, 2),
            (16, PrecisionMode::W8, 1),
            (2, PrecisionMode::W4, 4),
            (4, PrecisionMode::W4, 2),
            (8, PrecisionMode::W4, 1),
            (16, PrecisionMode::W4, 1),
            (2, PrecisionMode::W2, 2),
            (4, PrecisionMode::W2, 1),
            (8, PrecisionMode::W2, 1),
            (16, PrecisionMode::W2, 1),
        ];
        for &(m, mode, want) in cases {
            let cfg = PeConfig { multipliers: m, mult_width: 2 };
            assert_eq!(cfg.mode_latency(mode), want, "M={m} mode={mode}");
        }
    }

    #[test]
    fn pe_8x8_exhaustive_weights_random_acts() {
        let mut rng = Rng::seeded(77);
        let mut pe = ReconfigurablePe::new(PeConfig::default(), PrecisionMode::W8);
        for w in -128i32..=127 {
            pe.load_weight(w as u8, PrecisionMode::W8);
            let a = rng.int_of_bits(8);
            let got = combine(PrecisionMode::W8, pe.compute(a));
            assert_eq!(got, vec![(a * w) as i64], "a={a} w={w}");
        }
    }

    #[test]
    fn pe_8x4_exhaustive_weight_pairs() {
        let mut rng = Rng::seeded(78);
        let mut pe = ReconfigurablePe::new(PeConfig::default(), PrecisionMode::W4);
        for w0 in -8i32..=7 {
            for w1 in -8i32..=7 {
                pe.load_weight(pack_int4([w0, w1]), PrecisionMode::W4);
                let a = rng.int_of_bits(8);
                let got = combine(PrecisionMode::W4, pe.compute(a));
                assert_eq!(got, vec![(a * w0) as i64, (a * w1) as i64], "a={a} w0={w0} w1={w1}");
            }
        }
    }

    #[test]
    fn pe_8x2_exhaustive_weight_quads() {
        let mut pe = ReconfigurablePe::new(PeConfig::default(), PrecisionMode::W2);
        for a in [-128, -77, -1, 0, 1, 63, 127] {
            for w0 in -2i32..=1 {
                for w1 in -2i32..=1 {
                    for w2 in -2i32..=1 {
                        for w3 in -2i32..=1 {
                            pe.load_weight(pack_int2([w0, w1, w2, w3]), PrecisionMode::W2);
                            let got = combine(PrecisionMode::W2, pe.compute(a));
                            let want: Vec<i64> =
                                [w0, w1, w2, w3].iter().map(|&w| (a * w) as i64).collect();
                            assert_eq!(got, want, "a={a} w={:?}", [w0, w1, w2, w3]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pe_matches_interleaved_tile_fields() {
        // The PE reads exactly the packing convention produced by
        // dataflow::interleave_tiles.
        check(
            "pe-vs-interleave",
            79,
            60,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let k = mode.interleave_factor();
                let tiles: Vec<Mat> =
                    (0..k).map(|_| Mat::random(rng, 1, 1, mode.weight_bits())).collect();
                let a = rng.int_of_bits(8);
                (mode, tiles, a)
            },
            |(mode, tiles, a)| {
                let refs: Vec<&Mat> = tiles.iter().collect();
                let it = interleave_tiles(&refs, *mode).map_err(|e| e.to_string())?;
                let mut pe = ReconfigurablePe::new(PeConfig::default(), *mode);
                pe.load_weight(it.packed.get(0, 0) as u8, *mode);
                let got = combine(*mode, pe.compute(*a));
                for (s, t) in tiles.iter().enumerate() {
                    let want = (*a as i64) * t.get(0, 0) as i64;
                    if got[s] != want {
                        return Err(format!("source {s}: got {} want {want}", got[s]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dip_pe_is_plain_mac() {
        let mut pe = DipPe::default();
        pe.load_weight(-100);
        assert_eq!(pe.compute(100), -10_000);
        assert_eq!(pe.compute(0), 0);
    }

    #[test]
    fn latencies_via_pe_accessor() {
        let pe = ReconfigurablePe::new(PeConfig::default(), PrecisionMode::W8);
        assert_eq!(pe.latency(), 1);
        assert_eq!(pe.config().multipliers, 16);
        let slow =
            ReconfigurablePe::new(PeConfig { multipliers: 2, mult_width: 2 }, PrecisionMode::W8);
        assert_eq!(slow.latency(), 8);
    }
}
