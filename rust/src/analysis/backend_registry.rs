//! `backend-differential-registry`: every module that dispatches on
//! `Backend` must appear in the registry below, mapped to the
//! differential test suite that exercises it across backends.
//!
//! The repo's correctness story is differential: the functional
//! reference model is the oracle, and every accelerator backend
//! (`adip`, `dip`, `ws`, blocked array) is held bit-identical to it by
//! suite-level comparison. That only works if each *new* point of
//! backend dispatch is actually covered by a differential suite — a
//! fresh `match backend { ... }` in a scheduler that no suite sweeps is
//! silent coverage loss. The registry makes the coverage claim explicit
//! and machine-checked:
//!
//! * any `src/**` file whose production code references `Backend::`
//!   must have a registry entry;
//! * every registry entry must point at files that still exist (no
//!   stale paths after refactors), checked only on full-tree runs so
//!   fixture-corpus scans do not false-positive.
//!
//! Adding a backend dispatch site therefore forces a conscious choice
//! of which differential suite covers it — and the reviewer sees the
//! registry diff.

use super::rules::{RuleId, SourceFile, Violation};

/// source file → differential suites that sweep its backend dispatch.
pub const BACKEND_REGISTRY: &[(&str, &[&str])] = &[
    ("src/arch/mod.rs", &["tests/integration_backends.rs"]),
    ("src/arch/array.rs", &["tests/integration_backends.rs"]),
    ("src/arch/functional.rs", &["tests/integration_backends.rs"]),
    ("src/arch/adip.rs", &["tests/integration_backends.rs"]),
    ("src/arch/dip.rs", &["tests/integration_backends.rs"]),
    ("src/arch/ws.rs", &["tests/integration_backends.rs"]),
    (
        "src/coordinator/scheduler.rs",
        &["tests/integration_backends.rs", "tests/integration_pipeline.rs"],
    ),
    (
        "src/coordinator/server.rs",
        &["tests/integration_pipeline.rs", "tests/integration_balance.rs"],
    ),
    ("src/cluster/scheduler.rs", &["tests/integration_cluster.rs"]),
    ("src/main.rs", &["tests/integration_backends.rs"]),
];

/// Run the rule over the whole scanned file set.
pub fn check(files: &[SourceFile], out: &mut Vec<Violation>) {
    // A scan containing the crate root is a real-tree run; registry
    // staleness checks only make sense there.
    let full_tree = files.iter().any(|f| f.rel_path == "src/lib.rs");

    for f in files {
        if !f.rel_path.starts_with("src/") {
            continue;
        }
        let first_use = (1..=f.lines.len())
            .find(|&i| !f.is_test_line(i) && f.code(i).contains("Backend::"));
        let Some(line) = first_use else { continue };
        if !BACKEND_REGISTRY.iter().any(|(p, _)| *p == f.rel_path) {
            out.push(Violation {
                rule: RuleId::BackendDifferentialRegistry,
                file: f.rel_path.clone(),
                line,
                message: "module dispatches on Backend but has no entry in \
                          BACKEND_REGISTRY (src/analysis/backend_registry.rs): \
                          name the differential suite that covers it"
                    .into(),
            });
        }
    }

    if full_tree {
        let exists = |p: &str| files.iter().any(|f| f.rel_path == p);
        for (src, suites) in BACKEND_REGISTRY {
            if !exists(src) {
                out.push(Violation {
                    rule: RuleId::BackendDifferentialRegistry,
                    file: "src/analysis/backend_registry.rs".into(),
                    line: 1,
                    message: format!("registry entry {src:?} points at a missing file"),
                });
            }
            for suite in *suites {
                if !exists(suite) {
                    out.push(Violation {
                        rule: RuleId::BackendDifferentialRegistry,
                        file: "src/analysis/backend_registry.rs".into(),
                        line: 1,
                        message: format!(
                            "registry entry {src:?} names missing differential suite {suite:?}"
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(path: &str, src: &str) -> SourceFile {
        SourceFile::new(path.into(), src)
    }

    #[test]
    fn unregistered_backend_dispatch_is_flagged() {
        let files = vec![file("src/net/server.rs", "match b {\n    Backend::Adip => x(),\n}\n")];
        let mut out = Vec::new();
        check(&files, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, RuleId::BackendDifferentialRegistry);
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn registered_file_passes() {
        let files = vec![file("src/arch/adip.rs", "let b = Backend::Adip;\n")];
        let mut out = Vec::new();
        check(&files, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn test_only_dispatch_is_exempt() {
        let src =
            "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let b = Backend::Adip; }\n}\n";
        let files = vec![file("src/net/server.rs", src)];
        let mut out = Vec::new();
        check(&files, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn staleness_checked_only_on_full_tree() {
        // Partial scan (no src/lib.rs): a registry pointing at files
        // outside the scan set is fine.
        let files = vec![file("src/arch/adip.rs", "let b = Backend::Adip;\n")];
        let mut out = Vec::new();
        check(&files, &mut out);
        assert!(out.is_empty());

        // Full-tree scan missing the suites: every entry is stale.
        let files = vec![file("src/lib.rs", "pub mod arch;\n")];
        let mut out = Vec::new();
        check(&files, &mut out);
        assert!(
            out.iter().any(|v| v.message.contains("missing file")),
            "{out:?}"
        );
        assert!(out.iter().any(|v| v.message.contains("missing differential suite")));
    }

    #[test]
    fn registry_covers_the_known_dispatch_points() {
        for path in ["src/arch/mod.rs", "src/coordinator/scheduler.rs", "src/main.rs"] {
            assert!(
                BACKEND_REGISTRY.iter().any(|(p, _)| *p == path),
                "{path} must stay registered"
            );
        }
    }
}
