//! `atomic-ordering-justified`: every `Ordering::Relaxed` in production
//! code must carry a `relaxed-ok:` justification; `SeqCst` is banned.
//!
//! The repo's lock-free structures (obs span recorder, metrics
//! reservoir shards, balance-fabric gauges, weight-cache counters) are
//! correct *because* each Relaxed site is individually harmless — a
//! monotonic stat counter, a gauge, or a payload word ordered by a
//! Release/Acquire header elsewhere. That argument lives in a comment
//! at the site:
//!
//! ```text
//! counter.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
//! ```
//!
//! or, for a contiguous run of Relaxed lines (e.g. a metrics render
//! table), one comment directly above the run:
//!
//! ```text
//! // relaxed-ok: independent stat counters, no cross-field ordering
//! a.fetch_add(1, Ordering::Relaxed);
//! b.fetch_add(n, Ordering::Relaxed);
//! ```
//!
//! `SeqCst` is rejected with no annotation escape hatch short of a
//! `lint: allow` suppression: every ordering in this codebase is either
//! genuinely relaxed or a deliberate Release/Acquire pair, and `SeqCst`
//! almost always papers over an unstated protocol. Test code (tests/,
//! benches/, in-file `#[cfg(test)]` modules) is exempt.

use super::rules::{RuleId, SourceFile, Violation};

const MARKER: &str = "relaxed-ok";

/// The justification text after `relaxed-ok:`, if present and non-empty.
/// Doc comments are inert — they describe the convention (as the docs
/// above do), they never annotate a site.
fn reason(comment: &str) -> Option<&str> {
    if super::lexer::is_doc(comment) {
        return None;
    }
    let at = comment.find(MARKER)?;
    let rest = comment[at + MARKER.len()..].trim_start();
    let rest = rest.strip_prefix(':')?.trim();
    (!rest.is_empty()).then_some(rest)
}

/// Run the rule over one file, appending errors to `out` and
/// non-blocking findings (unused annotations) to `warn`.
pub fn check(file: &SourceFile, out: &mut Vec<Violation>, warn: &mut Vec<Violation>) {
    let n = file.lines.len();
    let mut annotation_used = vec![false; n + 1];

    for i in 1..=n {
        if !file.is_test_line(i) && file.code(i).contains("SeqCst") {
            out.push(Violation {
                rule: RuleId::AtomicOrderingJustified,
                file: file.rel_path.clone(),
                line: i,
                message: "Ordering::SeqCst is banned: name the actual protocol \
                          (Relaxed with a relaxed-ok justification, or a \
                          Release/Acquire pair)"
                    .into(),
            });
        }
    }

    let relaxed = |i: usize| {
        i >= 1 && i <= n && !file.is_test_line(i) && file.code(i).contains("Ordering::Relaxed")
    };

    let mut i = 1usize;
    while i <= n {
        if !relaxed(i) {
            i += 1;
            continue;
        }
        // Maximal run of consecutive Relaxed lines: one comment directly
        // above the run justifies every line in it.
        let start = i;
        let mut end = i;
        while relaxed(end + 1) {
            end += 1;
        }
        let mut head_ok = false;
        let mut j = start;
        while j > 1 {
            j -= 1;
            let comment_only =
                file.code(j).trim().is_empty() && !file.comment(j).trim().is_empty();
            if !comment_only {
                break;
            }
            if reason(file.comment(j)).is_some() {
                head_ok = true;
                annotation_used[j] = true;
            }
        }
        for k in start..=end {
            let own = reason(file.comment(k)).is_some();
            if own {
                annotation_used[k] = true;
            }
            if !own && !head_ok {
                out.push(Violation {
                    rule: RuleId::AtomicOrderingJustified,
                    file: file.rel_path.clone(),
                    line: k,
                    message: "Ordering::Relaxed without a `relaxed-ok: <why>` \
                              justification (same line, or a comment directly \
                              above the run)"
                        .into(),
                });
            }
        }
        i = end + 1;
    }

    // Annotation hygiene: a reason-less marker is an error; a marker that
    // justified nothing is a warning (stale annotations must not rot).
    for i in 1..=n {
        if file.is_test_line(i)
            || super::lexer::is_doc(file.comment(i))
            || !file.comment(i).contains(MARKER)
        {
            continue;
        }
        if reason(file.comment(i)).is_none() {
            out.push(Violation {
                rule: RuleId::LintAnnotation,
                file: file.rel_path.clone(),
                line: i,
                message: "relaxed-ok justification has no reason — say why the \
                          relaxed ordering is sufficient"
                    .into(),
            });
        } else if !annotation_used[i] {
            warn.push(Violation {
                rule: RuleId::LintAnnotation,
                file: file.rel_path.clone(),
                line: i,
                message: "relaxed-ok annotation does not cover any \
                          Ordering::Relaxed line"
                    .into(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> (Vec<Violation>, Vec<Violation>) {
        let f = SourceFile::new("src/x.rs".into(), src);
        let (mut out, mut warn) = (Vec::new(), Vec::new());
        check(&f, &mut out, &mut warn);
        (out, warn)
    }

    #[test]
    fn unjustified_relaxed_is_flagged_with_line() {
        let (out, _) = run("fn f() {\n    c.fetch_add(1, Ordering::Relaxed);\n}\n");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2);
        assert_eq!(out[0].rule, RuleId::AtomicOrderingJustified);
    }

    #[test]
    fn same_line_justification_passes() {
        let (out, warn) =
            run("c.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter\n");
        assert!(out.is_empty(), "{out:?}");
        assert!(warn.is_empty());
    }

    #[test]
    fn comment_above_covers_a_contiguous_run() {
        let src = "\
// relaxed-ok: independent stat counters
a.fetch_add(1, Ordering::Relaxed);
b.fetch_add(2, Ordering::Relaxed);
other();
c.fetch_add(3, Ordering::Relaxed);
";
        let (out, _) = run(src);
        assert_eq!(out.len(), 1, "the run break at `other()` ends coverage");
        assert_eq!(out[0].line, 5);
    }

    #[test]
    fn seqcst_is_always_flagged() {
        let (out, _) = run("x.store(1, Ordering::SeqCst);\n");
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("banned"));
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() \
                   { c.load(Ordering::Relaxed); s.load(Ordering::SeqCst); }\n}\n";
        let (out, warn) = run(src);
        assert!(out.is_empty(), "{out:?}");
        assert!(warn.is_empty());
    }

    #[test]
    fn relaxed_inside_string_is_inert() {
        let (out, _) = run("let s = \"Ordering::Relaxed\";\n");
        assert!(out.is_empty());
    }

    #[test]
    fn empty_reason_is_an_error() {
        let (out, _) = run("c.fetch_add(1, Ordering::Relaxed); // relaxed-ok:\n");
        assert_eq!(out.len(), 2, "unjustified relaxed + reason-less marker: {out:?}");
        assert!(out.iter().any(|v| v.rule == RuleId::LintAnnotation));
    }

    #[test]
    fn marker_mentions_in_doc_comments_are_inert() {
        let src = "\
//! every Relaxed carries a relaxed-ok: justification\n\
/// mentions relaxed-ok without a colon\n\
c.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter\n";
        let (out, warn) = run(src);
        assert!(out.is_empty(), "{out:?}");
        assert!(warn.is_empty(), "{warn:?}");
    }

    #[test]
    fn stale_annotation_is_a_warning() {
        let (out, warn) = run("// relaxed-ok: nothing below\nplain();\n");
        assert!(out.is_empty());
        assert_eq!(warn.len(), 1);
        assert_eq!(warn[0].rule, RuleId::LintAnnotation);
    }
}
