//! `wall-clock-containment`: `std::time::SystemTime::now()` stays inside
//! the telemetry tier's allowlisted timestamp helper.
//!
//! Everything on a serving path must measure time with the *monotonic*
//! `Instant` clock: wall clocks jump (NTP slews, suspend/resume, manual
//! changes), and a jump observed mid-measurement corrupts latency
//! accounting, deadline arithmetic and pacing — silently, and only on
//! the machines where it happens. The one legitimate consumer of wall
//! time is the telemetry tier, which stamps operator-facing watchdog
//! events with epoch milliseconds so they can be correlated with logs
//! from other machines (`telemetry/watchdog.rs::wall_clock_unix_ms`).
//!
//! The rule flags any `SystemTime::now` in code outside
//! `src/telemetry/`. Test code is *not* exempt: a test that asserts on
//! wall time is flaky by construction, and the fix (an `Instant`, or a
//! constant) is the same as in production code. Callers with a genuine
//! new need for wall time route it through the telemetry helper — or
//! carry an explicit `lint: allow(wall-clock-containment) <reason>`.

use super::rules::{RuleId, SourceFile, Violation};

/// The one directory allowed to read the wall clock: the telemetry tier
/// owns epoch timestamps (watchdog events, and any future operator-facing
/// stamp), everything else uses monotonic `Instant`s.
const ALLOWED_PREFIX: &str = "src/telemetry/";

/// Run the rule over one file.
pub fn check(file: &SourceFile, out: &mut Vec<Violation>) {
    if file.rel_path.starts_with(ALLOWED_PREFIX) || file.rel_path.contains("/src/telemetry/") {
        return;
    }
    for (idx, l) in file.lines.iter().enumerate() {
        if l.code.contains("SystemTime::now") {
            out.push(Violation {
                rule: RuleId::WallClockContainment,
                file: file.rel_path.clone(),
                line: idx + 1,
                message: "wall clock read outside src/telemetry/: use a monotonic \
                          Instant, or route operator-facing timestamps through \
                          telemetry::watchdog::wall_clock_unix_ms"
                    .into(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Violation> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn wall_clock_read_outside_telemetry_flagged() {
        let out = run(
            "src/coordinator/server.rs",
            "let t = std::time::SystemTime::now();\n",
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, RuleId::WallClockContainment);
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn unqualified_use_is_flagged_too() {
        let out = run("src/obs/mod.rs", "let t = SystemTime::now();\n");
        assert_eq!(out.len(), 1, "{out:?}");
    }

    #[test]
    fn telemetry_tier_is_exempt() {
        let src = "let ms = std::time::SystemTime::now();\n";
        assert!(run("src/telemetry/watchdog.rs", src).is_empty());
        assert!(run("rust/src/telemetry/http.rs", src).is_empty());
    }

    #[test]
    fn test_code_is_not_exempt() {
        let out = run("tests/integration_net.rs", "let t = SystemTime::now();\n");
        assert_eq!(out.len(), 1, "wall-clock flakiness is a test bug too");
    }

    #[test]
    fn mention_in_comment_or_string_is_inert() {
        let out = run(
            "src/coordinator/metrics.rs",
            "// never SystemTime::now here\nlet s = \"SystemTime::now\";\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn instant_now_passes() {
        let out = run("src/coordinator/server.rs", "let t = std::time::Instant::now();\n");
        assert!(out.is_empty(), "{out:?}");
    }
}
