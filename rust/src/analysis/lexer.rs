//! Comment/string-aware line scanner for the lint pass.
//!
//! `adip lint` must never mistake `"Ordering::Relaxed"` inside a string
//! for an atomic ordering, nor a `// lint: allow(...)` inside a raw
//! string for a real suppression. This module splits a Rust source file
//! into per-line (code, comment) pairs:
//!
//! * **code** — the line with every comment removed and the *contents*
//!   of string / raw-string / byte-string / char literals blanked to
//!   spaces (the delimiting quotes are kept, so the code text stays
//!   structurally aligned with the original columns).
//! * **comment** — the concatenated text of every comment on the line
//!   (line comments, and each line's share of a block comment).
//!
//! The scanner handles the full set of lexical shapes the rules need to
//! survive: nested block comments (`/* /* */ */`), raw strings with any
//! number of `#`s (`r##"…"##`), byte and raw-byte strings, char
//! literals vs. lifetimes (`'a'` vs `&'a str`), and string escapes
//! (`"\""`). It is a *line* scanner, not a full lexer: that is exactly
//! enough for line-anchored textual rules, and keeps it auditable.

/// One source line, split into sanitized code and comment text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SourceLine {
    /// The line with comments removed and literal contents blanked.
    pub code: String,
    /// Concatenated comment text appearing on this line (no `//`/`/*`
    /// markers; block comments contribute their per-line share).
    pub comment: String,
}

/// True when captured comment text came from a *doc* comment (`///`,
/// `//!`, `/** … */`, `/*! … */`). The scanner strips the two-character
/// opener, so doc comments are recognizable by the residual third
/// marker character leading the text. Doc comments *document* the
/// annotation conventions — they never carry live annotations or
/// suppressions, so the rules treat them as inert.
pub fn is_doc(comment: &str) -> bool {
    matches!(comment.chars().next(), Some('/' | '!' | '*'))
}

/// Scanner state that can persist across line boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Code,
    /// Inside a block comment, with nesting depth (`/*` inside `/*`).
    Block(u32),
    /// Inside a `"…"` (or `b"…"`) string.
    Str,
    /// Inside a raw string `r#…#"…"#…#` with this many `#`s.
    RawStr(u32),
}

/// Split `src` into per-line sanitized (code, comment) pairs.
///
/// The output always has exactly as many entries as `src` has lines
/// (`lines()` semantics: a trailing newline does not add an empty line).
pub fn strip_source(src: &str) -> Vec<SourceLine> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut i = 0usize;

    // True when `chars[i]` could begin a raw/byte-string prefix, i.e. the
    // previous character is not part of the same identifier (`number"` must
    // not read its trailing `r` as a raw-string opener).
    let prev_is_ident = |i: usize| {
        i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
    };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            // A newline ends the line in every state; multi-line
            // constructs (strings, block comments) carry their state over.
            out.push(SourceLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    // Line comment: the rest of the line is comment text.
                    let mut j = i + 2;
                    while j < n && chars[j] != '\n' {
                        comment.push(chars[j]);
                        j += 1;
                    }
                    i = j;
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if c == 'b' && !prev_is_ident(i) && i + 1 < n && chars[i + 1] == '"' {
                    code.push_str("b\"");
                    state = State::Str;
                    i += 2;
                } else if c == 'b' && !prev_is_ident(i) && i + 1 < n && chars[i + 1] == '\'' {
                    // Byte char literal b'x' — consume inline (cannot span lines).
                    code.push_str("b'");
                    i += 2;
                    i = consume_char_literal(&chars, i, &mut code);
                } else if (c == 'r' || c == 'b') && !prev_is_ident(i) {
                    // Possible raw (byte) string: r"…", r#"…"#, br"…", br##"…"##.
                    let mut j = i + 1;
                    if c == 'b' && j < n && chars[j] == 'r' {
                        j += 1;
                    } else if c == 'b' {
                        code.push(c);
                        i += 1;
                        continue;
                    }
                    let mut hashes = 0u32;
                    while j < n && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        for k in i..=j {
                            code.push(chars[k]);
                        }
                        state = State::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal or lifetime. `'\…'` and `'x'` are
                    // literals; `'ident` (no closing quote right after one
                    // char) is a lifetime/label and stays plain code.
                    let is_literal = i + 1 < n
                        && (chars[i + 1] == '\\'
                            || (i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\''));
                    code.push('\'');
                    i += 1;
                    if is_literal {
                        i = consume_char_literal(&chars, i, &mut code);
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::Block(depth) => {
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    state = State::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    state = if depth > 1 {
                        comment.push_str("*/");
                        State::Block(depth - 1)
                    } else {
                        State::Code
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < n {
                    // Escape: blank both characters (keeps `\"` inert).
                    code.push(' ');
                    if chars[i + 1] != '\n' {
                        code.push(' ');
                        i += 2;
                    } else {
                        i += 1; // line-continuation escape: newline handled above
                    }
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                // Close only on `"` followed by exactly `hashes` `#`s.
                if c == '"' {
                    let mut j = i + 1;
                    let mut seen = 0u32;
                    while j < n && seen < hashes && chars[j] == '#' {
                        seen += 1;
                        j += 1;
                    }
                    if seen == hashes {
                        code.push('"');
                        for _ in 0..hashes {
                            code.push('#');
                        }
                        state = State::Code;
                        i = j;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        out.push(SourceLine { code, comment });
    }
    out
}

/// Consume the body + closing quote of a char literal whose opening `'`
/// (and any `b` prefix) is already emitted; blanks the contents.
fn consume_char_literal(chars: &[char], mut i: usize, code: &mut String) -> usize {
    let n = chars.len();
    if i < n && chars[i] == '\\' {
        code.push(' ');
        i += 1;
        if i < n {
            code.push(' ');
            i += 1;
        }
        // multi-char escapes (\x7f, \u{…}) run to the closing quote below
    } else if i < n && chars[i] != '\'' {
        code.push(' ');
        i += 1;
    }
    while i < n && chars[i] != '\'' && chars[i] != '\n' {
        code.push(' ');
        i += 1;
    }
    if i < n && chars[i] == '\'' {
        code.push('\'');
        i += 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        strip_source(src).into_iter().map(|l| l.code).collect()
    }

    fn comments(src: &str) -> Vec<String> {
        strip_source(src).into_iter().map(|l| l.comment).collect()
    }

    #[test]
    fn line_comments_split_from_code() {
        let lines = strip_source("let x = 1; // trailing note\n// full-line note\n");
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].code, "let x = 1; ");
        assert_eq!(lines[0].comment, " trailing note");
        assert_eq!(lines[1].code, "");
        assert_eq!(lines[1].comment, " full-line note");
    }

    #[test]
    fn nested_block_comments_stay_comment_until_balanced() {
        let src = "a /* outer /* inner */ still comment */ b\nc /* open\nmid\nclose */ d\n";
        let got = codes(src);
        assert_eq!(got[0], "a  b");
        assert_eq!(got[1], "c ");
        assert_eq!(got[2], "");
        assert_eq!(got[3], " d");
        let cm = comments(src);
        assert!(cm[0].contains("outer"));
        assert!(cm[0].contains("inner"));
        assert_eq!(cm[2], "mid");
    }

    #[test]
    fn string_contents_blanked_including_fake_comments() {
        let got = codes("let s = \"// not a comment /* nor this */\"; // real\n");
        assert!(got[0].contains("let s = \""));
        assert!(!got[0].contains("not a comment"));
        assert!(!got[0].contains("/*"));
        let cm = comments("let s = \"// not a comment\"; // real\n");
        assert_eq!(cm[0], " real");
    }

    #[test]
    fn escaped_quotes_do_not_terminate_strings() {
        let got = codes("let s = \"a\\\"b\"; let t = 2;\n");
        assert!(got[0].ends_with("let t = 2;"));
        assert!(!got[0].contains('a'));
    }

    #[test]
    fn raw_strings_with_hashes_span_lines_and_hide_quotes() {
        let src = "let s = r#\"line \"quoted\" one\nOrdering::SeqCst\n\"# ; done\n";
        let got = codes(src);
        assert!(!got[0].contains("quoted"));
        assert_eq!(got[1].trim(), "", "raw string interior must be blanked");
        assert!(got[2].contains("; done"));
    }

    #[test]
    fn byte_and_raw_byte_strings_are_literals() {
        let got = codes("let a = b\"// x\"; let b2 = br#\"/* y */\"#; z\n");
        assert!(!got[0].contains("// x"));
        assert!(!got[0].contains("/* y */"));
        assert!(got[0].ends_with("; z"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        // '"' as a char literal must not open a string.
        let got = codes("let q = '\"'; let c = 'a'; let e = '\\n'; x\n");
        assert!(got[0].ends_with("; x"));
        // Lifetimes survive as code (no literal consumption).
        let got = codes("fn f<'a>(x: &'a str) -> &'a str { x } // c\n");
        assert!(got[0].contains("<'a>"));
        assert!(got[0].contains("&'a str"));
        // A labeled loop is not a char literal either.
        let got = codes("'outer: loop { break 'outer; } // c\n");
        assert!(got[0].contains("'outer: loop"));
    }

    #[test]
    fn identifier_trailing_r_is_not_a_raw_string() {
        let got = codes("let number = var + 1; let s = \"t\";\n");
        assert!(got[0].contains("let number = var + 1;"));
    }

    #[test]
    fn multiline_string_state_carries_over() {
        let src = "let s = \"first\nsecond // fake\nend\"; real();\n";
        let got = codes(src);
        assert!(got[1].trim().is_empty());
        assert!(got[2].ends_with("\"; real();"));
        assert_eq!(comments(src)[1], "");
    }

    #[test]
    fn doc_comments_are_distinguishable_from_plain_comments() {
        let lines = strip_source("/// outer doc\n//! inner doc\n// plain note\n/** block doc */\n");
        assert!(is_doc(&lines[0].comment), "{:?}", lines[0].comment);
        assert!(is_doc(&lines[1].comment), "{:?}", lines[1].comment);
        assert!(!is_doc(&lines[2].comment), "{:?}", lines[2].comment);
        assert!(is_doc(&lines[3].comment), "{:?}", lines[3].comment);
    }

    #[test]
    fn line_count_matches_lines() {
        let src = "a\nb\n\nc";
        assert_eq!(strip_source(src).len(), src.lines().count());
    }
}
