//! `no-deprecated-internal`: the `#[deprecated]` legacy submission shims
//! (`Coordinator::try_submit` / `Coordinator::submit_wait`) must not
//! grow internal callers.
//!
//! The shims exist solely for external compatibility until removal;
//! `rust/tests/integration_pipeline.rs` pins them behavior-identical to
//! the typed `Client::submit` path. Every *other* internal caller is
//! drift: it bypasses `SubmitOptions` (priority class, deadline, group
//! tag) and cancellation, and it delays the shims' removal.
//!
//! Any internal use of a deprecated item requires `#[allow(deprecated)]`
//! to build under the CI `-D warnings` wall, so the attribute is the
//! reliable marker: the rule flags `#[allow(deprecated)]` anywhere
//! outside the defining file and the pinning test, plus direct
//! `.try_submit(` / `::try_submit(` calls (`submit_wait` cannot be
//! matched textually — `Client::submit_wait` is the *blessed* path — but
//! calling the deprecated variant still trips the attribute check).

use super::rules::{RuleId, SourceFile, Violation};

/// Files allowed to reference the shims: where they are defined, and the
/// pinning test that holds them behavior-identical until removal.
const ALLOWED: [&str; 2] = ["src/coordinator/server.rs", "tests/integration_pipeline.rs"];

/// Run the rule over one file (test files included — only the pinning
/// test is exempt).
pub fn check(file: &SourceFile, out: &mut Vec<Violation>) {
    if ALLOWED.iter().any(|a| file.rel_path == *a || file.rel_path.ends_with(a)) {
        return;
    }
    for (idx, l) in file.lines.iter().enumerate() {
        let line = idx + 1;
        if l.code.contains(".try_submit(") || l.code.contains("::try_submit(") {
            out.push(Violation {
                rule: RuleId::NoDeprecatedInternal,
                file: file.rel_path.clone(),
                line,
                message: "internal caller of the deprecated try_submit shim: \
                          use Coordinator::client() + Client::submit(SubmitOptions::new(req))"
                    .into(),
            });
        }
        if l.code.contains("#[allow(deprecated)]") {
            out.push(Violation {
                rule: RuleId::NoDeprecatedInternal,
                file: file.rel_path.clone(),
                line,
                message: "allow(deprecated) outside the shim definitions and their \
                          pinning test: migrate to the typed Client API instead"
                    .into(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Violation> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn internal_try_submit_caller_flagged() {
        let out = run("src/net/server.rs", "let (id, rx) = coord.try_submit(req)?;\n");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, RuleId::NoDeprecatedInternal);
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn allow_deprecated_attribute_flagged_even_in_tests() {
        let out = run(
            "tests/integration_net.rs",
            "#[allow(deprecated)]\nlet o = coord.submit_wait(req).unwrap();\n",
        );
        assert_eq!(out.len(), 1, "{out:?}");
    }

    #[test]
    fn shim_definition_and_pinning_test_are_exempt() {
        let src = "#[allow(deprecated)]\nself.try_submit(req)\n";
        assert!(run("src/coordinator/server.rs", src).is_empty());
        assert!(run("tests/integration_pipeline.rs", src).is_empty());
    }

    #[test]
    fn typed_client_submit_wait_passes() {
        let out = run("src/main.rs", "let o = client.submit_wait(SubmitOptions::new(r))?;\n");
        assert!(out.is_empty(), "Client::submit_wait is the blessed path");
    }

    #[test]
    fn mention_in_comment_or_string_is_inert() {
        let out = run(
            "src/coordinator/mod.rs",
            "//! the `try_submit(...)` shim is deprecated\nlet s = \".try_submit(\";\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }
}
