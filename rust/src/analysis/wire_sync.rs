//! `wire-opcode-sync`: the wire protocol's `Frame` enum, its opcode
//! table, and the encode/decode match arms must stay in sync.
//!
//! The TCP tier's codec (`net/wire.rs`) spreads one protocol over four
//! places: the `OP_*` opcode constants, the `Frame` enum, `opcode()`,
//! `encode()` and `decode()`. Adding a frame and forgetting one of them
//! compiles fine (match arms on `_` or constants simply unused at one
//! end) but desyncs the protocol — the loopback differential gate only
//! catches frames a test happens to exercise. This rule mechanizes the
//! invariant:
//!
//! * every `Frame` variant is referenced in `opcode()`, `encode()` and
//!   `decode()`;
//! * every `const OP_*` opcode constant is referenced at least twice
//!   beyond its declaration (the `opcode()` table and the `decode()`
//!   dispatch).
//!
//! The rule fires on any scanned file named `wire.rs` that declares
//! `enum Frame`.

use super::rules::{RuleId, SourceFile, Violation};

/// `needle` occurs in `hay` as a whole token (no identifier characters
/// on either side).
fn contains_token(hay: &str, needle: &str) -> bool {
    count_token(hay, needle) > 0
}

fn count_token(hay: &str, needle: &str) -> usize {
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut count = 0usize;
    let mut from = 0usize;
    while let Some(at) = hay[from..].find(needle) {
        let start = from + at;
        let end = start + needle.len();
        let pre_ok = start == 0 || !hay[..start].chars().next_back().is_some_and(ident);
        let post_ok = !hay[end..].chars().next().is_some_and(ident);
        if pre_ok && post_ok {
            count += 1;
        }
        from = end;
    }
    count
}

/// Net brace delta of one sanitized code line.
fn brace_delta(code: &str) -> i32 {
    code.chars().fold(0, |d, c| match c {
        '{' => d + 1,
        '}' => d - 1,
        _ => d,
    })
}

/// Collect the concatenated code of a brace-balanced block starting at
/// 1-based `start` (the line containing the opening `{`).
fn block_code(file: &SourceFile, start: usize) -> String {
    let mut body = String::new();
    let mut depth = 0i32;
    let mut opened = false;
    for i in start..=file.lines.len() {
        let code = file.code(i);
        body.push_str(code);
        body.push('\n');
        depth += brace_delta(code);
        if depth > 0 {
            opened = true;
        }
        if opened && depth <= 0 {
            break;
        }
    }
    body
}

/// The `Frame` enum's variant names, with the enum's 1-based line.
fn frame_variants(file: &SourceFile) -> Option<(usize, Vec<String>)> {
    let n = file.lines.len();
    let start = (1..=n).find(|&i| {
        let c = file.code(i);
        c.contains("enum Frame") && !c.contains("enum FrameReader")
    })?;
    let mut variants = Vec::new();
    let mut depth = 0i32;
    for i in start..=n {
        if depth == 1 {
            let t = file.code(i).trim_start();
            if t.starts_with(|c: char| c.is_ascii_uppercase()) {
                let name: String =
                    t.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
                if !name.is_empty() {
                    variants.push(name);
                }
            }
        }
        depth += brace_delta(file.code(i));
        if i > start && depth <= 0 {
            break;
        }
    }
    Some((start, variants))
}

/// Run the rule over one file.
pub fn check(file: &SourceFile, out: &mut Vec<Violation>) {
    if !file.rel_path.ends_with("wire.rs") {
        return;
    }
    let Some((enum_line, variants)) = frame_variants(file) else { return };
    let n = file.lines.len();

    // The three codec functions, by (name, first line, body code).
    let funcs: Vec<(&str, usize, String)> = ["fn opcode(", "fn encode(", "fn decode("]
        .iter()
        .filter_map(|pat| {
            let line = (1..=n).find(|&i| file.code(i).contains(pat))?;
            let name = pat.trim_start_matches("fn ").trim_end_matches('(');
            Some((name, line, block_code(file, line)))
        })
        .collect();
    for pat in ["fn opcode(", "fn encode(", "fn decode("] {
        let name = pat.trim_start_matches("fn ").trim_end_matches('(');
        if !funcs.iter().any(|(f, _, _)| *f == name) {
            out.push(Violation {
                rule: RuleId::WireOpcodeSync,
                file: file.rel_path.clone(),
                line: enum_line,
                message: format!("wire codec is missing `fn {name}` for enum Frame"),
            });
        }
    }

    for v in &variants {
        let qualified = format!("Frame::{v}");
        for (fname, fline, body) in &funcs {
            if !contains_token(body, &qualified) {
                out.push(Violation {
                    rule: RuleId::WireOpcodeSync,
                    file: file.rel_path.clone(),
                    line: *fline,
                    message: format!(
                        "Frame::{v} has no match arm in {fname}() — wire protocol desync"
                    ),
                });
            }
        }
    }

    // Opcode constants: declaration + opcode() table + decode() dispatch.
    let all_code: String =
        (1..=n).map(|i| format!("{}\n", file.code(i))).collect();
    for i in 1..=n {
        let t = file.code(i).trim_start();
        let t = t.strip_prefix("pub ").unwrap_or(t);
        let Some(rest) = t.strip_prefix("const OP_") else { continue };
        let name: String = rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
        let full = format!("OP_{}", name.trim_end_matches(':'));
        let full = full.trim_end_matches(':').to_string();
        if count_token(&all_code, &full) < 3 {
            out.push(Violation {
                rule: RuleId::WireOpcodeSync,
                file: file.rel_path.clone(),
                line: i,
                message: format!(
                    "opcode constant {full} must be referenced by both opcode() and \
                     decode() (declaration alone is a desync)"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
const OP_PING: u8 = 0x01;
const OP_PONG: u8 = 0x81;
pub enum Frame {
    Ping { id: u64 },
    Pong(u64),
}
impl Frame {
    pub fn opcode(&self) -> u8 {
        match self {
            Frame::Ping { .. } => OP_PING,
            Frame::Pong(_) => OP_PONG,
        }
    }
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Frame::Ping { id } => enc(*id),
            Frame::Pong(v) => enc(*v),
        }
    }
    pub fn decode(op: u8, b: &[u8]) -> Frame {
        match op {
            OP_PING => Frame::Ping { id: 0 },
            OP_PONG => Frame::Pong(0),
            _ => panic!(),
        }
    }
}
";

    fn run(path: &str, src: &str) -> Vec<Violation> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn synced_codec_passes() {
        assert!(run("src/net/wire.rs", GOOD).is_empty());
    }

    #[test]
    fn missing_decode_arm_is_flagged() {
        let src = GOOD.replace("OP_PONG => Frame::Pong(0),", "");
        let out = run("src/net/wire.rs", &src);
        assert!(
            out.iter().any(|v| v.message.contains("Pong") && v.message.contains("decode")),
            "{out:?}"
        );
    }

    #[test]
    fn unused_opcode_constant_is_flagged() {
        let src = GOOD.replace("OP_PONG => Frame::Pong(0),", "_ => Frame::Pong(0),");
        let out = run("src/net/wire.rs", &src);
        assert!(out.iter().any(|v| v.message.contains("OP_PONG")), "{out:?}");
    }

    #[test]
    fn pub_const_opcodes_are_still_checked() {
        let src = GOOD
            .replace("const OP_PONG: u8 = 0x81;", "pub const OP_PONG: u8 = 0x81;")
            .replace("OP_PONG => Frame::Pong(0),", "_ => Frame::Pong(0),");
        let out = run("src/net/wire.rs", &src);
        assert!(out.iter().any(|v| v.message.contains("OP_PONG")), "{out:?}");
    }

    #[test]
    fn variant_prefix_collision_is_not_a_false_sync() {
        // `Submitted` arms must not satisfy the `Submit` variant.
        let src = GOOD
            .replace("Ping { id: u64 },", "Submit(u64),\n    Submitted(u64),")
            .replace("Frame::Ping { .. } => OP_PING,", "Frame::Submitted(_) => OP_PING,")
            .replace("Frame::Ping { id } => enc(*id),", "Frame::Submitted(v) => enc(*v),")
            .replace("OP_PING => Frame::Ping { id: 0 },", "OP_PING => Frame::Submitted(0),");
        let out = run("src/net/wire.rs", &src);
        assert!(
            out.iter().any(|v| v.message.contains("Frame::Submit ")),
            "Submit must be reported missing everywhere: {out:?}"
        );
    }

    #[test]
    fn non_wire_files_are_ignored() {
        assert!(run("src/net/server.rs", "enum Frame { X }\n").is_empty());
    }
}
