//! `adip lint` — repo-invariant static analysis over `rust/src/**`.
//!
//! # Why a hand-rolled linter
//!
//! The invariants this pass enforces are *this repo's* invariants, not
//! general Rust style — clippy cannot know that poison recovery is
//! load-bearing, that the wire codec has four places to keep in sync,
//! or which differential suite covers a backend dispatch site. The
//! linter is std-only (the repo has no proc-macro or syn dependency and
//! gains none here): a comment/string/raw-string-aware line scanner
//! ([`lexer`]) feeds a small rule framework ([`rules`]) so rules match
//! against *code* text with literals blanked and comments separated —
//! no false positives from `"Ordering::Relaxed"` inside a string or a
//! doc comment.
//!
//! # Rules
//!
//! | rule id | invariant |
//! |---|---|
//! | `atomic-ordering-justified` | every `Ordering::Relaxed` carries a `relaxed-ok: <why>`; `SeqCst` is banned ([`atomics`]) |
//! | `lock-poison-policy` | no bare `.unwrap()`/`.expect()` on lock guards outside tests ([`locks`]) |
//! | `no-deprecated-internal` | no internal callers of the deprecated submission shims ([`deprecated`]) |
//! | `wire-opcode-sync` | `Frame` variants ⇔ opcode table ⇔ encode/decode arms ([`wire_sync`]) |
//! | `backend-differential-registry` | every `Backend` dispatch site is mapped to a differential suite ([`backend_registry`]) |
//! | `wall-clock-containment` | `SystemTime::now` only inside `src/telemetry/`; serving paths use monotonic `Instant`s ([`wallclock`]) |
//! | `lint-annotation` | meta-rule: malformed/stale annotations and suppressions |
//!
//! # The memory-ordering audit (why `relaxed-ok` + a SeqCst ban)
//!
//! Every atomic in this codebase falls into one of three shapes, and
//! the annotation names which:
//!
//! 1. **Monotonic stat counters and gauges** (shed/failed/batch
//!    counters, queue-depth gauges, steal counters, cache hit/miss):
//!    values are reported, never used to synchronize. `Relaxed` is
//!    sufficient because no other memory access depends on them.
//! 2. **Unique-id allocation** (`next_id.fetch_add`): only uniqueness
//!    is required, which the RMW guarantees at any ordering.
//! 3. **Release/Acquire publication pairs** — the only places a
//!    happens-before edge is required, each documented at the site:
//!    * the obs span recorder publishes a record by `Release`-storing
//!      the header word after `Relaxed` payload stores; readers
//!      `Acquire`-load the header, ordering the payload reads;
//!    * the cancel registry `Release`-stores its length mirror after
//!      writing entries; the poll path `Acquire`-loads it;
//!    * the latency ring and reservoir shards pack each sample into a
//!      single atomic word, so slot stores need no cross-word ordering.
//!
//! `SeqCst` appears nowhere: every ordering is either genuinely relaxed
//! or a deliberate pair, and a `SeqCst` would paper over an unstated
//! protocol. The lint keeps it that way mechanically.
//!
//! # Annotation conventions
//!
//! * `// relaxed-ok: <why>` — same line as the `Ordering::Relaxed`, or
//!   a comment line directly above a contiguous run of Relaxed lines
//!   (covers the whole run).
//! * `// lint: allow(<rule-id>) <reason>` — suppresses one violation of
//!   `<rule-id>` on the same line or the line below. The reason is
//!   mandatory; unused suppressions are warnings (errors under
//!   `--deny-all`).
//! * Doc comments (`///`, `//!`, `/** */`) are inert to both grammars:
//!   they document the conventions (as this page does) without invoking
//!   them. Only plain `//` comments carry live annotations.
//!
//! # Scope
//!
//! The walker scans `*.rs` under the given root, skipping `vendor/`,
//! `target/`, hidden directories, and `lint_fixtures/` (the seeded
//! violation corpus is linted *directly* by its integration test, never
//! as part of a tree scan).

pub mod atomics;
pub mod backend_registry;
pub mod deprecated;
pub mod lexer;
pub mod locks;
pub mod report;
pub mod rules;
pub mod wallclock;
pub mod wire_sync;

use report::{LintReport, Suppressed};
use rules::{RuleId, SourceFile, Suppression, Violation};
use std::fs;
use std::io;
use std::path::Path;

/// Directory names never descended into during a tree scan.
const SKIP_DIRS: [&str; 3] = ["vendor", "target", "lint_fixtures"];

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if e.file_type()?.is_dir() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with('.') || SKIP_DIRS.contains(&name.as_ref()) {
                continue;
            }
            walk(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Root-relative path with forward slashes (stable across platforms for
/// reports, suppression scoping and the backend registry).
fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Split raw findings into (kept, suppressed, unused-suppression
/// warnings) given each file's parsed suppressions.
fn apply_suppressions(
    raw: Vec<Violation>,
    sups: &[(String, Suppression)],
) -> (Vec<Violation>, Vec<Suppressed>, Vec<Violation>) {
    let mut used = vec![false; sups.len()];
    let mut kept = Vec::new();
    let mut suppressed = Vec::new();
    for v in raw {
        // The meta-rule polices the annotations themselves; letting an
        // annotation silence it would be circular.
        let hit = (v.rule != RuleId::LintAnnotation)
            .then(|| {
                sups.iter().position(|(file, s)| {
                    *file == v.file
                        && s.rule == v.rule
                        && (s.line == v.line || s.line + 1 == v.line)
                })
            })
            .flatten();
        match hit {
            Some(i) => {
                used[i] = true;
                suppressed.push(Suppressed {
                    rule: v.rule,
                    file: v.file,
                    line: v.line,
                    reason: sups[i].1.reason.clone(),
                });
            }
            None => kept.push(v),
        }
    }
    let unused = sups
        .iter()
        .zip(&used)
        .filter(|(_, u)| !**u)
        .map(|((file, s), _)| Violation {
            rule: RuleId::LintAnnotation,
            file: file.clone(),
            line: s.line,
            message: format!("unused suppression: no {} violation here to allow", s.rule),
        })
        .collect();
    (kept, suppressed, unused)
}

/// Lint every `.rs` file under `root`. Strictness (`--deny-all`) is a
/// rendering/exit concern — the report always carries both severities.
pub fn run_lint(root: &Path) -> io::Result<LintReport> {
    let mut paths = Vec::new();
    walk(root, &mut paths)?;

    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let src = fs::read_to_string(p)?;
        files.push(SourceFile::new(rel_path(root, p), &src));
    }

    let mut raw = Vec::new();
    let mut warnings = Vec::new();
    let mut sups: Vec<(String, Suppression)> = Vec::new();
    for f in &files {
        let (file_sups, bad) = rules::parse_suppressions(f);
        raw.extend(bad);
        sups.extend(file_sups.into_iter().map(|s| (f.rel_path.clone(), s)));
        atomics::check(f, &mut raw, &mut warnings);
        locks::check(f, &mut raw);
        deprecated::check(f, &mut raw);
        wallclock::check(f, &mut raw);
        wire_sync::check(f, &mut raw);
    }
    backend_registry::check(&files, &mut raw);

    let (violations, suppressed, unused) = apply_suppressions(raw, &sups);
    warnings.extend(unused);

    let mut report = LintReport {
        files_scanned: files.len(),
        violations,
        warnings,
        suppressed,
    };
    report.sort();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(rule: RuleId, file: &str, line: usize) -> Violation {
        Violation { rule, file: file.into(), line, message: "m".into() }
    }

    fn sup(file: &str, rule: RuleId, line: usize) -> (String, Suppression) {
        (file.into(), Suppression { rule, line, reason: "why".into() })
    }

    #[test]
    fn suppression_covers_same_line_and_line_below() {
        let sups = vec![sup("src/a.rs", RuleId::LockPoisonPolicy, 5)];
        let raw = vec![
            v(RuleId::LockPoisonPolicy, "src/a.rs", 5),
            v(RuleId::LockPoisonPolicy, "src/a.rs", 6),
            v(RuleId::LockPoisonPolicy, "src/a.rs", 7),
        ];
        let (kept, suppressed, unused) = apply_suppressions(raw, &sups);
        assert_eq!(kept.len(), 1, "line 7 is out of the suppression's reach");
        assert_eq!(kept[0].line, 7);
        assert_eq!(suppressed.len(), 2);
        assert_eq!(suppressed[0].reason, "why");
        assert!(unused.is_empty());
    }

    #[test]
    fn suppression_is_rule_and_file_scoped() {
        let sups = vec![sup("src/a.rs", RuleId::LockPoisonPolicy, 5)];
        let raw = vec![
            v(RuleId::AtomicOrderingJustified, "src/a.rs", 5),
            v(RuleId::LockPoisonPolicy, "src/b.rs", 5),
        ];
        let (kept, suppressed, unused) = apply_suppressions(raw, &sups);
        assert_eq!(kept.len(), 2, "wrong rule / wrong file must not match");
        assert!(suppressed.is_empty());
        assert_eq!(unused.len(), 1, "the unmatched suppression is reported");
        assert_eq!(unused[0].rule, RuleId::LintAnnotation);
    }

    #[test]
    fn lint_annotation_violations_cannot_be_suppressed() {
        let sups = vec![sup("src/a.rs", RuleId::LintAnnotation, 3)];
        let raw = vec![v(RuleId::LintAnnotation, "src/a.rs", 3)];
        let (kept, suppressed, _) = apply_suppressions(raw, &sups);
        assert_eq!(kept.len(), 1, "meta-rule is not silenceable");
        assert!(suppressed.is_empty());
    }

    #[test]
    fn rel_path_uses_forward_slashes() {
        let root = Path::new("/repo/rust");
        let p = Path::new("/repo/rust/src/net/wire.rs");
        assert_eq!(rel_path(root, p), "src/net/wire.rs");
    }
}
