//! Lint report assembly and rendering (human text + JSON).
//!
//! The JSON writer is hand-rolled (std-only repo: no serde). The schema
//! is stable — CI uploads it as an artifact and downstream tooling may
//! parse it:
//!
//! ```json
//! {
//!   "files_scanned": 42,
//!   "deny_all": true,
//!   "clean": false,
//!   "violations": [ {"rule": "...", "file": "...", "line": 7, "message": "..."} ],
//!   "warnings":   [ ... same shape ... ],
//!   "suppressed": [ {"rule": "...", "file": "...", "line": 7, "reason": "..."} ]
//! }
//! ```

use super::rules::{RuleId, Violation};
use std::fmt::Write as _;

/// One applied suppression, for the report's audit trail.
#[derive(Debug, Clone)]
pub struct Suppressed {
    pub rule: RuleId,
    pub file: String,
    pub line: usize,
    pub reason: String,
}

/// Outcome of a lint run over one tree.
#[derive(Debug, Default)]
pub struct LintReport {
    pub files_scanned: usize,
    /// Blocking findings, sorted by (file, line, rule).
    pub violations: Vec<Violation>,
    /// Non-blocking findings (stale annotations / unused suppressions);
    /// promoted to blocking under `--deny-all`.
    pub warnings: Vec<Violation>,
    /// Violations silenced by a `lint: allow` with the audit reason.
    pub suppressed: Vec<Suppressed>,
}

impl LintReport {
    /// Canonical ordering for deterministic output.
    pub fn sort(&mut self) {
        let key = |v: &Violation| (v.file.clone(), v.line, v.rule.as_str());
        self.violations.sort_by_key(key);
        self.warnings.sort_by_key(key);
        self.suppressed.sort_by_key(|s| (s.file.clone(), s.line));
    }

    /// Whether the run passes under the given strictness.
    pub fn is_clean(&self, deny_all: bool) -> bool {
        self.violations.is_empty() && (!deny_all || self.warnings.is_empty())
    }

    /// Human-readable report.
    pub fn render_human(&self, deny_all: bool) -> String {
        let mut s = String::new();
        for v in &self.violations {
            let _ = writeln!(s, "error: {v}");
        }
        for w in &self.warnings {
            let label = if deny_all { "error(deny-all)" } else { "warning" };
            let _ = writeln!(s, "{label}: {w}");
        }
        let _ = writeln!(
            s,
            "adip lint: {} file(s), {} violation(s), {} warning(s), {} suppressed — {}",
            self.files_scanned,
            self.violations.len(),
            self.warnings.len(),
            self.suppressed.len(),
            if self.is_clean(deny_all) { "clean" } else { "FAILED" }
        );
        s
    }

    /// Stable JSON report (see module doc for the schema).
    pub fn render_json(&self, deny_all: bool) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        let _ = writeln!(s, "  \"files_scanned\": {},", self.files_scanned);
        let _ = writeln!(s, "  \"deny_all\": {deny_all},");
        let _ = writeln!(s, "  \"clean\": {},", self.is_clean(deny_all));
        render_violation_array(&mut s, "violations", &self.violations);
        s.push_str(",\n");
        render_violation_array(&mut s, "warnings", &self.warnings);
        s.push_str(",\n");
        s.push_str("  \"suppressed\": [");
        for (i, sup) in self.suppressed.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{\"rule\": {}, \"file\": {}, \"line\": {}, \"reason\": {}}}",
                json_str(sup.rule.as_str()),
                json_str(&sup.file),
                sup.line,
                json_str(&sup.reason)
            );
        }
        if !self.suppressed.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("]\n}\n");
        s
    }
}

fn render_violation_array(s: &mut String, name: &str, items: &[Violation]) {
    let _ = write!(s, "  \"{name}\": [");
    for (i, v) in items.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "\n    {{\"rule\": {}, \"file\": {}, \"line\": {}, \"message\": {}}}",
            json_str(v.rule.as_str()),
            json_str(&v.file),
            v.line,
            json_str(&v.message)
        );
    }
    if !items.is_empty() {
        s.push_str("\n  ");
    }
    s.push(']');
}

/// Minimal JSON string encoder (escapes quotes, backslashes, control
/// characters; everything else passes through as UTF-8).
fn json_str(v: &str) -> String {
    let mut out = String::with_capacity(v.len() + 2);
    out.push('"');
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LintReport {
        LintReport {
            files_scanned: 3,
            violations: vec![Violation {
                rule: RuleId::LockPoisonPolicy,
                file: "src/b.rs".into(),
                line: 9,
                message: "bare \"unwrap\"".into(),
            }],
            warnings: vec![Violation {
                rule: RuleId::LintAnnotation,
                file: "src/a.rs".into(),
                line: 2,
                message: "stale".into(),
            }],
            suppressed: vec![Suppressed {
                rule: RuleId::AtomicOrderingJustified,
                file: "src/a.rs".into(),
                line: 5,
                reason: "id counter".into(),
            }],
        }
    }

    #[test]
    fn clean_logic_respects_deny_all() {
        let mut r = sample();
        r.violations.clear();
        assert!(r.is_clean(false), "warnings alone pass by default");
        assert!(!r.is_clean(true), "deny-all promotes warnings");
        r.warnings.clear();
        assert!(r.is_clean(true));
    }

    #[test]
    fn human_render_has_spans_and_summary() {
        let out = sample().render_human(false);
        assert!(out.contains("error: src/b.rs:9: [lock-poison-policy]"), "{out}");
        assert!(out.contains("warning: src/a.rs:2: [lint-annotation]"));
        assert!(out.contains("3 file(s), 1 violation(s), 1 warning(s), 1 suppressed"));
        assert!(out.contains("FAILED"));
    }

    #[test]
    fn json_escapes_and_round_trips_fields() {
        let out = sample().render_json(true);
        assert!(out.contains("\"files_scanned\": 3"), "{out}");
        assert!(out.contains("\"deny_all\": true"));
        assert!(out.contains("\"clean\": false"));
        assert!(out.contains("\"rule\": \"lock-poison-policy\""));
        assert!(out.contains("\"message\": \"bare \\\"unwrap\\\"\""), "quote escaping: {out}");
        assert!(out.contains("\"reason\": \"id counter\""));
        // Balanced braces/brackets as a cheap well-formedness proxy.
        assert_eq!(out.matches('{').count(), out.matches('}').count());
        assert_eq!(out.matches('[').count(), out.matches(']').count());
    }

    #[test]
    fn empty_report_is_clean_and_renders_empty_arrays() {
        let r = LintReport::default();
        assert!(r.is_clean(true));
        let out = r.render_json(false);
        assert!(out.contains("\"violations\": []"), "{out}");
        assert!(out.contains("\"suppressed\": []"));
        assert!(r.render_human(false).contains("clean"));
    }

    #[test]
    fn sort_orders_by_file_then_line() {
        let mut r = LintReport::default();
        for (f, l) in [("src/z.rs", 1), ("src/a.rs", 9), ("src/a.rs", 2)] {
            r.violations.push(Violation {
                rule: RuleId::LockPoisonPolicy,
                file: f.into(),
                line: l,
                message: String::new(),
            });
        }
        r.sort();
        let got: Vec<_> = r.violations.iter().map(|v| (v.file.as_str(), v.line)).collect();
        assert_eq!(got, vec![("src/a.rs", 2), ("src/a.rs", 9), ("src/z.rs", 1)]);
    }
}
