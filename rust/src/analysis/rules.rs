//! Rule framework for `adip lint`: rule identities, violations,
//! per-file context, and the inline-suppression grammar.
//!
//! # Suppressions
//!
//! A violation is suppressed by an inline comment of the form
//!
//! ```text
//! // lint: allow(<rule-id>) <reason>
//! ```
//!
//! placed either on the violating line itself or on the line directly
//! above it. The reason is mandatory — a suppression without one is
//! itself a violation (`lint-annotation`). Suppressions that match no
//! violation are reported as warnings (promoted to errors under
//! `--deny-all`) so stale allows cannot accumulate.

use super::lexer::SourceLine;

/// Identity of every lint rule. `as_str` is the stable external name
/// used in reports, JSON and `lint: allow(...)` suppressions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RuleId {
    /// Every `Ordering::Relaxed` carries a `relaxed-ok:` justification;
    /// `SeqCst` is banned outright.
    AtomicOrderingJustified,
    /// No bare `.unwrap()` / `.expect()` on `Mutex`/`RwLock` guards
    /// outside test code (the poison-recovery idiom is mandatory).
    LockPoisonPolicy,
    /// No internal callers of the `#[deprecated]` submission shims
    /// outside the shims themselves and their pinning test.
    NoDeprecatedInternal,
    /// `net/wire.rs` opcode variants stay in sync with their
    /// `opcode()`/`encode()`/`decode()` match arms.
    WireOpcodeSync,
    /// Every module matching on `Backend` appears in the checked
    /// registry mapping it to the differential suite covering it.
    BackendDifferentialRegistry,
    /// `SystemTime::now` only inside `src/telemetry/` (the operator-
    /// facing timestamp helper); everything else uses monotonic
    /// `Instant`s.
    WallClockContainment,
    /// Meta-rule: malformed or unused `lint: allow` / `relaxed-ok`
    /// annotations.
    LintAnnotation,
}

impl RuleId {
    /// All rules, in report order.
    pub const ALL: [RuleId; 7] = [
        RuleId::AtomicOrderingJustified,
        RuleId::LockPoisonPolicy,
        RuleId::NoDeprecatedInternal,
        RuleId::WireOpcodeSync,
        RuleId::BackendDifferentialRegistry,
        RuleId::WallClockContainment,
        RuleId::LintAnnotation,
    ];

    /// Stable external rule name.
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleId::AtomicOrderingJustified => "atomic-ordering-justified",
            RuleId::LockPoisonPolicy => "lock-poison-policy",
            RuleId::NoDeprecatedInternal => "no-deprecated-internal",
            RuleId::WireOpcodeSync => "wire-opcode-sync",
            RuleId::BackendDifferentialRegistry => "backend-differential-registry",
            RuleId::WallClockContainment => "wall-clock-containment",
            RuleId::LintAnnotation => "lint-annotation",
        }
    }

    /// Parse an external rule name (as written in a suppression).
    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.as_str() == s)
    }
}

impl std::fmt::Display for RuleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One finding: rule, file, 1-based line, human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: RuleId,
    /// Path relative to the scan root, forward slashes.
    pub file: String,
    /// 1-based line number the finding anchors to.
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// One scanned file, pre-lexed, with its test-code classification.
pub struct SourceFile {
    /// Path relative to the scan root, forward slashes.
    pub rel_path: String,
    /// Sanitized (code, comment) per line — see [`super::lexer`].
    pub lines: Vec<SourceLine>,
    /// Per-line: is this line test code? True for every line of files
    /// under `tests/` or `benches/`, and for lines at or after a
    /// column-0 `#[cfg(test)]` that introduces a `mod` (the repo-wide
    /// test-module-at-end-of-file convention).
    pub is_test: Vec<bool>,
}

impl SourceFile {
    /// Classify and pre-lex one file.
    pub fn new(rel_path: String, source: &str) -> SourceFile {
        let lines = super::lexer::strip_source(source);
        let file_is_test = rel_path.starts_with("tests/") || rel_path.starts_with("benches/");
        let mut is_test = vec![file_is_test; lines.len()];
        if !file_is_test {
            // A column-0 `#[cfg(test)]` followed (allowing further
            // attributes) by a `mod` marks the in-file test module; by
            // repo convention it is the last item, so everything from
            // the attribute on is test code. Indented `#[cfg(test)]`
            // attributes gate single items inside production code and
            // are deliberately NOT treated as a region start.
            for (i, l) in lines.iter().enumerate() {
                if l.code.starts_with("#[cfg(test)]") {
                    let opens_mod = lines[i + 1..]
                        .iter()
                        .map(|n| n.code.trim_start())
                        .find(|t| !t.is_empty() && !t.starts_with("#["))
                        .is_some_and(|t| t.starts_with("mod ") || t.starts_with("pub mod "));
                    if opens_mod {
                        for t in is_test.iter_mut().skip(i) {
                            *t = true;
                        }
                        break;
                    }
                }
            }
        }
        SourceFile { rel_path, lines, is_test }
    }

    /// Sanitized code of 1-based line `n` ("" when out of range).
    pub fn code(&self, n: usize) -> &str {
        self.lines.get(n - 1).map_or("", |l| l.code.as_str())
    }

    /// Comment text of 1-based line `n` ("" when out of range).
    pub fn comment(&self, n: usize) -> &str {
        self.lines.get(n - 1).map_or("", |l| l.comment.as_str())
    }

    /// Whether 1-based line `n` is test code.
    pub fn is_test_line(&self, n: usize) -> bool {
        self.is_test.get(n - 1).copied().unwrap_or(false)
    }
}

/// One parsed `lint: allow(<rule>) <reason>` suppression.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    pub rule: RuleId,
    /// 1-based line the comment sits on. It covers this line and the
    /// next.
    pub line: usize,
    pub reason: String,
}

/// Extract every suppression in a file. Malformed ones (unknown rule,
/// missing reason, unbalanced paren) are returned as `lint-annotation`
/// violations instead. Doc comments are inert: they *describe* the
/// grammar (as this module's own docs do), they cannot invoke it.
pub fn parse_suppressions(file: &SourceFile) -> (Vec<Suppression>, Vec<Violation>) {
    const MARKER: &str = "lint: allow(";
    let mut sups = Vec::new();
    let mut bad = Vec::new();
    for (idx, l) in file.lines.iter().enumerate() {
        let line = idx + 1;
        if super::lexer::is_doc(&l.comment) {
            continue;
        }
        let Some(at) = l.comment.find(MARKER) else { continue };
        let rest = &l.comment[at + MARKER.len()..];
        let Some(close) = rest.find(')') else {
            bad.push(Violation {
                rule: RuleId::LintAnnotation,
                file: file.rel_path.clone(),
                line,
                message: "malformed suppression: missing ')' after rule name".into(),
            });
            continue;
        };
        let name = rest[..close].trim();
        let reason = rest[close + 1..].trim();
        let Some(rule) = RuleId::parse(name) else {
            bad.push(Violation {
                rule: RuleId::LintAnnotation,
                file: file.rel_path.clone(),
                line,
                message: format!("suppression names unknown rule {name:?}"),
            });
            continue;
        };
        if reason.is_empty() {
            bad.push(Violation {
                rule: RuleId::LintAnnotation,
                file: file.rel_path.clone(),
                line,
                message: format!("suppression for {rule} has no reason — say why"),
            });
            continue;
        }
        sups.push(Suppression { rule, line, reason: reason.to_string() });
    }
    (sups, bad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_names_round_trip() {
        for r in RuleId::ALL {
            assert_eq!(RuleId::parse(r.as_str()), Some(r));
        }
        assert_eq!(RuleId::parse("no-such-rule"), None);
    }

    #[test]
    fn test_region_starts_at_cfg_test_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\n";
        let f = SourceFile::new("src/x.rs".into(), src);
        assert!(!f.is_test_line(1));
        assert!(f.is_test_line(2));
        assert!(f.is_test_line(4));
    }

    #[test]
    fn indented_cfg_test_attribute_is_not_a_region() {
        let src = "enum W {\n    #[cfg(test)]\n    Panic,\n}\nfn hot() {}\n";
        let f = SourceFile::new("src/x.rs".into(), src);
        assert!(!f.is_test_line(5), "item-level cfg(test) must not swallow the file");
    }

    #[test]
    fn cfg_test_without_mod_is_not_a_region() {
        let src = "#[cfg(test)]\nfn helper() {}\nfn hot() {}\n";
        let f = SourceFile::new("src/x.rs".into(), src);
        assert!(!f.is_test_line(3));
    }

    #[test]
    fn tests_and_benches_files_are_all_test_code() {
        let f = SourceFile::new("tests/integration_x.rs".into(), "fn a() {}\n");
        assert!(f.is_test_line(1));
        let f = SourceFile::new("benches/bench_x.rs".into(), "fn a() {}\n");
        assert!(f.is_test_line(1));
    }

    #[test]
    fn suppression_parses_rule_and_reason() {
        let f = SourceFile::new(
            "src/x.rs".into(),
            "let x = 1; // lint: allow(lock-poison-policy) guard cannot poison here\n",
        );
        let (sups, bad) = parse_suppressions(&f);
        assert!(bad.is_empty());
        assert_eq!(sups.len(), 1);
        assert_eq!(sups[0].rule, RuleId::LockPoisonPolicy);
        assert_eq!(sups[0].line, 1);
        assert_eq!(sups[0].reason, "guard cannot poison here");
    }

    #[test]
    fn suppression_requires_known_rule_and_reason() {
        let f = SourceFile::new(
            "src/x.rs".into(),
            "// lint: allow(bogus-rule) text\n// lint: allow(lock-poison-policy)\n// lint: allow(lock-poison-policy\n",
        );
        let (sups, bad) = parse_suppressions(&f);
        assert!(sups.is_empty());
        assert_eq!(bad.len(), 3);
        assert!(bad[0].message.contains("unknown rule"));
        assert!(bad[1].message.contains("no reason"));
        assert!(bad[2].message.contains("missing ')'"));
    }

    #[test]
    fn suppression_examples_in_doc_comments_are_inert() {
        let f = SourceFile::new(
            "src/x.rs".into(),
            "/// // lint: allow(<rule-id>) <reason>\n//! lint: allow(bogus) example\n",
        );
        let (sups, bad) = parse_suppressions(&f);
        assert!(sups.is_empty() && bad.is_empty(), "{bad:?}");
    }

    #[test]
    fn suppression_inside_string_is_inert() {
        let f = SourceFile::new(
            "src/x.rs".into(),
            "let s = \"// lint: allow(lock-poison-policy) fake\";\n",
        );
        let (sups, bad) = parse_suppressions(&f);
        assert!(sups.is_empty() && bad.is_empty());
    }
}
