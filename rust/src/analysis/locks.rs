//! `lock-poison-policy`: no bare `.unwrap()` / `.expect()` on
//! `Mutex`/`RwLock` guards outside test code.
//!
//! The worker-panic recovery design (cluster pool workers rebuild their
//! core after a caught panic; the coordinator keeps serving) depends on
//! every lock acquisition using the documented poison idiom:
//!
//! ```text
//! self.state.lock().unwrap_or_else(PoisonError::into_inner)
//! ```
//!
//! A bare `.unwrap()` turns one panicking thread into a cascade: every
//! later acquirer of the poisoned lock panics too, wedging threads that
//! were designed to survive. The rule flags `.lock()` / `.read()` /
//! `.write()` (empty-argument forms — `Read::read(&mut buf)` and friends
//! take arguments and do not match) immediately followed by `.unwrap()`
//! or `.expect(`, on the same line or split across a method-chain line
//! break. Genuinely-fine sites (e.g. a guard that provably cannot
//! poison) use `// lint: allow(lock-poison-policy) <reason>`.

use super::rules::{RuleId, SourceFile, Violation};

const ACQUIRERS: [&str; 3] = [".lock()", ".read()", ".write()"];

/// Does `tail` (code following an acquirer) begin a bare guard unwrap?
fn bare_unwrap(tail: &str) -> bool {
    let t = tail.trim_start();
    t.starts_with(".unwrap()") || t.starts_with(".expect(")
}

/// Run the rule over one file.
pub fn check(file: &SourceFile, out: &mut Vec<Violation>) {
    let n = file.lines.len();
    for i in 1..=n {
        if file.is_test_line(i) {
            continue;
        }
        let code = file.code(i);
        let mut hit = false;
        for acq in ACQUIRERS {
            let mut from = 0usize;
            while let Some(at) = code[from..].find(acq) {
                let end = from + at + acq.len();
                if bare_unwrap(&code[end..]) {
                    hit = true;
                }
                // Chain split across lines: `.lock()` at end of line,
                // `.unwrap()` leading the next code line.
                if code[end..].trim().is_empty() && bare_unwrap(file.code(i + 1)) {
                    hit = true;
                }
                from = end;
            }
        }
        if hit {
            out.push(Violation {
                rule: RuleId::LockPoisonPolicy,
                file: file.rel_path.clone(),
                line: i,
                message: "bare unwrap/expect on a lock guard: use \
                          `.unwrap_or_else(PoisonError::into_inner)` (poison \
                          recovery is load-bearing for worker-panic survival)"
                    .into(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> Vec<Violation> {
        let f = SourceFile::new("src/x.rs".into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn bare_lock_unwrap_and_expect_flagged() {
        let out = run("let g = m.lock().unwrap();\nlet h = m.lock().expect(\"msg\");\n");
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].rule, RuleId::LockPoisonPolicy);
        assert_eq!((out[0].line, out[1].line), (1, 2));
    }

    #[test]
    fn rwlock_read_write_guards_flagged() {
        let out = run("let r = l.read().unwrap();\nlet w = l.write().expect(\"x\");\n");
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn poison_idiom_passes() {
        let out = run(
            "let g = m.lock().unwrap_or_else(PoisonError::into_inner);\n\
             let h = m.lock().unwrap_or_else(|e| e.into_inner());\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn io_read_with_arguments_is_not_a_guard() {
        let out = run("let n = sock.read(&mut buf).unwrap();\n");
        assert!(out.is_empty(), "Read::read takes args; not a lock");
    }

    #[test]
    fn split_chain_is_still_caught() {
        let out = run("let g = m\n    .lock()\n    .unwrap();\n");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2, "anchored at the acquirer line");
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() { m.lock().unwrap(); }\n}\n";
        assert!(run(src).is_empty());
    }

    #[test]
    fn result_unwrap_on_non_guard_passes() {
        let out = run("let v = compute().unwrap();\nlet w = parse().expect(\"p\");\n");
        assert!(out.is_empty());
    }
}
