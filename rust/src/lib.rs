//! # ADiP — Adaptive-Precision Systolic Array for Matrix Multiplication Acceleration
//!
//! Full-system reproduction of *“ADiP: Adaptive-Precision Systolic Array for
//! Matrix Multiplication Acceleration”* (Abdelmaksoud, Sestito, Wang,
//! Prodromakis — CS.AR 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides, bottom-up:
//!
//! * [`quant`] — precision modes (8b×8b / 8b×4b / 8b×2b), subword packing,
//!   quantization helpers (incl. BitNet-style ternary).
//! * [`dataflow`] — the ADiP/DiP preprocessing pipeline: column-rotation
//!   permutation, 2-/3-/4-way weight-tile interleaving, and Algorithm 1
//!   block (tiled) matrix multiplication.
//! * [`arch`] — bit-exact functional + cycle models of the reconfigurable
//!   PE (16 × 2-bit multipliers), the shared shifter/accumulator column
//!   unit, and the ADiP / DiP / weight-stationary (WS) arrays.
//! * [`analytical`] — the paper’s closed-form latency/throughput models
//!   (Eqs. (1)–(3)) plus the DiP-paper-derived WS/DiP baselines.
//! * [`sim`] — the cycle-accurate simulator used by the paper’s §V-B
//!   evaluation: tile-level timing, multi-bank SRAM / DRAM access
//!   accounting, and energy integration.
//! * [`power`] — 22 nm post-PnR-calibrated area/power models (Table I,
//!   Fig. 7) and DeepScaleTool-style technology normalization (Table II).
//! * [`workload`] — Transformer attention workload generators for GPT-2
//!   medium, BERT large and BitNet-1.58B (Fig. 1 / Fig. 8).
//! * [`coordinator`] — the L3 serving layer: request router, shared-input
//!   batcher (the asymmetric multi-matrix mode), tile scheduler,
//!   backpressure and metrics.
//! * [`balance`] — the coordinator-wide execution fabric: a global
//!   injector + per-worker deques with work-stealing (`StealPolicy`) and
//!   cross-request shard coalescing into asymmetric shared-input passes
//!   (see `balance/mod.rs` for the design doc).
//! * [`net`] — the network serving tier: a length-prefixed TCP wire
//!   protocol over the coordinator's `Client` API with row-band
//!   streaming of large outputs, backpressure mapped onto admission
//!   bounds (`Busy`), remote cancellation (`Cancel` → `Ticket::cancel`)
//!   and graceful drain (see `net/mod.rs` for the frame table).
//! * [`obs`] — per-ticket lifecycle tracing: a bounded, sharded,
//!   lock-free span recorder covering the whole pipeline, exported as
//!   Chrome/Perfetto trace-event JSON (`--trace-out`) and per ticket
//!   via `Ticket::trace()`.
//! * [`telemetry`] — the live telemetry tier: a background sampler
//!   deriving windowed rates/shapes from the metrics hub into bounded
//!   ring time-series, a watchdog rule engine (queue stall, deque skew,
//!   cache thrash, prepare backlog, worker panic), and a hand-rolled
//!   HTTP/1.1 scrape endpoint serving `/metrics`, `/healthz` and
//!   `/statusz` (`--telemetry=HOST:PORT`).
//! * [`cluster`] — multi-core execution: shards one GEMM (or shared-input
//!   set) across a persistent pool of array-core workers (pipelined shard
//!   ingress; legacy spawn-per-run engine kept as baseline) with a
//!   weight-tile cache shareable across coordinator workers, merging
//!   outputs bit-exactly and accounting per the max/sum/broadcast rules
//!   plus the explicit K-split reduce term (see `cluster/mod.rs`).
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) from the request path.
//! * [`report`] — regenerates every table and figure of the paper’s
//!   evaluation as text/CSV.
//! * [`analysis`] — the `adip lint` static analysis pass: repo-invariant
//!   rules (atomic-ordering justification, lock-poison policy, deprecated
//!   shim containment, wire-codec sync, backend differential registry)
//!   over a std-only comment/string-aware scanner. CI runs it blocking
//!   with `--deny-all=true`.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod analytical;
pub mod arch;
pub mod balance;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod net;
pub mod obs;
pub mod power;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod testutil;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
