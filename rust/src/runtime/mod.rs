//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` **once** at build time,
//! lowering the L2 JAX model (which calls the L1 Pallas kernels) to **HLO
//! text** under `artifacts/`. This module loads those files through the
//! `xla` crate (PJRT C API, CPU client), compiles them once, and executes
//! them from the request path — Python never runs at serving time.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! The PJRT path needs the external `xla` crate, which the offline build
//! environment does not provide. It is therefore gated behind the `pjrt`
//! cargo feature (add the `xla` dependency and build with
//! `--features pjrt`); without the feature [`ArtifactRuntime`] is a stub
//! whose `load` always fails and `try_load` always degrades gracefully —
//! callers already handle both paths.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context};
use anyhow::Result;

use crate::dataflow::Mat;

/// A loaded, compiled artifact registry backed by one PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl ArtifactRuntime {
    /// Create a runtime over `dir`, compiling every `*.hlo.txt` found.
    /// Returns an error if the directory is missing or empty — callers that
    /// want graceful degradation use [`ArtifactRuntime::try_load`].
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = HashMap::new();
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("artifacts directory {}", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) if n.ends_with(".hlo.txt") => n.trim_end_matches(".hlo.txt").to_string(),
                _ => continue,
            };
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            executables.insert(name, exe);
        }
        if executables.is_empty() {
            bail!("no *.hlo.txt artifacts in {}", dir.display());
        }
        Ok(ArtifactRuntime { client, executables, dir })
    }

    /// Like [`ArtifactRuntime::load`] but returns `None` when artifacts are
    /// absent (CI / before `make artifacts`), logging the reason to stderr.
    pub fn try_load(dir: impl AsRef<Path>) -> Option<ArtifactRuntime> {
        match ArtifactRuntime::load(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("[runtime] artifacts unavailable ({e}); functional fallback in use");
                None
            }
        }
    }

    /// Names of loaded executables.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute artifact `name` with f32 tensor inputs, returning the
    /// f32 outputs. Inputs are `(data, shape)` pairs; the artifact must
    /// have been lowered with `return_tuple=True` (aot.py does).
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})", self.names()))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name:?}: {e:?}"))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name:?}: {e:?}"))?;
        let tuple = out.decompose_tuple().map_err(|e| anyhow!("decomposing tuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(tuple.len());
        for t in tuple {
            vecs.push(t.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        Ok(vecs)
    }
}

/// Stub used when the crate is built without the `pjrt` feature: loading
/// always fails with an explanatory message, so `try_load` callers fall
/// back to the rust-functional numerics exactly as they do when the
/// artifacts have not been built.
#[cfg(not(feature = "pjrt"))]
pub struct ArtifactRuntime {
    dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl ArtifactRuntime {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let dir = dir.as_ref();
        anyhow::bail!(
            "cannot load artifacts from {}: built without the `pjrt` feature \
             (add the `xla` dependency and rebuild with `--features pjrt`)",
            dir.display()
        )
    }

    /// Like [`ArtifactRuntime::load`] but returns `None`, logging the reason.
    pub fn try_load(dir: impl AsRef<Path>) -> Option<ArtifactRuntime> {
        match ArtifactRuntime::load(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("[runtime] artifacts unavailable ({e}); functional fallback in use");
                None
            }
        }
    }

    /// Names of loaded executables (always empty in the stub).
    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    /// The artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without pjrt feature)".to_string()
    }

    /// Always fails: the PJRT backend is not compiled in.
    pub fn run_f32(&self, name: &str, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("unknown artifact {name:?}: built without the `pjrt` feature")
    }
}

/// Convert an integer matrix to the f32 buffer layout the artifacts take.
pub fn mat_to_f32(m: &Mat) -> Vec<f32> {
    m.as_slice().iter().map(|&v| v as f32).collect()
}

/// Convert an f32 output buffer back to an integer matrix (values are
/// exact integers for the quantized kernels; rounded defensively).
pub fn f32_to_mat(data: &[f32], rows: usize, cols: usize) -> Mat {
    assert_eq!(data.len(), rows * cols, "shape mismatch");
    Mat::from_vec(rows, cols, data.iter().map(|&v| v.round() as i32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn mat_roundtrip_through_f32() {
        let mut rng = Rng::seeded(1001);
        let m = Mat::random(&mut rng, 5, 7, 8);
        let f = mat_to_f32(&m);
        assert_eq!(f32_to_mat(&f, 5, 7), m);
    }

    #[test]
    fn missing_artifacts_fail_gracefully() {
        assert!(ArtifactRuntime::try_load("/nonexistent/path").is_none());
        let empty = std::env::temp_dir().join("adip-empty-artifacts");
        let _ = std::fs::create_dir_all(&empty);
        assert!(ArtifactRuntime::try_load(&empty).is_none());
    }

    // Full load-and-execute coverage lives in rust/tests/runtime_artifacts.rs
    // (integration test, skipped when `make artifacts` has not run).
}
