//! L3 coordinator — the serving layer around the simulated accelerator.
//!
//! A vLLM-router-style stack scaled to this paper: matmul/attention
//! requests arrive on a bounded queue, a precision selector picks the
//! execution mode, the **shared-input batcher** fuses compatible requests
//! into ADiP's asymmetric multi-matrix passes, and a pool of worker threads
//! (one simulated array core each) executes them through the co-simulator,
//! returning exact numerics + cycle/energy/memory accounting per request.
//!
//! * [`request`] — request/response types.
//! * [`precision`] — weight-precision → [`crate::quant::PrecisionMode`]
//!   selection policy (activation-to-activation pins 8b×8b).
//! * [`batcher`] — groups requests that share an input matrix into
//!   interleave sets (the Fig. 5(d) Q/K/V mode), never mixing shapes or
//!   modes.
//! * [`scheduler`] — turns batches into tile schedules on a core.
//! * [`server`] — the bounded-queue, multi-worker coordinator with
//!   backpressure and graceful shutdown. Each worker owns a
//!   [`crate::cluster::ClusterScheduler`] (a degenerate 1-core cluster on
//!   the persistent pool engine by default), so
//!   `CoordinatorConfig::cluster` can shard every request across a mesh of
//!   cores; one coordinator-wide shared weight-cache store lets sibling
//!   workers reuse each other's repeated projection tiles.
//! * [`metrics`] — atomic counters with a Prometheus-style text dump.

pub mod batcher;
pub mod metrics;
pub mod precision;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{form_batches, Batch};
pub use metrics::Metrics;
pub use precision::select_mode;
pub use request::{MatmulRequest, RequestId, RequestOutcome, ResponseMetrics};
pub use scheduler::CoreScheduler;
pub use server::{Coordinator, CoordinatorConfig};
