//! L3 coordinator — the serving layer around the simulated accelerator.
//!
//! A vLLM-router-style stack scaled to this paper, restructured as an
//! explicit three-stage **admit → prepare → execute** pipeline so
//! host-side preparation of request `i+1` overlaps execution of request
//! `i`:
//!
//! 1. **Admit** — callers hold a [`Client`] handle and submit through a
//!    [`SubmitOptions`] builder carrying a [`Priority`] class
//!    (`Interactive` / `Batch` / `Background`), an optional soft
//!    deadline, and an optional group tag that pre-declares shared-input
//!    fusion (Q/K/V off one `X` submitted as one group). Admission
//!    validates shapes *and* operand ranges, classifies, and enqueues
//!    onto the bounded ingress queue (full queue = backpressure reject).
//!    Every submission resolves through a typed [`Ticket`]
//!    (`wait`/`try_wait`/`wait_timeout`/`id`).
//! 2. **Prepare** — the router forms batches in a deterministic
//!    priority/deadline order (aging promotes overdue `Background` work,
//!    so nothing starves) via the **shared-input batcher** (which fixes
//!    each batch's precision mode as part of its fusion key), then a
//!    prepare-stage thread per worker fingerprints operands into
//!    `PreparedBatch`es queued ahead of execution — workers never idle
//!    on host-side packing.
//! 3. **Execute** — worker threads (one simulated cluster each) pull
//!    batches off the coordinator-wide **balance fabric**
//!    ([`crate::balance`]): formed batches land on their owner's deque,
//!    and — policy permitting ([`StealPolicy`]) — an idle worker pops the
//!    global injector or steals from the deepest sibling, so a skewed
//!    trace can no longer idle whole clusters. Compatible batches from
//!    *different* requests (byte-identical weight sets, same mode and
//!    shape) may be coalesced into one asymmetric shared-input pass
//!    ([`CoalesceConfig`]), with outputs and row-share accounting split
//!    back per ticket. Execution runs through the co-simulator as ADiP's
//!    multi-matrix passes, returning exact numerics + cycle/energy/memory
//!    accounting per request. Opt-in **deadline shedding**
//!    ([`shed_verdict`]) fails hopeless Background work fast with a
//!    distinct `shed:` error and demotes hopeless higher classes.
//!
//! * [`client`] — [`Client`] / [`SubmitOptions`] / [`Ticket`] /
//!   [`Priority`]: the public submission surface, including first-class
//!   cancellation ([`Ticket::cancel`] kills a request at any pipeline
//!   boundary, surfacing as [`RequestError::Cancelled`]). The legacy
//!   `Coordinator::try_submit` / `submit_wait` shims are `#[deprecated]`
//!   (still asserted byte-identical by the differential suite until
//!   removal).
//! * [`request`] — request/response types and the typed [`RequestError`]
//!   failure taxonomy (Shed / Cancelled / RangeCheck / Shutdown / …).
//! * [`precision`] — weight-precision → [`crate::quant::PrecisionMode`]
//!   selection policy (activation-to-activation pins 8b×8b); invoked by
//!   the prepare stage, off the execute path.
//! * [`batcher`] — priority/deadline/aging-ordered batch formation
//!   ([`batcher::plan_batches`]) over the shared-input fusion rules (the
//!   Fig. 5(d) Q/K/V mode), never mixing shapes or modes.
//! * [`prepare`] — the prepare stage: mode selection + operand
//!   fingerprinting on dedicated stage threads
//!   (`PrepareMode::Pipelined`, default) or inline on the worker
//!   (`PrepareMode::Inline`, the benchmarked serial baseline).
//! * [`scheduler`] — turns batches into tile schedules on a core.
//! * [`server`] — the pipeline itself: bounded-queue admission, router,
//!   prepare stage, multi-worker execution, backpressure and graceful
//!   shutdown. Each worker owns a [`crate::cluster::ClusterScheduler`]
//!   (a degenerate 1-core cluster on the persistent pool engine by
//!   default), so `CoordinatorConfig::cluster` can shard every request
//!   across a mesh of cores; one coordinator-wide shared weight-cache
//!   store lets sibling workers reuse each other's projection tiles.
//! * [`metrics`] — atomic counters with a Prometheus-style text dump,
//!   including per-class queue-wait series and the `prepared_depth`
//!   gauge that makes prepare/execute overlap observable. Carries the
//!   pipeline-wide [`crate::obs::Recorder`] for per-ticket lifecycle
//!   tracing (`CoordinatorConfig::trace`, off by default).

pub mod batcher;
pub mod client;
pub mod metrics;
pub mod precision;
pub(crate) mod prepare;
pub mod request;
pub mod scheduler;
pub mod server;

pub use crate::balance::{CoalesceConfig, StealPolicy};
pub use crate::obs::{SpanKind, SpanRecord, TraceMode};
pub use batcher::{form_batches, plan_batches, shed_verdict, Batch, Lane, ShedVerdict, WindowPlan};
pub use client::{Client, Priority, SubmitOptions, Ticket};
pub use metrics::Metrics;
pub use precision::select_mode;
pub use request::{
    MatmulRequest, RequestError, RequestId, RequestOutcome, ResponseMetrics, SHED_ERROR_PREFIX,
};
pub use scheduler::CoreScheduler;
pub use server::{Coordinator, CoordinatorConfig, PrepareMode};
