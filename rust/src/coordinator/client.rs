//! Client-facing submission API: [`Client`] handles, [`SubmitOptions`]
//! and typed [`Ticket`] completion objects.
//!
//! This is the coordinator's public serving surface. A [`Client`] is a
//! cheap, cloneable handle onto a running [`super::Coordinator`]; every
//! submission goes through a [`SubmitOptions`] builder that carries the
//! request plus its scheduling intent:
//!
//! * a [`Priority`] class (`Interactive` ahead of `Batch` ahead of
//!   `Background` in the batcher's deterministic service order),
//! * an optional **soft deadline** (deadline-ascending ordering within a
//!   class — a hint to the scheduler, never an admission filter), and
//! * an optional **group tag** that pre-declares shared-input fusion: all
//!   members of a group share one `input_id`, so Q/K/V projections off one
//!   `X` submitted as one group are fused into a single multi-matrix pass
//!   whenever they land in the same batching window.
//!
//! A successful submit returns a [`Ticket`] — the typed replacement for
//! the raw `Receiver<RequestOutcome>` the old API exposed — with
//! [`Ticket::wait`], [`Ticket::try_wait`], [`Ticket::wait_timeout`],
//! [`Ticket::id`] and first-class cancellation via [`Ticket::cancel`]
//! (honored at every pipeline boundary; a killed request resolves to
//! `Err(RequestError::Cancelled)`). The legacy `Coordinator::try_submit` /
//! `Coordinator::submit_wait` entry points are `#[deprecated]` thin shims
//! over this path (still asserted byte-identical by the differential
//! suite in `rust/tests/integration_pipeline.rs` until removal).

use std::collections::HashSet;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::{Recorder, SpanKind, SpanRecord, LANE_CLIENT};

use super::metrics::Metrics;
use super::request::{Envelope, MatmulRequest, RequestId, RequestOutcome};

/// Service class of a request. Classes earlier in [`Priority::ALL`] are
/// served first; the batcher's aging rule promotes overdue lower-class
/// work so nothing starves (see `batcher::plan_batches`). The single
/// source of truth for the service order is [`Priority::rank`] — the
/// enum deliberately does not derive `Ord`, so declaration order can
/// never silently diverge from the scheduler's order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Priority {
    /// Latency-critical requests (e.g. decode-path attention scores):
    /// served ahead of everything else.
    Interactive,
    /// The default class for throughput work (projection GEMM streams).
    #[default]
    Batch,
    /// Best-effort work (trace replays, offline re-scoring): served last,
    /// but aged into higher classes rather than starved.
    Background,
}

impl Priority {
    /// All classes, in service order.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Batch, Priority::Background];
    /// Number of classes (sizes the per-class metric arrays).
    pub const COUNT: usize = 3;

    /// Service rank: 0 is served first.
    pub fn rank(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
            Priority::Background => 2,
        }
    }

    /// Index into per-class metric arrays (same as [`Priority::rank`]).
    pub fn index(self) -> usize {
        self.rank()
    }

    /// Lower-case class name (metric labels, CLI).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }
}

impl fmt::Display for Priority {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Priority {
    type Err = String;

    fn from_str(s: &str) -> Result<Priority, String> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            "background" => Ok(Priority::Background),
            other => Err(format!("unknown priority {other:?} (interactive|batch|background)")),
        }
    }
}

/// Builder for one submission: the request plus its scheduling intent.
#[derive(Debug, Clone)]
pub struct SubmitOptions {
    request: MatmulRequest,
    priority: Priority,
    deadline: Option<Duration>,
    group: Option<u64>,
}

impl SubmitOptions {
    /// Wrap a request with default scheduling (class [`Priority::Batch`],
    /// no deadline, no group) — byte-identical to the legacy `try_submit`
    /// path.
    pub fn new(request: MatmulRequest) -> SubmitOptions {
        SubmitOptions { request, priority: Priority::default(), deadline: None, group: None }
    }

    /// Service class.
    pub fn priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    /// Soft deadline, relative to submit time. Within a class the batcher
    /// orders deadline-ascending (no deadline sorts last); an expired
    /// deadline never cancels a request — unless the coordinator opted
    /// into deadline shedding (`CoordinatorConfig::shed`), in which case
    /// a deadline that is already hopeless against the closed-form
    /// service bound fails fast with a distinct `shed:` error
    /// (Background) or demotes the request to Background
    /// (Interactive/Batch). See `batcher::shed_verdict`.
    pub fn deadline(mut self, soft: Duration) -> SubmitOptions {
        self.deadline = Some(soft);
        self
    }

    /// Group tag pre-declaring shared-input fusion: overwrites the
    /// request's `input_id` so every member of the group shares one fusion
    /// key. Members must also share the same activation `Arc` (the batcher
    /// only fuses requests referencing the *same* matrix object).
    pub fn group(mut self, group: u64) -> SubmitOptions {
        self.group = Some(group);
        self
    }
}

/// Cancellation rendezvous between [`Ticket::cancel`] callers and the
/// pipeline stages. Registered ids are honored at the next stage
/// boundary the request crosses — router window formation, the prepare
/// stage, or a worker popping the batch off the balance fabric — so a
/// cancel kills a request anywhere in admit → prepare → execute without
/// the stages polling. The common no-cancellation case costs one atomic
/// load per check; entries are removed when the cancel is honored or the
/// outcome is delivered, so the set cannot leak ids.
#[derive(Default)]
pub(crate) struct CancelRegistry {
    pending: Mutex<HashSet<RequestId>>,
    /// Mirror of `pending.len()` for the lock-free empty fast path.
    len: AtomicUsize,
}

impl CancelRegistry {
    /// Register a cancellation request for `id`.
    pub(crate) fn request(&self, id: RequestId) {
        let mut set = self.pending.lock().unwrap_or_else(|e| e.into_inner());
        if set.insert(id) {
            self.len.store(set.len(), Ordering::Release);
        }
    }

    /// Whether `id` has a pending cancellation. The empty fast path is a
    /// single atomic load, so stage boundaries can check every envelope
    /// without contending on the lock when nobody cancels.
    pub(crate) fn is_cancelled(&self, id: RequestId) -> bool {
        if self.len.load(Ordering::Acquire) == 0 {
            return false;
        }
        self.pending.lock().unwrap_or_else(|e| e.into_inner()).contains(&id)
    }

    /// Drop `id`'s entry once its ticket resolved (cancel honored, or the
    /// outcome raced the cancel and was delivered anyway).
    pub(crate) fn resolve(&self, id: RequestId) {
        if self.len.load(Ordering::Acquire) == 0 {
            return;
        }
        let mut set = self.pending.lock().unwrap_or_else(|e| e.into_inner());
        if set.remove(&id) {
            self.len.store(set.len(), Ordering::Release);
        }
    }

    /// Number of registered, not-yet-honored cancellations.
    pub(crate) fn pending(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }
}

/// Admission gate shared by the [`super::Coordinator`] and every
/// [`Client`] clone: the ingress sender (slot emptied on shutdown so
/// outstanding clients observe "coordinator stopped" instead of keeping
/// the router alive), the metrics sink, the cancellation registry and
/// the id counter.
pub(crate) struct Gate {
    ingress: RwLock<Option<SyncSender<Envelope>>>,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) cancels: Arc<CancelRegistry>,
    next_id: AtomicU64,
}

impl Gate {
    pub(crate) fn new(
        metrics: Arc<Metrics>,
        ingress: SyncSender<Envelope>,
        cancels: Arc<CancelRegistry>,
    ) -> Gate {
        Gate { ingress: RwLock::new(Some(ingress)), metrics, cancels, next_id: AtomicU64::new(1) }
    }

    /// Close admission: drops the ingress sender (the router drains and
    /// exits) while live `Client` clones start failing cleanly.
    pub(crate) fn close(&self) {
        *self.ingress.write().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

/// Cheap, cloneable submission handle onto a running coordinator.
///
/// Clones share the coordinator's admission gate; a handle outliving
/// `Coordinator::shutdown` fails submissions with "coordinator stopped"
/// rather than keeping the server threads alive.
#[derive(Clone)]
pub struct Client {
    gate: Arc<Gate>,
}

impl Client {
    pub(crate) fn new(gate: Arc<Gate>) -> Client {
        Client { gate }
    }

    /// Submit one request without blocking. Validation failures and
    /// backpressure (full admission queue) reject the submission; on
    /// success the returned [`Ticket`] resolves to the request's
    /// [`RequestOutcome`].
    pub fn submit(&self, opts: SubmitOptions) -> Result<Ticket> {
        let SubmitOptions { mut request, priority, deadline, group } = opts;
        if let Some(g) = group {
            request.input_id = g;
        }
        if let Err(reason) = request.validate() {
            self.gate.metrics.failed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            return Err(anyhow!("invalid request: {reason}"));
        }
        let id = self.gate.next_id.fetch_add(1, Ordering::Relaxed); // relaxed-ok: id allocation: RMW uniqueness is all that's needed
        request.id = id;
        let (tx, rx) = std::sync::mpsc::channel();
        let now = Instant::now();
        let env = Envelope {
            req: request,
            reply: tx,
            enqueued: now,
            priority,
            // a duration too large for the clock (e.g. Duration::MAX as a
            // "no deadline" sentinel) degrades to no deadline instead of
            // panicking on Instant overflow
            deadline: deadline.and_then(|d| now.checked_add(d)),
        };
        let guard = self.gate.ingress.read().unwrap_or_else(|e| e.into_inner());
        let Some(ingress) = guard.as_ref() else {
            return Err(anyhow!("coordinator stopped"));
        };
        let m = &self.gate.metrics;
        // The gauge is incremented *before* the send: once the envelope
        // is in the channel the router may drain and decrement it at any
        // moment, and add-after-send could then underflow the u64 gauge.
        m.queue_depth.fetch_add(1, Ordering::Relaxed); // relaxed-ok: depth gauge; incremented before send so drains never underflow
        match ingress.try_send(env) {
            Ok(()) => {
                m.accepted.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                m.class_accepted[priority.index()].fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                m.trace.event(SpanKind::Submit, id, LANE_CLIENT, priority.rank() as u64);
                Ok(Ticket {
                    id,
                    priority,
                    rx,
                    claimed: false,
                    stashed: None,
                    recorder: m.trace.clone(),
                    cancels: self.gate.cancels.clone(),
                })
            }
            Err(TrySendError::Full(_)) => {
                m.queue_depth.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: depth gauge
                m.rejected.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                Err(anyhow!(
                    "queue full ({} pending)",
                    m.queue_depth.load(Ordering::Relaxed) // relaxed-ok: gauge read for the error detail
                ))
            }
            Err(TrySendError::Disconnected(_)) => {
                m.queue_depth.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: depth gauge
                Err(anyhow!("coordinator stopped"))
            }
        }
    }

    /// Submit and block for the outcome (convenience).
    pub fn submit_wait(&self, opts: SubmitOptions) -> Result<RequestOutcome> {
        self.submit(opts)?.wait()
    }

    /// Number of cancellations requested but not yet honored by a
    /// pipeline stage. Converges to 0 once the affected tickets resolve —
    /// the cancellation-leak assertion of the race suite.
    pub fn pending_cancellations(&self) -> usize {
        self.gate.cancels.pending()
    }

    /// Submit a shared-input group (e.g. a Q/K/V triplet off one `X`) in
    /// one call: every member gets the `group` fusion tag, the given
    /// class, and back-to-back admission so the router usually windows
    /// them together. Returns one ticket per member, in order. On a
    /// mid-group rejection (backpressure) the error is returned and the
    /// already-admitted members stay in flight — their outcomes are
    /// simply discarded with the dropped tickets. Callers that need
    /// per-member rejection handling (retry, dedupe, partial waits)
    /// should submit members individually with
    /// [`SubmitOptions::group`] instead, as `adip serve` does.
    pub fn submit_group<I>(
        &self,
        group: u64,
        priority: Priority,
        requests: I,
    ) -> Result<Vec<Ticket>>
    where
        I: IntoIterator<Item = MatmulRequest>,
    {
        requests
            .into_iter()
            .map(|r| self.submit(SubmitOptions::new(r).priority(priority).group(group)))
            .collect()
    }
}

/// Typed completion handle for one submitted request.
///
/// The outcome can be claimed exactly once — through [`Ticket::wait`]
/// (consuming), or through the first [`Ticket::try_wait`] /
/// [`Ticket::wait_timeout`] call that returns `Ok(Some(_))`; after that,
/// polling again reports an error.
pub struct Ticket {
    id: RequestId,
    priority: Priority,
    rx: Receiver<RequestOutcome>,
    /// Set once a poll returned the outcome, so later polls error
    /// deterministically (the worker may drop its reply sender slightly
    /// after the outcome is consumed — the flag, not the channel state,
    /// is the contract).
    claimed: bool,
    /// Outcome drained off the channel by [`Ticket::cancel`]'s
    /// race-closing poll; consumed by the next wait/poll.
    stashed: Option<RequestOutcome>,
    /// Handle onto the coordinator's trace recorder, so the ticket can
    /// pull its own lifecycle spans ([`Ticket::trace`]).
    recorder: Recorder,
    /// Shared cancellation registry (see [`Ticket::cancel`]).
    cancels: Arc<CancelRegistry>,
}

impl fmt::Debug for Ticket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Ticket")
            .field("id", &self.id)
            .field("priority", &self.priority)
            .field("claimed", &self.claimed)
            .finish_non_exhaustive()
    }
}

impl Ticket {
    /// The id the coordinator assigned to this request.
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// The class the request was submitted under.
    pub fn priority(&self) -> Priority {
        self.priority
    }

    /// This ticket's lifecycle spans, in `(start, seq)` order — empty
    /// while tracing is off ([`crate::obs::TraceMode::Off`], the default)
    /// or when sampling skipped this ticket. Spans recorded after the
    /// call (e.g. the worker's `complete` event racing a prompt waiter)
    /// appear in later calls; for the full picture, call after the
    /// outcome arrived.
    pub fn trace(&self) -> Vec<SpanRecord> {
        self.recorder.for_ticket(self.id)
    }

    /// Request cancellation. Returns `true` when a cancellation was
    /// registered, `false` when the outcome had already arrived (a
    /// post-completion cancel is a no-op: the outcome stays claimable and
    /// nothing is registered).
    ///
    /// Cancellation is honored at the next stage boundary the request
    /// crosses — router window formation, the prepare stage, or a worker
    /// popping it off the balance fabric (which covers fabric deques,
    /// steals and coalesce windows: members are filtered before the
    /// merged pass forms). A batch already inside `execute` runs to
    /// completion — its outcome then wins the race and the registry entry
    /// is dropped. A honored cancel resolves the ticket with
    /// `Err(RequestError::Cancelled)`.
    pub fn cancel(&mut self) -> bool {
        if self.claimed || self.stashed.is_some() {
            return false;
        }
        // Already complete? Then cancelling is a no-op: stash the outcome
        // for the next wait/poll instead of registering a dead id.
        if let Ok(out) = self.rx.try_recv() {
            self.stashed = Some(out);
            return false;
        }
        self.cancels.request(self.id);
        self.recorder.event(SpanKind::Cancel, self.id, LANE_CLIENT, 0);
        // Close the submit/complete race: if the outcome landed between
        // the poll above and the registration, the pipeline may never see
        // the entry again — drain it now so the registry cannot leak.
        if let Ok(out) = self.rx.try_recv() {
            self.cancels.resolve(self.id);
            self.stashed = Some(out);
            return false;
        }
        true
    }

    /// Block until the outcome arrives.
    pub fn wait(mut self) -> Result<RequestOutcome> {
        if self.claimed {
            return Err(anyhow!("outcome already claimed"));
        }
        if let Some(out) = self.stashed.take() {
            return Ok(out);
        }
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }

    /// Non-blocking poll: `Ok(None)` while the request is still in
    /// flight, `Ok(Some(outcome))` exactly once when it completes.
    pub fn try_wait(&mut self) -> Result<Option<RequestOutcome>> {
        if self.claimed {
            return Err(anyhow!("outcome already claimed"));
        }
        if let Some(out) = self.stashed.take() {
            self.claimed = true;
            return Ok(Some(out));
        }
        match self.rx.try_recv() {
            Ok(out) => {
                self.claimed = true;
                Ok(Some(out))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(anyhow!("coordinator dropped the request"))
            }
        }
    }

    /// Bounded-wait poll: blocks up to `timeout`, then `Ok(None)` if the
    /// request is still in flight.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<Option<RequestOutcome>> {
        if self.claimed {
            return Err(anyhow!("outcome already claimed"));
        }
        if let Some(out) = self.stashed.take() {
            self.claimed = true;
            return Ok(Some(out));
        }
        match self.rx.recv_timeout(timeout) {
            Ok(out) => {
                self.claimed = true;
                Ok(Some(out))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow!("coordinator dropped the request"))
            }
        }
    }

    /// Unwrap into the legacy `(id, Receiver)` pair — the deprecated
    /// old-API shims (`Coordinator::try_submit`) are built on this. Must
    /// not follow a [`Ticket::cancel`] call: an outcome the cancel poll
    /// already drained off the channel cannot be put back.
    pub fn into_parts(self) -> (RequestId, Receiver<RequestOutcome>) {
        debug_assert!(self.stashed.is_none(), "into_parts after cancel would drop the outcome");
        (self.id, self.rx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_order_and_names() {
        assert!(Priority::Interactive.rank() < Priority::Batch.rank());
        assert!(Priority::Batch.rank() < Priority::Background.rank());
        assert_eq!(Priority::default(), Priority::Batch);
        for p in Priority::ALL {
            assert_eq!(p.name().parse::<Priority>().unwrap(), p);
            assert!(p.index() < Priority::COUNT);
        }
        assert!("turbo".parse::<Priority>().is_err());
    }

    #[test]
    fn options_builder_carries_intent() {
        let mut rng = crate::testutil::Rng::seeded(1);
        let req = MatmulRequest {
            id: 0,
            input_id: 9,
            a: Arc::new(crate::dataflow::Mat::random(&mut rng, 4, 4, 8)),
            bs: vec![Arc::new(crate::dataflow::Mat::random(&mut rng, 4, 4, 2))],
            weight_bits: 2,
            act_act: false,
            tag: String::new(),
        };
        let opts = SubmitOptions::new(req)
            .priority(Priority::Interactive)
            .deadline(Duration::from_millis(5))
            .group(42);
        assert_eq!(opts.priority, Priority::Interactive);
        assert_eq!(opts.deadline, Some(Duration::from_millis(5)));
        assert_eq!(opts.group, Some(42));
    }
}
