//! The prepare stage of the admit → prepare → execute pipeline.
//!
//! Everything host-side that used to run inline on the worker hot path —
//! operand fingerprinting for the weight cache and prepared-batch
//! assembly (the precision mode is fixed even earlier, by the batcher's
//! fusion key, and carried through) — happens here, on a dedicated stage
//! thread per worker (`PrepareMode::Pipelined`, the default). The stage turns the
//! router's raw [`BatchWork`] into [`PreparedBatch`]es queued ahead of
//! execution, so preparation of batch `i+1` overlaps execution of batch
//! `i` and workers never idle on host-side packing. The
//! `prepared_depth` gauge counts batches sitting fully prepared ahead of
//! a worker — nonzero under load is the observable proof of overlap.
//!
//! `PrepareMode::Inline` keeps the same code path but runs
//! [`prepare_batch`] on the worker thread right before execution — the
//! serial baseline the `bench_coordinator` pipelined-vs-inline gate
//! measures against. Both modes produce identical results and simulated
//! accounting (the prepared fingerprints are a pure function of the
//! operands; `rust/tests/integration_pipeline.rs` asserts it).

use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{fingerprint, PreparedFingerprints};
use crate::obs::{lane_worker, SpanKind};
use crate::quant::PrecisionMode;

use super::client::CancelRegistry;
use super::metrics::Metrics;
use super::request::{Envelope, RequestError, RequestOutcome, ResponseMetrics};

/// `SpanKind::Cancel` aux codes: which pipeline boundary honored the
/// cancellation (aux 0 is the client-side `Ticket::cancel` event).
pub(crate) const CANCEL_AT_ROUTER: u64 = 1;
pub(crate) const CANCEL_AT_PREPARE: u64 = 2;
pub(crate) const CANCEL_AT_WORKER: u64 = 3;

/// Fail one cancelled envelope: reply with [`RequestError::Cancelled`],
/// bump the cancelled/failed counters, record the Cancel span (aux says
/// which boundary honored it), and drop the registry entry so the set
/// stays empty in steady state.
pub(crate) fn honor_cancel(
    env: &Envelope,
    metrics: &Metrics,
    cancels: &CancelRegistry,
    lane: u32,
    aux: u64,
) {
    metrics.cancelled.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
    metrics.failed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
    metrics.trace.event(SpanKind::Cancel, env.req.id, lane, aux);
    let _ = env.reply.send(RequestOutcome {
        id: env.req.id,
        result: Err(RequestError::Cancelled),
        metrics: ResponseMetrics::default(),
    });
    cancels.resolve(env.req.id);
}

/// Drop every cancelled envelope from a formed batch, failing each via
/// [`honor_cancel`], and keep the flat per-weight fingerprint list (one
/// entry per member weight matrix, member order) aligned with the
/// survivors. Returns whether anything was removed — a changed batch has
/// a different weight set and may no longer share a coalesced pass with
/// partners gathered under the old key.
pub(crate) fn strip_cancelled_envelopes(
    envelopes: &mut Vec<Envelope>,
    mut weight_fps: Option<&mut Vec<u128>>,
    metrics: &Metrics,
    cancels: &CancelRegistry,
    lane: u32,
    aux: u64,
) -> bool {
    if cancels.pending() == 0 || !envelopes.iter().any(|e| cancels.is_cancelled(e.req.id)) {
        return false;
    }
    let old = std::mem::take(envelopes);
    let old_fps = weight_fps.as_mut().map(|w| std::mem::take(&mut **w));
    let mut pos = 0usize;
    for env in old {
        let n = env.req.bs.len();
        if cancels.is_cancelled(env.req.id) {
            honor_cancel(&env, metrics, cancels, lane, aux);
        } else {
            if let (Some(dst), Some(src)) = (weight_fps.as_mut(), old_fps.as_ref()) {
                dst.extend_from_slice(&src[pos..pos + n]);
            }
            envelopes.push(env);
        }
        pos += n;
    }
    true
}

/// One formed batch as the router hands it to the prepare stage: the
/// member envelopes in fusion order plus the routing decisions that are
/// already fixed at formation time.
pub(crate) struct BatchWork {
    pub envelopes: Vec<Envelope>,
    /// Execution mode the batcher grouped this batch under (the fusion
    /// key's mode — carried through, never re-derived downstream).
    pub mode: PrecisionMode,
    pub runtime_interleave: bool,
    /// Global batch-formation sequence number (the deterministic service
    /// order; stamped into every member's `ResponseMetrics`).
    pub batch_seq: u64,
    /// Per-weight fingerprints memoized push-side by the balance fabric's
    /// coalesce-key computation (`None` when coalescing is off):
    /// [`prepare_batch`] reuses them so the weight set is never hashed
    /// twice. Crate-internal trust, same policy as
    /// `PreparedFingerprints` — debug builds re-verify.
    pub weight_fps: Option<Vec<u128>>,
    /// When the batch entered the balance fabric (stamped by
    /// `Fabric::push`; `None` until then). Read by the executing worker
    /// to attribute fabric residency per member — observability only,
    /// never consulted by any scheduling decision.
    pub queued: Option<Instant>,
}

/// A batch with all host-side preparation done, queued ahead of
/// execution.
pub(crate) struct PreparedBatch {
    pub envelopes: Vec<Envelope>,
    /// Execution mode selected by the prepare stage.
    pub mode: PrecisionMode,
    pub runtime_interleave: bool,
    /// Operand fingerprints for the weight-cache probe (`None` while the
    /// cache is disabled — hashing would be pure waste).
    pub fps: Option<PreparedFingerprints>,
    pub batch_seq: u64,
    /// When the batch entered the balance fabric (see
    /// [`BatchWork::queued`]).
    pub queued: Option<Instant>,
    /// Host seconds [`prepare_batch`] spent on this batch — surfaced per
    /// member in `ResponseMetrics::prepare_seconds`.
    pub prepare_seconds: f64,
}

/// What a worker receives: a batch prepared by the stage thread
/// (pipelined mode) or one it must prepare itself (inline mode).
pub(crate) enum WorkMsg {
    Raw(BatchWork),
    Prepared(PreparedBatch),
}

impl WorkMsg {
    /// The member envelopes, whichever side of preparation the batch is on
    /// (the balance fabric's coalescer keys on them).
    pub(crate) fn envelopes(&self) -> &[Envelope] {
        match self {
            WorkMsg::Raw(w) => &w.envelopes,
            WorkMsg::Prepared(p) => &p.envelopes,
        }
    }

    /// The batch's fixed execution mode.
    pub(crate) fn mode(&self) -> PrecisionMode {
        match self {
            WorkMsg::Raw(w) => w.mode,
            WorkMsg::Prepared(p) => p.mode,
        }
    }

    /// Whether the batch needs runtime (multi-bank) interleaving.
    pub(crate) fn runtime_interleave(&self) -> bool {
        match self {
            WorkMsg::Raw(w) => w.runtime_interleave,
            WorkMsg::Prepared(p) => p.runtime_interleave,
        }
    }

    /// Prepared operand fingerprints, when the prepare stage hashed them.
    pub(crate) fn prepared_fps(&self) -> Option<&PreparedFingerprints> {
        match self {
            WorkMsg::Raw(_) => None,
            WorkMsg::Prepared(p) => p.fps.as_ref(),
        }
    }

    /// Stamp the instant the batch entered the balance fabric (called by
    /// `Fabric::push`; feeds fabric-residency attribution only).
    pub(crate) fn mark_queued(&mut self, t: Instant) {
        match self {
            WorkMsg::Raw(w) => w.queued = Some(t),
            WorkMsg::Prepared(p) => p.queued = Some(t),
        }
    }
}

/// Do the host-side preparation of one batch: when the weight cache
/// needs them, hash the operand fingerprints (the mode was already
/// selected at batch formation — it is the fusion key's mode and is
/// carried through unchanged). This is the work the pipelined stage
/// moves off the execute path.
pub(crate) fn prepare_batch(
    work: BatchWork,
    owner: usize,
    cache_enabled: bool,
    metrics: &Metrics,
) -> PreparedBatch {
    let t0 = Instant::now();
    let first = &work.envelopes[0].req;
    let fps = cache_enabled.then(|| PreparedFingerprints {
        act: fingerprint(&[first.a.as_ref()]),
        // reuse weight fingerprints the coalesce key already computed
        // push-side (hash-once); only the activation is hashed here
        weights: match &work.weight_fps {
            Some(w) => {
                debug_assert!(
                    w.iter()
                        .zip(work.envelopes.iter().flat_map(|e| e.req.bs.iter()))
                        .all(|(&f, b)| f == fingerprint(&[b.as_ref()])),
                    "stale memoized weight fingerprints"
                );
                w.clone()
            }
            None => work
                .envelopes
                .iter()
                .flat_map(|e| e.req.bs.iter())
                .map(|b| fingerprint(&[b.as_ref()]))
                .collect(),
        },
    });
    let prepare_seconds = t0.elapsed().as_secs_f64();
    metrics.record_prepare(prepare_seconds);
    for env in &work.envelopes {
        metrics.trace.span_since(SpanKind::Prepare, env.req.id, lane_worker(owner), t0, 0);
    }
    PreparedBatch {
        envelopes: work.envelopes,
        mode: work.mode,
        runtime_interleave: work.runtime_interleave,
        fps,
        batch_seq: work.batch_seq,
        queued: work.queued,
        prepare_seconds,
    }
}

/// Body of one pipelined prepare thread: pull raw batches from the
/// router, prepare them, and queue them on the balance fabric under this
/// stage's worker as owner. The fabric's bounded global capacity applies
/// backpressure to the stage (and through it, to the router);
/// `prepared_depth` counts batches prepared ahead of execution.
///
/// Shutdown chain: the router dropping its sender ends `rx` — the loop
/// drains every remaining raw batch first (prepared work is never
/// dropped), then exits; the coordinator closes the fabric only after
/// every prepare thread is joined, so the workers drain in turn.
pub(crate) fn prepare_loop(
    rx: Receiver<BatchWork>,
    fabric: Arc<crate::balance::injector::Fabric>,
    owner: usize,
    cache_enabled: bool,
    metrics: Arc<Metrics>,
    cancels: Arc<CancelRegistry>,
) {
    while let Ok(mut work) = rx.recv() {
        // Cancellation boundary: a request killed while its batch sat in
        // the stage queue fails here, before any hashing is spent on it.
        strip_cancelled_envelopes(
            &mut work.envelopes,
            work.weight_fps.as_mut(),
            &metrics,
            &cancels,
            lane_worker(owner),
            CANCEL_AT_PREPARE,
        );
        if work.envelopes.is_empty() {
            continue;
        }
        let prepared = prepare_batch(work, owner, cache_enabled, &metrics);
        // counted before the (possibly blocking) push: a prepared batch
        // waiting for fabric room is exactly "prepared ahead of execution"
        metrics.prepared_depth.fetch_add(1, Ordering::Relaxed); // relaxed-ok: depth gauge; report-only
        fabric.push(owner, WorkMsg::Prepared(prepared));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::weight_cache::combine_fingerprints;
    use crate::coordinator::client::Priority;
    use crate::coordinator::request::MatmulRequest;
    use crate::dataflow::Mat;
    use crate::testutil::Rng;

    fn envelope(rng: &mut Rng, bits: u32, n_b: usize) -> Envelope {
        // the receiver is dropped — prepare never replies, and a worker
        // send to a gone receiver is harmless by design
        let (tx, _rx) = std::sync::mpsc::channel();
        Envelope {
            req: MatmulRequest {
                id: 0,
                input_id: 1,
                a: Arc::new(Mat::random(rng, 8, 8, 8)),
                bs: (0..n_b).map(|_| Arc::new(Mat::random(rng, 8, 8, bits))).collect(),
                weight_bits: bits,
                act_act: false,
                tag: String::new(),
            },
            reply: tx,
            enqueued: Instant::now(),
            priority: Priority::Batch,
            deadline: None,
        }
    }

    #[test]
    fn prepare_carries_mode_and_hashes_all_member_operands() {
        let mut rng = Rng::seeded(31);
        let metrics = Metrics::default();
        let work = BatchWork {
            envelopes: vec![envelope(&mut rng, 2, 2), envelope(&mut rng, 2, 1)],
            mode: PrecisionMode::W2,
            runtime_interleave: false,
            batch_seq: 7,
            weight_fps: None,
            queued: None,
        };
        let expect_act = fingerprint(&[work.envelopes[0].req.a.as_ref()]);
        let expect_ws: Vec<u128> = work
            .envelopes
            .iter()
            .flat_map(|e| e.req.bs.iter())
            .map(|b| fingerprint(&[b.as_ref()]))
            .collect();
        let pb = prepare_batch(work, 0, true, &metrics);
        assert_eq!(pb.mode, PrecisionMode::W2);
        assert_eq!(pb.batch_seq, 7);
        assert!(pb.prepare_seconds >= 0.0);
        let fps = pb.fps.expect("cache enabled -> fingerprints prepared");
        assert_eq!(fps.act, expect_act);
        assert_eq!(fps.weights, expect_ws);
        assert_eq!(fps.weights.len(), 3, "concatenated in member order");
        // the combined form is what the degenerate cache probe uses
        let _ = combine_fingerprints(fps.weights.iter().copied());
        assert_eq!(metrics.prepared_batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn prepare_skips_hashing_when_cache_disabled() {
        let mut rng = Rng::seeded(33);
        let metrics = Metrics::default();
        let work = BatchWork {
            envelopes: vec![envelope(&mut rng, 8, 1)],
            mode: PrecisionMode::W8,
            runtime_interleave: true,
            batch_seq: 0,
            weight_fps: None,
            queued: None,
        };
        let pb = prepare_batch(work, 0, false, &metrics);
        assert!(pb.fps.is_none());
        assert!(pb.runtime_interleave);
        assert_eq!(pb.mode, PrecisionMode::W8);
    }
}
