//! The prepare stage of the admit → prepare → execute pipeline.
//!
//! Everything host-side that used to run inline on the worker hot path —
//! operand fingerprinting for the weight cache and prepared-batch
//! assembly (the precision mode is fixed even earlier, by the batcher's
//! fusion key, and carried through) — happens here, on a dedicated stage
//! thread per worker (`PrepareMode::Pipelined`, the default). The stage turns the
//! router's raw [`BatchWork`] into [`PreparedBatch`]es queued ahead of
//! execution, so preparation of batch `i+1` overlaps execution of batch
//! `i` and workers never idle on host-side packing. The
//! `prepared_depth` gauge counts batches sitting fully prepared ahead of
//! a worker — nonzero under load is the observable proof of overlap.
//!
//! `PrepareMode::Inline` keeps the same code path but runs
//! [`prepare_batch`] on the worker thread right before execution — the
//! serial baseline the `bench_coordinator` pipelined-vs-inline gate
//! measures against. Both modes produce identical results and simulated
//! accounting (the prepared fingerprints are a pure function of the
//! operands; `rust/tests/integration_pipeline.rs` asserts it).

use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{fingerprint, PreparedFingerprints};
use crate::quant::PrecisionMode;

use super::metrics::Metrics;
use super::request::Envelope;

/// One formed batch as the router hands it to the prepare stage: the
/// member envelopes in fusion order plus the routing decisions that are
/// already fixed at formation time.
pub(crate) struct BatchWork {
    pub envelopes: Vec<Envelope>,
    /// Execution mode the batcher grouped this batch under (the fusion
    /// key's mode — carried through, never re-derived downstream).
    pub mode: PrecisionMode,
    pub runtime_interleave: bool,
    /// Global batch-formation sequence number (the deterministic service
    /// order; stamped into every member's `ResponseMetrics`).
    pub batch_seq: u64,
}

/// A batch with all host-side preparation done, queued ahead of
/// execution.
pub(crate) struct PreparedBatch {
    pub envelopes: Vec<Envelope>,
    /// Execution mode selected by the prepare stage.
    pub mode: PrecisionMode,
    pub runtime_interleave: bool,
    /// Operand fingerprints for the weight-cache probe (`None` while the
    /// cache is disabled — hashing would be pure waste).
    pub fps: Option<PreparedFingerprints>,
    pub batch_seq: u64,
}

/// What a worker receives: a batch prepared by the stage thread
/// (pipelined mode) or one it must prepare itself (inline mode).
pub(crate) enum WorkMsg {
    Raw(BatchWork),
    Prepared(PreparedBatch),
}

/// Do the host-side preparation of one batch: when the weight cache
/// needs them, hash the operand fingerprints (the mode was already
/// selected at batch formation — it is the fusion key's mode and is
/// carried through unchanged). This is the work the pipelined stage
/// moves off the execute path.
pub(crate) fn prepare_batch(
    work: BatchWork,
    cache_enabled: bool,
    metrics: &Metrics,
) -> PreparedBatch {
    let t0 = Instant::now();
    let first = &work.envelopes[0].req;
    let fps = cache_enabled.then(|| PreparedFingerprints {
        act: fingerprint(&[first.a.as_ref()]),
        weights: work
            .envelopes
            .iter()
            .flat_map(|e| e.req.bs.iter())
            .map(|b| fingerprint(&[b.as_ref()]))
            .collect(),
    });
    metrics.record_prepare(t0.elapsed().as_secs_f64());
    PreparedBatch {
        envelopes: work.envelopes,
        mode: work.mode,
        runtime_interleave: work.runtime_interleave,
        fps,
        batch_seq: work.batch_seq,
    }
}

/// Body of one pipelined prepare thread: pull raw batches from the
/// router, prepare them, and queue them ahead of the paired worker. The
/// bounded output queue applies backpressure to the stage (and through
/// it, to the router); `prepared_depth` counts batches between the two.
///
/// Shutdown chain: the router dropping its sender ends `rx` — the loop
/// drains every remaining raw batch first (prepared work is never
/// dropped), then exits, dropping `tx` so the worker drains in turn.
pub(crate) fn prepare_loop(
    rx: Receiver<BatchWork>,
    tx: SyncSender<WorkMsg>,
    cache_enabled: bool,
    metrics: Arc<Metrics>,
) {
    while let Ok(work) = rx.recv() {
        let prepared = prepare_batch(work, cache_enabled, &metrics);
        // counted before the (possibly blocking) send: a prepared batch
        // waiting for queue room is exactly "prepared ahead of execution"
        metrics.prepared_depth.fetch_add(1, Ordering::Relaxed);
        if tx.send(WorkMsg::Prepared(prepared)).is_err() {
            metrics.prepared_depth.fetch_sub(1, Ordering::Relaxed);
            return; // worker gone (only during teardown)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::weight_cache::combine_fingerprints;
    use crate::coordinator::client::Priority;
    use crate::coordinator::request::MatmulRequest;
    use crate::dataflow::Mat;
    use crate::testutil::Rng;

    fn envelope(rng: &mut Rng, bits: u32, n_b: usize) -> Envelope {
        // the receiver is dropped — prepare never replies, and a worker
        // send to a gone receiver is harmless by design
        let (tx, _rx) = std::sync::mpsc::channel();
        Envelope {
            req: MatmulRequest {
                id: 0,
                input_id: 1,
                a: Arc::new(Mat::random(rng, 8, 8, 8)),
                bs: (0..n_b).map(|_| Arc::new(Mat::random(rng, 8, 8, bits))).collect(),
                weight_bits: bits,
                act_act: false,
                tag: String::new(),
            },
            reply: tx,
            enqueued: Instant::now(),
            priority: Priority::Batch,
            deadline: None,
        }
    }

    #[test]
    fn prepare_carries_mode_and_hashes_all_member_operands() {
        let mut rng = Rng::seeded(31);
        let metrics = Metrics::default();
        let work = BatchWork {
            envelopes: vec![envelope(&mut rng, 2, 2), envelope(&mut rng, 2, 1)],
            mode: PrecisionMode::W2,
            runtime_interleave: false,
            batch_seq: 7,
        };
        let expect_act = fingerprint(&[work.envelopes[0].req.a.as_ref()]);
        let expect_ws: Vec<u128> = work
            .envelopes
            .iter()
            .flat_map(|e| e.req.bs.iter())
            .map(|b| fingerprint(&[b.as_ref()]))
            .collect();
        let pb = prepare_batch(work, true, &metrics);
        assert_eq!(pb.mode, PrecisionMode::W2);
        assert_eq!(pb.batch_seq, 7);
        let fps = pb.fps.expect("cache enabled -> fingerprints prepared");
        assert_eq!(fps.act, expect_act);
        assert_eq!(fps.weights, expect_ws);
        assert_eq!(fps.weights.len(), 3, "concatenated in member order");
        // the combined form is what the degenerate cache probe uses
        let _ = combine_fingerprints(fps.weights.iter().copied());
        assert_eq!(metrics.prepared_batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn prepare_skips_hashing_when_cache_disabled() {
        let mut rng = Rng::seeded(33);
        let metrics = Metrics::default();
        let work = BatchWork {
            envelopes: vec![envelope(&mut rng, 8, 1)],
            mode: PrecisionMode::W8,
            runtime_interleave: true,
            batch_seq: 0,
        };
        let pb = prepare_batch(work, false, &metrics);
        assert!(pb.fps.is_none());
        assert!(pb.runtime_interleave);
        assert_eq!(pb.mode, PrecisionMode::W8);
    }
}
