//! Coordinator metrics: lock-free counters + Prometheus-style text dump.
//!
//! Alongside the global counters, the serving redesign added per-class
//! series (accepted/completed/queue-wait per [`Priority`]) and the
//! pipeline's prepare-stage series (`prepared_depth` — the gauge that
//! makes prepare/execute overlap observable — plus prepared totals,
//! prepare seconds and aging promotions).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Instant;

use crate::obs::Recorder;

use super::client::Priority;

/// Per-worker deque-depth gauges, grown to the actual worker count at
/// fabric startup. This replaces the fixed 16-slot array that silently
/// capped gauged fleets: every worker is now gauged individually, and
/// the `adip_worker_deque_gauges_truncated` series is retained (always
/// 0) so dashboards keyed on it keep working.
///
/// Writers store through a shared read lock (slots are atomics, so the
/// write lock is only ever taken by the idempotent, startup-time
/// [`WorkerGauges::ensure`]); a depth store for a not-yet-allocated
/// worker index grows the slot vector first, so no update is dropped.
#[derive(Debug, Default)]
pub struct WorkerGauges {
    slots: RwLock<Vec<AtomicU64>>,
}

impl WorkerGauges {
    /// Grow to at least `n` slots (never shrinks; idempotent).
    pub fn ensure(&self, n: usize) {
        // Poison recovery everywhere on this lock: a panicked worker
        // must never take the metrics endpoint down with it.
        if self.slots.read().unwrap_or_else(|e| e.into_inner()).len() >= n {
            return;
        }
        let mut slots = self.slots.write().unwrap_or_else(|e| e.into_inner());
        while slots.len() < n {
            slots.push(AtomicU64::new(0));
        }
    }

    /// Store worker `w`'s depth, growing the slot vector if needed.
    pub fn store(&self, w: usize, depth: u64) {
        {
            let slots = self.slots.read().unwrap_or_else(|e| e.into_inner());
            if let Some(slot) = slots.get(w) {
                slot.store(depth, Ordering::Relaxed); // relaxed-ok: depth gauge
                return;
            }
        }
        self.ensure(w + 1);
        self.store(w, depth);
    }

    /// Worker `w`'s last stored depth (0 for unallocated slots).
    pub fn load(&self, w: usize) -> u64 {
        let slots = self.slots.read().unwrap_or_else(|e| e.into_inner());
        slots.get(w).map_or(0, |s| s.load(Ordering::Relaxed)) // relaxed-ok: gauge read
    }

    /// One coherent copy of the first `n` gauges (missing slots read 0).
    pub fn snapshot(&self, n: usize) -> Vec<u64> {
        let slots = self.slots.read().unwrap_or_else(|e| e.into_inner());
        (0..n)
            .map(|w| slots.get(w).map_or(0, |s| s.load(Ordering::Relaxed))) // relaxed-ok: gauge read
            .collect()
    }
}

/// Construction stamp behind `adip_uptime_seconds`: taken exactly once,
/// when the owning [`Metrics`] is built (`Default` runs at
/// construction), so uptime is a property of the serving instance — not
/// of whoever happens to render it.
#[derive(Debug)]
struct StartStamp(Instant);

impl Default for StartStamp {
    fn default() -> StartStamp {
        StartStamp(Instant::now())
    }
}

/// Nearest-rank percentile over an ascending-sorted, non-empty slice:
/// rank `⌈p/100 · len⌉`, so the reported value is always an observed
/// sample and p = 100 is exactly the maximum (p = 0 degenerates to the
/// first element). This is the one index/rounding rule shared by
/// [`Metrics::queue_percentile`] and the per-class series in
/// [`Metrics::render`] — it used to *document* nearest-rank while
/// implementing linear-index rounding, which disagreed at small `len`.
fn percentile_of_sorted(sorted: &[f32], p: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)] as f64
}

/// One class's queue-wait samples from a reservoir snapshot, sorted
/// ascending — the shared per-class extraction behind
/// [`Metrics::class_queue_summary`] and [`Metrics::render`].
fn sorted_class_waits(snapshot: &[(f32, f32, u8)], class: Priority) -> Vec<f32> {
    let mut waits: Vec<f32> = snapshot
        .iter()
        .filter(|x| x.2 == class.index() as u8)
        .map(|x| x.0)
        .collect();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    waits
}

/// Atomic f64 stored as bits (sums only; no CAS loops needed beyond add).
#[derive(Debug, Default)]
struct AtomicF64(AtomicU64);

impl AtomicF64 {
    fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed); // relaxed-ok: single-word CAS loop; no other memory is guarded
        loop {
            let new = f64::from_bits(cur) + v;
            match self.0.compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::Relaxed, // relaxed-ok: CAS success ordering: the word is self-contained
                Ordering::Relaxed, // relaxed-ok: CAS failure ordering: the retry loop re-reads
            ) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    fn get(&self) -> f64 {
        // relaxed-ok: stat read of a self-contained packed word
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Shards in the default lock-free latency reservoir. Recording threads
/// are assigned round-robin, so any worker count up to this records with
/// zero cross-thread contention.
const LATENCY_SHARDS: usize = 16;

/// Packed samples retained per (shard, class) ring — together the shards
/// keep a sliding window of the most recent
/// `LATENCY_SHARDS · LATENCY_SHARD_CAP` samples per class.
const LATENCY_SHARD_CAP: usize = 1024;

/// Ring-slot sentinel for "never written". A real sample cannot collide:
/// it would need both packed halves to be all-ones NaN bit patterns, and
/// recorded latencies are finite (`record` re-maps the collision anyway).
const EMPTY_SLOT: u64 = u64::MAX;

/// One shard of the lock-free latency reservoir: per-class rings of
/// packed `(queue f32 << 32 | service f32)` words. The class is implied
/// by which ring a slot lives in, so a single atomic store publishes a
/// whole sample — a concurrent scrape can never observe a torn
/// `(queue, service, class)` triple.
#[derive(Debug)]
struct LatencyShard {
    slots: [Vec<AtomicU64>; Priority::COUNT],
    /// Monotone per-class write counters; slot = counter % CAP.
    written: [AtomicU64; Priority::COUNT],
}

impl Default for LatencyShard {
    fn default() -> LatencyShard {
        LatencyShard {
            slots: std::array::from_fn(|_| {
                (0..LATENCY_SHARD_CAP).map(|_| AtomicU64::new(EMPTY_SLOT)).collect()
            }),
            written: Default::default(),
        }
    }
}

/// The default latency reservoir: each recording thread owns one of
/// [`LATENCY_SHARDS`] private shards for its lifetime (round-robin
/// assignment on first record), so saturated recording never serializes
/// on a mutex; a scrape reads every slot with plain atomic loads.
#[derive(Debug)]
struct ShardedReservoir {
    shards: Vec<LatencyShard>,
}

impl Default for ShardedReservoir {
    fn default() -> ShardedReservoir {
        ShardedReservoir {
            shards: (0..LATENCY_SHARDS).map(|_| LatencyShard::default()).collect(),
        }
    }
}

impl ShardedReservoir {
    /// The calling thread's stable shard (assigned round-robin from a
    /// process-wide counter on first use).
    fn my_shard(&self) -> &LatencyShard {
        use std::cell::Cell;
        static NEXT_SHARD: AtomicU64 = AtomicU64::new(0);
        thread_local! {
            static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
        }
        let idx = SHARD.with(|s| {
            let mut v = s.get();
            if v == usize::MAX {
                v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) as usize % LATENCY_SHARDS; // relaxed-ok: round-robin shard pick; exactness not required
                s.set(v);
            }
            v
        });
        &self.shards[idx]
    }

    fn record(&self, sample: (f32, f32, u8)) {
        let mut packed = ((sample.0.to_bits() as u64) << 32) | sample.1.to_bits() as u64;
        if packed == EMPTY_SLOT {
            // unreachable for finite latencies; keep the sentinel unique
            packed -= 1;
        }
        let shard = self.my_shard();
        let class = sample.2 as usize;
        let slot =
            shard.written[class].fetch_add(1, Ordering::Relaxed) as usize % LATENCY_SHARD_CAP; // relaxed-ok: slot claim: RMW uniqueness; samples are packed single words
        shard.slots[class][slot].store(packed, Ordering::Relaxed); // relaxed-ok: packed single-word sample; no cross-word ordering
    }

    /// Copy out every occupied slot. A slot whose index was reserved but
    /// whose store has not landed yet still holds the sentinel or a
    /// previous complete sample — never a half-written one.
    fn snapshot(&self) -> Vec<(f32, f32, u8)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            for class in 0..Priority::COUNT {
                let n = (shard.written[class].load(Ordering::Relaxed) as usize) // relaxed-ok: approximate snapshot bound; torn views acceptable
                    .min(LATENCY_SHARD_CAP);
                for slot in &shard.slots[class][..n] {
                    let v = slot.load(Ordering::Relaxed); // relaxed-ok: packed single-word sample
                    if v == EMPTY_SLOT {
                        continue;
                    }
                    out.push((
                        f32::from_bits((v >> 32) as u32),
                        f32::from_bits(v as u32),
                        class as u8,
                    ));
                }
            }
        }
        out
    }

    /// Shards holding at least one recorded sample (occupancy gauge).
    fn occupied(&self) -> usize {
        self.shards
            .iter()
            .filter(|sh| sh.written.iter().any(|w| w.load(Ordering::Relaxed) > 0)) // relaxed-ok: approximate emptiness check
            .count()
    }
}

/// Coordinator-wide metrics, shared across threads.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted into the queue.
    pub accepted: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests that failed validation/execution.
    pub failed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Batches that fused ≥ 2 requests or matrices.
    pub fused_batches: AtomicU64,
    /// Total simulated cycles.
    pub sim_cycles: AtomicU64,
    /// Total stationary-tile passes.
    pub passes: AtomicU64,
    /// Total simulated memory traffic (paper policy bytes).
    pub memory_bytes: AtomicU64,
    /// Weight-tile cache hits (shards served without re-execution).
    pub cache_hits: AtomicU64,
    /// Subset of `cache_hits` served from an entry another worker of the
    /// shared store inserted (cross-worker reuse).
    pub cache_shared_hits: AtomicU64,
    /// Weight-tile cache misses (shards that executed).
    pub cache_misses: AtomicU64,
    /// Weight-tile cache evictions (LRU capacity pressure).
    pub cache_evictions: AtomicU64,
    /// Current queue depth.
    pub queue_depth: AtomicU64,
    /// Persistent cluster-pool workers across all coordinator workers
    /// (gauge; 0 for the per-run engine and for single-core clusters,
    /// which execute inline without pool threads).
    pub pool_workers: AtomicU64,
    /// Shards dispatched to persistent pool workers.
    pub pool_shards_dispatched: AtomicU64,
    /// Pool shard executions that panicked (recovered per-worker).
    pub pool_worker_panics: AtomicU64,
    /// Requests accepted per service class (indexed by
    /// [`Priority::index`]).
    pub class_accepted: [AtomicU64; Priority::COUNT],
    /// Requests completed per service class.
    pub class_completed: [AtomicU64; Priority::COUNT],
    /// Batches fully prepared but not yet picked up by a worker (gauge).
    /// Nonzero under load is the observable proof that the prepare stage
    /// runs ahead of execution.
    pub prepared_depth: AtomicU64,
    /// Batches that went through the prepare stage (pipelined or inline).
    pub prepared_batches: AtomicU64,
    /// Requests promoted at least one class by the batcher's aging rule.
    pub aging_promotions: AtomicU64,
    /// Requests failed fast at batch-formation time because their soft
    /// deadline was already hopeless (also counted in `failed`).
    pub shed: AtomicU64,
    /// Requests killed by `Ticket::cancel` (or a net-tier Cancel frame)
    /// before reaching execution (also counted in `failed`).
    pub cancelled: AtomicU64,
    /// Deadline-hopeless Interactive/Batch requests demoted to Background
    /// instead of shed. They still execute, re-classed end-to-end: their
    /// completion and queue-wait series count as Background (so their
    /// deliberately long waits cannot pollute the SLO of the class they
    /// forfeited), while `class_accepted` keeps the submitted class — the
    /// gap between the two is exactly this counter.
    pub deadline_demotions: AtomicU64,
    /// Batches taken from a sibling worker's deque by the balance
    /// fabric's work-stealing (includes Aggressive re-homing).
    pub steals: AtomicU64,
    /// Pop attempts where an idle worker scanned every sibling deque and
    /// found nothing to steal (once per pop, never during the shutdown
    /// drain). Steals under the fabric lock cannot race, so this is an
    /// idleness signal — spare capacity the trace never used — not steal
    /// contention.
    pub steal_failures: AtomicU64,
    /// Cross-request coalesced passes executed (≥ 2 member batches merged
    /// into one shared-weight stacked pass).
    pub coalesced_passes: AtomicU64,
    /// Member batches that executed inside a coalesced pass.
    pub coalesced_members: AtomicU64,
    /// Workers whose balance-fabric deque depth is gauged individually
    /// (the full worker count; 0 when no coordinator runs).
    pub balance_workers: AtomicU64,
    /// Per-worker deque depth gauges (indices `0..balance_workers`),
    /// dynamically sized — no worker-count cap (see [`WorkerGauges`]).
    pub worker_deque_depth: WorkerGauges,
    /// Coordinator worker threads lost to panics (the balance fabric
    /// re-homes their queued batches; service degrades but survives).
    /// Nonzero flips the telemetry tier's `/healthz` to 503.
    pub worker_panics: AtomicU64,
    /// Batches queued in the fabric's global injector (gauge).
    pub injector_depth: AtomicU64,
    /// Times a latency-recording thread found the legacy reservoir mutex
    /// held and had to wait (stays 0 in the default sharded mode, which
    /// has no lock to wait on — the differential the hot-path bench
    /// measures).
    pub metrics_lock_waits: AtomicU64,
    /// Cumulative shared-weight-cache lock acquisitions that had to wait
    /// (gauge mirroring the store's own counter; stored by the
    /// coordinator worker loop alongside the cache delta flush).
    pub cache_lock_waits: AtomicU64,
    /// Lock shards in the shared weight-cache store (gauge).
    pub cache_shards: AtomicU64,
    /// Weight-cache shards currently holding at least one entry (gauge).
    pub cache_shards_occupied: AtomicU64,
    /// Per-ticket lifecycle trace recorder (see [`crate::obs`]). Off —
    /// and unallocated — by default; `Coordinator::start` enables it
    /// per `CoordinatorConfig::trace`. Lives on the metrics handle so
    /// every pipeline stage that can count can also trace.
    pub trace: Recorder,
    /// Construction stamp for `adip_uptime_seconds` (see [`StartStamp`]).
    started: StartStamp,
    sim_energy_j: AtomicF64,
    queue_seconds: AtomicF64,
    service_seconds: AtomicF64,
    /// Total seconds shards waited in pool queues before pickup.
    pool_queue_seconds: AtomicF64,
    /// Host seconds spent preparing batches (validation already happened
    /// at admission; this is mode selection + fingerprinting + assembly).
    prepare_seconds: AtomicF64,
    /// Per-class queue-wait sums (means need a denominator:
    /// `class_completed`).
    class_queue_seconds: [AtomicF64; Priority::COUNT],
    /// Legacy bounded latency reservoir for percentile reporting:
    /// `(queue_s, service_s, class index)` triples plus the rolling
    /// overwrite cursor. At [`Metrics::MAX_SAMPLES`] the oldest sample is
    /// overwritten (sliding window), so percentiles keep tracking a
    /// long-running server instead of freezing on its warm-up period.
    /// Only written when `use_legacy_reservoir` is set ([`Metrics::legacy`]);
    /// the default path records into `sharded` without any lock.
    samples: std::sync::Mutex<(Vec<(f32, f32, u8)>, usize)>,
    /// Default lock-free latency store (see [`ShardedReservoir`]).
    sharded: ShardedReservoir,
    /// Route `record_latency` through the single-mutex `samples`
    /// reservoir instead of `sharded` — the pre-sharding behavior, kept
    /// as the differential/contention baseline.
    use_legacy_reservoir: bool,
}

impl Metrics {
    /// Record request completion accounting.
    pub fn record_completion(&self, cycles: u64, energy_j: f64, memory_bytes: u64, passes: u64) {
        // relaxed-ok: independent stat counters; cross-field tearing is fine in reports
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.memory_bytes.fetch_add(memory_bytes, Ordering::Relaxed);
        self.passes.fetch_add(passes, Ordering::Relaxed);
        self.sim_energy_j.add(energy_j);
    }

    /// Record weight-tile cache activity (per-batch deltas from a worker's
    /// cluster scheduler). `shared_hits` is the subset of `hits` served
    /// from entries a sibling worker inserted into a shared store.
    pub fn record_cache(&self, hits: u64, shared_hits: u64, misses: u64, evictions: u64) {
        // relaxed-ok: independent stat counters; cross-field tearing is fine in reports
        self.cache_hits.fetch_add(hits, Ordering::Relaxed);
        self.cache_shared_hits.fetch_add(shared_hits, Ordering::Relaxed);
        self.cache_misses.fetch_add(misses, Ordering::Relaxed);
        self.cache_evictions.fetch_add(evictions, Ordering::Relaxed);
    }

    /// Record persistent-pool activity (per-batch deltas from a worker's
    /// cluster scheduler): shards dispatched, seconds those shards waited
    /// in the pool queue, and worker panics survived.
    pub fn record_pool(&self, dispatched: u64, queue_wait_s: f64, panics: u64) {
        // relaxed-ok: independent stat counters; cross-field tearing is fine in reports
        self.pool_shards_dispatched.fetch_add(dispatched, Ordering::Relaxed);
        self.pool_worker_panics.fetch_add(panics, Ordering::Relaxed);
        self.pool_queue_seconds.add(queue_wait_s);
    }

    /// Total seconds shards waited in pool queues before a worker pickup.
    pub fn pool_queue_seconds_total(&self) -> f64 {
        self.pool_queue_seconds.get()
    }

    /// Mean pool queue wait (s) per dispatched shard; `None` before any
    /// shard was dispatched. (This used to divide by `count.max(1)`,
    /// which silently fabricated a `total/1` "mean" whenever seconds had
    /// accrued with a zero denominator.)
    pub fn mean_pool_queue_seconds(&self) -> Option<f64> {
        match self.pool_shards_dispatched.load(Ordering::Relaxed) { // relaxed-ok: stat read
            0 => None,
            n => Some(self.pool_queue_seconds.get() / n as f64),
        }
    }

    /// Cap on retained latency samples in the legacy reservoir (a
    /// sliding window once full; enough for stable p99 over any bench
    /// run here). The sharded store's window is
    /// `LATENCY_SHARDS · LATENCY_SHARD_CAP` per class.
    pub const MAX_SAMPLES: usize = 1 << 16;

    /// Metrics recording latencies through the legacy single-mutex
    /// reservoir — the pre-sharding hot path, kept as the differential
    /// and contention baseline that `bench_hotpath` measures the default
    /// sharded store against. Every series and reader is identical; only
    /// the `record_latency` synchronization differs.
    pub fn legacy() -> Metrics {
        Metrics { use_legacy_reservoir: true, ..Metrics::default() }
    }

    /// Whether this instance records through the legacy mutex reservoir.
    pub fn is_legacy_reservoir(&self) -> bool {
        self.use_legacy_reservoir
    }

    /// Record host-side latencies for one completed request of `class`.
    pub fn record_latency(&self, queue_s: f64, service_s: f64, class: Priority) {
        self.queue_seconds.add(queue_s);
        self.service_seconds.add(service_s);
        self.class_completed[class.index()].fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        self.class_queue_seconds[class.index()].add(queue_s);
        let sample = (queue_s as f32, service_s as f32, class.index() as u8);
        if !self.use_legacy_reservoir {
            self.sharded.record(sample);
            return;
        }
        let mut guard = self.samples.try_lock().unwrap_or_else(|_| {
            // contended: count the wait, then block like before
            self.metrics_lock_waits.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            // A worker that panicked mid-record only poisons the guard,
            // never the sample buffer itself — keep serving metrics.
            self.samples.lock().unwrap_or_else(|e| e.into_inner())
        });
        let (buf, cursor) = &mut *guard;
        if buf.len() < Self::MAX_SAMPLES {
            buf.push(sample);
        } else {
            // sliding window: overwrite the oldest so a long-running
            // server's percentiles never freeze on its warm-up period
            buf[*cursor] = sample;
            *cursor = (*cursor + 1) % Self::MAX_SAMPLES;
        }
    }

    /// One coherent copy of the latency reservoir, whichever hot-path
    /// store is active — every percentile/summary reader works over this
    /// so the two stores are observationally identical.
    fn sample_snapshot(&self) -> Vec<(f32, f32, u8)> {
        if self.use_legacy_reservoir {
            // Poison recovery: a panicked recorder must not take the
            // metrics endpoint down with it.
            self.samples.lock().unwrap_or_else(|e| e.into_inner()).0.clone()
        } else {
            self.sharded.snapshot()
        }
    }

    /// Record host seconds one batch spent in the prepare stage.
    pub fn record_prepare(&self, seconds: f64) {
        self.prepared_batches.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        self.prepare_seconds.add(seconds);
    }

    /// Total host seconds spent preparing batches.
    pub fn prepare_seconds_total(&self) -> f64 {
        self.prepare_seconds.get()
    }

    /// Queue-wait percentile in seconds (`p` in 0..=100); `None` when no
    /// samples were recorded.
    pub fn queue_percentile(&self, p: f64) -> Option<f64> {
        self.percentile(p, |s| s.0, None)
    }

    /// Service-time percentile in seconds.
    pub fn service_percentile(&self, p: f64) -> Option<f64> {
        self.percentile(p, |s| s.1, None)
    }

    /// Queue-wait percentile over one service class only.
    pub fn class_queue_percentile(&self, class: Priority, p: f64) -> Option<f64> {
        self.percentile(p, |s| s.0, Some(class))
    }

    /// Mean queue wait (s) per completed request of one class; `None`
    /// before any request of that class completed (no fabricated
    /// `total/1` means — see [`Metrics::mean_pool_queue_seconds`]).
    pub fn mean_class_queue_seconds(&self, class: Priority) -> Option<f64> {
        match self.class_completed[class.index()].load(Ordering::Relaxed) { // relaxed-ok: stat read
            0 => None,
            n => Some(self.class_queue_seconds[class.index()].get() / n as f64),
        }
    }

    fn percentile(
        &self,
        p: f64,
        f: impl Fn(&(f32, f32, u8)) -> f32,
        class: Option<Priority>,
    ) -> Option<f64> {
        assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
        // snapshot first (on the legacy store the lock is held only for
        // the copy); the O(n log n) sort runs over the copy so a metrics
        // scrape cannot stall workers recording latencies
        let mut vals: Vec<f32> = self
            .sample_snapshot()
            .iter()
            .filter(|s| match class {
                None => true,
                Some(c) => s.2 == c.index() as u8,
            })
            .map(&f)
            .collect();
        if vals.is_empty() {
            return None;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(percentile_of_sorted(&vals, p))
    }

    /// Total simulated energy (J).
    pub fn energy_j(&self) -> f64 {
        self.sim_energy_j.get()
    }

    /// Seconds since this instance was constructed (`adip_uptime_seconds`).
    pub fn uptime_seconds(&self) -> f64 {
        self.started.0.elapsed().as_secs_f64()
    }

    /// Mean host queue wait (s) per completed request; `None` before any
    /// request completed.
    pub fn mean_queue_seconds(&self) -> Option<f64> {
        match self.completed.load(Ordering::Relaxed) { // relaxed-ok: stat read
            0 => None,
            n => Some(self.queue_seconds.get() / n as f64),
        }
    }

    /// Mean host service time (s) per completed request; `None` before
    /// any request completed.
    pub fn mean_service_seconds(&self) -> Option<f64> {
        match self.completed.load(Ordering::Relaxed) { // relaxed-ok: stat read
            0 => None,
            n => Some(self.service_seconds.get() / n as f64),
        }
    }

    /// Human-readable per-class queue-wait table (one row per
    /// [`Priority`]) — the single source for the CLI summaries, so the
    /// serve and trace reports cannot drift apart.
    pub fn class_queue_summary(&self) -> String {
        // one reservoir snapshot for all six percentiles (same pattern
        // as `render`): one copy, one sort per class
        let snapshot = self.sample_snapshot();
        let mut s = String::new();
        for class in Priority::ALL {
            let i = class.index();
            let waits = sorted_class_waits(&snapshot, class);
            let pct = |p: f64| {
                if waits.is_empty() { 0.0 } else { percentile_of_sorted(&waits, p) }
            };
            s.push_str(&format!(
                "  {:<12} accepted {:>5} | completed {:>5} | queue wait mean {:.3} ms | p50 {:.3} ms | p95 {:.3} ms\n",
                class.name(),
                self.class_accepted[i].load(Ordering::Relaxed), // relaxed-ok: stat read
                self.class_completed[i].load(Ordering::Relaxed), // relaxed-ok: stat read
                self.mean_class_queue_seconds(class).unwrap_or(0.0) * 1e3,
                pct(50.0) * 1e3,
                pct(95.0) * 1e3
            ));
        }
        s
    }

    /// Prometheus text exposition. Every emitted series is preceded by
    /// its `# HELP`/`# TYPE` comment pair, and series whose value is
    /// genuinely absent (a mean or percentile whose denominator or
    /// sample set is empty — `Option<f64>::None` internally) are
    /// omitted entirely instead of rendered as a fabricated `0.0`.
    ///
    /// HELP text never contains `{` or `\n`, so line-oriented scrapers
    /// that key on `name{label=...}` prefixes cannot mistake a comment
    /// for a sample.
    pub fn render(&self) -> String {
        let mut s = String::new();
        series_f64(
            &mut s,
            "uptime_seconds",
            "counter",
            "Seconds since this serving instance's metrics were constructed.",
            self.uptime_seconds(),
        );
        head(&mut s, "build_info", "gauge", "Build metadata carried as labels; value is always 1.");
        let _ = writeln!(s, "adip_build_info{{version=\"{}\"}} 1", crate::VERSION);
        self.render_scalar_counters(&mut s);
        // per-worker deque gauges: every worker individually (the gauge
        // storage grows with the fleet, so nothing is truncated anymore;
        // the compatibility series below pins that fact at 0)
        let workers = self.balance_workers.load(Ordering::Relaxed) as usize; // relaxed-ok: gauge read
        if workers > 0 {
            head(&mut s, "worker_deque_depth", "gauge", "Balance-fabric deque depth per worker.");
            for (w, depth) in self.worker_deque_depth.snapshot(workers).into_iter().enumerate() {
                let _ = writeln!(s, "adip_worker_deque_depth{{worker=\"{w}\"}} {depth}");
            }
        }
        series_u64(
            &mut s,
            "worker_deque_gauges_truncated",
            "gauge",
            "Workers whose deque depth is not gauged individually (always 0 since the gauge storage became dynamic; kept for dashboard compatibility).",
            0,
        );
        series_f64(
            &mut s,
            "prepare_seconds_total",
            "counter",
            "Host seconds spent in the prepare stage.",
            self.prepare_seconds_total(),
        );
        self.render_class_series(&mut s);
        self.render_pool_and_contention(&mut s);
        series_u64(
            &mut s,
            "trace_dropped_total",
            "counter",
            "Trace records lost to full trace rings (tracing never blocks the hot path).",
            self.trace.dropped(),
        );
        series_f64(
            &mut s,
            "sim_energy_joules_total",
            "counter",
            "Total simulated energy in joules.",
            self.energy_j(),
        );
        series_opt(
            &mut s,
            "queue_seconds_mean",
            "Mean host queue wait per completed request; absent until a request completes.",
            self.mean_queue_seconds(),
        );
        series_opt(
            &mut s,
            "service_seconds_mean",
            "Mean host service time per completed request; absent until a request completes.",
            self.mean_service_seconds(),
        );
        for (name, help, v) in [
            (
                "queue_seconds_p50",
                "Queue-wait p50 over recent samples; absent without samples.",
                self.queue_percentile(50.0),
            ),
            (
                "queue_seconds_p99",
                "Queue-wait p99 over recent samples; absent without samples.",
                self.queue_percentile(99.0),
            ),
            (
                "service_seconds_p50",
                "Service-time p50 over recent samples; absent without samples.",
                self.service_percentile(50.0),
            ),
            (
                "service_seconds_p99",
                "Service-time p99 over recent samples; absent without samples.",
                self.service_percentile(99.0),
            ),
        ] {
            series_opt(&mut s, name, help, v);
        }
        s
    }

    fn render_scalar_counters(&self, s: &mut String) {
        // One row per scalar metric; kept tabular for reviewability.
        #[rustfmt::skip]
        let rows: [(&str, &str, &str, u64); 24] = [
            // relaxed-ok: render-time stat reads; fields are independent
            ("requests_accepted_total", "counter", "Requests accepted into the admission queue.", self.accepted.load(Ordering::Relaxed)),
            ("requests_rejected_total", "counter", "Requests rejected by admission backpressure.", self.rejected.load(Ordering::Relaxed)),
            ("requests_completed_total", "counter", "Requests completed successfully.", self.completed.load(Ordering::Relaxed)),
            ("requests_failed_total", "counter", "Requests that failed validation or execution.", self.failed.load(Ordering::Relaxed)),
            ("batches_total", "counter", "Batches executed.", self.batches.load(Ordering::Relaxed)),
            ("batches_fused_total", "counter", "Batches that fused more than one matrix or request.", self.fused_batches.load(Ordering::Relaxed)),
            ("sim_cycles_total", "counter", "Total simulated accelerator cycles.", self.sim_cycles.load(Ordering::Relaxed)),
            ("tile_passes_total", "counter", "Total stationary-tile passes.", self.passes.load(Ordering::Relaxed)),
            ("sim_memory_bytes_total", "counter", "Total simulated memory traffic in bytes.", self.memory_bytes.load(Ordering::Relaxed)),
            ("weight_cache_hits_total", "counter", "Weight-tile cache hits.", self.cache_hits.load(Ordering::Relaxed)),
            ("weight_cache_shared_hits_total", "counter", "Cache hits served by an entry another worker inserted.", self.cache_shared_hits.load(Ordering::Relaxed)),
            ("weight_cache_misses_total", "counter", "Weight-tile cache misses.", self.cache_misses.load(Ordering::Relaxed)),
            ("weight_cache_evictions_total", "counter", "Weight-tile cache evictions.", self.cache_evictions.load(Ordering::Relaxed)),
            ("queue_depth", "gauge", "Requests currently queued for batching.", self.queue_depth.load(Ordering::Relaxed)),
            ("shed_total", "counter", "Requests failed fast on a hopeless soft deadline.", self.shed.load(Ordering::Relaxed)),
            ("cancelled_total", "counter", "Requests killed by cancellation before execution.", self.cancelled.load(Ordering::Relaxed)),
            ("deadline_demotions_total", "counter", "Deadline-hopeless requests demoted to the background class.", self.deadline_demotions.load(Ordering::Relaxed)),
            ("steals_total", "counter", "Batches stolen from sibling worker deques.", self.steals.load(Ordering::Relaxed)),
            ("steal_failures_total", "counter", "Idle pops that found no victim worth stealing from.", self.steal_failures.load(Ordering::Relaxed)),
            ("coalesced_passes_total", "counter", "Cross-request coalesced passes executed.", self.coalesced_passes.load(Ordering::Relaxed)),
            ("coalesced_members_total", "counter", "Member batches executed inside coalesced passes.", self.coalesced_members.load(Ordering::Relaxed)),
            ("injector_depth", "gauge", "Batches queued in the balance fabric global injector.", self.injector_depth.load(Ordering::Relaxed)),
            ("worker_panics_total", "counter", "Coordinator worker threads lost to panics.", self.worker_panics.load(Ordering::Relaxed)),
            ("prepared_depth", "gauge", "Batches fully prepared but not yet picked up by a worker.", self.prepared_depth.load(Ordering::Relaxed)),
        ];
        for (name, kind, help, v) in rows {
            series_u64(s, name, kind, help, v);
        }
        series_u64(
            s,
            "prepared_batches_total",
            "counter",
            "Batches that went through the prepare stage.",
            self.prepared_batches.load(Ordering::Relaxed), // relaxed-ok: stat read
        );
        series_u64(
            s,
            "aging_promotions_total",
            "counter",
            "Requests promoted at least one class by the aging rule.",
            self.aging_promotions.load(Ordering::Relaxed), // relaxed-ok: stat read
        );
    }

    fn render_class_series(&self, s: &mut String) {
        // one snapshot of the reservoir serves every per-class percentile
        // below — per-class filter + sort over the copy, instead of a
        // copy + sort per series
        let snapshot = self.sample_snapshot();
        head(s, "class_requests_accepted_total", "counter", "Requests accepted per service class.");
        for class in Priority::ALL {
            let _ = writeln!(
                s,
                "adip_class_requests_accepted_total{{class=\"{}\"}} {}",
                class.name(),
                self.class_accepted[class.index()].load(Ordering::Relaxed) // relaxed-ok: stat read
            );
        }
        head(
            s,
            "class_requests_completed_total",
            "counter",
            "Requests completed per service class.",
        );
        for class in Priority::ALL {
            let _ = writeln!(
                s,
                "adip_class_requests_completed_total{{class=\"{}\"}} {}",
                class.name(),
                self.class_completed[class.index()].load(Ordering::Relaxed) // relaxed-ok: stat read
            );
        }
        let means: Vec<(Priority, f64)> = Priority::ALL
            .iter()
            .filter_map(|&c| self.mean_class_queue_seconds(c).map(|v| (c, v)))
            .collect();
        if !means.is_empty() {
            head(
                s,
                "class_queue_seconds_mean",
                "gauge",
                "Mean queue wait per completed request of the class; absent classes completed nothing.",
            );
            for (c, v) in means {
                let _ = writeln!(
                    s,
                    "adip_class_queue_seconds_mean{{class=\"{}\"}} {v:.6e}",
                    c.name()
                );
            }
        }
        let waits: Vec<Vec<f32>> =
            Priority::ALL.iter().map(|&c| sorted_class_waits(&snapshot, c)).collect();
        for (pname, p) in [("p50", 50.0), ("p95", 95.0)] {
            let vals: Vec<(Priority, f64)> = Priority::ALL
                .iter()
                .filter(|c| !waits[c.index()].is_empty())
                .map(|&c| (c, percentile_of_sorted(&waits[c.index()], p)))
                .collect();
            if vals.is_empty() {
                continue;
            }
            head(
                s,
                &format!("class_queue_seconds_{pname}"),
                "gauge",
                "Queue-wait percentile over the class's recent samples; absent classes have none.",
            );
            for (c, v) in vals {
                let _ = writeln!(
                    s,
                    "adip_class_queue_seconds_{pname}{{class=\"{}\"}} {v:.6e}",
                    c.name()
                );
            }
        }
    }

    fn render_pool_and_contention(&self, s: &mut String) {
        series_u64(
            s,
            "pool_workers",
            "gauge",
            "Persistent cluster-pool worker threads.",
            self.pool_workers.load(Ordering::Relaxed), // relaxed-ok: gauge read
        );
        series_u64(
            s,
            "pool_shards_dispatched_total",
            "counter",
            "Shard jobs dispatched to the cluster pool.",
            self.pool_shards_dispatched.load(Ordering::Relaxed), // relaxed-ok: stat read
        );
        series_u64(
            s,
            "pool_worker_panics_total",
            "counter",
            "Cluster-pool worker threads lost to panics.",
            self.pool_worker_panics.load(Ordering::Relaxed), // relaxed-ok: stat read
        );
        series_f64(
            s,
            "pool_queue_seconds_total",
            "counter",
            "Host seconds shard jobs spent waiting in the pool queue.",
            self.pool_queue_seconds_total(),
        );
        series_opt(
            s,
            "pool_queue_seconds_mean",
            "Mean pool queue wait per dispatched shard; absent until a shard is dispatched.",
            self.mean_pool_queue_seconds(),
        );
        series_u64(
            s,
            "metrics_lock_waits_total",
            "counter",
            "Contended acquisitions of the legacy latency-reservoir lock.",
            self.metrics_lock_waits.load(Ordering::Relaxed), // relaxed-ok: stat read
        );
        let (lat_shards, lat_occupied) = if self.use_legacy_reservoir {
            (0, 0)
        } else {
            (LATENCY_SHARDS as u64, self.sharded.occupied() as u64)
        };
        series_u64(
            s,
            "latency_shards",
            "gauge",
            "Latency-reservoir shards (0 when the legacy locked store is active).",
            lat_shards,
        );
        series_u64(
            s,
            "latency_shards_occupied",
            "gauge",
            "Latency-reservoir shards holding at least one sample.",
            lat_occupied,
        );
        series_u64(
            s,
            "weight_cache_lock_waits_total",
            "counter",
            "Contended acquisitions of weight-cache shard locks.",
            self.cache_lock_waits.load(Ordering::Relaxed), // relaxed-ok: stat read
        );
        series_u64(
            s,
            "weight_cache_shards",
            "gauge",
            "Weight-cache shards (0 for an unsharded cache).",
            self.cache_shards.load(Ordering::Relaxed), // relaxed-ok: gauge read
        );
        series_u64(
            s,
            "weight_cache_shards_occupied",
            "gauge",
            "Weight-cache shards holding at least one entry.",
            self.cache_shards_occupied.load(Ordering::Relaxed), // relaxed-ok: gauge read
        );
    }
}

/// `# HELP`/`# TYPE` preamble for one series. `help` must stay free of
/// `{` and newlines (see [`Metrics::render`]).
fn head(s: &mut String, name: &str, kind: &str, help: &str) {
    debug_assert!(!help.contains('{') && !help.contains('\n'));
    let _ = writeln!(s, "# HELP adip_{name} {help}\n# TYPE adip_{name} {kind}");
}

fn series_u64(s: &mut String, name: &str, kind: &str, help: &str, v: u64) {
    head(s, name, kind, help);
    let _ = writeln!(s, "adip_{name} {v}");
}

fn series_f64(s: &mut String, name: &str, kind: &str, help: &str, v: f64) {
    head(s, name, kind, help);
    let _ = writeln!(s, "adip_{name} {v:.6e}");
}

/// Gauge emitted only when the value exists — absent means/percentiles
/// vanish from the exposition instead of reading as a fabricated zero.
fn series_opt(s: &mut String, name: &str, help: &str, v: Option<f64>) {
    if let Some(v) = v {
        series_f64(s, name, "gauge", help, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.accepted.fetch_add(3, Ordering::Relaxed);
        m.record_completion(100, 1.5e-6, 2048, 4);
        m.record_completion(50, 0.5e-6, 1024, 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.sim_cycles.load(Ordering::Relaxed), 150);
        assert_eq!(m.memory_bytes.load(Ordering::Relaxed), 3072);
        assert!((m.energy_j() - 2.0e-6).abs() < 1e-12);
    }

    #[test]
    fn latency_means() {
        let m = Metrics::default();
        m.record_completion(1, 0.0, 0, 1);
        m.record_completion(1, 0.0, 0, 1);
        m.record_latency(0.2, 0.4, Priority::Batch);
        m.record_latency(0.4, 0.6, Priority::Batch);
        assert!((m.mean_queue_seconds().unwrap() - 0.3).abs() < 1e-12);
        assert!((m.mean_service_seconds().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn means_are_none_until_a_denominator_exists() {
        let m = Metrics::default();
        assert!(m.mean_queue_seconds().is_none());
        assert!(m.mean_service_seconds().is_none());
        assert!(m.mean_pool_queue_seconds().is_none());
        assert!(m.mean_class_queue_seconds(Priority::Interactive).is_none());
        // regression: seconds accrued against a zero denominator must not
        // fabricate a mean (the old `.max(1)` paths reported `total/1`)
        m.queue_seconds.add(0.7);
        m.service_seconds.add(0.7);
        m.pool_queue_seconds.add(0.7);
        m.class_queue_seconds[Priority::Interactive.index()].add(0.7);
        assert!(m.mean_queue_seconds().is_none());
        assert!(m.mean_service_seconds().is_none());
        assert!(m.mean_pool_queue_seconds().is_none());
        assert!(m.mean_class_queue_seconds(Priority::Interactive).is_none());
        // absent means vanish from the exposition entirely (no sample,
        // no orphan HELP/TYPE pair) instead of reading as `0.0`
        let text = m.render();
        assert!(!text.contains("adip_queue_seconds_mean"), "{text}");
        assert!(!text.contains("adip_pool_queue_seconds_mean"), "{text}");
        assert!(!text.contains("adip_class_queue_seconds_mean"), "{text}");
    }

    #[test]
    fn per_class_latency_accounting() {
        let m = Metrics::default();
        m.record_latency(0.1, 0.0, Priority::Interactive);
        m.record_latency(0.3, 0.0, Priority::Interactive);
        m.record_latency(0.8, 0.0, Priority::Background);
        assert_eq!(m.class_completed[Priority::Interactive.index()].load(Ordering::Relaxed), 2);
        assert_eq!(m.class_completed[Priority::Background.index()].load(Ordering::Relaxed), 1);
        assert_eq!(m.class_completed[Priority::Batch.index()].load(Ordering::Relaxed), 0);
        assert!((m.mean_class_queue_seconds(Priority::Interactive).unwrap() - 0.2).abs() < 1e-9);
        assert!((m.mean_class_queue_seconds(Priority::Background).unwrap() - 0.8).abs() < 1e-9);
        assert!(m.mean_class_queue_seconds(Priority::Batch).is_none());
        let p50 = m.class_queue_percentile(Priority::Background, 50.0).unwrap();
        assert!((p50 - 0.8).abs() < 1e-6, "{p50}");
        assert!(m.class_queue_percentile(Priority::Batch, 50.0).is_none());
        let text = m.render();
        assert!(text.contains("adip_class_requests_completed_total{class=\"interactive\"} 2"));
        assert!(text.contains("adip_class_queue_seconds_p95{class=\"background\"}"));
    }

    #[test]
    fn prepare_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.prepared_depth.fetch_add(2, Ordering::Relaxed);
        m.record_prepare(0.25);
        m.record_prepare(0.15);
        m.aging_promotions.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.prepared_batches.load(Ordering::Relaxed), 2);
        assert!((m.prepare_seconds_total() - 0.4).abs() < 1e-12);
        let text = m.render();
        assert!(text.contains("adip_prepared_depth 2"));
        assert!(text.contains("adip_prepared_batches_total 2"));
        assert!(text.contains("adip_aging_promotions_total 3"));
        assert!(text.contains("adip_prepare_seconds_total"));
    }

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        assert!(m.queue_percentile(50.0).is_none());
        for i in 1..=100 {
            m.record_latency(i as f64 / 100.0, (101 - i) as f64 / 100.0, Priority::Batch);
        }
        let p50 = m.queue_percentile(50.0).unwrap();
        assert!((p50 - 0.5).abs() < 0.02, "{p50}");
        let p99 = m.queue_percentile(99.0).unwrap();
        assert!(p99 >= 0.98, "{p99}");
        let s50 = m.service_percentile(50.0).unwrap();
        assert!((s50 - 0.5).abs() < 0.02, "{s50}");
        let text = m.render();
        assert!(text.contains("adip_queue_seconds_p99"));
    }

    #[test]
    #[should_panic]
    fn percentile_range_checked() {
        Metrics::default().queue_percentile(101.0);
    }

    #[test]
    fn render_contains_all_series() {
        let m = Metrics::default();
        let text = m.render();
        for key in [
            "adip_uptime_seconds",
            "adip_build_info{version=\"",
            "adip_worker_panics_total",
            "adip_requests_accepted_total",
            "adip_requests_rejected_total",
            "adip_batches_fused_total",
            "adip_sim_energy_joules_total",
            "adip_weight_cache_hits_total",
            "adip_weight_cache_shared_hits_total",
            "adip_weight_cache_misses_total",
            "adip_weight_cache_evictions_total",
            "adip_queue_depth",
            "adip_cancelled_total",
            "adip_prepared_depth",
            "adip_prepared_batches_total",
            "adip_aging_promotions_total",
            "adip_prepare_seconds_total",
            "adip_worker_deque_gauges_truncated",
            "adip_class_requests_accepted_total{class=\"interactive\"}",
            "adip_class_requests_completed_total{class=\"background\"}",
            "adip_pool_workers",
            "adip_pool_shards_dispatched_total",
            "adip_pool_worker_panics_total",
            "adip_pool_queue_seconds_total",
            "adip_metrics_lock_waits_total",
            "adip_latency_shards",
            "adip_latency_shards_occupied",
            "adip_weight_cache_lock_waits_total",
            "adip_weight_cache_shards",
            "adip_weight_cache_shards_occupied",
            "adip_trace_dropped_total",
        ] {
            assert!(text.contains(key), "{key} missing from:\n{text}");
        }
        // every series carries its HELP/TYPE preamble
        assert!(text.contains("# HELP adip_requests_accepted_total "), "{text}");
        assert!(text.contains("# TYPE adip_requests_accepted_total counter"));
        assert!(text.contains("# TYPE adip_queue_depth gauge"));
    }

    #[test]
    fn exposition_format_every_line_parses() {
        fn valid_name(n: &str) -> bool {
            !n.is_empty()
                && n.chars().next().unwrap().is_ascii_alphabetic()
                && n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
        }
        let m = Metrics::default();
        // populate every subsystem so the optional series render too
        m.record_completion(100, 1.5e-6, 2048, 4);
        m.record_latency(0.2, 0.4, Priority::Interactive);
        m.record_prepare(0.1);
        m.record_pool(4, 0.25, 0);
        m.balance_workers.store(20, Ordering::Relaxed);
        m.worker_deque_depth.ensure(20);
        let text = m.render();
        let mut typed = std::collections::HashSet::new();
        let mut samples = 0usize;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let (name, help) = rest.split_once(' ').unwrap_or_else(|| panic!("{line}"));
                assert!(valid_name(name), "{line}");
                assert!(!help.is_empty() && !help.contains('{'), "{line}");
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, kind) = rest.split_once(' ').unwrap_or_else(|| panic!("{line}"));
                assert!(valid_name(name), "{line}");
                assert!(kind == "counter" || kind == "gauge", "{line}");
                assert!(typed.insert(name.to_string()), "duplicate TYPE for {name}");
            } else {
                // sample line: name[{label="v",...}] value
                assert!(!line.starts_with('#'), "unrecognized comment: {line}");
                let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("{line}"));
                assert!(value.parse::<f64>().is_ok(), "unparseable value: {line}");
                let name = match series.split_once('{') {
                    None => series,
                    Some((name, labels)) => {
                        let labels = labels.strip_suffix('}').unwrap_or_else(|| panic!("{line}"));
                        for pair in labels.split(',') {
                            let (k, v) = pair.split_once('=').unwrap_or_else(|| panic!("{line}"));
                            assert!(valid_name(k), "{line}");
                            assert!(
                                v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                                "{line}"
                            );
                        }
                        name
                    }
                };
                assert!(valid_name(name), "{line}");
                assert!(typed.contains(name), "sample without preceding # TYPE: {line}");
                samples += 1;
            }
        }
        assert!(typed.len() > 30, "expected a full exposition, saw {} series", typed.len());
        assert!(samples > typed.len(), "labeled series should add extra samples");
    }

    /// Regression for the lifted 16-worker gauge cap: every worker of an
    /// oversized fleet is gauged individually, and the compatibility
    /// series `adip_worker_deque_gauges_truncated` stays pinned at 0.
    #[test]
    fn deque_gauges_cover_fleets_beyond_the_old_sixteen_cap() {
        const WORKERS: usize = 25; // > the old MAX_DEQUE_GAUGES of 16
        let m = Metrics::default();
        m.balance_workers.store(WORKERS as u64, Ordering::Relaxed);
        for w in 0..WORKERS {
            m.worker_deque_depth.store(w, w as u64 + 100);
        }
        let text = m.render();
        for w in 0..WORKERS {
            let line = format!("adip_worker_deque_depth{{worker=\"{w}\"}} {}", w + 100);
            assert!(text.contains(&line), "worker {w} missing:\n{text}");
        }
        assert!(text.contains("adip_worker_deque_gauges_truncated 0"), "{text}");
        assert!(!text.contains(&format!("worker=\"{WORKERS}\"")), "{text}");
        // gauge reads for never-stored workers are 0, not a panic
        assert_eq!(m.worker_deque_depth.load(WORKERS + 5), 0);
        assert_eq!(m.worker_deque_depth.snapshot(WORKERS + 2).len(), WORKERS + 2);
    }

    #[test]
    fn nearest_rank_percentile_boundaries() {
        // nearest-rank: rank ⌈p/100·len⌉, clamped; p=0 → first element
        for (vals, p, want) in [
            (&[1.0f32][..], 0.0, 1.0),
            (&[1.0][..], 50.0, 1.0),
            (&[1.0][..], 100.0, 1.0),
            (&[1.0, 2.0][..], 0.0, 1.0),
            (&[1.0, 2.0][..], 50.0, 1.0),
            (&[1.0, 2.0][..], 100.0, 2.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 0.0, 1.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 50.0, 2.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 75.0, 3.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 76.0, 4.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 100.0, 4.0),
        ] {
            assert_eq!(
                percentile_of_sorted(vals, p),
                want,
                "len {} p{p}",
                vals.len()
            );
        }
    }

    #[test]
    fn sharded_and_legacy_reservoirs_agree_on_percentiles() {
        let sharded = Metrics::default();
        let legacy = Metrics::legacy();
        assert!(!sharded.is_legacy_reservoir());
        assert!(legacy.is_legacy_reservoir());
        for i in 1..=100 {
            for m in [&sharded, &legacy] {
                m.record_latency(i as f64 / 100.0, (101 - i) as f64 / 100.0, Priority::Batch);
            }
        }
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(sharded.queue_percentile(p), legacy.queue_percentile(p), "p{p}");
            assert_eq!(sharded.service_percentile(p), legacy.service_percentile(p), "p{p}");
        }
        assert_eq!(
            sharded.class_queue_percentile(Priority::Batch, 50.0),
            legacy.class_queue_percentile(Priority::Batch, 50.0)
        );
        assert_eq!(sharded.class_queue_summary(), legacy.class_queue_summary());
        // the lock-free store reports its shards; legacy reports none
        assert!(sharded.render().contains("adip_latency_shards 16"));
        assert!(sharded.render().contains("adip_latency_shards_occupied 1"));
        assert!(legacy.render().contains("adip_latency_shards 0"));
        assert_eq!(legacy.metrics_lock_waits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn concurrent_scrape_never_panics_or_drops_samples() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        // 4 writers × 256 samples ≤ one shard ring's capacity, so every
        // sample is retained even if thread→shard assignment collides
        const WRITERS: usize = 4;
        const PER_WRITER: usize = 256;
        for m in [Metrics::default(), Metrics::legacy()] {
            let stop = Arc::new(AtomicBool::new(false));
            std::thread::scope(|scope| {
                let writers: Vec<_> = (0..WRITERS)
                    .map(|w| {
                        let m = &m;
                        scope.spawn(move || {
                            for i in 0..PER_WRITER {
                                let v = (w * PER_WRITER + i) as f64 * 1e-6;
                                m.record_latency(v, v, Priority::Interactive);
                            }
                        })
                    })
                    .collect();
                let scraper = {
                    let (m, stop) = (&m, &stop);
                    scope.spawn(move || {
                        let mut scrapes = 0u64;
                        while !stop.load(Ordering::Relaxed) || scrapes == 0 {
                            // scrapes racing saturated recording must
                            // never panic or observe a torn sample
                            let _ = m.queue_percentile(99.0);
                            let _ = m.class_queue_summary();
                            let _ = m.render();
                            scrapes += 1;
                        }
                        scrapes
                    })
                };
                for h in writers {
                    h.join().unwrap();
                }
                stop.store(true, Ordering::Relaxed);
                assert!(scraper.join().unwrap() >= 1);
            });
            // quiesced: nothing was dropped by either store
            let total = (WRITERS * PER_WRITER) as u64;
            assert_eq!(
                m.class_completed[Priority::Interactive.index()].load(Ordering::Relaxed),
                total
            );
            assert_eq!(m.sample_snapshot().len() as u64, total, "retained samples");
            let p100 = m.queue_percentile(100.0).unwrap();
            assert!((p100 - (total - 1) as f64 * 1e-6).abs() < 1e-9, "{p100}");
        }
    }

    #[test]
    fn balance_series_render_with_per_worker_labels() {
        let m = Metrics::default();
        m.shed.fetch_add(2, Ordering::Relaxed);
        m.deadline_demotions.fetch_add(1, Ordering::Relaxed);
        m.steals.fetch_add(7, Ordering::Relaxed);
        m.steal_failures.fetch_add(3, Ordering::Relaxed);
        m.coalesced_passes.fetch_add(4, Ordering::Relaxed);
        m.coalesced_members.fetch_add(9, Ordering::Relaxed);
        m.injector_depth.store(5, Ordering::Relaxed);
        m.balance_workers.store(2, Ordering::Relaxed);
        m.worker_deque_depth.store(0, 11);
        m.worker_deque_depth.store(1, 13);
        let text = m.render();
        assert!(text.contains("adip_shed_total 2"), "{text}");
        assert!(text.contains("adip_deadline_demotions_total 1"));
        assert!(text.contains("adip_steals_total 7"));
        assert!(text.contains("adip_steal_failures_total 3"));
        assert!(text.contains("adip_coalesced_passes_total 4"));
        assert!(text.contains("adip_coalesced_members_total 9"));
        assert!(text.contains("adip_injector_depth 5"));
        assert!(text.contains("adip_worker_deque_depth{worker=\"0\"} 11"));
        assert!(text.contains("adip_worker_deque_depth{worker=\"1\"} 13"));
        // gauges only render for registered workers
        assert!(!text.contains("adip_worker_deque_depth{worker=\"2\"}"));
        // with no coordinator running, no per-worker series at all
        let idle = Metrics::default().render();
        assert!(!idle.contains("adip_worker_deque_depth{"));
        assert!(idle.contains("adip_steals_total 0"));
    }

    #[test]
    fn cache_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.record_cache(3, 1, 2, 1);
        m.record_cache(1, 0, 0, 0);
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(m.cache_shared_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(m.cache_evictions.load(Ordering::Relaxed), 1);
        assert!(m.render().contains("adip_weight_cache_hits_total 4"));
        assert!(m.render().contains("adip_weight_cache_shared_hits_total 1"));
    }

    #[test]
    fn pool_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.pool_workers.store(8, Ordering::Relaxed);
        m.record_pool(4, 0.25, 0);
        m.record_pool(2, 0.15, 1);
        assert_eq!(m.pool_shards_dispatched.load(Ordering::Relaxed), 6);
        assert_eq!(m.pool_worker_panics.load(Ordering::Relaxed), 1);
        assert!((m.pool_queue_seconds_total() - 0.4).abs() < 1e-12);
        assert!((m.mean_pool_queue_seconds().unwrap() - 0.4 / 6.0).abs() < 1e-12);
        let text = m.render();
        assert!(text.contains("adip_pool_workers 8"));
        assert!(text.contains("adip_pool_shards_dispatched_total 6"));
        assert!(text.contains("adip_pool_worker_panics_total 1"));
    }

    #[test]
    fn atomic_f64_concurrent_adds() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.record_completion(1, 0.001, 0, 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!((m.energy_j() - 4.0).abs() < 1e-9);
        assert_eq!(m.completed.load(Ordering::Relaxed), 4000);
    }
    /// Regression: a thread that panics while holding the legacy sample
    /// reservoir must not wedge every later recorder/reader (the lock is
    /// recovered via `into_inner`, not unwrapped).
    #[test]
    fn poisoned_legacy_reservoir_keeps_recording() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::legacy());
        m.record_latency(0.1, 0.2, Priority::Batch);
        let poisoner = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.samples.lock().unwrap();
            panic!("poison the reservoir lock");
        })
        .join();
        assert!(m.samples.lock().is_err(), "precondition: lock is poisoned");
        m.record_latency(0.3, 0.4, Priority::Batch);
        assert_eq!(m.sample_snapshot().len(), 2, "recording survived the poison");
        assert!(m.queue_percentile(50.0).is_some());
    }
}
