//! Coordinator metrics: lock-free counters + Prometheus-style text dump.
//!
//! Alongside the global counters, the serving redesign added per-class
//! series (accepted/completed/queue-wait per [`Priority`]) and the
//! pipeline's prepare-stage series (`prepared_depth` — the gauge that
//! makes prepare/execute overlap observable — plus prepared totals,
//! prepare seconds and aging promotions).

use std::sync::atomic::{AtomicU64, Ordering};

use super::client::Priority;

/// How many per-worker deque-depth gauges the balance fabric exports
/// individually; workers beyond this (unrealistic for the simulated
/// clusters here) are simply not gauged per-worker.
pub const MAX_DEQUE_GAUGES: usize = 16;

/// Nearest-rank percentile over an ascending-sorted, non-empty slice:
/// rank `⌈p/100 · len⌉`, so the reported value is always an observed
/// sample and p = 100 is exactly the maximum (p = 0 degenerates to the
/// first element). This is the one index/rounding rule shared by
/// [`Metrics::queue_percentile`] and the per-class series in
/// [`Metrics::render`] — it used to *document* nearest-rank while
/// implementing linear-index rounding, which disagreed at small `len`.
fn percentile_of_sorted(sorted: &[f32], p: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)] as f64
}

/// One class's queue-wait samples from a reservoir snapshot, sorted
/// ascending — the shared per-class extraction behind
/// [`Metrics::class_queue_summary`] and [`Metrics::render`].
fn sorted_class_waits(snapshot: &[(f32, f32, u8)], class: Priority) -> Vec<f32> {
    let mut waits: Vec<f32> = snapshot
        .iter()
        .filter(|x| x.2 == class.index() as u8)
        .map(|x| x.0)
        .collect();
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    waits
}

/// Atomic f64 stored as bits (sums only; no CAS loops needed beyond add).
#[derive(Debug, Default)]
struct AtomicF64(AtomicU64);

impl AtomicF64 {
    fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let new = f64::from_bits(cur) + v;
            match self.0.compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Shards in the default lock-free latency reservoir. Recording threads
/// are assigned round-robin, so any worker count up to this records with
/// zero cross-thread contention.
const LATENCY_SHARDS: usize = 16;

/// Packed samples retained per (shard, class) ring — together the shards
/// keep a sliding window of the most recent
/// `LATENCY_SHARDS · LATENCY_SHARD_CAP` samples per class.
const LATENCY_SHARD_CAP: usize = 1024;

/// Ring-slot sentinel for "never written". A real sample cannot collide:
/// it would need both packed halves to be all-ones NaN bit patterns, and
/// recorded latencies are finite (`record` re-maps the collision anyway).
const EMPTY_SLOT: u64 = u64::MAX;

/// One shard of the lock-free latency reservoir: per-class rings of
/// packed `(queue f32 << 32 | service f32)` words. The class is implied
/// by which ring a slot lives in, so a single atomic store publishes a
/// whole sample — a concurrent scrape can never observe a torn
/// `(queue, service, class)` triple.
#[derive(Debug)]
struct LatencyShard {
    slots: [Vec<AtomicU64>; Priority::COUNT],
    /// Monotone per-class write counters; slot = counter % CAP.
    written: [AtomicU64; Priority::COUNT],
}

impl Default for LatencyShard {
    fn default() -> LatencyShard {
        LatencyShard {
            slots: std::array::from_fn(|_| {
                (0..LATENCY_SHARD_CAP).map(|_| AtomicU64::new(EMPTY_SLOT)).collect()
            }),
            written: Default::default(),
        }
    }
}

/// The default latency reservoir: each recording thread owns one of
/// [`LATENCY_SHARDS`] private shards for its lifetime (round-robin
/// assignment on first record), so saturated recording never serializes
/// on a mutex; a scrape reads every slot with plain atomic loads.
#[derive(Debug)]
struct ShardedReservoir {
    shards: Vec<LatencyShard>,
}

impl Default for ShardedReservoir {
    fn default() -> ShardedReservoir {
        ShardedReservoir {
            shards: (0..LATENCY_SHARDS).map(|_| LatencyShard::default()).collect(),
        }
    }
}

impl ShardedReservoir {
    /// The calling thread's stable shard (assigned round-robin from a
    /// process-wide counter on first use).
    fn my_shard(&self) -> &LatencyShard {
        use std::cell::Cell;
        static NEXT_SHARD: AtomicU64 = AtomicU64::new(0);
        thread_local! {
            static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
        }
        let idx = SHARD.with(|s| {
            let mut v = s.get();
            if v == usize::MAX {
                v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) as usize % LATENCY_SHARDS;
                s.set(v);
            }
            v
        });
        &self.shards[idx]
    }

    fn record(&self, sample: (f32, f32, u8)) {
        let mut packed = ((sample.0.to_bits() as u64) << 32) | sample.1.to_bits() as u64;
        if packed == EMPTY_SLOT {
            // unreachable for finite latencies; keep the sentinel unique
            packed -= 1;
        }
        let shard = self.my_shard();
        let class = sample.2 as usize;
        let slot =
            shard.written[class].fetch_add(1, Ordering::Relaxed) as usize % LATENCY_SHARD_CAP;
        shard.slots[class][slot].store(packed, Ordering::Relaxed);
    }

    /// Copy out every occupied slot. A slot whose index was reserved but
    /// whose store has not landed yet still holds the sentinel or a
    /// previous complete sample — never a half-written one.
    fn snapshot(&self) -> Vec<(f32, f32, u8)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            for class in 0..Priority::COUNT {
                let n = (shard.written[class].load(Ordering::Relaxed) as usize)
                    .min(LATENCY_SHARD_CAP);
                for slot in &shard.slots[class][..n] {
                    let v = slot.load(Ordering::Relaxed);
                    if v == EMPTY_SLOT {
                        continue;
                    }
                    out.push((
                        f32::from_bits((v >> 32) as u32),
                        f32::from_bits(v as u32),
                        class as u8,
                    ));
                }
            }
        }
        out
    }

    /// Shards holding at least one recorded sample (occupancy gauge).
    fn occupied(&self) -> usize {
        self.shards
            .iter()
            .filter(|sh| sh.written.iter().any(|w| w.load(Ordering::Relaxed) > 0))
            .count()
    }
}

/// Coordinator-wide metrics, shared across threads.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted into the queue.
    pub accepted: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests that failed validation/execution.
    pub failed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Batches that fused ≥ 2 requests or matrices.
    pub fused_batches: AtomicU64,
    /// Total simulated cycles.
    pub sim_cycles: AtomicU64,
    /// Total stationary-tile passes.
    pub passes: AtomicU64,
    /// Total simulated memory traffic (paper policy bytes).
    pub memory_bytes: AtomicU64,
    /// Weight-tile cache hits (shards served without re-execution).
    pub cache_hits: AtomicU64,
    /// Subset of `cache_hits` served from an entry another worker of the
    /// shared store inserted (cross-worker reuse).
    pub cache_shared_hits: AtomicU64,
    /// Weight-tile cache misses (shards that executed).
    pub cache_misses: AtomicU64,
    /// Weight-tile cache evictions (LRU capacity pressure).
    pub cache_evictions: AtomicU64,
    /// Current queue depth.
    pub queue_depth: AtomicU64,
    /// Persistent cluster-pool workers across all coordinator workers
    /// (gauge; 0 for the per-run engine and for single-core clusters,
    /// which execute inline without pool threads).
    pub pool_workers: AtomicU64,
    /// Shards dispatched to persistent pool workers.
    pub pool_shards_dispatched: AtomicU64,
    /// Pool shard executions that panicked (recovered per-worker).
    pub pool_worker_panics: AtomicU64,
    /// Requests accepted per service class (indexed by
    /// [`Priority::index`]).
    pub class_accepted: [AtomicU64; Priority::COUNT],
    /// Requests completed per service class.
    pub class_completed: [AtomicU64; Priority::COUNT],
    /// Batches fully prepared but not yet picked up by a worker (gauge).
    /// Nonzero under load is the observable proof that the prepare stage
    /// runs ahead of execution.
    pub prepared_depth: AtomicU64,
    /// Batches that went through the prepare stage (pipelined or inline).
    pub prepared_batches: AtomicU64,
    /// Requests promoted at least one class by the batcher's aging rule.
    pub aging_promotions: AtomicU64,
    /// Requests failed fast at batch-formation time because their soft
    /// deadline was already hopeless (also counted in `failed`).
    pub shed: AtomicU64,
    /// Deadline-hopeless Interactive/Batch requests demoted to Background
    /// instead of shed. They still execute, re-classed end-to-end: their
    /// completion and queue-wait series count as Background (so their
    /// deliberately long waits cannot pollute the SLO of the class they
    /// forfeited), while `class_accepted` keeps the submitted class — the
    /// gap between the two is exactly this counter.
    pub deadline_demotions: AtomicU64,
    /// Batches taken from a sibling worker's deque by the balance
    /// fabric's work-stealing (includes Aggressive re-homing).
    pub steals: AtomicU64,
    /// Pop attempts where an idle worker scanned every sibling deque and
    /// found nothing to steal (once per pop, never during the shutdown
    /// drain). Steals under the fabric lock cannot race, so this is an
    /// idleness signal — spare capacity the trace never used — not steal
    /// contention.
    pub steal_failures: AtomicU64,
    /// Cross-request coalesced passes executed (≥ 2 member batches merged
    /// into one shared-weight stacked pass).
    pub coalesced_passes: AtomicU64,
    /// Member batches that executed inside a coalesced pass.
    pub coalesced_members: AtomicU64,
    /// Workers whose balance-fabric deque depth is gauged individually
    /// (`min(workers, MAX_DEQUE_GAUGES)`; 0 when no coordinator runs).
    pub balance_workers: AtomicU64,
    /// Per-worker deque depth gauges (indices `0..balance_workers`).
    pub worker_deque_depth: [AtomicU64; MAX_DEQUE_GAUGES],
    /// Batches queued in the fabric's global injector (gauge).
    pub injector_depth: AtomicU64,
    /// Times a latency-recording thread found the legacy reservoir mutex
    /// held and had to wait (stays 0 in the default sharded mode, which
    /// has no lock to wait on — the differential the hot-path bench
    /// measures).
    pub metrics_lock_waits: AtomicU64,
    /// Cumulative shared-weight-cache lock acquisitions that had to wait
    /// (gauge mirroring the store's own counter; stored by the
    /// coordinator worker loop alongside the cache delta flush).
    pub cache_lock_waits: AtomicU64,
    /// Lock shards in the shared weight-cache store (gauge).
    pub cache_shards: AtomicU64,
    /// Weight-cache shards currently holding at least one entry (gauge).
    pub cache_shards_occupied: AtomicU64,
    sim_energy_j: AtomicF64,
    queue_seconds: AtomicF64,
    service_seconds: AtomicF64,
    /// Total seconds shards waited in pool queues before pickup.
    pool_queue_seconds: AtomicF64,
    /// Host seconds spent preparing batches (validation already happened
    /// at admission; this is mode selection + fingerprinting + assembly).
    prepare_seconds: AtomicF64,
    /// Per-class queue-wait sums (means need a denominator:
    /// `class_completed`).
    class_queue_seconds: [AtomicF64; Priority::COUNT],
    /// Legacy bounded latency reservoir for percentile reporting:
    /// `(queue_s, service_s, class index)` triples plus the rolling
    /// overwrite cursor. At [`Metrics::MAX_SAMPLES`] the oldest sample is
    /// overwritten (sliding window), so percentiles keep tracking a
    /// long-running server instead of freezing on its warm-up period.
    /// Only written when `use_legacy_reservoir` is set ([`Metrics::legacy`]);
    /// the default path records into `sharded` without any lock.
    samples: std::sync::Mutex<(Vec<(f32, f32, u8)>, usize)>,
    /// Default lock-free latency store (see [`ShardedReservoir`]).
    sharded: ShardedReservoir,
    /// Route `record_latency` through the single-mutex `samples`
    /// reservoir instead of `sharded` — the pre-sharding behavior, kept
    /// as the differential/contention baseline.
    use_legacy_reservoir: bool,
}

impl Metrics {
    /// Record request completion accounting.
    pub fn record_completion(&self, cycles: u64, energy_j: f64, memory_bytes: u64, passes: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.sim_cycles.fetch_add(cycles, Ordering::Relaxed);
        self.memory_bytes.fetch_add(memory_bytes, Ordering::Relaxed);
        self.passes.fetch_add(passes, Ordering::Relaxed);
        self.sim_energy_j.add(energy_j);
    }

    /// Record weight-tile cache activity (per-batch deltas from a worker's
    /// cluster scheduler). `shared_hits` is the subset of `hits` served
    /// from entries a sibling worker inserted into a shared store.
    pub fn record_cache(&self, hits: u64, shared_hits: u64, misses: u64, evictions: u64) {
        self.cache_hits.fetch_add(hits, Ordering::Relaxed);
        self.cache_shared_hits.fetch_add(shared_hits, Ordering::Relaxed);
        self.cache_misses.fetch_add(misses, Ordering::Relaxed);
        self.cache_evictions.fetch_add(evictions, Ordering::Relaxed);
    }

    /// Record persistent-pool activity (per-batch deltas from a worker's
    /// cluster scheduler): shards dispatched, seconds those shards waited
    /// in the pool queue, and worker panics survived.
    pub fn record_pool(&self, dispatched: u64, queue_wait_s: f64, panics: u64) {
        self.pool_shards_dispatched.fetch_add(dispatched, Ordering::Relaxed);
        self.pool_worker_panics.fetch_add(panics, Ordering::Relaxed);
        self.pool_queue_seconds.add(queue_wait_s);
    }

    /// Total seconds shards waited in pool queues before a worker pickup.
    pub fn pool_queue_seconds_total(&self) -> f64 {
        self.pool_queue_seconds.get()
    }

    /// Mean pool queue wait (s) per dispatched shard; `None` before any
    /// shard was dispatched. (This used to divide by `count.max(1)`,
    /// which silently fabricated a `total/1` "mean" whenever seconds had
    /// accrued with a zero denominator.)
    pub fn mean_pool_queue_seconds(&self) -> Option<f64> {
        match self.pool_shards_dispatched.load(Ordering::Relaxed) {
            0 => None,
            n => Some(self.pool_queue_seconds.get() / n as f64),
        }
    }

    /// Cap on retained latency samples in the legacy reservoir (a
    /// sliding window once full; enough for stable p99 over any bench
    /// run here). The sharded store's window is
    /// `LATENCY_SHARDS · LATENCY_SHARD_CAP` per class.
    pub const MAX_SAMPLES: usize = 1 << 16;

    /// Metrics recording latencies through the legacy single-mutex
    /// reservoir — the pre-sharding hot path, kept as the differential
    /// and contention baseline that `bench_hotpath` measures the default
    /// sharded store against. Every series and reader is identical; only
    /// the `record_latency` synchronization differs.
    pub fn legacy() -> Metrics {
        Metrics { use_legacy_reservoir: true, ..Metrics::default() }
    }

    /// Whether this instance records through the legacy mutex reservoir.
    pub fn is_legacy_reservoir(&self) -> bool {
        self.use_legacy_reservoir
    }

    /// Record host-side latencies for one completed request of `class`.
    pub fn record_latency(&self, queue_s: f64, service_s: f64, class: Priority) {
        self.queue_seconds.add(queue_s);
        self.service_seconds.add(service_s);
        self.class_completed[class.index()].fetch_add(1, Ordering::Relaxed);
        self.class_queue_seconds[class.index()].add(queue_s);
        let sample = (queue_s as f32, service_s as f32, class.index() as u8);
        if !self.use_legacy_reservoir {
            self.sharded.record(sample);
            return;
        }
        let mut guard = self.samples.try_lock().unwrap_or_else(|_| {
            // contended: count the wait, then block like before
            self.metrics_lock_waits.fetch_add(1, Ordering::Relaxed);
            self.samples.lock().expect("metrics lock")
        });
        let (buf, cursor) = &mut *guard;
        if buf.len() < Self::MAX_SAMPLES {
            buf.push(sample);
        } else {
            // sliding window: overwrite the oldest so a long-running
            // server's percentiles never freeze on its warm-up period
            buf[*cursor] = sample;
            *cursor = (*cursor + 1) % Self::MAX_SAMPLES;
        }
    }

    /// One coherent copy of the latency reservoir, whichever hot-path
    /// store is active — every percentile/summary reader works over this
    /// so the two stores are observationally identical.
    fn sample_snapshot(&self) -> Vec<(f32, f32, u8)> {
        if self.use_legacy_reservoir {
            self.samples.lock().expect("metrics lock").0.clone()
        } else {
            self.sharded.snapshot()
        }
    }

    /// Record host seconds one batch spent in the prepare stage.
    pub fn record_prepare(&self, seconds: f64) {
        self.prepared_batches.fetch_add(1, Ordering::Relaxed);
        self.prepare_seconds.add(seconds);
    }

    /// Total host seconds spent preparing batches.
    pub fn prepare_seconds_total(&self) -> f64 {
        self.prepare_seconds.get()
    }

    /// Queue-wait percentile in seconds (`p` in 0..=100); `None` when no
    /// samples were recorded.
    pub fn queue_percentile(&self, p: f64) -> Option<f64> {
        self.percentile(p, |s| s.0, None)
    }

    /// Service-time percentile in seconds.
    pub fn service_percentile(&self, p: f64) -> Option<f64> {
        self.percentile(p, |s| s.1, None)
    }

    /// Queue-wait percentile over one service class only.
    pub fn class_queue_percentile(&self, class: Priority, p: f64) -> Option<f64> {
        self.percentile(p, |s| s.0, Some(class))
    }

    /// Mean queue wait (s) per completed request of one class; `None`
    /// before any request of that class completed (no fabricated
    /// `total/1` means — see [`Metrics::mean_pool_queue_seconds`]).
    pub fn mean_class_queue_seconds(&self, class: Priority) -> Option<f64> {
        match self.class_completed[class.index()].load(Ordering::Relaxed) {
            0 => None,
            n => Some(self.class_queue_seconds[class.index()].get() / n as f64),
        }
    }

    fn percentile(
        &self,
        p: f64,
        f: impl Fn(&(f32, f32, u8)) -> f32,
        class: Option<Priority>,
    ) -> Option<f64> {
        assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
        // snapshot first (on the legacy store the lock is held only for
        // the copy); the O(n log n) sort runs over the copy so a metrics
        // scrape cannot stall workers recording latencies
        let mut vals: Vec<f32> = self
            .sample_snapshot()
            .iter()
            .filter(|s| match class {
                None => true,
                Some(c) => s.2 == c.index() as u8,
            })
            .map(&f)
            .collect();
        if vals.is_empty() {
            return None;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(percentile_of_sorted(&vals, p))
    }

    /// Total simulated energy (J).
    pub fn energy_j(&self) -> f64 {
        self.sim_energy_j.get()
    }

    /// Mean host queue wait (s) per completed request; `None` before any
    /// request completed.
    pub fn mean_queue_seconds(&self) -> Option<f64> {
        match self.completed.load(Ordering::Relaxed) {
            0 => None,
            n => Some(self.queue_seconds.get() / n as f64),
        }
    }

    /// Mean host service time (s) per completed request; `None` before
    /// any request completed.
    pub fn mean_service_seconds(&self) -> Option<f64> {
        match self.completed.load(Ordering::Relaxed) {
            0 => None,
            n => Some(self.service_seconds.get() / n as f64),
        }
    }

    /// Human-readable per-class queue-wait table (one row per
    /// [`Priority`]) — the single source for the CLI summaries, so the
    /// serve and trace reports cannot drift apart.
    pub fn class_queue_summary(&self) -> String {
        // one reservoir snapshot for all six percentiles (same pattern
        // as `render`): one copy, one sort per class
        let snapshot = self.sample_snapshot();
        let mut s = String::new();
        for class in Priority::ALL {
            let i = class.index();
            let waits = sorted_class_waits(&snapshot, class);
            let pct = |p: f64| {
                if waits.is_empty() { 0.0 } else { percentile_of_sorted(&waits, p) }
            };
            s.push_str(&format!(
                "  {:<12} accepted {:>5} | completed {:>5} | queue wait mean {:.3} ms | p50 {:.3} ms | p95 {:.3} ms\n",
                class.name(),
                self.class_accepted[i].load(Ordering::Relaxed),
                self.class_completed[i].load(Ordering::Relaxed),
                self.mean_class_queue_seconds(class).unwrap_or(0.0) * 1e3,
                pct(50.0) * 1e3,
                pct(95.0) * 1e3
            ));
        }
        s
    }

    /// Prometheus-style text exposition.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let c = |name: &str, v: u64| format!("adip_{name} {v}\n");
        s.push_str(&c("requests_accepted_total", self.accepted.load(Ordering::Relaxed)));
        s.push_str(&c("requests_rejected_total", self.rejected.load(Ordering::Relaxed)));
        s.push_str(&c("requests_completed_total", self.completed.load(Ordering::Relaxed)));
        s.push_str(&c("requests_failed_total", self.failed.load(Ordering::Relaxed)));
        s.push_str(&c("batches_total", self.batches.load(Ordering::Relaxed)));
        s.push_str(&c("batches_fused_total", self.fused_batches.load(Ordering::Relaxed)));
        s.push_str(&c("sim_cycles_total", self.sim_cycles.load(Ordering::Relaxed)));
        s.push_str(&c("tile_passes_total", self.passes.load(Ordering::Relaxed)));
        s.push_str(&c("sim_memory_bytes_total", self.memory_bytes.load(Ordering::Relaxed)));
        s.push_str(&c("weight_cache_hits_total", self.cache_hits.load(Ordering::Relaxed)));
        s.push_str(&c(
            "weight_cache_shared_hits_total",
            self.cache_shared_hits.load(Ordering::Relaxed),
        ));
        s.push_str(&c("weight_cache_misses_total", self.cache_misses.load(Ordering::Relaxed)));
        s.push_str(&c(
            "weight_cache_evictions_total",
            self.cache_evictions.load(Ordering::Relaxed),
        ));
        s.push_str(&c("queue_depth", self.queue_depth.load(Ordering::Relaxed)));
        s.push_str(&c("shed_total", self.shed.load(Ordering::Relaxed)));
        s.push_str(&c(
            "deadline_demotions_total",
            self.deadline_demotions.load(Ordering::Relaxed),
        ));
        s.push_str(&c("steals_total", self.steals.load(Ordering::Relaxed)));
        s.push_str(&c("steal_failures_total", self.steal_failures.load(Ordering::Relaxed)));
        s.push_str(&c(
            "coalesced_passes_total",
            self.coalesced_passes.load(Ordering::Relaxed),
        ));
        s.push_str(&c(
            "coalesced_members_total",
            self.coalesced_members.load(Ordering::Relaxed),
        ));
        s.push_str(&c("injector_depth", self.injector_depth.load(Ordering::Relaxed)));
        let gauged = (self.balance_workers.load(Ordering::Relaxed) as usize).min(MAX_DEQUE_GAUGES);
        for w in 0..gauged {
            s.push_str(&format!(
                "adip_worker_deque_depth{{worker=\"{w}\"}} {}\n",
                self.worker_deque_depth[w].load(Ordering::Relaxed)
            ));
        }
        s.push_str(&c("prepared_depth", self.prepared_depth.load(Ordering::Relaxed)));
        s.push_str(&c("prepared_batches_total", self.prepared_batches.load(Ordering::Relaxed)));
        s.push_str(&c("aging_promotions_total", self.aging_promotions.load(Ordering::Relaxed)));
        s.push_str(&format!("adip_prepare_seconds_total {:.6e}\n", self.prepare_seconds_total()));
        // one snapshot of the reservoir serves every per-class percentile
        // below — per-class filter + sort over the copy, instead of a
        // copy + sort per series
        let snapshot = self.sample_snapshot();
        for class in Priority::ALL {
            let l = class.name();
            let i = class.index();
            s.push_str(&format!(
                "adip_class_requests_accepted_total{{class=\"{l}\"}} {}\n",
                self.class_accepted[i].load(Ordering::Relaxed)
            ));
            s.push_str(&format!(
                "adip_class_requests_completed_total{{class=\"{l}\"}} {}\n",
                self.class_completed[i].load(Ordering::Relaxed)
            ));
            s.push_str(&format!(
                "adip_class_queue_seconds_mean{{class=\"{l}\"}} {:.6e}\n",
                self.mean_class_queue_seconds(class).unwrap_or(0.0)
            ));
            let waits = sorted_class_waits(&snapshot, class);
            for (pname, p) in [("p50", 50.0), ("p95", 95.0)] {
                let v = if waits.is_empty() { 0.0 } else { percentile_of_sorted(&waits, p) };
                s.push_str(&format!(
                    "adip_class_queue_seconds_{pname}{{class=\"{l}\"}} {v:.6e}\n"
                ));
            }
        }
        s.push_str(&c("pool_workers", self.pool_workers.load(Ordering::Relaxed)));
        s.push_str(&c(
            "pool_shards_dispatched_total",
            self.pool_shards_dispatched.load(Ordering::Relaxed),
        ));
        s.push_str(&c(
            "pool_worker_panics_total",
            self.pool_worker_panics.load(Ordering::Relaxed),
        ));
        s.push_str(&format!(
            "adip_pool_queue_seconds_total {:.6e}\n",
            self.pool_queue_seconds_total()
        ));
        s.push_str(&format!(
            "adip_pool_queue_seconds_mean {:.6e}\n",
            self.mean_pool_queue_seconds().unwrap_or(0.0)
        ));
        s.push_str(&c(
            "metrics_lock_waits_total",
            self.metrics_lock_waits.load(Ordering::Relaxed),
        ));
        let (lat_shards, lat_occupied) = if self.use_legacy_reservoir {
            (0, 0)
        } else {
            (LATENCY_SHARDS as u64, self.sharded.occupied() as u64)
        };
        s.push_str(&c("latency_shards", lat_shards));
        s.push_str(&c("latency_shards_occupied", lat_occupied));
        s.push_str(&c(
            "weight_cache_lock_waits_total",
            self.cache_lock_waits.load(Ordering::Relaxed),
        ));
        s.push_str(&c("weight_cache_shards", self.cache_shards.load(Ordering::Relaxed)));
        s.push_str(&c(
            "weight_cache_shards_occupied",
            self.cache_shards_occupied.load(Ordering::Relaxed),
        ));
        s.push_str(&format!("adip_sim_energy_joules_total {:.6e}\n", self.energy_j()));
        s.push_str(&format!(
            "adip_queue_seconds_mean {:.6e}\n",
            self.mean_queue_seconds().unwrap_or(0.0)
        ));
        s.push_str(&format!(
            "adip_service_seconds_mean {:.6e}\n",
            self.mean_service_seconds().unwrap_or(0.0)
        ));
        for (name, v) in [
            ("adip_queue_seconds_p50", self.queue_percentile(50.0)),
            ("adip_queue_seconds_p99", self.queue_percentile(99.0)),
            ("adip_service_seconds_p50", self.service_percentile(50.0)),
            ("adip_service_seconds_p99", self.service_percentile(99.0)),
        ] {
            s.push_str(&format!("{name} {:.6e}\n", v.unwrap_or(0.0)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.accepted.fetch_add(3, Ordering::Relaxed);
        m.record_completion(100, 1.5e-6, 2048, 4);
        m.record_completion(50, 0.5e-6, 1024, 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.sim_cycles.load(Ordering::Relaxed), 150);
        assert_eq!(m.memory_bytes.load(Ordering::Relaxed), 3072);
        assert!((m.energy_j() - 2.0e-6).abs() < 1e-12);
    }

    #[test]
    fn latency_means() {
        let m = Metrics::default();
        m.record_completion(1, 0.0, 0, 1);
        m.record_completion(1, 0.0, 0, 1);
        m.record_latency(0.2, 0.4, Priority::Batch);
        m.record_latency(0.4, 0.6, Priority::Batch);
        assert!((m.mean_queue_seconds().unwrap() - 0.3).abs() < 1e-12);
        assert!((m.mean_service_seconds().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn means_are_none_until_a_denominator_exists() {
        let m = Metrics::default();
        assert!(m.mean_queue_seconds().is_none());
        assert!(m.mean_service_seconds().is_none());
        assert!(m.mean_pool_queue_seconds().is_none());
        assert!(m.mean_class_queue_seconds(Priority::Interactive).is_none());
        // regression: seconds accrued against a zero denominator must not
        // fabricate a mean (the old `.max(1)` paths reported `total/1`)
        m.queue_seconds.add(0.7);
        m.service_seconds.add(0.7);
        m.pool_queue_seconds.add(0.7);
        m.class_queue_seconds[Priority::Interactive.index()].add(0.7);
        assert!(m.mean_queue_seconds().is_none());
        assert!(m.mean_service_seconds().is_none());
        assert!(m.mean_pool_queue_seconds().is_none());
        assert!(m.mean_class_queue_seconds(Priority::Interactive).is_none());
        // the rendered exposition falls back to an explicit zero
        let text = m.render();
        assert!(text.contains("adip_queue_seconds_mean 0.000000e0"), "{text}");
        assert!(text.contains("adip_pool_queue_seconds_mean 0.000000e0"));
    }

    #[test]
    fn per_class_latency_accounting() {
        let m = Metrics::default();
        m.record_latency(0.1, 0.0, Priority::Interactive);
        m.record_latency(0.3, 0.0, Priority::Interactive);
        m.record_latency(0.8, 0.0, Priority::Background);
        assert_eq!(m.class_completed[Priority::Interactive.index()].load(Ordering::Relaxed), 2);
        assert_eq!(m.class_completed[Priority::Background.index()].load(Ordering::Relaxed), 1);
        assert_eq!(m.class_completed[Priority::Batch.index()].load(Ordering::Relaxed), 0);
        assert!((m.mean_class_queue_seconds(Priority::Interactive).unwrap() - 0.2).abs() < 1e-9);
        assert!((m.mean_class_queue_seconds(Priority::Background).unwrap() - 0.8).abs() < 1e-9);
        assert!(m.mean_class_queue_seconds(Priority::Batch).is_none());
        let p50 = m.class_queue_percentile(Priority::Background, 50.0).unwrap();
        assert!((p50 - 0.8).abs() < 1e-6, "{p50}");
        assert!(m.class_queue_percentile(Priority::Batch, 50.0).is_none());
        let text = m.render();
        assert!(text.contains("adip_class_requests_completed_total{class=\"interactive\"} 2"));
        assert!(text.contains("adip_class_queue_seconds_p95{class=\"background\"}"));
    }

    #[test]
    fn prepare_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.prepared_depth.fetch_add(2, Ordering::Relaxed);
        m.record_prepare(0.25);
        m.record_prepare(0.15);
        m.aging_promotions.fetch_add(3, Ordering::Relaxed);
        assert_eq!(m.prepared_batches.load(Ordering::Relaxed), 2);
        assert!((m.prepare_seconds_total() - 0.4).abs() < 1e-12);
        let text = m.render();
        assert!(text.contains("adip_prepared_depth 2"));
        assert!(text.contains("adip_prepared_batches_total 2"));
        assert!(text.contains("adip_aging_promotions_total 3"));
        assert!(text.contains("adip_prepare_seconds_total"));
    }

    #[test]
    fn percentiles() {
        let m = Metrics::default();
        assert!(m.queue_percentile(50.0).is_none());
        for i in 1..=100 {
            m.record_latency(i as f64 / 100.0, (101 - i) as f64 / 100.0, Priority::Batch);
        }
        let p50 = m.queue_percentile(50.0).unwrap();
        assert!((p50 - 0.5).abs() < 0.02, "{p50}");
        let p99 = m.queue_percentile(99.0).unwrap();
        assert!(p99 >= 0.98, "{p99}");
        let s50 = m.service_percentile(50.0).unwrap();
        assert!((s50 - 0.5).abs() < 0.02, "{s50}");
        let text = m.render();
        assert!(text.contains("adip_queue_seconds_p99"));
    }

    #[test]
    #[should_panic]
    fn percentile_range_checked() {
        Metrics::default().queue_percentile(101.0);
    }

    #[test]
    fn render_contains_all_series() {
        let m = Metrics::default();
        let text = m.render();
        for key in [
            "adip_requests_accepted_total",
            "adip_requests_rejected_total",
            "adip_batches_fused_total",
            "adip_sim_energy_joules_total",
            "adip_weight_cache_hits_total",
            "adip_weight_cache_shared_hits_total",
            "adip_weight_cache_misses_total",
            "adip_weight_cache_evictions_total",
            "adip_queue_depth",
            "adip_prepared_depth",
            "adip_prepared_batches_total",
            "adip_aging_promotions_total",
            "adip_prepare_seconds_total",
            "adip_class_requests_accepted_total{class=\"interactive\"}",
            "adip_class_requests_completed_total{class=\"background\"}",
            "adip_class_queue_seconds_mean{class=\"batch\"}",
            "adip_pool_workers",
            "adip_pool_shards_dispatched_total",
            "adip_pool_worker_panics_total",
            "adip_pool_queue_seconds_total",
            "adip_pool_queue_seconds_mean",
            "adip_metrics_lock_waits_total",
            "adip_latency_shards",
            "adip_latency_shards_occupied",
            "adip_weight_cache_lock_waits_total",
            "adip_weight_cache_shards",
            "adip_weight_cache_shards_occupied",
        ] {
            assert!(text.contains(key), "{key} missing from:\n{text}");
        }
    }

    #[test]
    fn nearest_rank_percentile_boundaries() {
        // nearest-rank: rank ⌈p/100·len⌉, clamped; p=0 → first element
        for (vals, p, want) in [
            (&[1.0f32][..], 0.0, 1.0),
            (&[1.0][..], 50.0, 1.0),
            (&[1.0][..], 100.0, 1.0),
            (&[1.0, 2.0][..], 0.0, 1.0),
            (&[1.0, 2.0][..], 50.0, 1.0),
            (&[1.0, 2.0][..], 100.0, 2.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 0.0, 1.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 50.0, 2.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 75.0, 3.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 76.0, 4.0),
            (&[1.0, 2.0, 3.0, 4.0][..], 100.0, 4.0),
        ] {
            assert_eq!(
                percentile_of_sorted(vals, p),
                want,
                "len {} p{p}",
                vals.len()
            );
        }
    }

    #[test]
    fn sharded_and_legacy_reservoirs_agree_on_percentiles() {
        let sharded = Metrics::default();
        let legacy = Metrics::legacy();
        assert!(!sharded.is_legacy_reservoir());
        assert!(legacy.is_legacy_reservoir());
        for i in 1..=100 {
            for m in [&sharded, &legacy] {
                m.record_latency(i as f64 / 100.0, (101 - i) as f64 / 100.0, Priority::Batch);
            }
        }
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(sharded.queue_percentile(p), legacy.queue_percentile(p), "p{p}");
            assert_eq!(sharded.service_percentile(p), legacy.service_percentile(p), "p{p}");
        }
        assert_eq!(
            sharded.class_queue_percentile(Priority::Batch, 50.0),
            legacy.class_queue_percentile(Priority::Batch, 50.0)
        );
        assert_eq!(sharded.class_queue_summary(), legacy.class_queue_summary());
        // the lock-free store reports its shards; legacy reports none
        assert!(sharded.render().contains("adip_latency_shards 16"));
        assert!(sharded.render().contains("adip_latency_shards_occupied 1"));
        assert!(legacy.render().contains("adip_latency_shards 0"));
        assert_eq!(legacy.metrics_lock_waits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn concurrent_scrape_never_panics_or_drops_samples() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        // 4 writers × 256 samples ≤ one shard ring's capacity, so every
        // sample is retained even if thread→shard assignment collides
        const WRITERS: usize = 4;
        const PER_WRITER: usize = 256;
        for m in [Metrics::default(), Metrics::legacy()] {
            let stop = Arc::new(AtomicBool::new(false));
            std::thread::scope(|scope| {
                let writers: Vec<_> = (0..WRITERS)
                    .map(|w| {
                        let m = &m;
                        scope.spawn(move || {
                            for i in 0..PER_WRITER {
                                let v = (w * PER_WRITER + i) as f64 * 1e-6;
                                m.record_latency(v, v, Priority::Interactive);
                            }
                        })
                    })
                    .collect();
                let scraper = {
                    let (m, stop) = (&m, &stop);
                    scope.spawn(move || {
                        let mut scrapes = 0u64;
                        while !stop.load(Ordering::Relaxed) || scrapes == 0 {
                            // scrapes racing saturated recording must
                            // never panic or observe a torn sample
                            let _ = m.queue_percentile(99.0);
                            let _ = m.class_queue_summary();
                            let _ = m.render();
                            scrapes += 1;
                        }
                        scrapes
                    })
                };
                for h in writers {
                    h.join().unwrap();
                }
                stop.store(true, Ordering::Relaxed);
                assert!(scraper.join().unwrap() >= 1);
            });
            // quiesced: nothing was dropped by either store
            let total = (WRITERS * PER_WRITER) as u64;
            assert_eq!(
                m.class_completed[Priority::Interactive.index()].load(Ordering::Relaxed),
                total
            );
            assert_eq!(m.sample_snapshot().len() as u64, total, "retained samples");
            let p100 = m.queue_percentile(100.0).unwrap();
            assert!((p100 - (total - 1) as f64 * 1e-6).abs() < 1e-9, "{p100}");
        }
    }

    #[test]
    fn balance_series_render_with_per_worker_labels() {
        let m = Metrics::default();
        m.shed.fetch_add(2, Ordering::Relaxed);
        m.deadline_demotions.fetch_add(1, Ordering::Relaxed);
        m.steals.fetch_add(7, Ordering::Relaxed);
        m.steal_failures.fetch_add(3, Ordering::Relaxed);
        m.coalesced_passes.fetch_add(4, Ordering::Relaxed);
        m.coalesced_members.fetch_add(9, Ordering::Relaxed);
        m.injector_depth.store(5, Ordering::Relaxed);
        m.balance_workers.store(2, Ordering::Relaxed);
        m.worker_deque_depth[0].store(11, Ordering::Relaxed);
        m.worker_deque_depth[1].store(13, Ordering::Relaxed);
        let text = m.render();
        assert!(text.contains("adip_shed_total 2"), "{text}");
        assert!(text.contains("adip_deadline_demotions_total 1"));
        assert!(text.contains("adip_steals_total 7"));
        assert!(text.contains("adip_steal_failures_total 3"));
        assert!(text.contains("adip_coalesced_passes_total 4"));
        assert!(text.contains("adip_coalesced_members_total 9"));
        assert!(text.contains("adip_injector_depth 5"));
        assert!(text.contains("adip_worker_deque_depth{worker=\"0\"} 11"));
        assert!(text.contains("adip_worker_deque_depth{worker=\"1\"} 13"));
        // gauges only render for registered workers
        assert!(!text.contains("adip_worker_deque_depth{worker=\"2\"}"));
        // with no coordinator running, no per-worker series at all
        let idle = Metrics::default().render();
        assert!(!idle.contains("adip_worker_deque_depth{"));
        assert!(idle.contains("adip_steals_total 0"));
    }

    #[test]
    fn cache_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.record_cache(3, 1, 2, 1);
        m.record_cache(1, 0, 0, 0);
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(m.cache_shared_hits.load(Ordering::Relaxed), 1);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(m.cache_evictions.load(Ordering::Relaxed), 1);
        assert!(m.render().contains("adip_weight_cache_hits_total 4"));
        assert!(m.render().contains("adip_weight_cache_shared_hits_total 1"));
    }

    #[test]
    fn pool_counters_accumulate_and_render() {
        let m = Metrics::default();
        m.pool_workers.store(8, Ordering::Relaxed);
        m.record_pool(4, 0.25, 0);
        m.record_pool(2, 0.15, 1);
        assert_eq!(m.pool_shards_dispatched.load(Ordering::Relaxed), 6);
        assert_eq!(m.pool_worker_panics.load(Ordering::Relaxed), 1);
        assert!((m.pool_queue_seconds_total() - 0.4).abs() < 1e-12);
        assert!((m.mean_pool_queue_seconds().unwrap() - 0.4 / 6.0).abs() < 1e-12);
        let text = m.render();
        assert!(text.contains("adip_pool_workers 8"));
        assert!(text.contains("adip_pool_shards_dispatched_total 6"));
        assert!(text.contains("adip_pool_worker_panics_total 1"));
    }

    #[test]
    fn atomic_f64_concurrent_adds() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.record_completion(1, 0.001, 0, 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!((m.energy_j() - 4.0).abs() < 1e-9);
        assert_eq!(m.completed.load(Ordering::Relaxed), 4000);
    }
}
