//! The coordinator server: a three-stage **admit → prepare → execute**
//! pipeline on std threads/channels (the offline crate snapshot has no
//! async runtime; on small hosts the thread pipeline is the right tool
//! anyway).
//!
//! ```text
//!            ADMIT                    PREPARE                  EXECUTE
//! Client::submit(opts)          adip-prepare-0             adip-worker-0
//!   validate + classify    ┌──▶ [raw batches] ─▶ mode/fps ─▶ [prepared] ─▶ cluster
//!        │                 │                                   queue        exec
//!        ▼                 │
//!  [bounded ingress] ─▶ router: window → priority/deadline/aging order
//!        │ (reject when     │   → form_batches → round-robin dispatch
//!        │  full =          └──▶ adip-prepare-1 ─▶ … ─▶ adip-worker-1
//!        ▼  backpressure)
//!     Metrics ◀─────────── outcomes via per-request channels (Tickets)
//! ```
//!
//! * **Admit** — [`super::client::Client::submit`] validates (shapes *and*
//!   operand ranges), assigns the id, stamps the scheduling lane
//!   (priority class, soft deadline, group tag) and enqueues; a full
//!   queue rejects (backpressure).
//! * **Prepare** — one stage thread per worker turns formed batches into
//!   [`PreparedBatch`]es (mode selection, weight/activation
//!   fingerprinting) queued ahead of execution, so preparing batch `i+1`
//!   overlaps executing batch `i`. `PrepareMode::Inline` runs the same
//!   code on the worker thread instead — the serial baseline for the
//!   `bench_coordinator` overlap gate. The `prepared_depth` gauge counts
//!   batches sitting ready ahead of workers.
//! * **Execute** — each worker owns a [`ClusterScheduler`] (by default a
//!   persistent pool of per-core threads, see `cluster/mod.rs`) and,
//!   unless `shared_weight_cache` is disabled, all workers share one
//!   coordinator-wide [`SharedWeightCache`] store
//!   (`adip_weight_cache_shared_hits_total`). Workers pull from the
//!   coordinator-wide **balance fabric** ([`crate::balance`]) instead of
//!   private channels: the router/prepare stages push each batch to its
//!   round-robin owner's deque, and — per [`StealPolicy`] — an idle
//!   worker pops the global injector or steals from the deepest sibling,
//!   while compatible same-weight batches from different requests may be
//!   coalesced into one stacked shared-input pass ([`CoalesceConfig`]).
//!   With the default `StealPolicy::Off` and coalescing disabled the
//!   fabric reproduces the legacy static dispatch exactly.
//!
//! Batch formation is priority-aware ([`plan_batches`]): Interactive
//! ahead of Batch ahead of Background, deadline-ascending within a class,
//! FIFO tiebreak, with aging promotion so Background work is never
//! starved. The formation order is stamped into every outcome as
//! `ResponseMetrics::batch_seq`, making the deterministic service order
//! observable.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::analytical::cluster::estimate_cluster;
use crate::analytical::gemm::{GemmShape, MemoryPolicy};
use crate::arch::{ArchConfig, Architecture, Backend};
use crate::balance::injector::Fabric;
use crate::balance::split_back::split_back;
use crate::balance::{CoalesceConfig, StealPolicy};
use crate::cluster::{
    fingerprint, ClusterConfig, ClusterScheduler, PoolMode, PreparedFingerprints,
    SharedWeightCache,
};
use crate::dataflow::Mat;
use crate::obs::{lane_worker, SpanKind, TraceMode, LANE_ROUTER};
use crate::telemetry::{TelemetryConfig, TelemetryServer};

use super::batcher::{plan_batches, shed_verdict, Lane, ShedVerdict};
use super::client::{CancelRegistry, Client, Gate, Priority, SubmitOptions, Ticket};
use super::metrics::Metrics;
use super::prepare::{
    honor_cancel, prepare_batch, prepare_loop, strip_cancelled_envelopes, BatchWork,
    PreparedBatch, WorkMsg, CANCEL_AT_ROUTER, CANCEL_AT_WORKER,
};
use super::request::{Envelope, MatmulRequest, RequestError, RequestId, RequestOutcome};
use super::scheduler::{attribute_members, MemberResult};
use super::select_mode;

/// Where batch preparation runs (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrepareMode {
    /// A dedicated prepare thread per worker overlaps preparation with
    /// execution (the default). When the weight cache is disabled there
    /// is no host-side prepare work to overlap, so this collapses to
    /// direct dispatch — no stage threads, no extra queue hop.
    #[default]
    Pipelined,
    /// Preparation runs serially on the worker thread right before
    /// execution — the baseline the overlap is benchmarked against.
    Inline,
}

impl std::fmt::Display for PrepareMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PrepareMode::Pipelined => "pipelined",
            PrepareMode::Inline => "inline",
        })
    }
}

impl std::str::FromStr for PrepareMode {
    type Err = String;

    fn from_str(s: &str) -> Result<PrepareMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "pipelined" | "pipeline" => Ok(PrepareMode::Pipelined),
            "inline" => Ok(PrepareMode::Inline),
            other => Err(format!("unknown prepare mode {other:?} (pipelined|inline)")),
        }
    }
}

/// Coordinator configuration.
///
/// The defaults are the serving defaults everywhere in the crate:
/// `Backend::Functional` execution, a degenerate single-core cluster per
/// worker, and the pipelined prepare stage — which is accounting-neutral
/// (prepared fingerprints are a pure function of the operands), so
/// existing callers that spread `..Default::default()` keep byte-identical
/// outputs and simulated accounting.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    /// Architecture each core simulates.
    pub arch: Architecture,
    /// Array size per core.
    pub n: usize,
    /// Worker threads (each owns one simulated cluster of cores).
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Max requests gathered into one batching window.
    pub batch_window: usize,
    /// Execution backend of every worker core. `Backend::Functional`
    /// (default) serves from the fast whole-GEMM path; pin
    /// `Backend::CycleAccurate` for calibration/validation runs where the
    /// register-level golden path must execute every request.
    pub backend: Backend,
    /// Per-worker cluster execution: shard count, split axis, weight
    /// cache and pool mode (default: 1 core, M split, cache off,
    /// persistent pool).
    pub cluster: ClusterConfig,
    /// Share one weight-cache store across every worker (default), so
    /// siblings reuse each other's projection tiles (`shared_hits`); off =
    /// one private store per worker. Irrelevant while the cache capacity
    /// is 0, and can never change outputs either way (hits are bit-exact
    /// by key construction).
    pub shared_weight_cache: bool,
    /// Where batch preparation runs (default: pipelined stage threads).
    pub prepare: PrepareMode,
    /// Capacity of each worker's prepared-batch queue (how far the
    /// prepare stage may run ahead of execution).
    pub prepared_capacity: usize,
    /// Aging interval of the batcher's no-starvation rule: every full
    /// interval a request has waited promotes it one priority class,
    /// where it competes on the class's normal deadline→FIFO terms.
    /// `Duration::ZERO` disables aging. Trade-off: once queue waits
    /// exceed the interval under sustained overload, promoted work
    /// reaches the Interactive rank and service degrades toward FIFO —
    /// deliberate (overload fairness beats starvation), but it means the
    /// interval should sit well above the burst waits you still want
    /// strictly class-ordered.
    pub aging: Duration,
    /// Work-stealing across workers' deques on the balance fabric
    /// (default [`StealPolicy::Off`] — the static legacy dispatch; see
    /// `balance/mod.rs`). Stealing can never change outputs, and with the
    /// weight cache disabled cannot change per-ticket accounting either.
    pub steal: StealPolicy,
    /// Cross-request shard coalescing: merge queued batches with
    /// byte-identical weight sets (same precision mode and `K`/`N` shape)
    /// into one asymmetric shared-input pass, attributing accounting back
    /// by row share (default off; see `balance/coalescer.rs`).
    pub coalesce: CoalesceConfig,
    /// Deadline shedding: at batch-formation time, fail-fast Background
    /// requests whose soft deadline is already hopeless (per the
    /// closed-form `estimate_cluster` service bound) with a distinct
    /// `shed:` error, and demote hopeless Interactive/Batch requests to
    /// Background. Default off — a soft deadline is then purely an
    /// ordering hint, as before.
    pub shed: bool,
    /// Per-ticket lifecycle tracing (see [`crate::obs`]). Off by default;
    /// `TraceMode::Sample(n)` traces every n-th ticket. Tracing can never
    /// change outputs or simulated accounting — recorders only read
    /// clocks and write their own rings (`integration_pipeline.rs`
    /// asserts off ≡ on ≡ sampled bit-exactly).
    pub trace: TraceMode,
    /// Live telemetry tier (see [`crate::telemetry`]): HTTP scrape
    /// endpoint + background sampler + watchdog. Off by default
    /// (`listen: None` spawns nothing). Telemetry is strictly read-only
    /// over [`Metrics`], so enabling it can never change outputs or
    /// per-ticket accounting — `integration_telemetry.rs` asserts
    /// off ≡ on bit-exactly across both backends.
    pub telemetry: TelemetryConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            arch: Architecture::Adip,
            n: 32,
            workers: 2,
            queue_capacity: 256,
            batch_window: 16,
            backend: Backend::Functional,
            cluster: ClusterConfig::default(),
            shared_weight_cache: true,
            prepare: PrepareMode::default(),
            prepared_capacity: 4,
            aging: Duration::from_millis(100),
            steal: StealPolicy::Off,
            coalesce: CoalesceConfig::default(),
            shed: false,
            trace: TraceMode::Off,
            telemetry: TelemetryConfig::default(),
        }
    }
}

/// Router-side handle to one worker's pipeline: either through its
/// prepare stage (pipelined) or straight onto the balance fabric
/// (inline/direct, tagged with the owning worker).
enum StageTx {
    Prepare(SyncSender<BatchWork>),
    Direct(usize),
}

/// The running coordinator.
pub struct Coordinator {
    gate: Arc<Gate>,
    client: Client,
    fabric: Arc<Fabric>,
    router: Option<JoinHandle<()>>,
    preparers: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    telemetry: Option<TelemetryServer>,
}

impl Coordinator {
    /// Start the router + prepare-stage + worker threads.
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        assert!(
            cfg.workers > 0
                && cfg.queue_capacity > 0
                && cfg.batch_window > 0
                && cfg.prepared_capacity > 0
        );
        let metrics = Arc::new(Metrics::default());
        if cfg.trace != TraceMode::Off {
            metrics.trace.enable(cfg.trace);
        }
        // One cancellation registry per coordinator: `Ticket::cancel`
        // registers ids, every pipeline boundary (router window, prepare
        // stage, worker pop) honors them (see `prepare::honor_cancel`).
        let cancels = Arc::new(CancelRegistry::default());
        let (ingress_tx, ingress_rx) = sync_channel::<Envelope>(cfg.queue_capacity);
        // Single-core clusters execute inline (no pool threads), so the
        // gauge only counts real persistent workers.
        if cfg.cluster.pool == PoolMode::Persistent && cfg.cluster.effective_cores() > 1 {
            metrics
                .pool_workers
                .store((cfg.workers * cfg.cluster.effective_cores()) as u64, Ordering::Relaxed); // relaxed-ok: capacity gauge, set once at startup
        }
        // One weight-cache store per coordinator (the promoted cross-worker
        // design): sibling workers reuse each other's projection tiles.
        // `shared_weight_cache: false` falls back to a private store per
        // worker.
        let shared_cache =
            cfg.shared_weight_cache.then(|| SharedWeightCache::new(cfg.cluster.cache));

        // The balance fabric replaces the per-worker work channels: one
        // global injector + per-worker deques, bounded at the same total
        // the channel bounds used to give (workers × prepared_capacity),
        // so the backpressure chain toward the router is unchanged.
        let fabric = Fabric::new(
            cfg.workers,
            cfg.workers * cfg.prepared_capacity,
            cfg.steal,
            cfg.coalesce,
            metrics.clone(),
        );
        // full worker count: `render` gauges every worker individually
        // (gauge storage is dynamically sized by `Fabric::new`;
        // `adip_worker_deque_gauges_truncated` stays at 0 for dashboard
        // compatibility)
        metrics.balance_workers.store(cfg.workers as u64, Ordering::Relaxed); // relaxed-ok: worker-count gauge, set once at startup

        let mut stage_txs = Vec::new();
        let mut preparers = Vec::new();
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let m = metrics.clone();
            let cache = shared_cache
                .clone()
                .unwrap_or_else(|| SharedWeightCache::new(cfg.cluster.cache));
            let f = fabric.clone();
            let c = cancels.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("adip-worker-{w}"))
                    .spawn(move || worker_loop(w, f, cfg, m, cache, c))
                    .expect("spawn worker"),
            );
            match cfg.prepare {
                // With the weight cache disabled there are no
                // fingerprints to compute — the stage would be a pure
                // channel hop plus an idle thread per worker — so the
                // pipeline collapses to direct dispatch (same rationale
                // as the 1-core cluster executing inline, PR 3).
                PrepareMode::Pipelined if cfg.cluster.cache.enabled() => {
                    let (prep_tx, prep_rx) = sync_channel::<BatchWork>(cfg.prepared_capacity);
                    let m = metrics.clone();
                    let f = fabric.clone();
                    let c = cancels.clone();
                    preparers.push(
                        std::thread::Builder::new()
                            .name(format!("adip-prepare-{w}"))
                            .spawn(move || prepare_loop(prep_rx, f, w, true, m, c))
                            .expect("spawn prepare stage"),
                    );
                    stage_txs.push(StageTx::Prepare(prep_tx));
                }
                PrepareMode::Pipelined | PrepareMode::Inline => {
                    stage_txs.push(StageTx::Direct(w))
                }
            }
        }

        let m = metrics.clone();
        let f = fabric.clone();
        let c = cancels.clone();
        let router = std::thread::Builder::new()
            .name("adip-router".into())
            .spawn(move || router_loop(ingress_rx, stage_txs, f, cfg, m, c))
            .expect("spawn router");

        // The telemetry tier is pure observation: it shares the metrics
        // hub and spawns its own sampler + listener threads, but no
        // pipeline stage ever consults it — off ≡ on bit-exactly.
        let telemetry = cfg.telemetry.listen.map(|addr| {
            TelemetryServer::start(
                addr,
                cfg.telemetry.sample_interval,
                metrics.clone(),
                telemetry_policies(&cfg),
            )
            .expect("start telemetry tier")
        });

        let gate = Arc::new(Gate::new(metrics, ingress_tx, cancels));
        let client = Client::new(gate.clone());
        Coordinator { gate, client, fabric, router: Some(router), preparers, workers, telemetry }
    }

    /// A cheap, cloneable submission handle. Handles stay valid across
    /// the coordinator's lifetime; after [`Coordinator::shutdown`] they
    /// fail submissions with "coordinator stopped".
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Legacy entry point — thin shim over [`Client::submit`] with
    /// default [`SubmitOptions`] (class `Batch`, no deadline, no group):
    /// byte-identical behavior to the pre-`Client` API. On success the
    /// request id and a receiver for the outcome are returned; a full
    /// queue rejects the request (backpressure).
    ///
    /// Deprecated since PR 8: use `coord.client().submit(SubmitOptions::new(req))`
    /// — a [`Ticket`] carries the same id/receiver pair (`Ticket::into_parts`)
    /// plus cancellation. `rust/tests/integration_pipeline.rs` pins the
    /// shim behavior-identical to the typed path until removal.
    #[deprecated(note = "use Coordinator::client() + Client::submit(SubmitOptions::new(req))")]
    pub fn try_submit(
        &self,
        req: MatmulRequest,
    ) -> Result<(RequestId, Receiver<RequestOutcome>)> {
        self.client.submit(SubmitOptions::new(req)).map(Ticket::into_parts)
    }

    /// Legacy entry point — submit and block for the outcome. Shim over
    /// [`Client::submit_wait`], so the two paths cannot diverge.
    ///
    /// Deprecated since PR 8: use
    /// `coord.client().submit_wait(SubmitOptions::new(req))`.
    #[deprecated(note = "use Coordinator::client() + Client::submit_wait(SubmitOptions::new(req))")]
    pub fn submit_wait(&self, req: MatmulRequest) -> Result<RequestOutcome> {
        self.client.submit_wait(SubmitOptions::new(req))
    }

    /// Shared metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.gate.metrics.clone()
    }

    /// Bound telemetry scrape address, when the tier is enabled
    /// (resolves `--telemetry=HOST:0` ephemeral binds).
    pub fn telemetry_addr(&self) -> Option<std::net::SocketAddr> {
        self.telemetry.as_ref().map(TelemetryServer::local_addr)
    }

    /// The running telemetry tier, when enabled (tests reach through
    /// this for sampler/watchdog state).
    pub fn telemetry(&self) -> Option<&TelemetryServer> {
        self.telemetry.as_ref()
    }

    /// Mark the stack as (not) draining: `/healthz` flips to 503 so
    /// load balancers stop routing here before the actual shutdown.
    /// No-op with telemetry off.
    pub fn set_draining(&self, draining: bool) {
        if let Some(t) = &self.telemetry {
            t.set_draining(draining);
        }
    }

    /// Stop accepting requests, drain in-flight work through all three
    /// stages (router → prepare → fabric → workers), join every thread.
    /// The fabric is closed only after every producer has been joined, so
    /// workers drain every queued batch — nothing admitted is dropped.
    pub fn shutdown(mut self) {
        // health goes unready first, so a scraper polling through the
        // drain sees 503 before the listener disappears
        self.set_draining(true);
        self.gate.close();
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for p in self.preparers.drain(..) {
            let _ = p.join();
        }
        self.fabric.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // telemetry goes last: the final scrape can still observe the
        // fully drained counters
        if let Some(mut t) = self.telemetry.take() {
            t.shutdown();
        }
    }
}

/// The policy table rendered in `/statusz`: every knob of the serving
/// configuration that an operator would want to confirm from a live
/// process, as display strings.
fn telemetry_policies(cfg: &CoordinatorConfig) -> Vec<(String, String)> {
    vec![
        ("arch".into(), cfg.arch.name().into()),
        ("array_n".into(), cfg.n.to_string()),
        ("workers".into(), cfg.workers.to_string()),
        ("queue_capacity".into(), cfg.queue_capacity.to_string()),
        ("batch_window".into(), cfg.batch_window.to_string()),
        ("backend".into(), cfg.backend.name().into()),
        ("prepare".into(), cfg.prepare.to_string()),
        ("prepared_capacity".into(), cfg.prepared_capacity.to_string()),
        ("aging_ms".into(), cfg.aging.as_millis().to_string()),
        ("steal".into(), cfg.steal.name().into()),
        ("coalesce".into(), if cfg.coalesce.active() { "on" } else { "off" }.into()),
        ("shed".into(), if cfg.shed { "on" } else { "off" }.into()),
        ("shared_weight_cache".into(), if cfg.shared_weight_cache { "on" } else { "off" }.into()),
        ("trace".into(), format!("{:?}", cfg.trace)),
    ]
}

fn router_loop(
    ingress: Receiver<Envelope>,
    stage_txs: Vec<StageTx>,
    fabric: Arc<Fabric>,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    cancels: Arc<CancelRegistry>,
) {
    let mut next_stage = 0usize;
    // starts at 1: batch_seq 0 is the "never routed" sentinel that
    // direct (coordinator-less) scheduler use reports
    let mut batch_seq = 1u64;
    let aging_us = cfg.aging.as_micros() as u64;
    loop {
        // blocking pull of the first request, then drain a window
        let first = match ingress.recv() {
            Ok(e) => e,
            Err(_) => break, // ingress closed: drain done
        };
        let mut window = vec![first];
        while window.len() < cfg.batch_window {
            match ingress.try_recv() {
                Ok(e) => window.push(e),
                Err(_) => break,
            }
        }
        metrics.queue_depth.fetch_sub(window.len() as u64, Ordering::Relaxed); // relaxed-ok: depth gauge

        // Cancellation boundary: requests cancelled while waiting in the
        // ingress queue fail here, before a lane or plan is built around
        // them.
        if cancels.pending() > 0 {
            window.retain(|env| {
                if cancels.is_cancelled(env.req.id) {
                    honor_cancel(env, &metrics, &cancels, LANE_ROUTER, CANCEL_AT_ROUTER);
                    return false;
                }
                true
            });
            if window.is_empty() {
                continue;
            }
        }

        // scheduling lanes are snapshotted once per window so the plan is
        // a pure (deterministic) function of its inputs
        let now = Instant::now();
        let mut lanes: Vec<Lane> = window
            .iter()
            .map(|e| Lane {
                priority: e.priority,
                deadline_us: e.deadline.map_or(i64::MAX, |d| {
                    // clamped casts: a far-future sentinel deadline must
                    // saturate to "no deadline", not wrap negative into
                    // maximum urgency
                    let ahead = i64::try_from(d.saturating_duration_since(now).as_micros())
                        .unwrap_or(i64::MAX);
                    if ahead > 0 {
                        ahead
                    } else {
                        i64::try_from(now.saturating_duration_since(d).as_micros())
                            .map_or(i64::MIN, |o| -o)
                    }
                }),
                age_us: u64::try_from(
                    now.saturating_duration_since(e.enqueued).as_micros(),
                )
                .unwrap_or(u64::MAX),
            })
            .collect();

        // Deadline shedding (opt-in): a request whose soft deadline is
        // already hopeless against the closed-form service bound either
        // fails fast here (Background → distinct `shed:` error, no pass
        // burned) or forfeits its latency claim (Interactive/Batch →
        // demoted to Background for this window's plan). The estimate is
        // a lower bound on service, so shedding is conservative.
        if cfg.shed {
            let acfg = ArchConfig::with_n(cfg.n);
            let (mut kept_w, mut kept_l) =
                (Vec::with_capacity(window.len()), Vec::with_capacity(lanes.len()));
            for (mut env, mut lane) in window.into_iter().zip(lanes) {
                if lane.deadline_us != i64::MAX {
                    let r = &env.req;
                    let mode = select_mode(r.weight_bits, r.act_act);
                    let est = estimate_cluster(
                        cfg.arch,
                        &acfg,
                        GemmShape::new(r.a.rows(), r.a.cols(), r.bs[0].cols()),
                        r.bs.len(),
                        mode,
                        &cfg.cluster,
                        MemoryPolicy::default(),
                    );
                    match shed_verdict(lane.priority, lane.deadline_us, est.cycles) {
                        ShedVerdict::Keep => {}
                        ShedVerdict::Demote => {
                            // re-class end-to-end: the lane (so this
                            // window's plan orders it as Background), the
                            // lane's age (so the batcher's aging rule
                            // cannot promote it right back within the
                            // same plan), and the envelope (so per-class
                            // latency metrics attribute its deliberately
                            // long wait to Background, not to the class
                            // whose SLO it forfeited)
                            lane.priority = Priority::Background;
                            lane.age_us = 0;
                            env.priority = Priority::Background;
                            metrics.deadline_demotions.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                            metrics.trace.event(SpanKind::Demote, env.req.id, LANE_ROUTER, 0);
                        }
                        ShedVerdict::Shed => {
                            metrics.shed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                            metrics.failed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                            metrics.trace.event(SpanKind::Shed, env.req.id, LANE_ROUTER, 0);
                            let _ = env.reply.send(RequestOutcome {
                                id: env.req.id,
                                result: Err(RequestError::Shed {
                                    detail: format!(
                                        "soft deadline hopeless at batch formation \
                                         (needs ~{} µs simulated service, {} µs \
                                         headroom)",
                                        est.cycles / 1_000,
                                        lane.deadline_us
                                    ),
                                }),
                                metrics: Default::default(),
                            });
                            cancels.resolve(env.req.id);
                            continue;
                        }
                    }
                }
                kept_w.push(env);
                kept_l.push(lane);
            }
            window = kept_w;
            lanes = kept_l;
            if window.is_empty() {
                continue;
            }
        }

        let reqs: Vec<MatmulRequest> = window.iter().map(|e| e.req.clone()).collect();
        let plan = plan_batches(&reqs, &lanes, aging_us);
        if plan.promotions > 0 {
            metrics.aging_promotions.fetch_add(plan.promotions, Ordering::Relaxed); // relaxed-ok: stat counter
            for &idx in &plan.promoted {
                metrics.trace.event(SpanKind::Promote, reqs[idx].id, LANE_ROUTER, 0);
            }
        }

        // move envelopes into their batches (indices are into `window`)
        let mut slots: Vec<Option<Envelope>> = window.into_iter().map(Some).collect();
        for b in plan.batches {
            let envelopes: Vec<Envelope> =
                b.members.iter().map(|&i| slots[i].take().expect("batch partition")).collect();
            metrics.batches.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            if envelopes.len() > 1 || envelopes[0].req.bs.len() > 1 {
                metrics.fused_batches.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            }
            for env in &envelopes {
                // queue span: admission → batch formation; the formation
                // event carries the deterministic service order
                metrics.trace.span_since(SpanKind::Queue, env.req.id, LANE_ROUTER, env.enqueued, 0);
                metrics.trace.event(SpanKind::BatchForm, env.req.id, LANE_ROUTER, batch_seq);
            }
            let work = BatchWork {
                envelopes,
                mode: b.mode,
                runtime_interleave: b.runtime_interleave,
                batch_seq,
                weight_fps: None,
                queued: None,
            };
            batch_seq += 1;
            // round-robin ownership; a blocking send/push applies
            // backpressure to the router (ingress queue keeps absorbing
            // bursts). The owner is only an affinity under stealing
            // policies — an idle sibling may take the batch later.
            let delivered = match &stage_txs[next_stage % stage_txs.len()] {
                StageTx::Prepare(tx) => tx.send(work).is_ok(),
                StageTx::Direct(owner) => {
                    fabric.push(*owner, WorkMsg::Raw(work));
                    true
                }
            };
            if !delivered {
                return; // pipeline gone
            }
            next_stage += 1;
        }
    }
}

fn worker_loop(
    w: usize,
    fabric: Arc<Fabric>,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    cache: SharedWeightCache,
    cancels: Arc<CancelRegistry>,
) {
    /// On any exit — normal drain or panic — report the worker down so
    /// its queued batches re-home to the injector and producers redirect
    /// there (a dead worker must degrade service, never wedge a blocked
    /// `Fabric::push` and with it the router and shutdown). A *panicked*
    /// exit additionally bumps `worker_panics`, which latches `/healthz`
    /// unready — a coordinator that lost a worker is degraded for good.
    struct DownGuard(Arc<Fabric>, usize, Arc<Metrics>);
    impl Drop for DownGuard {
        fn drop(&mut self) {
            if std::thread::panicking() {
                self.2.worker_panics.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone health counter
            }
            self.0.worker_down(self.1);
        }
    }
    let _down = DownGuard(fabric.clone(), w, metrics.clone());
    // keep a handle to the store for the contention/occupancy gauges
    // (with private per-worker stores the gauges show the last flusher's
    // store — the shared default is the configuration they exist for)
    let cache_handle = cache.clone();
    let mut core =
        ClusterScheduler::with_shared_cache(cfg.arch, cfg.n, cfg.backend, cfg.cluster, cache);
    core.set_trace(metrics.trace.clone(), lane_worker(w));
    let cache_enabled = cfg.cluster.cache.enabled();
    if cache_enabled {
        metrics.cache_shards.store(cache_handle.shard_count() as u64, Ordering::Relaxed); // relaxed-ok: shard-count gauge, set once
    }
    let mut cache_seen = core.cache_stats();
    let mut pool_seen = core.pool_stats();
    while let Some(group) = fabric.pop(w) {
        let popped = Instant::now();
        let mut prepared: Vec<PreparedBatch> = group
            .into_iter()
            .map(|msg| match msg {
                WorkMsg::Prepared(p) => {
                    metrics.prepared_depth.fetch_sub(1, Ordering::Relaxed); // relaxed-ok: depth gauge
                    p
                }
                // inline mode: the prepare work runs here, serialized with
                // execution — the baseline the pipelined stage is gated
                // against
                WorkMsg::Raw(work) => prepare_batch(work, w, cache_enabled, &metrics),
            })
            .collect();
        // Cancellation boundary: the last check before the array, covering
        // fabric residency, steals, and coalesce gathering — a cancelled
        // member never executes. A partially stripped batch has a changed
        // weight set, so it may no longer share a coalesced pass with
        // partners gathered under the old key: it runs solo instead.
        let mut stripped_solo: Vec<PreparedBatch> = Vec::new();
        if cancels.pending() > 0 {
            let full = std::mem::take(&mut prepared);
            let group_size = full.len();
            for mut item in full {
                let changed = strip_cancelled_envelopes(
                    &mut item.envelopes,
                    item.fps.as_mut().map(|f| &mut f.weights),
                    &metrics,
                    &cancels,
                    lane_worker(w),
                    CANCEL_AT_WORKER,
                );
                if item.envelopes.is_empty() {
                    continue; // every member cancelled — batch dissolved
                }
                if changed && group_size > 1 {
                    stripped_solo.push(item);
                } else {
                    prepared.push(item);
                }
            }
            if prepared.is_empty() && stripped_solo.is_empty() {
                continue;
            }
        }
        let started = Instant::now();
        let coalesced = prepared.len() > 1;
        if coalesced {
            // attribute the merge: the group leader carries the member
            // count, every other member points back at the leader
            let leader = prepared[0].envelopes[0].req.id;
            metrics.trace.event(SpanKind::Coalesce, leader, lane_worker(w), prepared.len() as u64);
            for item in &prepared[1..] {
                for env in &item.envelopes {
                    metrics.trace.event(
                        SpanKind::CoalesceMember,
                        env.req.id,
                        lane_worker(w),
                        leader,
                    );
                }
            }
        }
        // Execute: a solo batch runs the existing prepared path; a
        // coalesced group runs as one stacked shared-weight pass and is
        // split back per member (see balance/{coalescer,split_back}.rs).
        // The bool tags whether the item ran inside a merged pass (feeds
        // `ResponseMetrics::batched` — stripped stragglers ran solo).
        let mut executed: Vec<(BatchOutcome, bool)> = Vec::new();
        if coalesced {
            executed.extend(
                execute_coalesced(&mut core, w, prepared, &metrics)
                    .into_iter()
                    .map(|o| (o, true)),
            );
        } else if let Some(item) = prepared.pop() {
            executed.push((execute_solo(&mut core, item), false));
        }
        for item in stripped_solo {
            executed.push((execute_solo(&mut core, item), false));
        }
        let exec_elapsed = started.elapsed();
        // flush cache + pool activity regardless of batch outcome (a
        // failed batch may still have probed or populated the cache, or
        // dispatched shards before erroring)
        let cache_now = core.cache_stats();
        let d = cache_now.delta_since(&cache_seen);
        cache_seen = cache_now;
        if d.hits + d.misses + d.evictions > 0 {
            metrics.record_cache(d.hits, d.shared_hits, d.misses, d.evictions);
        }
        if cache_enabled {
            metrics
                .cache_lock_waits
                .store(cache_handle.lock_waits(), Ordering::Relaxed); // relaxed-ok: stat mirror, refreshed per batch
            metrics
                .cache_shards_occupied
                .store(cache_handle.occupied_shards() as u64, Ordering::Relaxed); // relaxed-ok: stat mirror, refreshed per batch
        }
        let pool_now = core.pool_stats();
        let pd = pool_now.delta_since(&pool_seen);
        pool_seen = pool_now;
        if pd.dispatched + pd.worker_panics > 0 {
            metrics.record_pool(pd.dispatched, pd.queue_wait_s, pd.worker_panics);
        }
        let completed: usize =
            executed.iter().map(|((_, o), _)| o.as_ref().map_or(0, Vec::len)).sum();
        let service = exec_elapsed.as_secs_f64() / completed.max(1) as f64;
        for ((item, outcome), merged) in executed {
            // fabric residency: push-stamp → this worker's pop (per item —
            // a stolen batch was stamped by its original producer)
            let fabric_seconds = item
                .queued
                .map(|q| popped.saturating_duration_since(q).as_secs_f64())
                .unwrap_or(0.0);
            match outcome {
                Ok(results) => {
                    for (env, mut res) in item.envelopes.iter().zip(results) {
                        res.metrics.queue_seconds = (started - env.enqueued).as_secs_f64();
                        res.metrics.service_seconds = service;
                        res.metrics.prepare_seconds = item.prepare_seconds;
                        res.metrics.fabric_seconds = fabric_seconds;
                        res.metrics.execute_seconds = service;
                        res.metrics.batch_seq = item.batch_seq;
                        // a coalesced member executed in a merged pass even
                        // if its own batch was a singleton
                        res.metrics.batched |= merged;
                        if let Some(q) = item.queued {
                            metrics.trace.span_at(
                                SpanKind::Fabric,
                                env.req.id,
                                lane_worker(w),
                                q,
                                popped.saturating_duration_since(q),
                                0,
                            );
                        }
                        metrics.trace.span_at(
                            SpanKind::Execute,
                            env.req.id,
                            lane_worker(w),
                            started,
                            exec_elapsed,
                            item.batch_seq,
                        );
                        metrics.record_completion(
                            res.metrics.cycles,
                            res.metrics.energy_j,
                            res.metrics.memory.paper_total_bytes(),
                            res.metrics.passes,
                        );
                        metrics.record_latency(
                            res.metrics.queue_seconds,
                            service,
                            env.priority,
                        );
                        let _ = env.reply.send(RequestOutcome {
                            id: env.req.id,
                            result: Ok(res.outputs),
                            metrics: res.metrics,
                        });
                        metrics.trace.event(SpanKind::Complete, env.req.id, lane_worker(w), 0);
                        // a cancel that raced past the pop boundary lost:
                        // the outcome stands — but its registry entry must
                        // not outlive the request
                        cancels.resolve(env.req.id);
                    }
                }
                Err(e) => {
                    for env in &item.envelopes {
                        metrics.failed.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                        let _ = env.reply.send(RequestOutcome {
                            id: env.req.id,
                            result: Err(e.clone()),
                            metrics: Default::default(),
                        });
                        cancels.resolve(env.req.id);
                    }
                }
            }
        }
    }
}

/// One executed batch: the batch plus its per-member results (or the
/// typed error every member envelope is failed with).
type BatchOutcome = (PreparedBatch, std::result::Result<Vec<MemberResult>, RequestError>);

/// Execute one batch through the prepared path, classifying any run
/// error into the typed [`RequestError`] taxonomy.
fn execute_solo(core: &mut ClusterScheduler, item: PreparedBatch) -> BatchOutcome {
    core.set_trace_ticket(item.envelopes[0].req.id);
    let members: Vec<&MatmulRequest> = item.envelopes.iter().map(|e| &e.req).collect();
    let outcome = core
        .execute_batch_prepared(&members, item.mode, item.runtime_interleave, item.fps.as_ref())
        .map_err(|e| RequestError::from_execution(e.to_string()));
    (item, outcome)
}

/// Execute a coalesced group as **one** asymmetric shared-input pass:
/// stack the member batches' activations along `M` (the coalescer
/// guarantees equal `K`/`N` shape and byte-identical weight sets), run the
/// stacked set through the cluster once, then split outputs and row-share
/// accounting back per member batch and apply the ordinary in-batch
/// attribution. A run error fails every member — tickets are never lost.
fn execute_coalesced(
    core: &mut ClusterScheduler,
    w: usize,
    items: Vec<PreparedBatch>,
    metrics: &Metrics,
) -> Vec<BatchOutcome> {
    let first = &items[0].envelopes[0].req;
    let leader = first.id;
    core.set_trace_ticket(leader);
    let k = first.a.cols();
    let mode = items[0].mode;
    let member_rows: Vec<usize> =
        items.iter().map(|i| i.envelopes[0].req.a.rows()).collect();
    let total_rows: usize = member_rows.iter().sum();
    let mut stacked = Vec::with_capacity(total_rows * k);
    for it in &items {
        stacked.extend_from_slice(it.envelopes[0].req.a.as_slice());
    }
    let a_cat = Arc::new(Mat::from_vec(total_rows, k, stacked));
    // weight sets are byte-identical across members (coalesce-key
    // invariant): execute against the first member's set, through the
    // prepared/shared path — the requests' existing `Arc<Mat>` handles
    // are reused (no weight deep-copies) and the prepare stage's weight
    // fingerprints serve the cache probe, so the only execute-path hash
    // is the stacked activation's (which exists only post-merge).
    let bs: Vec<&Arc<Mat>> =
        items[0].envelopes.iter().flat_map(|e| e.req.bs.iter()).collect();
    let fps = items[0].fps.as_ref().map(|f| PreparedFingerprints {
        act: fingerprint(&[a_cat.as_ref()]),
        weights: f.weights.clone(),
    });
    match core.run_gemm_set_prepared(&a_cat, &bs, mode, false, fps.as_ref()) {
        Ok(run) => {
            metrics.coalesced_passes.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            metrics.coalesced_members.fetch_add(items.len() as u64, Ordering::Relaxed); // relaxed-ok: stat counter
            let t_split = Instant::now();
            let parts = split_back(&run.result, &member_rows);
            metrics.trace.span_since(
                SpanKind::SplitBack,
                leader,
                lane_worker(w),
                t_split,
                items.len() as u64,
            );
            items
                .into_iter()
                .zip(parts)
                .map(|(item, part)| {
                    let members: Vec<&MatmulRequest> =
                        item.envelopes.iter().map(|e| &e.req).collect();
                    let results = attribute_members(&members, &part);
                    (item, Ok(results))
                })
                .collect()
        }
        Err(_) => {
            // No shared failure fate across clients: a failed stacked
            // pass (e.g. a transient pool-worker panic, which PR 3 made
            // recoverable) falls back to executing every member solo —
            // each ticket then succeeds or fails on its own merits.
            items.into_iter().map(|item| execute_solo(core, item)).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::client::Priority;
    use crate::coordinator::request::SHED_ERROR_PREFIX;
    use crate::dataflow::Mat;
    use crate::testutil::Rng;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            n: 8,
            workers: 2,
            queue_capacity: 64,
            batch_window: 8,
            ..Default::default()
        }
    }

    fn request(rng: &mut Rng, input_id: u64, bits: u32) -> MatmulRequest {
        MatmulRequest {
            id: 0,
            input_id,
            a: Arc::new(Mat::random(rng, 16, 16, 8)),
            bs: vec![Arc::new(Mat::random(rng, 16, 16, bits))],
            weight_bits: bits,
            act_act: false,
            tag: "t".into(),
        }
    }

    #[test]
    fn end_to_end_correct_results() {
        let coord = Coordinator::start(cfg());
        let mut rng = Rng::seeded(901);
        let req = request(&mut rng, 1, 8);
        let want = req.a.matmul(&req.bs[0]);
        let out = coord.client().submit_wait(SubmitOptions::new(req)).unwrap();
        assert_eq!(out.result.unwrap()[0], want);
        assert!(out.metrics.cycles > 0);
        coord.shutdown();
    }

    #[test]
    fn client_submit_resolves_tickets_with_ids() {
        let coord = Coordinator::start(cfg());
        let client = coord.client();
        let mut rng = Rng::seeded(902);
        let req = request(&mut rng, 1, 2);
        let want = req.a.matmul(&req.bs[0]);
        let ticket = client
            .submit(SubmitOptions::new(req).priority(Priority::Interactive))
            .unwrap();
        assert!(ticket.id() > 0);
        assert_eq!(ticket.priority(), Priority::Interactive);
        let out = ticket.wait().unwrap();
        assert_eq!(out.result.unwrap()[0], want);
        coord.shutdown();
        // handles outliving shutdown fail cleanly instead of hanging
        let err = client.submit(SubmitOptions::new(request(&mut rng, 1, 2))).unwrap_err();
        assert!(err.to_string().contains("stopped"), "{err}");
    }

    #[test]
    fn concurrent_submissions_all_complete_exactly_once() {
        let coord = Coordinator::start(cfg());
        let client = coord.client();
        let mut rng = Rng::seeded(903);
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..32u64 {
            let bits = *rng.choose(&[2, 4, 8]);
            let r = request(&mut rng, i % 4, bits);
            expected.push((r.a.clone(), r.bs[0].clone()));
            let (id, rx) = client.submit(SubmitOptions::new(r)).unwrap().into_parts();
            rxs.push((id, rx));
        }
        let mut seen = std::collections::HashSet::new();
        for (i, (id, rx)) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap();
            assert_eq!(out.id, id);
            assert!(seen.insert(id), "duplicate completion");
            let (a, b) = &expected[i];
            assert_eq!(out.result.unwrap()[0], a.matmul(b));
        }
        let m = coord.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), 32);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
        coord.shutdown();
    }

    #[test]
    fn hopeless_background_deadline_is_shed_and_interactive_demoted() {
        let coord = Coordinator::start(CoordinatorConfig { shed: true, ..cfg() });
        let client = coord.client();
        let mut rng = Rng::seeded(915);
        // big enough that the closed-form service estimate is ≥ 1 µs —
        // the shed decision must be driven by the estimate, not by the
        // sub-µs truncation corner
        let mut big = |input_id: u64| MatmulRequest {
            id: 0,
            input_id,
            a: Arc::new(Mat::random(&mut rng, 96, 96, 8)),
            bs: vec![Arc::new(Mat::random(&mut rng, 96, 96, 8))],
            weight_bits: 8,
            act_act: false,
            tag: "big".into(),
        };
        // an already-expired deadline is hopeless by definition
        let bg = client
            .submit(
                SubmitOptions::new(big(1))
                    .priority(Priority::Background)
                    .deadline(Duration::ZERO),
            )
            .unwrap();
        let out = bg.wait().unwrap();
        assert!(out.was_shed(), "background + hopeless deadline must shed: {:?}", out.result);
        let err = out.result.unwrap_err();
        assert!(matches!(err, RequestError::Shed { .. }), "{err:?}");
        // the typed variant still renders the legacy greppable prefix
        assert!(err.to_string().starts_with(SHED_ERROR_PREFIX));
        // interactive work is demoted, never shed — it still executes
        let hot = client
            .submit(
                SubmitOptions::new(big(2))
                    .priority(Priority::Interactive)
                    .deadline(Duration::ZERO),
            )
            .unwrap();
        let out = hot.wait().unwrap();
        assert!(!out.was_shed());
        assert!(out.result.is_ok(), "demoted work still completes");
        // achievable deadlines are untouched
        let easy = client
            .submit(
                SubmitOptions::new(big(3))
                    .priority(Priority::Background)
                    .deadline(Duration::from_secs(60)),
            )
            .unwrap();
        assert!(easy.wait().unwrap().result.is_ok());
        let m = coord.metrics();
        assert_eq!(m.shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_demotions.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1, "shed counts as failed too");
        coord.shutdown();
    }

    #[test]
    fn shedding_off_keeps_soft_deadlines_advisory() {
        let coord = Coordinator::start(cfg());
        let mut rng = Rng::seeded(917);
        let t = coord
            .client()
            .submit(
                SubmitOptions::new(request(&mut rng, 1, 8))
                    .priority(Priority::Background)
                    .deadline(Duration::ZERO),
            )
            .unwrap();
        assert!(t.wait().unwrap().result.is_ok(), "expired deadline must not cancel");
        assert_eq!(coord.metrics().shed.load(Ordering::Relaxed), 0);
        coord.shutdown();
    }

    #[test]
    fn same_weight_requests_coalesce_into_one_pass() {
        let coord = Coordinator::start(CoordinatorConfig {
            n: 8,
            workers: 1,
            queue_capacity: 64,
            batch_window: 1, // one batch per request: coalescing, not fusion
            coalesce: CoalesceConfig {
                enabled: true,
                window: Duration::from_millis(500),
                max_members: 8,
            },
            ..Default::default()
        });
        let client = coord.client();
        let mut rng = Rng::seeded(919);
        let b = Arc::new(Mat::random(&mut rng, 16, 16, 2));
        let mut want = Vec::new();
        let tickets: Vec<Ticket> = (0..3u64)
            .map(|i| {
                let a = Arc::new(Mat::random(&mut rng, 16, 16, 8));
                want.push(a.matmul(&b));
                let req = MatmulRequest {
                    id: 0,
                    input_id: 100 + i, // distinct inputs: the batcher cannot fuse
                    a,
                    bs: vec![b.clone()],
                    weight_bits: 2,
                    act_act: false,
                    tag: String::new(),
                };
                client.submit(SubmitOptions::new(req)).unwrap()
            })
            .collect();
        for (t, w) in tickets.into_iter().zip(&want) {
            let out = t.wait().unwrap();
            assert_eq!(&out.result.unwrap()[0], w, "coalesced outputs must be bit-exact");
        }
        let m = coord.metrics();
        assert!(
            m.coalesced_passes.load(Ordering::Relaxed) >= 1,
            "same-weight solo batches must coalesce"
        );
        assert!(m.coalesced_members.load(Ordering::Relaxed) >= 2);
        coord.shutdown();
    }

    #[test]
    fn stealing_policies_serve_identical_results() {
        let mut rng = Rng::seeded(921);
        let reqs: Vec<MatmulRequest> =
            (0..12u64).map(|i| request(&mut rng, 1000 + i, 2)).collect();
        let want: Vec<Mat> = reqs.iter().map(|r| r.a.matmul(&r.bs[0])).collect();
        for steal in StealPolicy::ALL {
            let coord = Coordinator::start(CoordinatorConfig {
                n: 8,
                workers: 3,
                queue_capacity: 64,
                batch_window: 1,
                steal,
                ..Default::default()
            });
            let client = coord.client();
            let tickets: Vec<Ticket> = reqs
                .iter()
                .map(|r| client.submit(SubmitOptions::new(r.clone())).unwrap())
                .collect();
            for (t, w) in tickets.into_iter().zip(&want) {
                assert_eq!(&t.wait().unwrap().result.unwrap()[0], w, "{steal}");
            }
            assert_eq!(coord.metrics().completed.load(Ordering::Relaxed), 12, "{steal}");
            coord.shutdown();
        }
    }

    #[test]
    fn cache_contention_gauges_surface_in_render() {
        let coord = Coordinator::start(CoordinatorConfig {
            // capacity ≥ MIN_SHARDED_CAPACITY: the store runs sharded
            cluster: crate::cluster::ClusterConfig::with_cores(1).with_cache(64),
            workers: 2,
            ..cfg()
        });
        let mut rng = Rng::seeded(923);
        let client = coord.client();
        let r = request(&mut rng, 1, 8);
        for _ in 0..3 {
            assert!(client.submit_wait(SubmitOptions::new(r.clone())).unwrap().result.is_ok());
        }
        let text = coord.metrics().render();
        coord.shutdown();
        assert!(text.contains("adip_weight_cache_shards 8"), "{text}");
        assert!(text.contains("adip_weight_cache_lock_waits_total"));
        // repeated identical requests populate at least one shard
        let m = |key: &str| {
            text.lines()
                .find(|l| l.starts_with(key))
                .and_then(|l| l.split(' ').nth(1))
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or_else(|| panic!("{key} missing:\n{text}"))
        };
        assert!(m("adip_weight_cache_shards_occupied") >= 1);
        assert!(m("adip_weight_cache_hits_total") >= 1, "re-served request must hit");
    }

    #[test]
    fn blocked_kernel_serves_identical_results_and_accounting() {
        use crate::arch::KernelMode;
        let mut rng = Rng::seeded(925);
        let reqs: Vec<MatmulRequest> = (0..6u64).map(|i| request(&mut rng, i, 2)).collect();
        let run = |kernel: KernelMode| {
            let coord = Coordinator::start(CoordinatorConfig {
                cluster: crate::cluster::ClusterConfig::with_cores(1)
                    .with_kernel(kernel)
                    .with_kernel_threads(2),
                ..cfg()
            });
            let client = coord.client();
            let outs: Vec<_> = reqs
                .iter()
                .map(|r| {
                    let o = client.submit_wait(SubmitOptions::new(r.clone())).unwrap();
                    (o.result.unwrap(), o.metrics.cycles, o.metrics.passes)
                })
                .collect();
            let m = coord.metrics();
            let totals = (
                m.sim_cycles.load(Ordering::Relaxed),
                m.passes.load(Ordering::Relaxed),
                m.memory_bytes.load(Ordering::Relaxed),
            );
            coord.shutdown();
            (outs, totals)
        };
        let (naive, naive_totals) = run(KernelMode::Naive);
        let (blocked, blocked_totals) = run(KernelMode::Blocked);
        assert_eq!(naive, blocked, "served kernels must be bit-exact");
        assert_eq!(naive_totals, blocked_totals, "accounting must be kernel-invariant");
    }

    #[test]
    fn invalid_requests_rejected_upfront() {
        let coord = Coordinator::start(cfg());
        let mut rng = Rng::seeded(905);
        let mut bad = request(&mut rng, 1, 8);
        bad.bs.clear();
        assert!(coord.client().submit(SubmitOptions::new(bad)).is_err());
        assert_eq!(coord.metrics().failed.load(Ordering::Relaxed), 1);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue, no workers consuming fast: overflow must reject
        let c = CoordinatorConfig {
            n: 8,
            workers: 1,
            queue_capacity: 2,
            batch_window: 1,
            ..Default::default()
        };
        let coord = Coordinator::start(c);
        let client = coord.client();
        let mut rng = Rng::seeded(907);
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            // big-ish requests keep the worker busy
            let r = MatmulRequest {
                id: 0,
                input_id: 0,
                a: Arc::new(Mat::random(&mut rng, 64, 64, 8)),
                bs: vec![Arc::new(Mat::random(&mut rng, 64, 64, 8))],
                weight_bits: 8,
                act_act: false,
                tag: String::new(),
            };
            match client.submit(SubmitOptions::new(r)) {
                Ok(t) => rxs.push(t.into_parts().1),
                Err(_) => rejected += 1,
            }
        }
        // accepted requests still all complete
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let m = coord.metrics();
        assert_eq!(m.rejected.load(Ordering::Relaxed), rejected);
        assert_eq!(
            m.completed.load(Ordering::Relaxed) + m.rejected.load(Ordering::Relaxed),
            64
        );
        coord.shutdown();
    }

    #[test]
    fn qkv_requests_get_fused() {
        let coord = Coordinator::start(CoordinatorConfig {
            n: 8,
            workers: 1,
            queue_capacity: 64,
            batch_window: 8,
            ..Default::default()
        });
        let client = coord.client();
        let mut rng = Rng::seeded(909);
        let x = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let r = MatmulRequest {
                id: 0,
                input_id: 77,
                a: x.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, 16, 16, 2))],
                weight_bits: 2,
                act_act: false,
                tag: "qkv".into(),
            };
            rxs.push(client.submit(SubmitOptions::new(r)).unwrap().into_parts().1);
        }
        let mut any_batched = false;
        for rx in rxs {
            let out = rx.recv().unwrap();
            assert!(out.result.is_ok());
            any_batched |= out.metrics.batched;
        }
        // the router windowed them together (single worker, same instant)
        assert!(any_batched, "Q/K/V requests should fuse");
        assert!(coord.metrics().fused_batches.load(Ordering::Relaxed) >= 1);
        coord.shutdown();
    }

    #[test]
    fn submit_group_pre_declares_fusion() {
        let coord = Coordinator::start(CoordinatorConfig {
            n: 8,
            workers: 1,
            queue_capacity: 64,
            batch_window: 8,
            ..Default::default()
        });
        let client = coord.client();
        let mut rng = Rng::seeded(911);
        let x = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        // inconsistent input_ids on purpose: the group tag overrides them
        let reqs: Vec<MatmulRequest> = (0..3)
            .map(|i| MatmulRequest {
                id: 0,
                input_id: 500 + i, // would defeat fusion if kept
                a: x.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, 16, 16, 2))],
                weight_bits: 2,
                act_act: false,
                tag: format!("g{i}"),
            })
            .collect();
        let want: Vec<Mat> = reqs.iter().map(|r| r.a.matmul(&r.bs[0])).collect();
        let tickets = client.submit_group(7, Priority::Interactive, reqs).unwrap();
        assert_eq!(tickets.len(), 3);
        let mut any_batched = false;
        for (t, w) in tickets.into_iter().zip(&want) {
            let out = t.wait().unwrap();
            assert_eq!(&out.result.unwrap()[0], w);
            any_batched |= out.metrics.batched;
        }
        assert!(any_batched, "grouped Q/K/V should fuse");
        coord.shutdown();
    }
}
