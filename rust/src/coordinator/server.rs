//! The coordinator server: bounded ingress queue, batching router, worker
//! pool, backpressure and graceful shutdown — all on std threads/channels
//! (the offline crate snapshot has no async runtime; on a 1-vCPU host the
//! thread pool is the right tool anyway).
//!
//! ```text
//! submit() ──▶ [bounded queue] ──▶ router thread ──▶ worker 0 (cluster)
//!                  │ (reject when full = backpressure)   worker 1 …
//!                  ▼                                     │
//!             Metrics ◀──────── outcomes via per-request channels
//! ```
//!
//! Each worker owns a [`ClusterScheduler`] — by default a persistent pool
//! of per-core threads (see `cluster/mod.rs`) — and, unless
//! `shared_weight_cache` is disabled, every worker shares one
//! coordinator-wide [`SharedWeightCache`] store so siblings reuse each
//! other's repeated projection tiles (surfaced as
//! `adip_weight_cache_shared_hits_total`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::{Architecture, Backend};
use crate::cluster::{ClusterConfig, ClusterScheduler, PoolMode, SharedWeightCache};

use super::batcher::form_batches;
use super::metrics::Metrics;
use super::request::{Envelope, MatmulRequest, RequestId, RequestOutcome};

/// Coordinator configuration.
///
/// The defaults are the serving defaults everywhere in the crate:
/// `Backend::Functional` execution and a degenerate single-core cluster
/// per worker (no sharding, weight cache off) — byte-identical accounting
/// to the pre-cluster coordinator, so existing callers that spread
/// `..Default::default()` keep their behavior.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    /// Architecture each core simulates.
    pub arch: Architecture,
    /// Array size per core.
    pub n: usize,
    /// Worker threads (each owns one simulated cluster of cores).
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Max requests gathered into one batching window.
    pub batch_window: usize,
    /// Execution backend of every worker core. `Backend::Functional`
    /// (default) serves from the fast whole-GEMM path; pin
    /// `Backend::CycleAccurate` for calibration/validation runs where the
    /// register-level golden path must execute every request.
    pub backend: Backend,
    /// Per-worker cluster execution: shard count, split axis, weight
    /// cache and pool mode (default: 1 core, M split, cache off,
    /// persistent pool).
    pub cluster: ClusterConfig,
    /// Share one weight-cache store across every worker (default), so
    /// siblings reuse each other's projection tiles (`shared_hits`); off =
    /// one private store per worker. Irrelevant while the cache capacity
    /// is 0, and can never change outputs either way (hits are bit-exact
    /// by key construction).
    pub shared_weight_cache: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            arch: Architecture::Adip,
            n: 32,
            workers: 2,
            queue_capacity: 256,
            batch_window: 16,
            backend: Backend::Functional,
            cluster: ClusterConfig::default(),
            shared_weight_cache: true,
        }
    }
}

/// Work sent to a worker: the envelopes of one batch.
struct WorkItem {
    envelopes: Vec<Envelope>,
    runtime_interleave: bool,
}

/// The running coordinator.
pub struct Coordinator {
    ingress: SyncSender<Envelope>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the router + worker threads.
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        assert!(cfg.workers > 0 && cfg.queue_capacity > 0 && cfg.batch_window > 0);
        let metrics = Arc::new(Metrics::default());
        let (ingress_tx, ingress_rx) = sync_channel::<Envelope>(cfg.queue_capacity);
        // Single-core clusters execute inline (no pool threads), so the
        // gauge only counts real persistent workers.
        if cfg.cluster.pool == PoolMode::Persistent && cfg.cluster.effective_cores() > 1 {
            metrics
                .pool_workers
                .store((cfg.workers * cfg.cluster.effective_cores()) as u64, Ordering::Relaxed);
        }
        // One weight-cache store per coordinator (the promoted cross-worker
        // design): sibling workers reuse each other's projection tiles.
        // `shared_weight_cache: false` falls back to a private store per
        // worker.
        let shared_cache =
            cfg.shared_weight_cache.then(|| SharedWeightCache::new(cfg.cluster.cache));

        // worker channels
        let mut worker_txs = Vec::new();
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let (tx, rx) = sync_channel::<WorkItem>(4);
            worker_txs.push(tx);
            let m = metrics.clone();
            let cache = shared_cache
                .clone()
                .unwrap_or_else(|| SharedWeightCache::new(cfg.cluster.cache));
            workers.push(
                std::thread::Builder::new()
                    .name(format!("adip-worker-{w}"))
                    .spawn(move || worker_loop(rx, cfg, m, cache))
                    .expect("spawn worker"),
            );
        }

        let m = metrics.clone();
        let router = std::thread::Builder::new()
            .name("adip-router".into())
            .spawn(move || router_loop(ingress_rx, worker_txs, cfg, m))
            .expect("spawn router");

        Coordinator {
            ingress: ingress_tx,
            metrics,
            next_id: AtomicU64::new(1),
            router: Some(router),
            workers,
        }
    }

    /// Submit a request without blocking. On success the request id is
    /// assigned and a receiver for the outcome is returned; a full queue
    /// rejects the request (backpressure).
    pub fn try_submit(
        &self,
        mut req: MatmulRequest,
    ) -> Result<(RequestId, Receiver<RequestOutcome>)> {
        if let Err(reason) = req.validate() {
            self.metrics.failed.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!("invalid request: {reason}"));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        let (tx, rx) = std::sync::mpsc::channel();
        let env = Envelope { req, reply: tx, enqueued: Instant::now() };
        match self.ingress.try_send(env) {
            Ok(()) => {
                self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
                self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                Ok((id, rx))
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!("queue full ({} pending)", self.metrics.queue_depth.load(Ordering::Relaxed)))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("coordinator stopped")),
        }
    }

    /// Submit and block for the outcome (convenience).
    pub fn submit_wait(&self, req: MatmulRequest) -> Result<RequestOutcome> {
        let (_, rx) = self.try_submit(req)?;
        rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))
    }

    /// Shared metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Stop accepting requests, drain in-flight work, join all threads.
    pub fn shutdown(mut self) {
        drop(self.ingress);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn router_loop(
    ingress: Receiver<Envelope>,
    worker_txs: Vec<SyncSender<WorkItem>>,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
) {
    let mut next_worker = 0usize;
    loop {
        // blocking pull of the first request, then drain a window
        let first = match ingress.recv() {
            Ok(e) => e,
            Err(_) => break, // ingress closed: drain done
        };
        let mut window = vec![first];
        while window.len() < cfg.batch_window {
            match ingress.try_recv() {
                Ok(e) => window.push(e),
                Err(_) => break,
            }
        }
        metrics.queue_depth.fetch_sub(window.len() as u64, Ordering::Relaxed);

        let reqs: Vec<MatmulRequest> = window.iter().map(|e| e.req.clone()).collect();
        let batches = form_batches(&reqs);

        // move envelopes into their batches (indices are into `window`)
        let mut slots: Vec<Option<Envelope>> = window.into_iter().map(Some).collect();
        for b in batches {
            let envelopes: Vec<Envelope> =
                b.members.iter().map(|&i| slots[i].take().expect("batch partition")).collect();
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            if envelopes.len() > 1 || envelopes[0].req.bs.len() > 1 {
                metrics.fused_batches.fetch_add(1, Ordering::Relaxed);
            }
            let item = WorkItem { envelopes, runtime_interleave: b.runtime_interleave };
            // round-robin dispatch; blocking send applies backpressure to
            // the router (ingress queue keeps absorbing bursts)
            if worker_txs[next_worker % worker_txs.len()].send(item).is_err() {
                return; // workers gone
            }
            next_worker += 1;
        }
    }
}

fn worker_loop(
    rx: Receiver<WorkItem>,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    cache: SharedWeightCache,
) {
    let mut core =
        ClusterScheduler::with_shared_cache(cfg.arch, cfg.n, cfg.backend, cfg.cluster, cache);
    let mut cache_seen = core.cache_stats();
    let mut pool_seen = core.pool_stats();
    while let Ok(item) = rx.recv() {
        let started = Instant::now();
        let members: Vec<&MatmulRequest> = item.envelopes.iter().map(|e| &e.req).collect();
        let outcome = core.execute_batch(&members, item.runtime_interleave);
        // flush cache + pool activity regardless of batch outcome (a
        // failed batch may still have probed or populated the cache, or
        // dispatched shards before erroring)
        let cache_now = core.cache_stats();
        let d = cache_now.delta_since(&cache_seen);
        cache_seen = cache_now;
        if d.hits + d.misses + d.evictions > 0 {
            metrics.record_cache(d.hits, d.shared_hits, d.misses, d.evictions);
        }
        let pool_now = core.pool_stats();
        let pd = pool_now.delta_since(&pool_seen);
        pool_seen = pool_now;
        if pd.dispatched + pd.worker_panics > 0 {
            metrics.record_pool(pd.dispatched, pd.queue_wait_s, pd.worker_panics);
        }
        match outcome {
            Ok(results) => {
                let service = started.elapsed().as_secs_f64() / results.len() as f64;
                for (env, mut res) in item.envelopes.iter().zip(results) {
                    res.metrics.queue_seconds = (started - env.enqueued).as_secs_f64();
                    res.metrics.service_seconds = service;
                    metrics.record_completion(
                        res.metrics.cycles,
                        res.metrics.energy_j,
                        res.metrics.memory.paper_total_bytes(),
                        res.metrics.passes,
                    );
                    metrics.record_latency(res.metrics.queue_seconds, service);
                    let _ = env.reply.send(RequestOutcome {
                        id: env.req.id,
                        result: Ok(res.outputs),
                        metrics: res.metrics,
                    });
                }
            }
            Err(e) => {
                for env in &item.envelopes {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = env.reply.send(RequestOutcome {
                        id: env.req.id,
                        result: Err(e.to_string()),
                        metrics: Default::default(),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Mat;
    use crate::testutil::Rng;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig { n: 8, workers: 2, queue_capacity: 64, batch_window: 8, ..Default::default() }
    }

    fn request(rng: &mut Rng, input_id: u64, bits: u32) -> MatmulRequest {
        MatmulRequest {
            id: 0,
            input_id,
            a: Arc::new(Mat::random(rng, 16, 16, 8)),
            bs: vec![Arc::new(Mat::random(rng, 16, 16, bits))],
            weight_bits: bits,
            act_act: false,
            tag: "t".into(),
        }
    }

    #[test]
    fn end_to_end_correct_results() {
        let coord = Coordinator::start(cfg());
        let mut rng = Rng::seeded(901);
        let req = request(&mut rng, 1, 8);
        let want = req.a.matmul(&req.bs[0]);
        let out = coord.submit_wait(req).unwrap();
        assert_eq!(out.result.unwrap()[0], want);
        assert!(out.metrics.cycles > 0);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete_exactly_once() {
        let coord = Coordinator::start(cfg());
        let mut rng = Rng::seeded(903);
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..32u64 {
            let bits = *rng.choose(&[2, 4, 8]);
            let r = request(&mut rng, i % 4, bits);
            expected.push((r.a.clone(), r.bs[0].clone()));
            let (id, rx) = coord.try_submit(r).unwrap();
            rxs.push((id, rx));
        }
        let mut seen = std::collections::HashSet::new();
        for (i, (id, rx)) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap();
            assert_eq!(out.id, id);
            assert!(seen.insert(id), "duplicate completion");
            let (a, b) = &expected[i];
            assert_eq!(out.result.unwrap()[0], a.matmul(b));
        }
        let m = coord.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), 32);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
        coord.shutdown();
    }

    #[test]
    fn invalid_requests_rejected_upfront() {
        let coord = Coordinator::start(cfg());
        let mut rng = Rng::seeded(905);
        let mut bad = request(&mut rng, 1, 8);
        bad.bs.clear();
        assert!(coord.try_submit(bad).is_err());
        assert_eq!(coord.metrics().failed.load(Ordering::Relaxed), 1);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue, no workers consuming fast: overflow must reject
        let c = CoordinatorConfig {
            n: 8,
            workers: 1,
            queue_capacity: 2,
            batch_window: 1,
            ..Default::default()
        };
        let coord = Coordinator::start(c);
        let mut rng = Rng::seeded(907);
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            // big-ish requests keep the worker busy
            let r = MatmulRequest {
                id: 0,
                input_id: 0,
                a: Arc::new(Mat::random(&mut rng, 64, 64, 8)),
                bs: vec![Arc::new(Mat::random(&mut rng, 64, 64, 8))],
                weight_bits: 8,
                act_act: false,
                tag: String::new(),
            };
            match coord.try_submit(r) {
                Ok((_, rx)) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        // accepted requests still all complete
        for rx in rxs {
            assert!(rx.recv().unwrap().result.is_ok());
        }
        let m = coord.metrics();
        assert_eq!(m.rejected.load(Ordering::Relaxed), rejected);
        assert_eq!(
            m.completed.load(Ordering::Relaxed) + m.rejected.load(Ordering::Relaxed),
            64
        );
        coord.shutdown();
    }

    #[test]
    fn qkv_requests_get_fused() {
        let coord = Coordinator::start(CoordinatorConfig {
            n: 8,
            workers: 1,
            queue_capacity: 64,
            batch_window: 8,
            ..Default::default()
        });
        let mut rng = Rng::seeded(909);
        let x = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let r = MatmulRequest {
                id: 0,
                input_id: 77,
                a: x.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, 16, 16, 2))],
                weight_bits: 2,
                act_act: false,
                tag: "qkv".into(),
            };
            rxs.push(coord.try_submit(r).unwrap().1);
        }
        let mut any_batched = false;
        for rx in rxs {
            let out = rx.recv().unwrap();
            assert!(out.result.is_ok());
            any_batched |= out.metrics.batched;
        }
        // the router windowed them together (single worker, same instant)
        assert!(any_batched, "Q/K/V requests should fuse");
        assert!(coord.metrics().fused_batches.load(Ordering::Relaxed) >= 1);
        coord.shutdown();
    }
}
