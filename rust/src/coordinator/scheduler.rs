//! Core scheduler: executes a fused batch on one simulated array core.
//!
//! One [`CoreScheduler`] wraps one co-simulated array. It is the shard
//! execution engine of the cluster layer — in the default
//! [`crate::cluster::PoolMode::Persistent`] configuration each core is
//! owned by a long-lived pool worker thread that runs
//! [`CoreScheduler::run_set`] on queued shards (and is rebuilt from
//! scratch if a shard panics mid-run).
//! A batch's weight matrices are concatenated in member order, run as a
//! shared-input multi-matrix GEMM set, and the outputs are routed back to
//! their requests. Cycle/energy/memory accounting is attributed to members
//! proportionally to their matrix count (the shared activation traffic is
//! genuinely shared — that attribution choice is what makes fused requests
//! individually cheaper, mirroring the paper's memory-efficiency claim).

use crate::arch::{build_array, ArchConfig, Architecture, Backend, SystolicArray};
use crate::dataflow::Mat;
use crate::quant::PrecisionMode;
use crate::sim::cosim::{CoSim, CoSimResult};

use super::precision::select_mode;
use super::request::{MatmulRequest, ResponseMetrics};

/// One simulated core + its co-simulator.
pub struct CoreScheduler {
    cosim: CoSim<Box<dyn SystolicArray + Send>>,
    arch: Architecture,
    backend: Backend,
}

/// Execution result for one member request of a batch.
#[derive(Debug)]
pub struct MemberResult {
    /// Outputs for this member's weight matrices (in submit order).
    pub outputs: Vec<Mat>,
    /// Accounting attributed to this member.
    pub metrics: ResponseMetrics,
}

impl CoreScheduler {
    /// Build a core for an architecture at size `n` with the default
    /// backend — `Backend::Functional`, matching
    /// [`super::CoordinatorConfig::default`]'s serving defaults (functional
    /// backend, one core): a bare `CoreScheduler` and a default
    /// single-core cluster produce byte-identical accounting.
    pub fn new(arch: Architecture, n: usize) -> CoreScheduler {
        CoreScheduler::with_backend(arch, n, Backend::default())
    }

    /// Build a core for an architecture at size `n` on a specific
    /// execution backend (`Backend::CycleAccurate` pins the register-level
    /// golden path; used by calibration runs and the differential tests).
    pub fn with_backend(arch: Architecture, n: usize, backend: Backend) -> CoreScheduler {
        CoreScheduler::with_config(arch, ArchConfig::with_n(n).with_backend(backend))
    }

    /// Build a core from a full [`ArchConfig`] — the cluster layer uses
    /// this to thread the functional kernel selection (`cfg.kernel` /
    /// `cfg.kernel_threads`) through to every pool worker's array.
    pub fn with_config(arch: Architecture, cfg: ArchConfig) -> CoreScheduler {
        let backend = cfg.backend;
        CoreScheduler { cosim: CoSim::new(build_array(arch, cfg)), arch, backend }
    }

    /// Which architecture this core simulates.
    pub fn architecture(&self) -> Architecture {
        self.arch
    }

    /// Which execution backend this core runs on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Execute one shared-input GEMM set directly on this core, returning
    /// the raw (un-attributed) co-simulation result. This is the shard
    /// execution primitive the cluster scheduler
    /// ([`crate::cluster::ClusterScheduler`]) dispatches to its worker
    /// pool; [`CoreScheduler::execute_batch`] layers per-member
    /// attribution on top of it.
    pub fn run_set(
        &mut self,
        a: &Mat,
        bs: &[&Mat],
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> anyhow::Result<CoSimResult> {
        self.cosim.run_gemm_set(a, bs, mode, runtime_interleave)
    }

    /// Execute a batch of fused requests (all sharing `members[0].a`).
    /// Returns one [`MemberResult`] per member, in order.
    pub fn execute_batch(
        &mut self,
        members: &[&MatmulRequest],
        runtime_interleave: bool,
    ) -> anyhow::Result<Vec<MemberResult>> {
        assert!(!members.is_empty());
        let first = members[0];
        let mode = select_mode(first.weight_bits, first.act_act);
        let a: &Mat = &first.a;
        let bs: Vec<&Mat> = members.iter().flat_map(|m| m.bs.iter().map(|b| b.as_ref())).collect();
        let res = self.cosim.run_gemm_set(a, &bs, mode, runtime_interleave)?;
        Ok(attribute_members(members, &res))
    }
}

/// Split a fused run's outputs back per member and attribute accounting
/// proportionally to each member's matrix count (the shared activation
/// traffic is genuinely shared — see the module docs). Used by both the
/// single-core and the cluster execution paths so their per-request
/// accounting is identical.
pub(crate) fn attribute_members(
    members: &[&MatmulRequest],
    res: &CoSimResult,
) -> Vec<MemberResult> {
    let total: u64 = members.iter().map(|m| m.bs.len() as u64).sum();
    let fused = members.len() > 1 || members[0].bs.len() > 1;
    let mut out = Vec::with_capacity(members.len());
    let mut cursor = 0usize;
    for m in members {
        let n_b = m.bs.len();
        let share = n_b as f64 / total as f64;
        let outputs = res.outputs[cursor..cursor + n_b].to_vec();
        cursor += n_b;
        let mut mem = res.memory;
        mem.act_read_bytes = (mem.act_read_bytes as f64 * share) as u64;
        mem.weight_read_bytes = (mem.weight_read_bytes as f64 * share) as u64;
        mem.output_write_bytes = (mem.output_write_bytes as f64 * share) as u64;
        out.push(MemberResult {
            outputs,
            metrics: ResponseMetrics {
                cycles: (res.cycles as f64 * share).round() as u64,
                energy_j: res.energy_j * share,
                memory: mem,
                passes: (res.passes as f64 * share).round() as u64,
                queue_seconds: 0.0,
                service_seconds: 0.0,
                prepare_seconds: 0.0,
                fabric_seconds: 0.0,
                execute_seconds: 0.0,
                batched: fused,
                // stamped by the coordinator worker from the router's
                // batch-formation sequence; 0 for direct scheduler use
                batch_seq: 0,
            },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;
    use std::sync::Arc;

    fn req(rng: &mut Rng, id: u64, input: &Arc<Mat>, bits: u32, n_b: usize) -> MatmulRequest {
        let dim = input.cols();
        MatmulRequest {
            id,
            input_id: 1,
            a: input.clone(),
            bs: (0..n_b).map(|_| Arc::new(Mat::random(rng, dim, dim, bits))).collect(),
            weight_bits: bits,
            act_act: false,
            tag: String::new(),
        }
    }

    #[test]
    fn fused_batch_outputs_route_correctly() {
        let mut rng = Rng::seeded(801);
        let a = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let r1 = req(&mut rng, 1, &a, 2, 1);
        let r2 = req(&mut rng, 2, &a, 2, 2);
        let mut core = CoreScheduler::new(Architecture::Adip, 8);
        let results = core.execute_batch(&[&r1, &r2], false).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].outputs.len(), 1);
        assert_eq!(results[1].outputs.len(), 2);
        assert_eq!(results[0].outputs[0], a.matmul(&r1.bs[0]));
        assert_eq!(results[1].outputs[0], a.matmul(&r2.bs[0]));
        assert_eq!(results[1].outputs[1], a.matmul(&r2.bs[1]));
        assert!(results[0].metrics.batched);
        // attribution: r2 gets 2× r1's share
        assert!(results[1].metrics.cycles >= results[0].metrics.cycles);
    }

    #[test]
    fn fusion_cheaper_than_solo_execution() {
        // Narrow outputs (one column tile — the head-size-limited case the
        // Fig. 5(d) Q/K/V mode exists for): without cross-request fusion
        // there is nothing to interleave, so fusing 4 requests must ~4×
        // the per-request efficiency.
        let mut rng = Rng::seeded(803);
        let a = Arc::new(Mat::random(&mut rng, 32, 32, 8));
        let reqs: Vec<MatmulRequest> = (0..4)
            .map(|i| MatmulRequest {
                id: i,
                input_id: 1,
                a: a.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, 32, 8, 2))],
                weight_bits: 2,
                act_act: false,
                tag: String::new(),
            })
            .collect();
        let refs: Vec<&MatmulRequest> = reqs.iter().collect();

        let mut core = CoreScheduler::new(Architecture::Adip, 8);
        let fused = core.execute_batch(&refs, false).unwrap();
        let fused_total: u64 = fused.iter().map(|r| r.metrics.cycles).sum();

        let mut solo_total = 0;
        for r in &reqs {
            let mut c = CoreScheduler::new(Architecture::Adip, 8);
            let res = c.execute_batch(&[r], false).unwrap();
            solo_total += res[0].metrics.cycles;
        }
        let gain = solo_total as f64 / fused_total as f64;
        assert!(gain > 3.5, "fusion gain {gain} (solo {solo_total} vs fused {fused_total})");
    }

    #[test]
    fn all_architectures_execute() {
        let mut rng = Rng::seeded(805);
        let a = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let r = req(&mut rng, 1, &a, 8, 1);
        for arch in Architecture::ALL {
            let mut core = CoreScheduler::new(arch, 8);
            let out = core.execute_batch(&[&r], false).unwrap();
            assert_eq!(out[0].outputs[0], a.matmul(&r.bs[0]), "{arch}");
        }
    }

    #[test]
    fn backends_agree_on_batch_outputs_and_cycles() {
        let mut rng = Rng::seeded(807);
        let a = Arc::new(Mat::random(&mut rng, 24, 24, 8));
        let r1 = req(&mut rng, 1, &a, 2, 2);
        let r2 = req(&mut rng, 2, &a, 2, 1);
        let mut fast = CoreScheduler::with_backend(Architecture::Adip, 8, Backend::Functional);
        let mut golden =
            CoreScheduler::with_backend(Architecture::Adip, 8, Backend::CycleAccurate);
        assert_eq!(fast.backend(), Backend::Functional);
        assert_eq!(golden.backend(), Backend::CycleAccurate);
        let rf = fast.execute_batch(&[&r1, &r2], false).unwrap();
        let rg = golden.execute_batch(&[&r1, &r2], false).unwrap();
        assert_eq!(rf.len(), rg.len());
        for (f, g) in rf.iter().zip(&rg) {
            assert_eq!(f.outputs, g.outputs);
            assert_eq!(f.metrics.cycles, g.metrics.cycles);
            assert_eq!(f.metrics.passes, g.metrics.passes);
        }
    }
}
