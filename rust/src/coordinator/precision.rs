//! Precision-mode selection policy.
//!
//! Mirrors the paper's workload mapping (§V-B): projection
//! (activation-to-weight) requests run at the narrowest mode that fits the
//! quantized weight width — 2-bit/ternary → 8b×2b, ≤4-bit → 8b×4b,
//! otherwise 8b×8b — while activation-to-activation requests always run at
//! 8b×8b (dynamic operands cannot be pre-quantized below 8 bits without
//! accuracy loss, and their preprocessing happens at runtime).
//!
//! In the three-stage pipeline this policy runs at batch formation (the
//! batcher's fusion key fixes each batch's mode, carried through the
//! prepare stage unchanged), off the worker's execute path; admission
//! (`MatmulRequest::validate`) uses it too, to check operand ranges
//! against the mode the request will actually run at.

use crate::quant::PrecisionMode;

/// Select the execution mode for a request.
pub fn select_mode(weight_bits: u32, act_act: bool) -> PrecisionMode {
    if act_act {
        PrecisionMode::W8
    } else {
        PrecisionMode::for_weight_bits(weight_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn projection_modes_follow_weight_width() {
        assert_eq!(select_mode(1, false), PrecisionMode::W2); // BitNet ternary
        assert_eq!(select_mode(2, false), PrecisionMode::W2);
        assert_eq!(select_mode(3, false), PrecisionMode::W4);
        assert_eq!(select_mode(4, false), PrecisionMode::W4); // BERT-large 4-bit
        assert_eq!(select_mode(8, false), PrecisionMode::W8); // GPT-2 8-bit
    }

    #[test]
    fn act_act_pins_w8() {
        for bits in 1..=8 {
            assert_eq!(select_mode(bits, true), PrecisionMode::W8);
        }
    }
}
