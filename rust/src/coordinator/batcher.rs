//! Shared-input batcher — the asymmetric multi-matrix fusion policy.
//!
//! Groups pending requests that (a) share the same input operand
//! (`input_id`), (b) selected the same precision mode, and (c) have
//! identical GEMM shapes, into interleave sets of at most
//! `interleave_factor` weight matrices (Fig. 5(b)–(d)). Requests that
//! cannot be fused are emitted as singleton batches (they still benefit
//! from adjacent-column fusion inside the scheduler).
//!
//! Invariants (property-tested):
//! * every input request appears in exactly one batch,
//! * a batch never mixes input ids, modes, shapes or act-act classes,
//! * no batch exceeds the mode's interleave capacity.

use crate::quant::PrecisionMode;

use super::precision::select_mode;
use super::request::MatmulRequest;

/// A fused execution unit: indices into the submitted slice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    /// Execution mode of the whole batch.
    pub mode: PrecisionMode,
    /// Member request indices (into the slice passed to [`form_batches`]).
    pub members: Vec<usize>,
    /// Total weight matrices across members.
    pub matrices: usize,
    /// Whether this batch fused ≥ 2 requests (or a multi-B request).
    pub fused: bool,
    /// Runtime (multi-bank) interleaving required — activation-to-
    /// activation operands.
    pub runtime_interleave: bool,
}

/// Fusion key: requests must agree on all fields to share a pass. The
/// `a_ptr` field is the address of the shared input matrix — requests only
/// fuse when they reference the *same* activation object, so an
/// inconsistent `input_id` can never corrupt results.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    input_id: u64,
    a_ptr: usize,
    mode: PrecisionMode,
    a_rows: usize,
    a_cols: usize,
    b_cols: usize,
    act_act: bool,
}

/// Form batches over a window of pending requests (order-stable greedy
/// bin packing per fusion key).
pub fn form_batches(reqs: &[MatmulRequest]) -> Vec<Batch> {
    use std::collections::HashMap;
    let mut bins: HashMap<Key, Vec<Batch>> = HashMap::new();
    let mut order: Vec<Key> = Vec::new();

    for (idx, r) in reqs.iter().enumerate() {
        let mode = select_mode(r.weight_bits, r.act_act);
        let key = Key {
            input_id: r.input_id,
            a_ptr: std::sync::Arc::as_ptr(&r.a) as usize,
            mode,
            a_rows: r.a.rows(),
            a_cols: r.a.cols(),
            b_cols: r.bs[0].cols(),
            act_act: r.act_act,
        };
        let cap = mode.interleave_factor();
        let entry = bins.entry(key).or_insert_with(|| {
            order.push(key);
            Vec::new()
        });
        // greedy: drop into the first bin with room for all of this
        // request's matrices (requests are never split across passes)
        let need = r.bs.len();
        let slot = entry.iter_mut().find(|b| b.matrices + need <= cap);
        match slot {
            Some(b) => {
                b.members.push(idx);
                b.matrices += need;
                b.fused = true;
            }
            None => entry.push(Batch {
                mode,
                members: vec![idx],
                matrices: need,
                fused: need > 1,
                runtime_interleave: r.act_act,
            }),
        }
    }

    // stable order: keys in first-seen order, bins in creation order
    let mut out = Vec::new();
    for key in order {
        out.extend(bins.remove(&key).unwrap());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Mat;
    use crate::testutil::{check, Rng};
    use std::sync::Arc;

    fn mk_shared(
        id: u64,
        input_id: u64,
        a: &Arc<Mat>,
        bits: u32,
        act_act: bool,
        n_b: usize,
    ) -> MatmulRequest {
        let mut rng = Rng::seeded(id + 100);
        let shape = a.rows();
        MatmulRequest {
            id,
            input_id,
            a: a.clone(),
            bs: (0..n_b)
                .map(|_| Arc::new(Mat::random(&mut rng, shape, shape, bits)))
                .collect(),
            weight_bits: bits,
            act_act,
            tag: String::new(),
        }
    }

    fn mk(id: u64, input_id: u64, bits: u32, act_act: bool, n_b: usize, shape: usize) -> MatmulRequest {
        // deterministic shared input per (input_id, shape): same Arc is
        // required for fusion, so tests build them from a small pool
        use std::collections::HashMap;
        use std::sync::{Mutex, OnceLock};
        static POOL: OnceLock<Mutex<HashMap<(u64, usize), Arc<Mat>>>> = OnceLock::new();
        let pool = POOL.get_or_init(|| Mutex::new(HashMap::new()));
        let a = pool
            .lock()
            .unwrap()
            .entry((input_id, shape))
            .or_insert_with(|| {
                let mut rng = Rng::seeded(input_id * 31 + shape as u64);
                Arc::new(Mat::random(&mut rng, shape, shape, 8))
            })
            .clone();
        mk_shared(id, input_id, &a, bits, act_act, n_b)
    }

    #[test]
    fn qkv_fuses_into_one_batch() {
        // three 2-bit single-B requests off the same input → one 3-matrix pass
        let reqs = vec![mk(1, 42, 2, false, 1, 8), mk(2, 42, 2, false, 1, 8), mk(3, 42, 2, false, 1, 8)];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].members, vec![0, 1, 2]);
        assert_eq!(batches[0].matrices, 3);
        assert!(batches[0].fused);
        assert_eq!(batches[0].mode, PrecisionMode::W2);
    }

    #[test]
    fn capacity_respected() {
        // five 2-bit requests: 4 + 1
        let reqs: Vec<_> = (0..5).map(|i| mk(i, 9, 2, false, 1, 8)).collect();
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].matrices, 4);
        assert_eq!(batches[1].matrices, 1);
        // 4-bit capacity is 2
        let reqs: Vec<_> = (0..3).map(|i| mk(i, 9, 4, false, 1, 8)).collect();
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn incompatible_requests_never_mix() {
        let reqs = vec![
            mk(1, 1, 2, false, 1, 8),  // input 1
            mk(2, 2, 2, false, 1, 8),  // different input
            mk(3, 1, 4, false, 1, 8),  // different mode
            mk(4, 1, 2, true, 1, 8),   // act-act (W8)
            mk(5, 1, 2, false, 1, 16), // different shape
        ];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 5, "{batches:?}");
    }

    #[test]
    fn multi_b_requests_count_matrices() {
        // a 3-matrix request + a 1-matrix request fit one 2-bit pass
        let reqs = vec![mk(1, 5, 2, false, 3, 8), mk(2, 5, 2, false, 1, 8)];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].matrices, 4);
        // but a 2-matrix request cannot join it
        let reqs = vec![mk(1, 5, 2, false, 3, 8), mk(2, 5, 2, false, 2, 8)];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn act_act_batches_flag_runtime_interleave() {
        let reqs = vec![mk(1, 3, 8, true, 1, 8)];
        let batches = form_batches(&reqs);
        assert!(batches[0].runtime_interleave);
        assert_eq!(batches[0].mode, PrecisionMode::W8);
    }

    #[test]
    fn partition_property() {
        // every request lands in exactly one batch; constraints hold
        check(
            "batcher-partition",
            701,
            40,
            |rng| {
                let n = 1 + rng.below(20);
                (0..n as u64)
                    .map(|i| {
                        let bits = *rng.choose(&[2u32, 4, 8]);
                        let act_act = rng.below(4) == 0;
                        let cap = select_mode(bits, act_act).interleave_factor();
                        mk(i, rng.below(3) as u64, bits, act_act, 1 + rng.below(cap), 8)
                    })
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let batches = form_batches(reqs);
                let mut seen = vec![0usize; reqs.len()];
                for b in &batches {
                    if b.matrices > b.mode.interleave_factor() {
                        return Err(format!("overfull batch {b:?}"));
                    }
                    let total: usize = b.members.iter().map(|&i| reqs[i].bs.len()).sum();
                    if total != b.matrices {
                        return Err("matrix count mismatch".into());
                    }
                    let first = &reqs[b.members[0]];
                    for &i in &b.members {
                        seen[i] += 1;
                        let r = &reqs[i];
                        if r.input_id != first.input_id
                            || r.act_act != first.act_act
                            || select_mode(r.weight_bits, r.act_act) != b.mode
                        {
                            return Err(format!("mixed batch {b:?}"));
                        }
                    }
                }
                if seen.iter().any(|&s| s != 1) {
                    return Err(format!("not a partition: {seen:?}"));
                }
                Ok(())
            },
        );
    }
}
