//! Shared-input batcher — the asymmetric multi-matrix fusion policy, with
//! priority-aware batch formation.
//!
//! Groups pending requests that (a) share the same input operand
//! (`input_id`), (b) selected the same precision mode, and (c) have
//! identical GEMM shapes, into interleave sets of at most
//! `interleave_factor` weight matrices (Fig. 5(b)–(d)). Requests that
//! cannot be fused are emitted as singleton batches (they still benefit
//! from adjacent-column fusion inside the scheduler).
//!
//! [`plan_batches`] adds the service-order policy on top of the fusion
//! rules: within one batching window, requests are visited in a
//! **deterministic priority order** — `Interactive` ahead of `Batch`
//! ahead of `Background`, deadline-ascending within a class, FIFO
//! (arrival-order) tiebreak — and batches are emitted in the order they
//! are opened by that traversal, so higher-priority work is dispatched
//! (and therefore executed) first. **Aging** prevents starvation: every
//! full `aging` interval a request has waited promotes it one class, so
//! overdue `Background` work rises to compete with fresh `Interactive`
//! arrivals on equal (deadline→FIFO) terms instead of being starved
//! behind them — and since windows are dispatched FIFO, even work that
//! loses every within-window tiebreak is served within a bounded number
//! of batches. The ordering is a pure function of
//! the window contents and lanes — seeded traces reproduce identical
//! batch orders (property-tested below).
//!
//! Invariants (property-tested):
//! * every input request appears in exactly one batch,
//! * a batch never mixes input ids, modes, shapes or act-act classes,
//! * no batch exceeds the mode's interleave capacity,
//! * [`form_batches`] (all-default lanes) and [`plan_batches`] agree.

use crate::quant::PrecisionMode;

use super::client::Priority;
use super::precision::select_mode;
use super::request::MatmulRequest;

/// A fused execution unit: indices into the submitted slice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    /// Execution mode of the whole batch.
    pub mode: PrecisionMode,
    /// Member request indices (into the slice passed to [`form_batches`]).
    pub members: Vec<usize>,
    /// Total weight matrices across members.
    pub matrices: usize,
    /// Whether this batch fused ≥ 2 requests (or a multi-B request).
    pub fused: bool,
    /// Runtime (multi-bank) interleaving required — activation-to-
    /// activation operands.
    pub runtime_interleave: bool,
}

/// Fusion key: requests must agree on all fields to share a pass. The
/// `a_ptr` field is the address of the shared input matrix — requests only
/// fuse when they reference the *same* activation object, so an
/// inconsistent `input_id` can never corrupt results.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    input_id: u64,
    a_ptr: usize,
    mode: PrecisionMode,
    a_rows: usize,
    a_cols: usize,
    b_cols: usize,
    act_act: bool,
}

/// Scheduling lane of one pending request, as the router sees it at
/// window-formation time. All fields are plain numbers so the planner is
/// a pure (deterministic, testable) function of its inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lane {
    /// Service class the request was submitted under.
    pub priority: Priority,
    /// Soft-deadline headroom in µs (negative = overdue, `i64::MAX` =
    /// no deadline). Orders deadline-ascending within a class.
    pub deadline_us: i64,
    /// Time the request has already waited in the admission queue (µs);
    /// drives aging promotion.
    pub age_us: u64,
}

impl Default for Lane {
    fn default() -> Lane {
        Lane { priority: Priority::default(), deadline_us: i64::MAX, age_us: 0 }
    }
}

/// What the deadline-shedding policy decides for one request at
/// batch-formation time (see [`shed_verdict`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedVerdict {
    /// Deadline absent or still achievable: schedule normally.
    Keep,
    /// Deadline hopeless but the class is Interactive/Batch: execute
    /// anyway, demoted to Background — the latency claim is forfeit, the
    /// work is not.
    Demote,
    /// Deadline hopeless and the class is Background: fail fast with a
    /// `shed:` error instead of burning a pass on work nobody can use in
    /// time.
    Shed,
}

/// Deadline shedding: decide whether a request whose **soft deadline is
/// already hopeless at batch-formation time** should still execute.
///
/// `est_cycles` is the closed-form service estimate for the request's
/// shape on the worker's cluster
/// ([`crate::analytical::cluster::estimate_cluster`]); at the simulated
/// 1 GHz clock one cycle is one nanosecond, so the deadline is hopeless
/// when the remaining headroom (µs; negative = overdue) is below
/// `est_cycles / 1000`. The estimate deliberately ignores host queueing —
/// it is a *lower bound* on service, so a shed decision is conservative:
/// anything shed could not have met its deadline even on an idle
/// coordinator. Opt-in via `CoordinatorConfig::shed`; a soft deadline
/// remains a pure ordering hint when shedding is off.
pub fn shed_verdict(priority: Priority, deadline_us: i64, est_cycles: u64) -> ShedVerdict {
    if deadline_us == i64::MAX {
        return ShedVerdict::Keep; // no deadline
    }
    let est_us = i64::try_from(est_cycles / 1_000).unwrap_or(i64::MAX);
    if deadline_us >= est_us {
        return ShedVerdict::Keep;
    }
    match priority {
        Priority::Background => ShedVerdict::Shed,
        Priority::Interactive | Priority::Batch => ShedVerdict::Demote,
    }
}

/// One window's batch plan: the batches in deterministic service order
/// plus the aging bookkeeping.
#[derive(Debug, Clone)]
pub struct WindowPlan {
    /// Batches in dispatch (service) order.
    pub batches: Vec<Batch>,
    /// Requests whose class was promoted at least one level by aging.
    pub promotions: u64,
    /// Indices into the window's `reqs` of the promoted requests, in
    /// window (arrival) order — `promoted.len() == promotions`. Lets the
    /// router attribute each promotion to its ticket in the trace.
    pub promoted: Vec<usize>,
}

/// Form batches over a window of pending requests with all-default lanes
/// (FIFO visit order). Thin shim over [`plan_batches`].
///
/// Batch *membership* is identical to the pre-priority batcher; the
/// emission order differs in one corner: when a fusion key overflows
/// into multiple bins, overflow bins are emitted at the position they
/// were opened (interleaved with other keys) instead of grouped behind
/// the key's first bin. Membership, modes and capacities are unchanged,
/// so outputs and per-request accounting cannot differ — only which
/// round-robin worker a batch lands on may.
pub fn form_batches(reqs: &[MatmulRequest]) -> Vec<Batch> {
    plan_batches(reqs, &vec![Lane::default(); reqs.len()], 0).batches
}

/// Priority-aware batch formation (see the module docs for the policy).
///
/// `lanes[i]` describes the scheduling lane of `reqs[i]`;
/// `aging_us == 0` disables aging promotion. Batch members still index
/// into `reqs` in its original order; only the *visit* order (and hence
/// bin packing and batch emission order) follows the service order.
pub fn plan_batches(reqs: &[MatmulRequest], lanes: &[Lane], aging_us: u64) -> WindowPlan {
    use std::collections::HashMap;
    assert_eq!(reqs.len(), lanes.len(), "one lane per request");

    // Deterministic service order: (effective class, deadline, FIFO).
    // The sort is stable and the window is in arrival order, so equal
    // keys keep FIFO order; aging subtracts one class per full interval
    // waited, flooring at Interactive.
    let mut promotions = 0u64;
    let mut promoted: Vec<usize> = Vec::new();
    let ranked: Vec<usize> = {
        let mut keyed: Vec<(usize, i64, usize)> = Vec::with_capacity(reqs.len());
        for (idx, lane) in lanes.iter().enumerate() {
            let base = lane.priority.rank();
            let promote = if aging_us > 0 { (lane.age_us / aging_us) as usize } else { 0 };
            let eff = base.saturating_sub(promote);
            if eff < base {
                promotions += 1;
                promoted.push(idx);
            }
            // Promotion lifts the class only; within a class the uniform
            // deadline→FIFO order applies to promoted and native work
            // alike (an urgency bonus for promoted work would invert
            // same-age ordering under overload). A promoted request can
            // still sort behind deadline-carrying natives of its new
            // class, but never past its own window — windows dispatch
            // FIFO, so overdue work is served within a bounded number of
            // batches regardless.
            keyed.push((eff, lane.deadline_us, idx));
        }
        keyed.sort_by_key(|&(eff, dl, _)| (eff, dl));
        keyed.into_iter().map(|(_, _, idx)| idx).collect()
    };

    // Greedy bin packing per fusion key, visiting requests in service
    // order; batches are emitted in the order their bin was opened, so
    // the plan's dispatch order respects the service order of each
    // batch's first (highest-ranked) member.
    let mut out: Vec<Batch> = Vec::new();
    let mut bins: HashMap<Key, Vec<usize>> = HashMap::new(); // key -> indices into `out`
    for idx in ranked {
        let r = &reqs[idx];
        let mode = select_mode(r.weight_bits, r.act_act);
        let key = Key {
            input_id: r.input_id,
            a_ptr: std::sync::Arc::as_ptr(&r.a) as usize,
            mode,
            a_rows: r.a.rows(),
            a_cols: r.a.cols(),
            b_cols: r.bs[0].cols(),
            act_act: r.act_act,
        };
        let cap = mode.interleave_factor();
        // greedy: drop into the first open bin with room for all of this
        // request's matrices (requests are never split across passes)
        let need = r.bs.len();
        let entry = bins.entry(key).or_default();
        let slot = entry.iter().copied().find(|&b| out[b].matrices + need <= cap);
        match slot {
            Some(b) => {
                out[b].members.push(idx);
                out[b].matrices += need;
                out[b].fused = true;
            }
            None => {
                entry.push(out.len());
                out.push(Batch {
                    mode,
                    members: vec![idx],
                    matrices: need,
                    fused: need > 1,
                    runtime_interleave: r.act_act,
                });
            }
        }
    }
    WindowPlan { batches: out, promotions, promoted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Mat;
    use crate::testutil::{check, Rng};
    use std::sync::Arc;

    fn mk_shared(
        id: u64,
        input_id: u64,
        a: &Arc<Mat>,
        bits: u32,
        act_act: bool,
        n_b: usize,
    ) -> MatmulRequest {
        let mut rng = Rng::seeded(id + 100);
        let shape = a.rows();
        MatmulRequest {
            id,
            input_id,
            a: a.clone(),
            bs: (0..n_b)
                .map(|_| Arc::new(Mat::random(&mut rng, shape, shape, bits)))
                .collect(),
            weight_bits: bits,
            act_act,
            tag: String::new(),
        }
    }

    fn mk(
        id: u64,
        input_id: u64,
        bits: u32,
        act_act: bool,
        n_b: usize,
        shape: usize,
    ) -> MatmulRequest {
        // deterministic shared input per (input_id, shape): same Arc is
        // required for fusion, so tests build them from a small pool
        use std::collections::HashMap;
        use std::sync::{Mutex, OnceLock};
        static POOL: OnceLock<Mutex<HashMap<(u64, usize), Arc<Mat>>>> = OnceLock::new();
        let pool = POOL.get_or_init(|| Mutex::new(HashMap::new()));
        let a = pool
            .lock()
            .unwrap()
            .entry((input_id, shape))
            .or_insert_with(|| {
                let mut rng = Rng::seeded(input_id * 31 + shape as u64);
                Arc::new(Mat::random(&mut rng, shape, shape, 8))
            })
            .clone();
        mk_shared(id, input_id, &a, bits, act_act, n_b)
    }

    #[test]
    fn qkv_fuses_into_one_batch() {
        // three 2-bit single-B requests off the same input → one 3-matrix pass
        let reqs =
            vec![mk(1, 42, 2, false, 1, 8), mk(2, 42, 2, false, 1, 8), mk(3, 42, 2, false, 1, 8)];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].members, vec![0, 1, 2]);
        assert_eq!(batches[0].matrices, 3);
        assert!(batches[0].fused);
        assert_eq!(batches[0].mode, PrecisionMode::W2);
    }

    #[test]
    fn capacity_respected() {
        // five 2-bit requests: 4 + 1
        let reqs: Vec<_> = (0..5).map(|i| mk(i, 9, 2, false, 1, 8)).collect();
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].matrices, 4);
        assert_eq!(batches[1].matrices, 1);
        // 4-bit capacity is 2
        let reqs: Vec<_> = (0..3).map(|i| mk(i, 9, 4, false, 1, 8)).collect();
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn incompatible_requests_never_mix() {
        let reqs = vec![
            mk(1, 1, 2, false, 1, 8),  // input 1
            mk(2, 2, 2, false, 1, 8),  // different input
            mk(3, 1, 4, false, 1, 8),  // different mode
            mk(4, 1, 2, true, 1, 8),   // act-act (W8)
            mk(5, 1, 2, false, 1, 16), // different shape
        ];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 5, "{batches:?}");
    }

    #[test]
    fn multi_b_requests_count_matrices() {
        // a 3-matrix request + a 1-matrix request fit one 2-bit pass
        let reqs = vec![mk(1, 5, 2, false, 3, 8), mk(2, 5, 2, false, 1, 8)];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].matrices, 4);
        // but a 2-matrix request cannot join it
        let reqs = vec![mk(1, 5, 2, false, 3, 8), mk(2, 5, 2, false, 2, 8)];
        let batches = form_batches(&reqs);
        assert_eq!(batches.len(), 2);
    }

    #[test]
    fn act_act_batches_flag_runtime_interleave() {
        let reqs = vec![mk(1, 3, 8, true, 1, 8)];
        let batches = form_batches(&reqs);
        assert!(batches[0].runtime_interleave);
        assert_eq!(batches[0].mode, PrecisionMode::W8);
    }

    /// Distinct-key requests (distinct inputs) so batch order mirrors
    /// request order 1:1 — isolates the ordering policy from fusion.
    fn solo(i: u64) -> MatmulRequest {
        mk(i, 1000 + i, 2, false, 1, 8)
    }

    fn lane(p: Priority, deadline_us: i64, age_us: u64) -> Lane {
        Lane { priority: p, deadline_us, age_us }
    }

    #[test]
    fn service_order_is_priority_then_deadline_then_fifo() {
        let reqs: Vec<_> = (0..6).map(solo).collect();
        let lanes = vec![
            lane(Priority::Background, i64::MAX, 0), // 0
            lane(Priority::Interactive, 500, 0),     // 1: tight deadline
            lane(Priority::Batch, i64::MAX, 0),      // 2
            lane(Priority::Interactive, i64::MAX, 0), // 3: no deadline
            lane(Priority::Interactive, 500, 0),     // 4: deadline tie -> FIFO after 1
            lane(Priority::Batch, 100, 0),           // 5: deadline beats 2
        ];
        let plan = plan_batches(&reqs, &lanes, 0);
        let order: Vec<usize> = plan.batches.iter().map(|b| b.members[0]).collect();
        assert_eq!(order, vec![1, 4, 3, 5, 2, 0]);
        assert_eq!(plan.promotions, 0);
    }

    #[test]
    fn seeded_windows_reproduce_identical_batch_orders() {
        let mut rng = Rng::seeded(411);
        let reqs: Vec<_> = (0..16)
            .map(|i| mk(i, rng.below(4) as u64, *rng.choose(&[2u32, 4, 8]), false, 1, 8))
            .collect();
        let lanes: Vec<_> = (0..16)
            .map(|_| {
                lane(
                    *rng.choose(&Priority::ALL),
                    *rng.choose(&[100i64, 5_000, i64::MAX]),
                    rng.below(60_000) as u64,
                )
            })
            .collect();
        let a = plan_batches(&reqs, &lanes, 20_000);
        let b = plan_batches(&reqs, &lanes, 20_000);
        assert_eq!(a.batches, b.batches, "planning must be deterministic");
        assert_eq!(a.promotions, b.promotions);
    }

    #[test]
    fn aging_promotes_overdue_background_ahead_of_fresh_interactive() {
        let reqs: Vec<_> = (0..3).map(solo).collect();
        // background has waited 2 full aging intervals -> Interactive
        // rank, and FIFO (arrival order) puts it ahead of the fresh one
        let lanes = vec![
            lane(Priority::Background, i64::MAX, 45_000),
            lane(Priority::Interactive, i64::MAX, 0),
            lane(Priority::Batch, i64::MAX, 0),
        ];
        let plan = plan_batches(&reqs, &lanes, 20_000);
        let order: Vec<usize> = plan.batches.iter().map(|b| b.members[0]).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert_eq!(plan.promotions, 1);
        // promotion lifts the class only: a deadline-carrying native of
        // the promoted class still sorts first (uniform deadline→FIFO
        // within a class — an urgency bonus would invert same-age
        // ordering under overload), while the promoted request beats
        // deadline-less natives by FIFO
        let lanes = vec![
            lane(Priority::Background, i64::MAX, 45_000),
            lane(Priority::Interactive, 500, 0),
            lane(Priority::Batch, i64::MAX, 0),
        ];
        let plan = plan_batches(&reqs, &lanes, 20_000);
        let order: Vec<usize> = plan.batches.iter().map(|b| b.members[0]).collect();
        assert_eq!(order, vec![1, 0, 2], "deadline-carrying native first, then promoted by FIFO");
        // one interval only promotes one level: Background -> Batch
        let lanes = vec![
            lane(Priority::Background, i64::MAX, 25_000),
            lane(Priority::Interactive, i64::MAX, 0),
            lane(Priority::Batch, i64::MAX, 0),
        ];
        let plan = plan_batches(&reqs, &lanes, 20_000);
        let order: Vec<usize> = plan.batches.iter().map(|b| b.members[0]).collect();
        assert_eq!(order, vec![1, 0, 2], "aged background ties Batch, FIFO wins");
        // aging disabled: base classes only
        let plan = plan_batches(&reqs, &lanes, 0);
        let order: Vec<usize> = plan.batches.iter().map(|b| b.members[0]).collect();
        assert_eq!(order, vec![1, 2, 0]);
        assert_eq!(plan.promotions, 0);
    }

    #[test]
    fn shed_verdicts_by_class_and_headroom() {
        use ShedVerdict::*;
        // no deadline: always kept, however large the estimate
        assert_eq!(shed_verdict(Priority::Background, i64::MAX, u64::MAX), Keep);
        // achievable: 2 ms headroom vs 1 ms estimated service
        assert_eq!(shed_verdict(Priority::Background, 2_000, 1_000_000), Keep);
        // exact boundary is achievable (>=)
        assert_eq!(shed_verdict(Priority::Interactive, 1_000, 1_000_000), Keep);
        // hopeless: overdue or shorter than the service estimate
        assert_eq!(shed_verdict(Priority::Background, 500, 1_000_000), Shed);
        assert_eq!(shed_verdict(Priority::Background, -10, 1), Shed);
        assert_eq!(shed_verdict(Priority::Interactive, 500, 1_000_000), Demote);
        assert_eq!(shed_verdict(Priority::Batch, -10, 1), Demote);
        // sub-µs estimates truncate to 0: any non-negative headroom keeps
        assert_eq!(shed_verdict(Priority::Background, 0, 999), Keep);
    }

    #[test]
    fn priority_never_breaks_fusion_invariants() {
        // mixed-class Q/K/V off one input still fuses into one batch when
        // the classes tie after ordering has run (same key, capacity 4)
        let reqs =
            vec![mk(1, 77, 2, false, 1, 8), mk(2, 77, 2, false, 1, 8), mk(3, 77, 2, false, 1, 8)];
        let lanes = vec![
            lane(Priority::Batch, i64::MAX, 0),
            lane(Priority::Interactive, i64::MAX, 0),
            lane(Priority::Background, i64::MAX, 0),
        ];
        let plan = plan_batches(&reqs, &lanes, 0);
        assert_eq!(plan.batches.len(), 1, "one fusion key -> one batch");
        // visited in service order: Interactive member opened the bin
        assert_eq!(plan.batches[0].members, vec![1, 0, 2]);
        assert_eq!(plan.batches[0].matrices, 3);
    }

    /// Independent oracle: the pre-priority batcher (greedy first-fit
    /// per fusion key, FIFO visit order, bins grouped behind their key).
    /// Reimplemented here so the shim test compares against the old
    /// algorithm, not against itself.
    fn legacy_form_batches(reqs: &[MatmulRequest]) -> Vec<Batch> {
        use std::collections::HashMap;
        let mut bins: HashMap<(u64, usize, PrecisionMode, usize, usize, usize, bool), Vec<Batch>> =
            HashMap::new();
        let mut order = Vec::new();
        for (idx, r) in reqs.iter().enumerate() {
            let mode = select_mode(r.weight_bits, r.act_act);
            let key = (
                r.input_id,
                Arc::as_ptr(&r.a) as usize,
                mode,
                r.a.rows(),
                r.a.cols(),
                r.bs[0].cols(),
                r.act_act,
            );
            let cap = mode.interleave_factor();
            let entry = bins.entry(key).or_insert_with(|| {
                order.push(key);
                Vec::new()
            });
            let need = r.bs.len();
            match entry.iter_mut().find(|b| b.matrices + need <= cap) {
                Some(b) => {
                    b.members.push(idx);
                    b.matrices += need;
                    b.fused = true;
                }
                None => entry.push(Batch {
                    mode,
                    members: vec![idx],
                    matrices: need,
                    fused: need > 1,
                    runtime_interleave: r.act_act,
                }),
            }
        }
        let mut out = Vec::new();
        for key in order {
            out.extend(bins.remove(&key).unwrap());
        }
        out
    }

    #[test]
    fn default_lanes_match_the_legacy_batcher() {
        check(
            "plan-default-lanes-fifo",
            721,
            30,
            |rng| {
                let n = 1 + rng.below(16);
                (0..n as u64)
                    .map(|i| {
                        let bits = *rng.choose(&[2u32, 4, 8]);
                        mk(i, rng.below(3) as u64, bits, false, 1 + rng.below(2), 8)
                    })
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let mut shim = form_batches(reqs);
                if shim != plan_batches(reqs, &vec![Lane::default(); reqs.len()], 0).batches {
                    return Err("form_batches must be the default-lane plan".into());
                }
                // vs the old algorithm: identical batch *membership*
                // (emission order may differ only in the documented
                // key-overflow corner, so compare order-normalized)
                let mut legacy = legacy_form_batches(reqs);
                shim.sort_by_key(|b| b.members[0]);
                legacy.sort_by_key(|b| b.members[0]);
                if shim != legacy {
                    return Err(format!("shim {shim:?} != legacy batcher {legacy:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn partition_property() {
        // every request lands in exactly one batch; constraints hold
        check(
            "batcher-partition",
            701,
            40,
            |rng| {
                let n = 1 + rng.below(20);
                (0..n as u64)
                    .map(|i| {
                        let bits = *rng.choose(&[2u32, 4, 8]);
                        let act_act = rng.below(4) == 0;
                        let cap = select_mode(bits, act_act).interleave_factor();
                        mk(i, rng.below(3) as u64, bits, act_act, 1 + rng.below(cap), 8)
                    })
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let batches = form_batches(reqs);
                let mut seen = vec![0usize; reqs.len()];
                for b in &batches {
                    if b.matrices > b.mode.interleave_factor() {
                        return Err(format!("overfull batch {b:?}"));
                    }
                    let total: usize = b.members.iter().map(|&i| reqs[i].bs.len()).sum();
                    if total != b.matrices {
                        return Err("matrix count mismatch".into());
                    }
                    let first = &reqs[b.members[0]];
                    for &i in &b.members {
                        seen[i] += 1;
                        let r = &reqs[i];
                        if r.input_id != first.input_id
                            || r.act_act != first.act_act
                            || select_mode(r.weight_bits, r.act_act) != b.mode
                        {
                            return Err(format!("mixed batch {b:?}"));
                        }
                    }
                }
                if seen.iter().any(|&s| s != 1) {
                    return Err(format!("not a partition: {seen:?}"));
                }
                Ok(())
            },
        );
    }
}
