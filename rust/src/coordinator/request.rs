//! Request / response types of the coordinator.

use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::dataflow::Mat;
use crate::sim::memory::MemoryCounters;

/// Monotonic request identifier.
pub type RequestId = u64;

/// A matrix-multiplication request: `C_s = A · B_s` for one or more weight
/// matrices sharing the activation matrix `A`.
#[derive(Debug, Clone)]
pub struct MatmulRequest {
    /// Assigned by the coordinator on submit.
    pub id: RequestId,
    /// Identifier of the shared input operand. Requests with equal
    /// `input_id` (and compatible shape/precision) may be fused by the
    /// batcher into one multi-matrix pass. Producers that reuse an
    /// activation (e.g. Q/K/V off one `X`) must tag it consistently.
    pub input_id: u64,
    /// The activation matrix (int8 values).
    pub a: Arc<Mat>,
    /// Weight matrices (entries must fit `weight_bits`).
    pub bs: Vec<Arc<Mat>>,
    /// Weight bit-width as quantized (1–8; 1 maps to the 2-bit mode).
    pub weight_bits: u32,
    /// Activation-to-activation workload (dynamic operand): forces 8b×8b
    /// and runtime (multi-bank) interleaving.
    pub act_act: bool,
    /// Free-form tag for metrics/debugging (stage name etc.).
    pub tag: String,
}

impl MatmulRequest {
    /// Basic shape/content validation; returns a reason when malformed.
    pub fn validate(&self) -> Result<(), String> {
        if self.bs.is_empty() {
            return Err("no weight matrices".into());
        }
        if !(1..=8).contains(&self.weight_bits) {
            return Err(format!("weight_bits {} out of 1..=8", self.weight_bits));
        }
        let (r, c) = (self.bs[0].rows(), self.bs[0].cols());
        for (i, b) in self.bs.iter().enumerate() {
            if b.rows() != r || b.cols() != c {
                return Err(format!("weight matrix {i} shape mismatch"));
            }
            if self.a.cols() != b.rows() {
                return Err(format!(
                    "inner dims: A is {}x{}, B{i} is {}x{}",
                    self.a.rows(),
                    self.a.cols(),
                    b.rows(),
                    b.cols()
                ));
            }
        }
        Ok(())
    }
}

/// Per-request accounting returned with the outputs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ResponseMetrics {
    /// Simulated accelerator cycles attributed to this request.
    pub cycles: u64,
    /// Simulated energy (J) attributed to this request.
    pub energy_j: f64,
    /// Simulated memory traffic attributed to this request.
    pub memory: MemoryCounters,
    /// Stationary-tile passes executed for this request.
    pub passes: u64,
    /// Host wall-clock the request waited in the queue (seconds).
    pub queue_seconds: f64,
    /// Host wall-clock spent executing (seconds).
    pub service_seconds: f64,
    /// Whether the request was fused into a shared-input batch.
    pub batched: bool,
}

/// Completion message for one request.
#[derive(Debug)]
pub struct RequestOutcome {
    /// The request id.
    pub id: RequestId,
    /// Output matrices (one per weight matrix), or an error string.
    pub result: Result<Vec<Mat>, String>,
    /// Accounting (valid also for failed requests where meaningful).
    pub metrics: ResponseMetrics,
}

/// Internal envelope: request + response channel + enqueue timestamp.
pub(crate) struct Envelope {
    pub req: MatmulRequest,
    pub reply: Sender<RequestOutcome>,
    pub enqueued: std::time::Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn req(bits: u32) -> MatmulRequest {
        let mut rng = Rng::seeded(1);
        MatmulRequest {
            id: 1,
            input_id: 7,
            a: Arc::new(Mat::random(&mut rng, 4, 4, 8)),
            bs: vec![Arc::new(Mat::random(&mut rng, 4, 4, bits.min(8)))],
            weight_bits: bits,
            act_act: false,
            tag: "test".into(),
        }
    }

    #[test]
    fn validation_accepts_well_formed() {
        assert!(req(8).validate().is_ok());
        assert!(req(2).validate().is_ok());
    }

    #[test]
    fn validation_rejects_malformed() {
        let mut r = req(8);
        r.bs.clear();
        assert!(r.validate().is_err());
        let mut r = req(8);
        r.weight_bits = 9;
        assert!(r.validate().is_err());
        let mut rng = Rng::seeded(2);
        let mut r = req(8);
        r.bs.push(Arc::new(Mat::random(&mut rng, 3, 4, 8)));
        assert!(r.validate().is_err());
        let mut r = req(8);
        r.a = Arc::new(Mat::zeros(4, 5));
        assert!(r.validate().is_err());
    }
}
