//! Request / response types of the coordinator.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use crate::dataflow::Mat;
use crate::quant::value_range;
use crate::sim::memory::MemoryCounters;

use super::client::Priority;
use super::precision::select_mode;

/// Monotonic request identifier.
pub type RequestId = u64;

/// A matrix-multiplication request: `C_s = A · B_s` for one or more weight
/// matrices sharing the activation matrix `A`.
#[derive(Debug, Clone)]
pub struct MatmulRequest {
    /// Assigned by the coordinator on submit.
    pub id: RequestId,
    /// Identifier of the shared input operand. Requests with equal
    /// `input_id` (and compatible shape/precision) may be fused by the
    /// batcher into one multi-matrix pass. Producers that reuse an
    /// activation (e.g. Q/K/V off one `X`) must tag it consistently.
    pub input_id: u64,
    /// The activation matrix (int8 values).
    pub a: Arc<Mat>,
    /// Weight matrices (entries must fit `weight_bits`).
    pub bs: Vec<Arc<Mat>>,
    /// Weight bit-width as quantized (1–8; 1 maps to the 2-bit mode).
    pub weight_bits: u32,
    /// Activation-to-activation workload (dynamic operand): forces 8b×8b
    /// and runtime (multi-bank) interleaving.
    pub act_act: bool,
    /// Free-form tag for metrics/debugging (stage name etc.).
    pub tag: String,
}

impl MatmulRequest {
    /// Shape *and content* validation; returns a reason when malformed.
    ///
    /// Content rules (this is the admission stage — a request that passes
    /// here can never fail the pack-time range check deep inside a
    /// worker):
    /// * activation-to-activation requests must declare `weight_bits == 8`
    ///   (dynamic operands are never pre-quantized below 8 bits; the
    ///   precision selector pins 8b×8b for them),
    /// * every weight entry must fit the *selected mode's* width — the
    ///   signed range of `select_mode(weight_bits, act_act).weight_bits()`
    ///   bits, so `weight_bits = 1` (BitNet ternary) checks against the
    ///   2-bit mode it maps to,
    /// * every activation entry must fit the 8-bit operand width.
    pub fn validate(&self) -> Result<(), String> {
        if self.bs.is_empty() {
            return Err("no weight matrices".into());
        }
        if !(1..=8).contains(&self.weight_bits) {
            return Err(format!("weight_bits {} out of 1..=8", self.weight_bits));
        }
        if self.act_act && self.weight_bits != 8 {
            return Err(format!(
                "act_act requests run 8b\u{d7}8b but declared weight_bits {}",
                self.weight_bits
            ));
        }
        let mode_bits = select_mode(self.weight_bits, self.act_act).weight_bits();
        let (wlo, whi) = value_range(mode_bits);
        let (alo, ahi) = value_range(8);
        if let Some(bad) = self.a.as_slice().iter().find(|&&v| !(alo..=ahi).contains(&v)) {
            return Err(format!("activation entry {bad} out of 8-bit range {alo}..={ahi}"));
        }
        let (r, c) = (self.bs[0].rows(), self.bs[0].cols());
        for (i, b) in self.bs.iter().enumerate() {
            if b.rows() != r || b.cols() != c {
                return Err(format!("weight matrix {i} shape mismatch"));
            }
            if self.a.cols() != b.rows() {
                return Err(format!(
                    "inner dims: A is {}x{}, B{i} is {}x{}",
                    self.a.rows(),
                    self.a.cols(),
                    b.rows(),
                    b.cols()
                ));
            }
            if let Some(bad) = b.as_slice().iter().find(|&&v| !(wlo..=whi).contains(&v)) {
                return Err(format!(
                    "weight matrix {i} entry {bad} does not fit the {mode_bits}-bit mode \
                     range {wlo}..={whi}"
                ));
            }
        }
        Ok(())
    }
}

/// Per-request accounting returned with the outputs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ResponseMetrics {
    /// Simulated accelerator cycles attributed to this request.
    pub cycles: u64,
    /// Simulated energy (J) attributed to this request.
    pub energy_j: f64,
    /// Simulated memory traffic attributed to this request.
    pub memory: MemoryCounters,
    /// Stationary-tile passes executed for this request.
    pub passes: u64,
    /// Host wall-clock the request waited in the queue (seconds).
    pub queue_seconds: f64,
    /// Host wall-clock spent executing (seconds).
    pub service_seconds: f64,
    /// Host wall-clock the batch spent in the prepare stage (seconds).
    /// Stage timings below are measured from the same clock reads the
    /// trace spans use, so a ticket's trace and its `ResponseMetrics`
    /// cannot disagree; 0.0 when the stage did not run (direct scheduler
    /// use, raw batches prepared inline on the worker).
    pub prepare_seconds: f64,
    /// Host wall-clock between the batch entering the balance fabric
    /// (injector or a worker deque) and a worker popping it (seconds).
    pub fabric_seconds: f64,
    /// Host wall-clock share of the execute stage attributed to this
    /// request (seconds).
    pub execute_seconds: f64,
    /// Whether the request was fused into a shared-input batch.
    pub batched: bool,
    /// Global sequence number (from 1) of the batch this request
    /// executed in — assigned by the router at batch-formation time, so
    /// it exposes the coordinator's deterministic
    /// (priority/deadline/aging) service order to callers and tests.
    /// 0 means the request never went through the router (direct
    /// scheduler use).
    pub batch_seq: u64,
}

/// Error prefix of outcomes failed fast by the deadline-shedding policy
/// (see `batcher::shed_verdict`): a `shed:` error means the request never
/// executed because its soft deadline was already hopeless at
/// batch-formation time. Kept for log greps — typed matchers should use
/// [`RequestError::Shed`].
pub const SHED_ERROR_PREFIX: &str = "shed:";

/// Typed failure classes of a request's lifetime. Replaces the former
/// stringly-typed `Result<_, String>` signaling: matchers switch on the
/// variant while `Display` keeps the historical strings byte-compatible
/// ([`RequestError::Shed`] still renders behind [`SHED_ERROR_PREFIX`];
/// execute-stage messages render verbatim), so log greps survive the
/// migration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// Admission validation rejected the request contents. Normally
    /// surfaced synchronously by `Client::submit`; carried here so remote
    /// (net-tier) submissions can report it through the same taxonomy.
    Validation(String),
    /// Failed fast by the deadline-shedding policy — the request never
    /// executed because its soft deadline was already hopeless at
    /// batch-formation time.
    Shed {
        /// Verdict detail (estimated service vs remaining headroom).
        detail: String,
    },
    /// Killed by `Ticket::cancel` (or a net-tier Cancel frame) before it
    /// reached execution.
    Cancelled,
    /// An operand failed the executed mode's range check at pack time
    /// inside a worker (admission validation makes this unreachable via
    /// `Client::submit`; direct scheduler use can still trip it).
    RangeCheck {
        /// Index of the offending weight matrix within its request.
        set_index: usize,
        /// The scheduler's full message, rendered verbatim by `Display`.
        detail: String,
    },
    /// The coordinator (or the serving tier fronting it) shut down before
    /// the request completed.
    Shutdown,
    /// Any other execution failure, carrying the scheduler's message.
    Execution(String),
}

impl RequestError {
    /// Classify a stringified execute-stage error into the typed
    /// taxonomy. Range-check failures keep their weight-set index
    /// machine-readable: the functional/cycle backends report
    /// `weight matrix {i} value {v} out of {w}-bit range ...` (possibly
    /// behind `shard {s}:` context), which parses into
    /// [`RequestError::RangeCheck`]; everything else lands in
    /// [`RequestError::Execution`].
    pub fn from_execution(msg: String) -> RequestError {
        if let Some(pos) = msg.find("weight matrix ") {
            let rest = &msg[pos + "weight matrix ".len()..];
            let digits: &str =
                &rest[..rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len())];
            if !digits.is_empty() && rest[digits.len()..].starts_with(" value ") {
                if let Ok(set_index) = digits.parse() {
                    return RequestError::RangeCheck { set_index, detail: msg };
                }
            }
        }
        RequestError::Execution(msg)
    }
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Validation(reason) => write!(f, "invalid request: {reason}"),
            RequestError::Shed { detail } => write!(f, "{SHED_ERROR_PREFIX} {detail}"),
            RequestError::Cancelled => f.write_str("cancelled"),
            RequestError::RangeCheck { detail, .. } => f.write_str(detail),
            RequestError::Shutdown => f.write_str("coordinator stopped"),
            RequestError::Execution(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for RequestError {}

/// Completion message for one request.
#[derive(Debug)]
pub struct RequestOutcome {
    /// The request id.
    pub id: RequestId,
    /// Output matrices (one per weight matrix), or a typed failure.
    pub result: Result<Vec<Mat>, RequestError>,
    /// Accounting (valid also for failed requests where meaningful).
    pub metrics: ResponseMetrics,
}

impl RequestOutcome {
    /// Whether this request was shed (failed fast on a hopeless soft
    /// deadline) rather than executed.
    pub fn was_shed(&self) -> bool {
        matches!(self.result, Err(RequestError::Shed { .. }))
    }
}

/// Internal envelope: request + response channel + scheduling lane
/// (class, soft deadline, enqueue timestamp).
pub(crate) struct Envelope {
    pub req: MatmulRequest,
    pub reply: Sender<RequestOutcome>,
    pub enqueued: Instant,
    /// Service class the request was submitted under.
    pub priority: Priority,
    /// Absolute soft deadline (submit time + the requested offset).
    pub deadline: Option<Instant>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn req(bits: u32) -> MatmulRequest {
        let mut rng = Rng::seeded(1);
        MatmulRequest {
            id: 1,
            input_id: 7,
            a: Arc::new(Mat::random(&mut rng, 4, 4, 8)),
            bs: vec![Arc::new(Mat::random(&mut rng, 4, 4, bits.min(8)))],
            weight_bits: bits,
            act_act: false,
            tag: "test".into(),
        }
    }

    #[test]
    fn validation_accepts_well_formed() {
        assert!(req(8).validate().is_ok());
        assert!(req(2).validate().is_ok());
    }

    #[test]
    fn validation_rejects_malformed() {
        let mut r = req(8);
        r.bs.clear();
        assert!(r.validate().is_err());
        let mut r = req(8);
        r.weight_bits = 9;
        assert!(r.validate().is_err());
        let mut rng = Rng::seeded(2);
        let mut r = req(8);
        r.bs.push(Arc::new(Mat::random(&mut rng, 3, 4, 8)));
        assert!(r.validate().is_err());
        let mut r = req(8);
        r.a = Arc::new(Mat::zeros(4, 5));
        assert!(r.validate().is_err());
    }

    /// Regression: the doc always claimed "entries must fit `weight_bits`"
    /// but `validate` never looked at matrix contents — an out-of-range
    /// weight sailed through admission and only failed at pack time deep
    /// inside a worker.
    #[test]
    fn validation_checks_weight_entries_fit_the_mode() {
        // 2-bit mode range is -2..=1: a 5 must be rejected up front
        let mut r = req(2);
        let mut w = (*r.bs[0]).clone();
        w.set(1, 1, 5);
        r.bs[0] = Arc::new(w);
        let err = r.validate().unwrap_err();
        assert!(err.contains("does not fit"), "{err}");
        // weight_bits = 1 maps to the 2-bit mode: BitNet ternary {-1,0,1}
        // must pass even though +1 does not fit a 1-bit signed field
        let mut r = req(1);
        r.bs[0] = Arc::new(Mat::from_vec(
            4,
            4,
            vec![-1, 0, 1, -1, 0, 1, -1, 0, 1, -1, 0, 1, -1, 0, 1, 0],
        ));
        assert!(r.validate().is_ok());
        // ... but -3 exceeds even the 2-bit mode range
        let mut r = req(1);
        r.bs[0] = Arc::new(Mat::from_vec(4, 4, vec![-3; 16]));
        assert!(r.validate().is_err());
        // activations are 8-bit operands regardless of mode
        let mut r = req(8);
        let mut a = (*r.a).clone();
        a.set(0, 0, 300);
        r.a = Arc::new(a);
        let err = r.validate().unwrap_err();
        assert!(err.contains("activation"), "{err}");
    }

    #[test]
    fn request_error_display_is_byte_compatible_with_the_legacy_strings() {
        // the shed class keeps its greppable prefix exactly
        let shed = RequestError::Shed { detail: "soft deadline hopeless".into() };
        assert_eq!(shed.to_string(), format!("{SHED_ERROR_PREFIX} soft deadline hopeless"));
        assert!(shed.to_string().starts_with(SHED_ERROR_PREFIX));
        // execute-stage messages render verbatim
        let msg = "shard 0: weight matrix 2 value 9 out of 2-bit range -2..=1";
        assert_eq!(RequestError::from_execution(msg.into()).to_string(), msg);
        assert_eq!(RequestError::Execution("boom".into()).to_string(), "boom");
        assert_eq!(RequestError::Cancelled.to_string(), "cancelled");
        assert_eq!(RequestError::Shutdown.to_string(), "coordinator stopped");
        assert_eq!(
            RequestError::Validation("no weight matrices".into()).to_string(),
            "invalid request: no weight matrices"
        );
    }

    #[test]
    fn from_execution_classifies_range_checks_with_their_set_index() {
        let msg = "shard 3: weight matrix 2 value 9 out of 2-bit range -2..=1";
        match RequestError::from_execution(msg.into()) {
            RequestError::RangeCheck { set_index, detail } => {
                assert_eq!(set_index, 2);
                assert!(detail.contains("out of 2-bit range"));
            }
            other => panic!("expected RangeCheck, got {other:?}"),
        }
        // admission-style messages ("entry", not "value") and plain
        // failures stay in the Execution catch-all
        assert!(matches!(
            RequestError::from_execution("weight matrix 1 shape mismatch".into()),
            RequestError::Execution(_)
        ));
        assert!(matches!(
            RequestError::from_execution("cluster worker pool disconnected".into()),
            RequestError::Execution(_)
        ));
    }

    /// Regression: `act_act` forces the 8b×8b mode, so a request that
    /// declares a narrower weight width is inconsistent and must be
    /// rejected at admission.
    #[test]
    fn validation_requires_act_act_to_declare_8_bits() {
        for bits in [1u32, 2, 4, 7] {
            let mut r = req(8);
            r.act_act = true;
            r.weight_bits = bits;
            let err = r.validate().unwrap_err();
            assert!(err.contains("act_act"), "{err}");
        }
        let mut r = req(8);
        r.act_act = true;
        assert!(r.validate().is_ok());
    }
}
