//! DeepScaleTool-style technology normalization to 22 nm (Table II).
//!
//! The paper normalizes competitor metrics to 22 nm with DeepScaleTool
//! [39, 40]. The tool itself is not available offline; per the
//! substitution policy the per-node factors below are **re-derived from
//! the paper's own before/after pairs** in Table II:
//!
//! | node  | derived from            | area-eff ×        | energy-eff ×      |
//! |-------|-------------------------|-------------------|-------------------|
//! | 7 nm  | TPU v4i 0.345→0.017,    | 0.0493            | 0.439             |
//! |       | 0.786→0.345             |                   |                   |
//! | 40 nm | DTQAtten 0.676→2.302,   | 3.23 (geo-mean of | 1.52              |
//! |       | DTATrans 0.979→2.984    | 3.405 / 3.048)    |                   |
//! | 65 nm | BitSystolic 0.1→0.935,  | 9.35              | 7.10              |
//! |       | 26.7/4→47.412           |                   |                   |
//!
//! The published pairs embed rounding, so reproductions are asserted to
//! within ~12% (exact for 65 nm and 7 nm, the 40 nm pair is internally
//! inconsistent at the percent level — see DESIGN.md §Substitutions).

use anyhow::{bail, Result};

/// Area-efficiency (TOPS/mm²) multiplication factor when normalizing a
/// design at `from_nm` to 22 nm.
pub fn area_eff_to_22nm(from_nm: u32) -> Result<f64> {
    Ok(match from_nm {
        22 => 1.0,
        7 => 0.0493,
        40 => 3.23,
        65 => 9.35,
        other => bail!("no DeepScaleTool factor derived for {other} nm"),
    })
}

/// Energy-efficiency (TOPS/W) multiplication factor when normalizing a
/// design at `from_nm` to 22 nm.
pub fn energy_eff_to_22nm(from_nm: u32) -> Result<f64> {
    Ok(match from_nm {
        22 => 1.0,
        7 => 0.439,
        40 => 1.52,
        65 => 7.10,
        other => bail!("no DeepScaleTool factor derived for {other} nm"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_22nm() {
        assert_eq!(area_eff_to_22nm(22).unwrap(), 1.0);
        assert_eq!(energy_eff_to_22nm(22).unwrap(), 1.0);
    }

    #[test]
    fn tpu_v4i_row_reproduced() {
        // 7 nm: 0.345 TOPS/mm² → 0.017; 0.786 TOPS/W → 0.345.
        let area = 0.345 * area_eff_to_22nm(7).unwrap();
        assert!((area - 0.017).abs() < 0.0005, "{area}");
        let energy = 0.786 * energy_eff_to_22nm(7).unwrap();
        assert!((energy - 0.345).abs() < 0.005, "{energy}");
    }

    #[test]
    fn bitsystolic_row_reproduced() {
        // 65 nm: area eff 0.1 → 0.935 (published applies the node factor to
        // the 2b×2b point); energy eff 26.7/4 (8b×2b equivalence) → 47.412.
        let area = 0.1 * area_eff_to_22nm(65).unwrap();
        assert!((area - 0.935).abs() < 0.01, "{area}");
        let energy = (26.7 / 4.0) * energy_eff_to_22nm(65).unwrap();
        assert!((energy - 47.412).abs() < 0.5, "{energy}");
    }

    #[test]
    fn dtq_and_dta_rows_within_tolerance() {
        // 40 nm rows: published pairs are mutually inconsistent by ~11%,
        // the geo-mean factor lands within that band for both.
        for (before, after) in [(0.676, 2.302), (0.979, 2.984)] {
            let got: f64 = before * area_eff_to_22nm(40).unwrap();
            assert!((got / after - 1.0).abs() < 0.12, "{before}→{got} vs {after}");
        }
        for (before, after) in [(1.298, 1.973), (1.623, 2.470)] {
            let got: f64 = before * energy_eff_to_22nm(40).unwrap();
            assert!((got / after - 1.0).abs() < 0.02, "{before}→{got} vs {after}");
        }
    }

    #[test]
    fn unknown_nodes_error() {
        assert!(area_eff_to_22nm(130).is_err());
        assert!(energy_eff_to_22nm(3).is_err());
    }
}
