//! 22 nm-calibrated area/power/energy models and technology normalization.
//!
//! The paper implements DiP and ADiP from synthesis to GDSII (Cadence
//! Genus/Innovus, commercial 22 nm, 0.8 V, 1 GHz) and reports post-PnR
//! area/power points (Table I, Fig. 7, Table II). We do not have that flow;
//! per the substitution policy [`model`] is a component-structured model
//! **calibrated to reproduce every published point exactly**, and
//! [`scaling`] re-derives the DeepScaleTool normalization factors used by
//! Table II from the paper's own before/after pairs.

pub mod model;
pub mod scaling;

pub use model::{
    adip_point, dip_point, energy_joules, overheads, ws_point, HwPoint, Overheads, EVAL_SIZES,
};
pub use scaling::{area_eff_to_22nm, energy_eff_to_22nm};
