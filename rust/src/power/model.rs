//! Post-PnR-calibrated area & power model (Table I, Fig. 7).
//!
//! Anchors (published):
//! * DiP 64×64: **1.00 mm², 0.858 W** at 22 nm / 0.8 V / 1 GHz (Table II).
//! * ADiP-vs-DiP overhead ratios per size (Table I, with the extra digit
//!   recoverable from the Fig. 7 percentages):
//!   area 1.406 / 1.34 / 1.266 / 1.289 / 1.307 and power 1.625 / 1.59 /
//!   1.566 / 1.628 / 1.690 for N ∈ {4, 8, 16, 32, 64}. “Total overhead”
//!   is their product (verified to reproduce the 2.3 / 2.13 / 1.99 / 2.1 /
//!   2.2 column).
//! * WS-vs-DiP: DiP improves power up to **1.25×** and area up to
//!   **1.09×** (§V-B) — applied as constant WS ratios.
//!
//! Structure between anchors: DiP area/power decompose as PE array (∝ N²)
//! plus boundary periphery (∝ N) with a 90/10 split at 64×64 — the split
//! only affects non-published interpolated sizes and is documented as an
//! assumption in DESIGN.md §Substitutions.

/// Array sizes of the paper's design space exploration.
pub const EVAL_SIZES: [usize; 5] = [4, 8, 16, 32, 64];

/// ADiP/DiP area overhead ratios at [`EVAL_SIZES`] (Fig. 7(a)).
const ADIP_AREA_RATIO: [f64; 5] = [1.406, 1.34, 1.266, 1.289, 1.307];
/// ADiP/DiP power overhead ratios at [`EVAL_SIZES`] (Fig. 7(b)).
const ADIP_POWER_RATIO: [f64; 5] = [1.6251, 1.59, 1.566, 1.628, 1.690];

/// DiP 64×64 post-PnR anchors (Table II).
const DIP_64_AREA_MM2: f64 = 1.0;
const DIP_64_POWER_W: f64 = 0.858;

/// WS/DiP constant ratios (§V-B “up to” values).
const WS_AREA_RATIO: f64 = 1.09;
const WS_POWER_RATIO: f64 = 1.25;

/// PE-array share of DiP area/power at 64×64 (remainder ∝ N periphery).
const PE_SHARE: f64 = 0.9;

/// One architecture instance's physical point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwPoint {
    /// Post-PnR area in mm².
    pub area_mm2: f64,
    /// Total power at 1 GHz / 0.8 V in W.
    pub power_w: f64,
}

/// ADiP-vs-DiP overheads at a size (the Table I row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Overheads {
    /// Array size `N`.
    pub n: usize,
    /// Area overhead (×).
    pub area_x: f64,
    /// Power overhead (×).
    pub power_x: f64,
    /// Total overhead (×) — area × power.
    pub total_x: f64,
}

/// Piecewise-linear interpolation of a ratio table in log₂(N).
fn interp_ratio(table: &[f64; 5], n: usize) -> f64 {
    assert!(n >= 2, "array size too small");
    let x = (n as f64).log2();
    let xs: Vec<f64> = EVAL_SIZES.iter().map(|&s| (s as f64).log2()).collect();
    if x <= xs[0] {
        return table[0];
    }
    if x >= xs[4] {
        return table[4];
    }
    for i in 0..4 {
        if x <= xs[i + 1] {
            let t = (x - xs[i]) / (xs[i + 1] - xs[i]);
            return table[i] + t * (table[i + 1] - table[i]);
        }
    }
    unreachable!()
}

/// N²/N component scaling relative to the 64×64 anchor.
fn size_scale(n: usize) -> f64 {
    let r = n as f64 / 64.0;
    PE_SHARE * r * r + (1.0 - PE_SHARE) * r
}

/// DiP physical point at size `n`.
pub fn dip_point(n: usize) -> HwPoint {
    HwPoint {
        area_mm2: DIP_64_AREA_MM2 * size_scale(n),
        power_w: DIP_64_POWER_W * size_scale(n),
    }
}

/// ADiP physical point at size `n` (DiP × calibrated overhead ratios).
pub fn adip_point(n: usize) -> HwPoint {
    let d = dip_point(n);
    HwPoint {
        area_mm2: d.area_mm2 * interp_ratio(&ADIP_AREA_RATIO, n),
        power_w: d.power_w * interp_ratio(&ADIP_POWER_RATIO, n),
    }
}

/// Conventional WS physical point at size `n` (DiP × FIFO overheads).
pub fn ws_point(n: usize) -> HwPoint {
    let d = dip_point(n);
    HwPoint { area_mm2: d.area_mm2 * WS_AREA_RATIO, power_w: d.power_w * WS_POWER_RATIO }
}

/// The Table I overhead row at size `n`. The published "total overhead"
/// column is the product of the *two-decimal rounded* area and power
/// ratios (verified: 1.41×1.63 = 2.30, 1.27×1.57 = 1.99, 1.30×1.69 = 2.20
/// — exactly the published 2.3 / 1.99 / 2.2), so the model reproduces that
/// convention.
pub fn overheads(n: usize) -> Overheads {
    let a = interp_ratio(&ADIP_AREA_RATIO, n);
    let p = interp_ratio(&ADIP_POWER_RATIO, n);
    let round2 = |v: f64| (v * 100.0).round() / 100.0;
    Overheads { n, area_x: a, power_x: p, total_x: round2(a) * round2(p) }
}

/// Energy in joules for `cycles` at `power_w` and `freq_hz`.
pub fn energy_joules(power_w: f64, cycles: u64, freq_hz: f64) -> f64 {
    power_w * cycles as f64 / freq_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round2(v: f64) -> f64 {
        (v * 100.0).round() / 100.0
    }

    #[test]
    fn table1_overhead_columns_reproduced() {
        // Table I: (area, power, total) per size, rounded as published.
        let published: [(usize, f64, f64, f64); 5] = [
            (4, 1.41, 1.63, 2.3),
            (8, 1.34, 1.59, 2.13),
            (16, 1.27, 1.57, 1.99),
            (32, 1.29, 1.63, 2.1),
            (64, 1.3, 1.69, 2.2),
        ];
        for (n, a, p, t) in published {
            let o = overheads(n);
            assert!((round2(o.area_x) - a).abs() < 0.011, "n={n} area {} vs {a}", o.area_x);
            assert!((round2(o.power_x) - p).abs() < 0.011, "n={n} power {} vs {p}", o.power_x);
            // totals are published at 2–3 significant digits
            assert!((o.total_x - t).abs() < 0.03, "n={n} total {} vs {t}", o.total_x);
        }
    }

    #[test]
    fn fig7_percentages_reproduced() {
        // Fig. 7: area overhead 40.6% → 26.6% → 28.9% → 30.7%;
        // power 62.5% → 56.6% → 62.8% → 69%.
        let pts = [(4, 40.6, 62.5), (16, 26.6, 56.6), (32, 28.9, 62.8), (64, 30.7, 69.0)];
        for (n, area_pct, power_pct) in pts {
            let o = overheads(n);
            assert!(((o.area_x - 1.0) * 100.0 - area_pct).abs() < 0.11, "n={n} area");
            assert!(((o.power_x - 1.0) * 100.0 - power_pct).abs() < 0.11, "n={n} power");
        }
    }

    #[test]
    fn dip_and_adip_64x64_anchors() {
        let d = dip_point(64);
        assert!((d.area_mm2 - 1.0).abs() < 1e-12);
        assert!((d.power_w - 0.858).abs() < 1e-12);
        let a = adip_point(64);
        // Table II publishes 1.32 mm² / 1.452 W (ratio rounding: 1.307 /
        // 1.690 of Table I give 1.307 mm² / 1.450 W — within 1.1%).
        assert!((a.area_mm2 - 1.32).abs() < 0.015, "area {}", a.area_mm2);
        assert!((a.power_w - 1.452).abs() < 0.003, "power {}", a.power_w);
    }

    #[test]
    fn adip_64x64_efficiency_metrics() {
        // Table II: 8.192 TOPS @8b×8b → 5.64 TOPS/W / 6.2 TOPS/mm²;
        // ×4 at 8b×2b → 22.57 TOPS/W / 24.82 TOPS/mm².
        let a = adip_point(64);
        let tops8 = 8.192;
        assert!((tops8 / a.power_w - 5.64).abs() < 0.03);
        assert!((tops8 / a.area_mm2 - 6.2).abs() < 0.08);
        assert!((4.0 * tops8 / a.power_w - 22.57).abs() < 0.12);
        assert!((4.0 * tops8 / a.area_mm2 - 24.82).abs() < 0.32);
        // DiP energy efficiency: 9.548 TOPS/W.
        let d = dip_point(64);
        assert!((tops8 / d.power_w - 9.548).abs() < 0.01);
    }

    #[test]
    fn ws_ratios_and_energy_eff_per_area() {
        let (w, d) = (ws_point(32), dip_point(32));
        assert!((w.area_mm2 / d.area_mm2 - 1.09).abs() < 1e-12);
        assert!((w.power_w / d.power_w - 1.25).abs() < 1e-12);
        // §V-B: DiP beats WS in energy efficiency per area by up to 2.02×.
        // Single-tile throughput ratio (3N−2)/(2N−1) × power 1.25 × area 1.09.
        let n = 32.0f64;
        let thr = (3.0 * n - 2.0) / (2.0 * n - 1.0);
        let gain = thr * 1.25 * 1.09;
        assert!((gain - 2.02).abs() < 0.02, "gain {gain}");
    }

    #[test]
    fn interpolation_monotone_between_anchors() {
        // area/power grow monotonically with N
        let mut last = 0.0;
        for n in [4, 6, 8, 12, 16, 24, 32, 48, 64, 96] {
            let a = adip_point(n).area_mm2;
            assert!(a > last, "n={n}");
            last = a;
        }
        // ratio interpolation stays within table bounds
        for n in 4..=64 {
            let o = overheads(n);
            assert!(o.area_x >= 1.26 && o.area_x <= 1.41, "n={n} {o:?}");
        }
    }

    #[test]
    fn energy_accounting() {
        // 1 W for 1e9 cycles at 1 GHz = 1 J
        assert!((energy_joules(1.0, 1_000_000_000, 1e9) - 1.0).abs() < 1e-12);
        assert!((energy_joules(0.5, 2_000_000_000, 1e9) - 1.0).abs() < 1e-12);
    }
}
