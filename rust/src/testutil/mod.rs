//! Deterministic randomness + a mini property-testing harness.
//!
//! The offline crate snapshot for this environment has neither `rand` nor
//! `proptest`, so the library ships a small, dependency-free xorshift PRNG
//! and a bounded property-check loop with first-failure reporting. All
//! randomized tests in the crate run through this module with fixed seeds,
//! so failures are exactly reproducible.

/// xorshift64* pseudo-random generator — deterministic, seedable, fast.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a non-zero seed (0 is mapped to a constant).
    pub fn seeded(seed: u64) -> Rng {
        Rng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform `u32`.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `lo..=hi` (inclusive).
    pub fn i32_range(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u64;
        lo + (self.next_u64() % span) as i32
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    /// Random signed value fitting `bits` bits.
    pub fn int_of_bits(&mut self, bits: u32) -> i32 {
        let (lo, hi) = crate::quant::value_range(bits);
        self.i32_range(lo, hi)
    }

    /// Vector of random signed `bits`-bit values.
    pub fn int_vec(&mut self, len: usize, bits: u32) -> Vec<i32> {
        (0..len).map(|_| self.int_of_bits(bits)).collect()
    }

    /// Vector of random floats in `[lo, hi)`.
    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Run `cases` random property checks. `gen` produces a case from the RNG,
/// `prop` returns `Err(reason)` on failure. Panics with the seed, case
/// index and debug repr of the first failing case, so it can be replayed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::seeded(seed);
    for i in 0..cases {
        let case = gen(&mut rng);
        if let Err(reason) = prop(&case) {
            panic!(
                "property {name:?} failed at case {i}/{cases} (seed {seed}):\n  \
                 reason: {reason}\n  case: {case:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut rng = Rng::seeded(1);
        for _ in 0..1000 {
            let v = rng.i32_range(-5, 5);
            assert!((-5..=5).contains(&v));
            let f = rng.f32_range(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
            let b = rng.int_of_bits(2);
            assert!((-2..=1).contains(&b));
        }
    }

    #[test]
    fn rng_covers_range() {
        // all values of a small range appear
        let mut rng = Rng::seeded(9);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[(rng.i32_range(-2, 1) + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn check_passes_good_property() {
        check("additive-identity", 7, 50, |r| r.i32_range(-100, 100), |&x| {
            if x + 0 == x { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn check_reports_failures() {
        check("always-fails", 7, 10, |r| r.next_u32(), |_| Err("nope".into()));
    }
}
