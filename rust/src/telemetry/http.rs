//! Minimal HTTP/1.1 scrape endpoint over `std::net` (same hand-rolled
//! listener discipline as `rust/src/net/server.rs`: non-blocking accept
//! poll so shutdown never hangs, one session thread per connection,
//! socket read timeouts so sessions notice the stop flag).
//!
//! Three routes, all `GET`:
//!
//! * `/metrics` — the full Prometheus text exposition: everything
//!   `Metrics::render` emits plus the telemetry tier's own series
//!   (watchdog fire counters, sampler tick count/interval). The extras
//!   are appended *here*, not inside `render`, so the exposition every
//!   other consumer sees is bit-identical with telemetry off.
//! * `/healthz` — liveness + readiness: `200 ok` or `503` naming every
//!   failing condition (draining, worker-panic, queue-stall).
//! * `/statusz` — a hand-rolled JSON snapshot: depths, cache occupancy,
//!   active policies, sampler series tails, recent watchdog events.
//!
//! Anything else: `400` (malformed request line), `404` (unknown path),
//! `405 Allow: GET` (wrong method), `505` (not HTTP/1.x). One request
//! per connection (`Connection: close`) — scrapers at 1 Hz don't need
//! keep-alive, and one-shot sessions keep the lifecycle trivial.

use std::fmt::Write as _;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::Priority;

use super::watchdog::Rule;
use super::TelemetryState;

/// Accept-poll pause of the non-blocking listener thread.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
/// Socket read timeout — the granularity at which sessions notice the
/// stop flag.
const READ_POLL: Duration = Duration::from_millis(25);
/// Request-head cap; a scrape request is a few hundred bytes, anything
/// bigger is refused.
const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Series samples included in each `/statusz` tail.
const STATUS_TAIL: usize = 20;
/// Watchdog events included in `/statusz`.
const STATUS_EVENTS: usize = 16;

/// The telemetry HTTP listener.
pub struct HttpServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    listener: Option<JoinHandle<()>>,
    sessions: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind and serve (`:0` binds an ephemeral port).
    pub fn bind(addr: SocketAddr, state: Arc<TelemetryState>) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind telemetry {addr}"))?;
        let local_addr = listener.local_addr().context("telemetry local_addr")?;
        listener.set_nonblocking(true).context("telemetry set_nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let sessions: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let handle = {
            let (stop, sessions) = (stop.clone(), sessions.clone());
            thread::Builder::new()
                .name("adip-telemetry-http".into())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok((stream, _peer)) => {
                                let (state, stop) = (state.clone(), stop.clone());
                                let h = thread::Builder::new()
                                    .name("adip-telemetry-session".into())
                                    .spawn(move || session(stream, state, stop))
                                    .expect("spawn telemetry session");
                                sessions.lock().unwrap_or_else(|e| e.into_inner()).push(h);
                            }
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                                thread::sleep(ACCEPT_POLL);
                            }
                            // transient accept failures must not kill the
                            // scrape endpoint
                            Err(_) => thread::sleep(ACCEPT_POLL),
                        }
                    }
                })
                .context("spawn telemetry listener")?
        };
        Ok(HttpServer { local_addr, stop, listener: Some(handle), sessions })
    }

    /// The bound address (resolves `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, wake every session, join all threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.listener.take() {
            let _ = h.join();
        }
        let handles: Vec<_> =
            std::mem::take(&mut *self.sessions.lock().unwrap_or_else(|e| e.into_inner()));
        for h in handles {
            let _ = h.join();
        }
    }
}

/// One connection: read a single request head, answer it, close.
fn session(mut stream: TcpStream, state: Arc<TelemetryState>, stop: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let head = match read_request_head(&mut stream, &stop) {
        ReadHead::Complete(h) => h,
        ReadHead::Oversized => {
            respond(&mut stream, 400, "Bad Request", "text/plain", "request head too large\n");
            return;
        }
        ReadHead::Closed => return,
    };
    let request_line = head.lines().next().unwrap_or("");
    let (status, reason, content_type, body) = route(&state, request_line);
    respond(&mut stream, status, reason, content_type, &body);
}

enum ReadHead {
    Complete(String),
    Oversized,
    Closed,
}

/// Read until the blank line ending the request head (body, if any, is
/// ignored — every route is a GET).
fn read_request_head(stream: &mut TcpStream, stop: &AtomicBool) -> ReadHead {
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        if stop.load(Ordering::Acquire) {
            return ReadHead::Closed;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadHead::Closed,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if head_complete(&buf) {
                    return ReadHead::Complete(String::from_utf8_lossy(&buf).into_owned());
                }
                if buf.len() > MAX_HEAD_BYTES {
                    return ReadHead::Oversized;
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut => {}
            Err(_) => return ReadHead::Closed,
        }
    }
}

/// A request head ends at the first blank line (tolerates bare-`\n`
/// clients like a hand-typed `nc` session).
fn head_complete(buf: &[u8]) -> bool {
    buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n")
}

/// Dispatch one request line to its route.
fn route(
    state: &TelemetryState,
    request_line: &str,
) -> (u16, &'static str, &'static str, String) {
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(target), Some(version)) =
        (parts.next(), parts.next(), parts.next())
    else {
        return (400, "Bad Request", "text/plain", "malformed request line\n".into());
    };
    if !version.starts_with("HTTP/1.") {
        return (
            505,
            "HTTP Version Not Supported",
            "text/plain",
            "only HTTP/1.x is served here\n".into(),
        );
    }
    if method != "GET" {
        return (405, "Method Not Allowed", "text/plain", "only GET is served here\n".into());
    }
    let path = target.split('?').next().unwrap_or(target);
    match path {
        "/metrics" => {
            (200, "OK", "text/plain; version=0.0.4; charset=utf-8", render_metrics(state))
        }
        "/healthz" => {
            let reasons = state.health();
            if reasons.is_empty() {
                (200, "OK", "text/plain", "ok\n".into())
            } else {
                let detail = format!("unhealthy: {}\n", reasons.join(", "));
                (503, "Service Unavailable", "text/plain", detail)
            }
        }
        "/statusz" => (200, "OK", "application/json", statusz_json(state)),
        _ => (
            404,
            "Not Found",
            "text/plain",
            "not found (try /metrics, /healthz, /statusz)\n".into(),
        ),
    }
}

fn respond(stream: &mut TcpStream, status: u16, reason: &str, content_type: &str, body: &str) {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    if status == 405 {
        head.push_str("Allow: GET\r\n");
    }
    head.push_str("\r\n");
    // best-effort: a scraper that hung up mid-response is its problem
    let _ = stream.write_all(head.as_bytes()).and_then(|()| stream.write_all(body.as_bytes()));
}

/// The `/metrics` body: the coordinator exposition plus the telemetry
/// tier's own series (appended here so `Metrics::render` stays
/// bit-identical with telemetry off).
fn render_metrics(state: &TelemetryState) -> String {
    let mut s = state.metrics.render();
    state.watchdog.render_prometheus(&mut s);
    let _ = writeln!(
        s,
        "# HELP adip_telemetry_samples_total Sampler ticks taken by the telemetry tier.\n\
         # TYPE adip_telemetry_samples_total counter\n\
         adip_telemetry_samples_total {}",
        state.series.ticks.load(Ordering::Acquire)
    );
    let _ = writeln!(
        s,
        "# HELP adip_telemetry_sample_interval_seconds Configured sampler interval.\n\
         # TYPE adip_telemetry_sample_interval_seconds gauge\n\
         adip_telemetry_sample_interval_seconds {:.6e}",
        state.sample_interval.as_secs_f64()
    );
    s
}

/// Escape a string for a JSON literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// A JSON-safe number (JSON has no NaN/Inf; clamp them to 0).
fn json_num(v: f64) -> String {
    if v.is_finite() { format!("{v:.6}") } else { "0.000000".into() }
}

fn json_num_list(vs: &[f64]) -> String {
    let items: Vec<String> = vs.iter().map(|&v| json_num(v)).collect();
    format!("[{}]", items.join(","))
}

/// The `/statusz` body: one JSON object, hand-rolled on `std` like
/// everything else in this tier.
fn statusz_json(state: &TelemetryState) -> String {
    let m = &state.metrics;
    let reasons = state.health();
    // relaxed-ok: statusz stat reads; fields are independent
    let workers = m.balance_workers.load(Ordering::Relaxed) as usize;
    let mut s = String::with_capacity(4096);
    s.push_str("{\n");
    let _ = writeln!(s, "  \"version\": \"{}\",", json_escape(crate::VERSION));
    let _ = writeln!(s, "  \"uptime_seconds\": {},", json_num(m.uptime_seconds()));
    let _ = writeln!(s, "  \"healthy\": {},", reasons.is_empty());
    let unhealthy: Vec<String> =
        reasons.iter().map(|r| format!("\"{}\"", json_escape(r))).collect();
    let _ = writeln!(s, "  \"unhealthy_reasons\": [{}],", unhealthy.join(","));
    let _ = writeln!(s, "  \"draining\": {},", state.draining.load(Ordering::Acquire));
    let _ = writeln!(
        s,
        "  \"sample_interval_ms\": {},",
        json_num(state.sample_interval.as_secs_f64() * 1e3)
    );
    let _ = writeln!(s, "  \"samples\": {},", state.series.ticks.load(Ordering::Acquire));
    let _ = writeln!(s, "  \"workers\": {workers},");
    // relaxed-ok: statusz stat read; monotone health counter
    let _ = writeln!(s, "  \"worker_panics\": {},", m.worker_panics.load(Ordering::Relaxed));
    let depths: Vec<String> =
        m.worker_deque_depth.snapshot(workers).iter().map(u64::to_string).collect();
    let _ = writeln!(s, "  \"worker_deque_depths\": [{}],", depths.join(","));
    // relaxed-ok: statusz gauge/stat reads; fields are independent
    let _ = writeln!(s, "  \"injector_depth\": {},", m.injector_depth.load(Ordering::Relaxed));
    let _ = writeln!(s, "  \"prepared_depth\": {},", m.prepared_depth.load(Ordering::Relaxed));
    let _ = writeln!(s, "  \"queue_depth\": {},", m.queue_depth.load(Ordering::Relaxed));
    let _ = writeln!(
        s,
        "  \"cache\": {{\"shards\": {}, \"shards_occupied\": {}, \"hits\": {}, \
         \"shared_hits\": {}, \"misses\": {}, \"evictions\": {}}},",
        // relaxed-ok: statusz cache stat reads; fields are independent
        m.cache_shards.load(Ordering::Relaxed),
        m.cache_shards_occupied.load(Ordering::Relaxed),
        m.cache_hits.load(Ordering::Relaxed),
        m.cache_shared_hits.load(Ordering::Relaxed),
        m.cache_misses.load(Ordering::Relaxed),
        m.cache_evictions.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        s,
        "  \"counters\": {{\"accepted\": {}, \"completed\": {}, \"rejected\": {}, \
         \"failed\": {}, \"shed\": {}, \"cancelled\": {}, \"steals\": {}, \"batches\": {}}},",
        // relaxed-ok: statusz counter reads; fields are independent
        m.accepted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        m.rejected.load(Ordering::Relaxed),
        m.failed.load(Ordering::Relaxed),
        m.shed.load(Ordering::Relaxed),
        m.cancelled.load(Ordering::Relaxed),
        m.steals.load(Ordering::Relaxed),
        m.batches.load(Ordering::Relaxed)
    );
    let policies: Vec<String> = state
        .policies
        .iter()
        .map(|(k, v)| format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)))
        .collect();
    let _ = writeln!(s, "  \"policies\": {{{}}},", policies.join(", "));
    // per-class queue-wait deltas over the last two samples (the sampler
    // stores absolutes; the delta is the "shape" a controller wants)
    s.push_str("  \"class_queue_deltas\": {");
    let mut first = true;
    for class in Priority::ALL {
        let i = class.index();
        let d50 = series_delta(&state.series.class_queue_p50[i]);
        let d95 = series_delta(&state.series.class_queue_p95[i]);
        if !first {
            s.push_str(", ");
        }
        first = false;
        let _ = write!(
            s,
            "\"{}\": {{\"p50_delta\": {}, \"p95_delta\": {}}}",
            class.name(),
            json_num(d50),
            json_num(d95)
        );
    }
    s.push_str("},\n");
    s.push_str("  \"series\": {\n");
    let all = state.series.all();
    for (i, series) in all.iter().enumerate() {
        let comma = if i + 1 == all.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    \"{}\": {}{comma}",
            json_escape(series.name()),
            json_num_list(&series.tail(STATUS_TAIL))
        );
    }
    s.push_str("  },\n");
    s.push_str("  \"watchdog\": {\n    \"fired\": {");
    for (i, rule) in Rule::ALL.iter().enumerate() {
        let comma = if i + 1 == Rule::ALL.len() { "" } else { ", " };
        let _ = write!(s, "\"{}\": {}{comma}", rule.name(), state.watchdog.fired(*rule));
    }
    let _ = writeln!(
        s,
        "}},\n    \"queue_stall_active\": {},",
        state.watchdog.stall_active()
    );
    s.push_str("    \"recent\": [\n");
    let events = state.watchdog.recent_events();
    let tail = &events[events.len().saturating_sub(STATUS_EVENTS)..];
    for (i, ev) in tail.iter().enumerate() {
        let comma = if i + 1 == tail.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "      {{\"rule\": \"{}\", \"unix_ms\": {}, \"tick\": {}, \"detail\": \"{}\"}}{comma}",
            ev.rule.name(),
            ev.unix_ms,
            ev.tick,
            json_escape(&ev.detail)
        );
    }
    s.push_str("    ]\n  }\n}\n");
    s
}

/// Change between the last two samples of a series (0 with fewer than 2).
fn series_delta(series: &super::sampler::Series) -> f64 {
    let t = series.tail(2);
    match t.as_slice() {
        [a, b] => b - a,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::telemetry::watchdog::Observation;

    fn test_state() -> Arc<TelemetryState> {
        Arc::new(TelemetryState::new(
            Arc::new(Metrics::default()),
            Duration::from_millis(50),
            vec![("steal".into(), "Off".into())],
        ))
    }

    #[test]
    fn route_malformed_and_unknown() {
        let st = test_state();
        assert_eq!(route(&st, "GARBAGE").0, 400);
        assert_eq!(route(&st, "").0, 400);
        assert_eq!(route(&st, "GET /metrics").0, 400, "missing version");
        assert_eq!(route(&st, "GET /nope HTTP/1.1").0, 404);
        assert_eq!(route(&st, "POST /metrics HTTP/1.1").0, 405);
        assert_eq!(route(&st, "GET /metrics HTTP/2").0, 505);
    }

    #[test]
    fn metrics_route_appends_telemetry_series() {
        let st = test_state();
        let (status, _, ct, body) = route(&st, "GET /metrics HTTP/1.1");
        assert_eq!(status, 200);
        assert!(ct.starts_with("text/plain"));
        assert!(body.contains("adip_requests_completed_total"), "coordinator exposition");
        assert!(body.contains("adip_watchdog_events_total{rule=\"queue_stall\"} 0"));
        assert!(body.contains("adip_telemetry_samples_total 0"));
        assert!(body.contains("adip_telemetry_sample_interval_seconds"));
        // query strings are tolerated (Prometheus can add ?timeout=..)
        assert_eq!(route(&st, "GET /metrics?x=1 HTTP/1.0").0, 200);
    }

    #[test]
    fn healthz_flips_on_drain_and_panic_and_stall() {
        let st = test_state();
        assert_eq!(route(&st, "GET /healthz HTTP/1.1").0, 200);
        st.draining.store(true, Ordering::Release);
        let (status, _, _, body) = route(&st, "GET /healthz HTTP/1.1");
        assert_eq!(status, 503);
        assert!(body.contains("draining"), "{body}");
        st.draining.store(false, Ordering::Release);
        st.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
        let (status, _, _, body) = route(&st, "GET /healthz HTTP/1.1");
        assert_eq!(status, 503);
        assert!(body.contains("worker-panic"), "{body}");
    }

    #[test]
    fn healthz_reports_active_stall() {
        let st = test_state();
        for _ in 0..3 {
            st.watchdog.observe(&Observation {
                injector_depth: 5,
                ..Observation::default()
            });
        }
        assert!(st.watchdog.stall_active());
        let (status, _, _, body) = route(&st, "GET /healthz HTTP/1.1");
        assert_eq!(status, 503);
        assert!(body.contains("queue-stall"), "{body}");
    }

    #[test]
    fn statusz_is_wellformed() {
        let st = test_state();
        st.metrics.record_completion(10, 0.0, 0, 1);
        st.metrics.balance_workers.store(2, Ordering::Relaxed);
        st.metrics.worker_deque_depth.store(0, 3);
        st.metrics.worker_deque_depth.store(1, 1);
        let mut prev = super::super::sampler::PrevCounters::new(&st.metrics);
        let obs = super::super::sampler::sample_tick(&st.metrics, &st.series, &mut prev);
        st.watchdog.observe(&obs);
        let (status, _, ct, body) = route(&st, "GET /statusz HTTP/1.1");
        assert_eq!(status, 200);
        assert_eq!(ct, "application/json");
        for key in [
            "\"version\"",
            "\"uptime_seconds\"",
            "\"healthy\": true",
            "\"worker_deque_depths\": [3,1]",
            "\"policies\": {\"steal\": \"Off\"}",
            "\"completions_per_s\"",
            "\"queue_p50_interactive\"",
            "\"class_queue_deltas\"",
            "\"queue_stall_active\": false",
            "\"fired\": {\"queue_stall\": 0",
        ] {
            assert!(body.contains(key), "{key} missing from:\n{body}");
        }
        // brace/bracket balance — the cheap structural sanity check; the
        // python CI validator does the real parse
        let balance = |open: char, close: char| {
            body.chars().filter(|&c| c == open).count()
                == body.chars().filter(|&c| c == close).count()
        };
        assert!(balance('{', '}'), "{body}");
        assert!(balance('[', ']'), "{body}");
        assert!(!body.contains("NaN") && !body.contains("inf"), "{body}");
    }

    #[test]
    fn json_escaping_and_numbers() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        assert_eq!(json_num(1.5), "1.500000");
        assert_eq!(json_num(f64::NAN), "0.000000");
        assert_eq!(json_num(f64::INFINITY), "0.000000");
        assert_eq!(json_num_list(&[1.0, 2.5]), "[1.000000,2.500000]");
    }

    #[test]
    fn head_completion_detects_both_line_endings() {
        assert!(head_complete(b"GET / HTTP/1.1\r\n\r\n"));
        assert!(head_complete(b"GET / HTTP/1.1\n\n"));
        assert!(!head_complete(b"GET / HTTP/1.1\r\n"));
    }
}
