//! Live telemetry tier: scrape endpoint, time-series sampler, watchdog.
//!
//! Everything here is strictly *read-only* over the serving stack: the
//! sampler snapshots [`Metrics`](crate::coordinator::metrics::Metrics)
//! counters, the watchdog evaluates rules over those snapshots, and the
//! HTTP listener renders both. No pipeline code path consults telemetry
//! state, so running with telemetry off is bit-for-bit identical to not
//! having the tier at all — the differential tests in
//! `tests/integration_telemetry.rs` hold the stack to that.
//!
//! Layout:
//!
//! * [`sampler`] — background thread turning monotone counters into
//!   fixed-capacity ring time-series (rates, hit-rate windows, skew).
//! * [`watchdog`] — rule engine over sampled observations (queue stall,
//!   deque skew, cache thrash, prepare backlog, worker panic) with a
//!   bounded event ring.
//! * [`http`] — hand-rolled HTTP/1.1 listener serving `GET /metrics`
//!   (Prometheus), `GET /healthz` (200/503), `GET /statusz` (JSON).
//!
//! The whole tier is opt-in: [`TelemetryConfig::listen`] defaults to
//! `None` and the coordinator spawns nothing when it stays that way.

pub mod http;
pub mod sampler;
pub mod watchdog;

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::metrics::Metrics;

use http::HttpServer;
use sampler::{SampleSet, Sampler};
use watchdog::Watchdog;

/// Default sampler tick; fine-grained enough to catch sub-second stalls
/// while keeping the sampling cost invisible next to matmul work.
pub const DEFAULT_SAMPLE_INTERVAL: Duration = Duration::from_millis(250);

/// Where (and how often) to run the telemetry tier.
///
/// `Copy` on purpose: it rides inside `CoordinatorConfig`, which is
/// moved into worker closures by value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Address for the HTTP scrape listener; `None` disables the whole
    /// tier (no sampler thread, no listener, no watchdog state).
    pub listen: Option<SocketAddr>,
    /// Sampler tick interval.
    pub sample_interval: Duration,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { listen: None, sample_interval: DEFAULT_SAMPLE_INTERVAL }
    }
}

impl TelemetryConfig {
    /// Whether the tier should be started at all.
    pub fn enabled(&self) -> bool {
        self.listen.is_some()
    }
}

/// Shared state between the sampler thread, the watchdog, and the HTTP
/// handlers.
#[derive(Debug)]
pub struct TelemetryState {
    /// The coordinator's metrics hub (read-only from this tier).
    pub metrics: Arc<Metrics>,
    /// Sampled time-series rings.
    pub series: SampleSet,
    /// Rule engine + bounded event ring.
    pub watchdog: Watchdog,
    /// Flipped by the coordinator when a drain begins; turns `/healthz`
    /// into 503 so load balancers stop routing here.
    pub draining: AtomicBool,
    /// Configured sampler tick (rendered in `/metrics` and `/statusz`).
    pub sample_interval: Duration,
    /// Active serving policies (`key`, `value`) rendered in `/statusz`.
    pub policies: Vec<(String, String)>,
}

impl TelemetryState {
    /// Fresh state over an existing metrics hub.
    pub fn new(
        metrics: Arc<Metrics>,
        sample_interval: Duration,
        policies: Vec<(String, String)>,
    ) -> TelemetryState {
        TelemetryState {
            metrics,
            series: SampleSet::default(),
            watchdog: Watchdog::default(),
            draining: AtomicBool::new(false),
            sample_interval,
            policies,
        }
    }

    /// Every reason the stack is not ready to take traffic (empty when
    /// healthy). Order is stable so `/healthz` bodies are deterministic.
    pub fn health(&self) -> Vec<&'static str> {
        let mut reasons = Vec::new();
        if self.draining.load(Ordering::Acquire) {
            reasons.push("draining");
        }
        // relaxed-ok: health probe of a monotone counter; staleness by
        // one increment only delays the 503 by a scrape
        if self.metrics.worker_panics.load(Ordering::Relaxed) > 0 {
            reasons.push("worker-panic");
        }
        if self.watchdog.stall_active() {
            reasons.push("queue-stall");
        }
        reasons
    }
}

/// The running tier: sampler thread + HTTP listener over shared state.
pub struct TelemetryServer {
    state: Arc<TelemetryState>,
    sampler: Option<Sampler>,
    http: Option<HttpServer>,
    local_addr: SocketAddr,
}

impl TelemetryServer {
    /// Bind the scrape endpoint and start sampling.
    pub fn start(
        addr: SocketAddr,
        sample_interval: Duration,
        metrics: Arc<Metrics>,
        policies: Vec<(String, String)>,
    ) -> Result<TelemetryServer> {
        let state = Arc::new(TelemetryState::new(metrics, sample_interval, policies));
        let http = HttpServer::bind(addr, state.clone())?;
        let local_addr = http.local_addr();
        let sampler = Sampler::spawn(state.clone(), sample_interval);
        Ok(TelemetryServer { state, sampler: Some(sampler), http: Some(http), local_addr })
    }

    /// The bound scrape address (resolves `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The shared state (for tests and the coordinator's drain hook).
    pub fn state(&self) -> &Arc<TelemetryState> {
        &self.state
    }

    /// Mark the stack as (not) draining; `/healthz` flips accordingly.
    pub fn set_draining(&self, draining: bool) {
        self.state.draining.store(draining, Ordering::Release);
    }

    /// Stop the sampler, then the listener (scrapes in flight finish).
    pub fn shutdown(&mut self) {
        if let Some(sampler) = self.sampler.take() {
            sampler.shutdown();
        }
        if let Some(http) = self.http.take() {
            http.shutdown();
        }
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl std::fmt::Debug for TelemetryServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetryServer").field("local_addr", &self.local_addr).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::time::Instant;

    fn get(addr: SocketAddr, target: &str) -> (u16, String) {
        let mut stream = TcpStream::connect(addr).expect("connect telemetry");
        write!(stream, "GET {target} HTTP/1.1\r\nHost: test\r\n\r\n").expect("send request");
        let mut raw = String::new();
        stream.read_to_string(&mut raw).expect("read response");
        let status: u16 = raw
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .expect("status code");
        let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    #[test]
    fn tier_serves_all_three_endpoints_and_shuts_down() {
        let metrics = Arc::new(Metrics::default());
        let mut server = TelemetryServer::start(
            "127.0.0.1:0".parse().expect("addr"),
            Duration::from_millis(10),
            metrics.clone(),
            vec![("workers".into(), "2".into())],
        )
        .expect("start telemetry");
        let addr = server.local_addr();

        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("adip_uptime_seconds"), "{body}");
        assert!(body.contains("adip_watchdog_events_total"), "{body}");

        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        assert_eq!(body, "ok\n");

        let (status, body) = get(addr, "/statusz");
        assert_eq!(status, 200);
        assert!(body.contains("\"policies\": {\"workers\": \"2\"}"), "{body}");

        let (status, _) = get(addr, "/nope");
        assert_eq!(status, 404);

        // sampler is actually ticking
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.state().series.ticks.load(Ordering::Acquire) == 0 {
            assert!(Instant::now() < deadline, "sampler never ticked");
            std::thread::sleep(Duration::from_millis(5));
        }

        server.set_draining(true);
        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 503);
        assert!(body.contains("draining"), "{body}");

        server.shutdown();
        // idempotent (Drop will call it again)
        server.shutdown();
    }

    #[test]
    fn config_defaults_are_off() {
        let cfg = TelemetryConfig::default();
        assert!(!cfg.enabled());
        assert_eq!(cfg.sample_interval, DEFAULT_SAMPLE_INTERVAL);
        let on = TelemetryConfig {
            listen: Some("127.0.0.1:9464".parse().expect("addr")),
            ..TelemetryConfig::default()
        };
        assert!(on.enabled());
    }
}
