//! Time-series sampler: a background thread snapshotting the live
//! [`Metrics`] at a fixed interval into bounded packed-atomic rings.
//!
//! Each tick derives *windowed* shapes the cumulative counters cannot
//! express — completions/s, steals/s, shed/s, cache hit-rate over the
//! window, eviction rate, injector-depth and prepared-backlog gauges,
//! the per-worker deque-skew coefficient, and per-class queue-wait
//! p50/p95 — pushes them into the [`SampleSet`] rings (read lock-free by
//! `/statusz` sessions), and hands the window's digest to the
//! [`Watchdog`](super::watchdog::Watchdog).
//!
//! The sampler only *reads* metrics: it cannot change outputs or
//! per-ticket accounting, which is what keeps telemetry-off runs
//! bit-identical to telemetry-on runs. Each ring slot is one f64 packed
//! into an `AtomicU64`; the single writer publishes a slot with a
//! `Release` store of the write counter, so readers never observe a torn
//! sample (the same single-word discipline as the latency reservoir).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::Priority;

use super::watchdog::Observation;

/// Samples retained per series ring — at the default 250 ms interval,
/// one minute of history.
pub const SERIES_CAP: usize = 240;

/// Sleep granularity of the sampler loop, so shutdown never waits a
/// whole sample interval.
const STOP_POLL: Duration = Duration::from_millis(10);

/// One bounded time-series ring: f64 samples packed into atomic words,
/// single writer (the sampler thread), lock-free readers.
#[derive(Debug)]
pub struct Series {
    name: String,
    slots: Vec<AtomicU64>,
    /// Monotone write counter; `Release`-stored after the slot write so
    /// a reader's `Acquire` load orders the slot reads behind it.
    written: AtomicU64,
}

impl Series {
    fn new(name: impl Into<String>) -> Series {
        Series {
            name: name.into(),
            slots: (0..SERIES_CAP).map(|_| AtomicU64::new(0)).collect(),
            written: AtomicU64::new(0),
        }
    }

    /// The series name as shown in `/statusz`.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Append one sample (single writer: the sampler thread).
    pub fn push(&self, v: f64) {
        let w = self.written.load(Ordering::Relaxed); // relaxed-ok: single-writer counter, no concurrent RMW
        self.slots[w as usize % SERIES_CAP].store(v.to_bits(), Ordering::Relaxed); // relaxed-ok: publication ordered by the Release store below
        self.written.store(w + 1, Ordering::Release);
    }

    /// Samples ever written (not capped by the ring).
    pub fn len(&self) -> u64 {
        self.written.load(Ordering::Acquire)
    }

    /// Whether nothing has been sampled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The most recent sample, if any.
    pub fn last(&self) -> Option<f64> {
        self.tail(1).pop()
    }

    /// The most recent `n` samples, oldest first (fewer if the series is
    /// younger than `n`; at most [`SERIES_CAP`]).
    pub fn tail(&self, n: usize) -> Vec<f64> {
        let w = self.written.load(Ordering::Acquire);
        let have = (w.min(SERIES_CAP as u64)) as usize;
        let take = n.min(have);
        (0..take)
            .map(|i| {
                let idx = (w as usize - take + i) % SERIES_CAP;
                // relaxed-ok: slot reads ordered by the Acquire load of `written` above
                f64::from_bits(self.slots[idx].load(Ordering::Relaxed))
            })
            .collect()
    }
}

/// Every sampled series, plus the tick counter. Shared read-only with
/// HTTP sessions.
#[derive(Debug)]
pub struct SampleSet {
    /// Requests completed per second over the window.
    pub completions_per_s: Series,
    /// Batches stolen per second over the window.
    pub steals_per_s: Series,
    /// Requests shed per second over the window.
    pub sheds_per_s: Series,
    /// Weight-cache hit rate over the window's lookups (carries the
    /// previous value through windows with no lookups).
    pub cache_hit_rate: Series,
    /// Weight-cache evictions per second over the window.
    pub cache_evictions_per_s: Series,
    /// Injector depth gauge at each tick (its trend is the queue-stall
    /// rule's input).
    pub injector_depth: Series,
    /// Prepared-batch backlog gauge at each tick.
    pub prepared_depth: Series,
    /// Coefficient of variation (stddev/mean) of per-worker deque
    /// depths; 0 when idle or single-worker.
    pub deque_skew: Series,
    /// Per-class queue-wait p50 at each tick (seconds; 0 until the class
    /// has samples), indexed by [`Priority::index`].
    pub class_queue_p50: Vec<Series>,
    /// Per-class queue-wait p95 at each tick.
    pub class_queue_p95: Vec<Series>,
    /// Sampler ticks taken.
    pub ticks: AtomicU64,
}

impl Default for SampleSet {
    fn default() -> SampleSet {
        SampleSet {
            completions_per_s: Series::new("completions_per_s"),
            steals_per_s: Series::new("steals_per_s"),
            sheds_per_s: Series::new("sheds_per_s"),
            cache_hit_rate: Series::new("cache_hit_rate"),
            cache_evictions_per_s: Series::new("cache_evictions_per_s"),
            injector_depth: Series::new("injector_depth"),
            prepared_depth: Series::new("prepared_depth"),
            deque_skew: Series::new("deque_skew"),
            class_queue_p50: Priority::ALL
                .iter()
                .map(|c| Series::new(format!("queue_p50_{}", c.name())))
                .collect(),
            class_queue_p95: Priority::ALL
                .iter()
                .map(|c| Series::new(format!("queue_p95_{}", c.name())))
                .collect(),
            ticks: AtomicU64::new(0),
        }
    }
}

impl SampleSet {
    /// Every series, in `/statusz` order.
    pub fn all(&self) -> Vec<&Series> {
        let mut out = vec![
            &self.completions_per_s,
            &self.steals_per_s,
            &self.sheds_per_s,
            &self.cache_hit_rate,
            &self.cache_evictions_per_s,
            &self.injector_depth,
            &self.prepared_depth,
            &self.deque_skew,
        ];
        out.extend(self.class_queue_p50.iter());
        out.extend(self.class_queue_p95.iter());
        out
    }
}

/// Cumulative-counter snapshot carried between ticks, so each tick can
/// derive window deltas and rates.
#[derive(Debug)]
pub struct PrevCounters {
    at: Instant,
    completed: u64,
    steals: u64,
    shed: u64,
    cache_hits: u64,
    cache_misses: u64,
    cache_evictions: u64,
}

impl PrevCounters {
    /// Baseline from the current counter values (the first window starts
    /// now, not at server start — no spurious rate spike on tick 1).
    pub fn new(metrics: &Metrics) -> PrevCounters {
        // relaxed-ok: baseline stat reads; fields are independent
        PrevCounters {
            at: Instant::now(),
            completed: metrics.completed.load(Ordering::Relaxed),
            steals: metrics.steals.load(Ordering::Relaxed),
            shed: metrics.shed.load(Ordering::Relaxed),
            cache_hits: metrics.cache_hits.load(Ordering::Relaxed),
            cache_misses: metrics.cache_misses.load(Ordering::Relaxed),
            cache_evictions: metrics.cache_evictions.load(Ordering::Relaxed),
        }
    }
}

/// Coefficient of variation (stddev/mean) over per-worker deque depths;
/// 0 for empty fleets or an all-idle (zero-mean) fleet.
fn skew_coefficient(depths: &[u64]) -> f64 {
    if depths.is_empty() {
        return 0.0;
    }
    let n = depths.len() as f64;
    let mean = depths.iter().map(|&d| d as f64).sum::<f64>() / n;
    if mean <= 0.0 {
        return 0.0;
    }
    let var = depths.iter().map(|&d| (d as f64 - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() / mean
}

/// Take one sampler tick: snapshot the metrics, push every derived
/// series, and return the window digest for the watchdog. Public so the
/// tick-latency micro-bench and tests can drive ticks without a thread.
pub fn sample_tick(metrics: &Metrics, series: &SampleSet, prev: &mut PrevCounters) -> Observation {
    let now = Instant::now();
    let dt = now.duration_since(prev.at).as_secs_f64().max(1e-9);
    // relaxed-ok: sampler-tick stat reads; fields are independent
    let completed = metrics.completed.load(Ordering::Relaxed);
    let steals = metrics.steals.load(Ordering::Relaxed);
    let shed = metrics.shed.load(Ordering::Relaxed);
    let cache_hits = metrics.cache_hits.load(Ordering::Relaxed);
    let cache_misses = metrics.cache_misses.load(Ordering::Relaxed);
    let cache_evictions = metrics.cache_evictions.load(Ordering::Relaxed);
    let injector = metrics.injector_depth.load(Ordering::Relaxed);
    let prepared = metrics.prepared_depth.load(Ordering::Relaxed);
    let panics = metrics.worker_panics.load(Ordering::Relaxed);
    let workers = metrics.balance_workers.load(Ordering::Relaxed) as usize;

    let completions_delta = completed.saturating_sub(prev.completed);
    let hits_delta = cache_hits.saturating_sub(prev.cache_hits);
    let misses_delta = cache_misses.saturating_sub(prev.cache_misses);
    let evictions_delta = cache_evictions.saturating_sub(prev.cache_evictions);

    series.completions_per_s.push(completions_delta as f64 / dt);
    series.steals_per_s.push(steals.saturating_sub(prev.steals) as f64 / dt);
    series.sheds_per_s.push(shed.saturating_sub(prev.shed) as f64 / dt);
    let lookups = hits_delta + misses_delta;
    let hit_rate = if lookups > 0 {
        hits_delta as f64 / lookups as f64
    } else {
        // no lookups this window: carry the previous rate so the series
        // reads as "last known", not as a phantom 0%-hit collapse
        series.cache_hit_rate.last().unwrap_or(0.0)
    };
    series.cache_hit_rate.push(hit_rate);
    series.cache_evictions_per_s.push(evictions_delta as f64 / dt);
    series.injector_depth.push(injector as f64);
    series.prepared_depth.push(prepared as f64);
    let skew = skew_coefficient(&metrics.worker_deque_depth.snapshot(workers));
    series.deque_skew.push(skew);
    for class in Priority::ALL {
        let i = class.index();
        series.class_queue_p50[i]
            .push(metrics.class_queue_percentile(class, 50.0).unwrap_or(0.0));
        series.class_queue_p95[i]
            .push(metrics.class_queue_percentile(class, 95.0).unwrap_or(0.0));
    }
    series.ticks.fetch_add(1, Ordering::Release);

    prev.at = now;
    prev.completed = completed;
    prev.steals = steals;
    prev.shed = shed;
    prev.cache_hits = cache_hits;
    prev.cache_misses = cache_misses;
    prev.cache_evictions = cache_evictions;

    Observation {
        completions_delta,
        injector_depth: injector,
        deque_skew: skew,
        cache_hits_delta: hits_delta,
        cache_evictions_delta: evictions_delta,
        prepared_depth: prepared,
        worker_panics: panics,
    }
}

/// The background sampler thread.
#[derive(Debug)]
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Sampler {
    /// Spawn the sampler over a shared telemetry state. Takes the first
    /// tick after one full interval (the baseline is captured at spawn).
    pub fn spawn(state: Arc<super::TelemetryState>, interval: Duration) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("adip-telemetry-sampler".into())
                .spawn(move || {
                    let mut prev = PrevCounters::new(&state.metrics);
                    while !stop.load(Ordering::Acquire) {
                        // stepped sleep so shutdown latency is bounded by
                        // STOP_POLL, not the sample interval
                        let wake = Instant::now() + interval;
                        while Instant::now() < wake {
                            if stop.load(Ordering::Acquire) {
                                return;
                            }
                            std::thread::sleep(STOP_POLL.min(interval));
                        }
                        let obs = sample_tick(&state.metrics, &state.series, &mut prev);
                        state.watchdog.observe(&obs);
                    }
                })
                .expect("spawn telemetry sampler")
        };
        Sampler { stop, handle: Some(handle) }
    }

    /// Stop and join the sampler thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_ring_keeps_the_tail() {
        let s = Series::new("t");
        assert!(s.is_empty());
        assert!(s.last().is_none());
        for i in 0..(SERIES_CAP + 10) {
            s.push(i as f64);
        }
        assert_eq!(s.len(), SERIES_CAP as u64 + 10);
        assert_eq!(s.last(), Some((SERIES_CAP + 9) as f64));
        let tail = s.tail(4);
        assert_eq!(
            tail,
            vec![
                (SERIES_CAP + 6) as f64,
                (SERIES_CAP + 7) as f64,
                (SERIES_CAP + 8) as f64,
                (SERIES_CAP + 9) as f64
            ]
        );
        // asking for more than the ring holds returns the whole ring
        assert_eq!(s.tail(SERIES_CAP * 2).len(), SERIES_CAP);
        assert_eq!(s.tail(SERIES_CAP * 2)[0], 10.0, "oldest retained sample");
    }

    #[test]
    fn skew_coefficient_shapes() {
        assert_eq!(skew_coefficient(&[]), 0.0);
        assert_eq!(skew_coefficient(&[0, 0, 0]), 0.0, "idle fleet has no skew");
        assert_eq!(skew_coefficient(&[5, 5, 5, 5]), 0.0, "balanced fleet has no skew");
        // one hot worker among idle siblings: stddev/mean = sqrt(3) ≈ 1.73
        let hot = skew_coefficient(&[8, 0, 0, 0]);
        assert!((hot - 3.0f64.sqrt()).abs() < 1e-9, "{hot}");
        // mild imbalance scores well below the hot-spot shape
        assert!(skew_coefficient(&[4, 5, 6, 5]) < 0.2);
    }

    #[test]
    fn sample_tick_derives_rates_and_gauges() {
        let m = Metrics::default();
        let series = SampleSet::default();
        let mut prev = PrevCounters::new(&m);
        // window activity: 4 completions, 2 steals, cache 3 hits / 1 miss
        for _ in 0..4 {
            m.record_completion(10, 0.0, 0, 1);
        }
        m.steals.fetch_add(2, Ordering::Relaxed);
        m.record_cache(3, 0, 1, 0);
        m.injector_depth.store(7, Ordering::Relaxed);
        m.prepared_depth.store(2, Ordering::Relaxed);
        m.balance_workers.store(2, Ordering::Relaxed);
        m.worker_deque_depth.store(0, 6);
        m.worker_deque_depth.store(1, 0);
        m.record_latency(0.5, 0.1, Priority::Interactive);
        std::thread::sleep(Duration::from_millis(5));
        let obs = sample_tick(&m, &series, &mut prev);
        assert_eq!(obs.completions_delta, 4);
        assert_eq!(obs.injector_depth, 7);
        assert_eq!(obs.cache_hits_delta, 3);
        assert_eq!(obs.prepared_depth, 2);
        assert_eq!(series.ticks.load(Ordering::Acquire), 1);
        let cps = series.completions_per_s.last().unwrap();
        assert!(cps > 0.0, "{cps}");
        let sps = series.steals_per_s.last().unwrap();
        assert!(sps > 0.0 && sps < cps, "{sps} vs {cps}");
        assert_eq!(series.cache_hit_rate.last(), Some(0.75));
        assert_eq!(series.injector_depth.last(), Some(7.0));
        assert_eq!(series.prepared_depth.last(), Some(2.0));
        // [6, 0]: mean 3, stddev 3 → coefficient 1
        assert!((series.deque_skew.last().unwrap() - 1.0).abs() < 1e-9);
        let p50 = series.class_queue_p50[Priority::Interactive.index()].last().unwrap();
        assert!((p50 - 0.5).abs() < 1e-6, "{p50}");
        assert_eq!(series.class_queue_p50[Priority::Batch.index()].last(), Some(0.0));

        // a second, idle window: rates fall to 0, hit rate carries over
        std::thread::sleep(Duration::from_millis(5));
        let obs = sample_tick(&m, &series, &mut prev);
        assert_eq!(obs.completions_delta, 0);
        assert_eq!(series.completions_per_s.last(), Some(0.0));
        assert_eq!(series.cache_hit_rate.last(), Some(0.75), "carried through idle window");
        assert_eq!(series.ticks.load(Ordering::Acquire), 2);
    }

    #[test]
    fn sample_set_lists_every_series() {
        let s = SampleSet::default();
        let names: Vec<&str> = s.all().iter().map(|x| x.name()).collect();
        assert_eq!(names.len(), 8 + 2 * Priority::COUNT);
        for want in [
            "completions_per_s",
            "steals_per_s",
            "sheds_per_s",
            "cache_hit_rate",
            "cache_evictions_per_s",
            "injector_depth",
            "prepared_depth",
            "deque_skew",
            "queue_p50_interactive",
            "queue_p95_background",
        ] {
            assert!(names.contains(&want), "{want} missing from {names:?}");
        }
    }
}
