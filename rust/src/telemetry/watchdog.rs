//! Watchdog: a rule engine over the sampler's windowed observations.
//!
//! Every sampler tick feeds one [`Observation`] into
//! [`Watchdog::observe`]. Each rule tracks a *sustained episode*: the
//! breach condition must hold for [`WatchdogConfig::windows`] consecutive
//! ticks before the rule fires, and a firing episode stays latched —
//! silent — until the condition clears, so one sustained stall produces
//! exactly one event (not one per tick). Fired events carry a wall-clock
//! timestamp and a human detail string into a bounded ring surfaced by
//! `/statusz`, and per-rule counters surfaced as
//! `adip_watchdog_events_total{rule=...}`.
//!
//! The watchdog only ever *reads* metrics (via the sampler) and writes
//! its own state, so it can never perturb pipeline behavior — the same
//! observability contract the trace recorder keeps. These events are
//! exactly the decision inputs ROADMAP item 3's adaptive controller will
//! consume.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Events retained in the bounded ring (`/statusz` shows the tail; the
/// per-rule counters never forget).
pub const EVENT_RING_CAP: usize = 64;

/// Identity of every watchdog rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Injector depth held or rose while completions stayed flat: the
    /// fabric is accepting work it isn't finishing.
    QueueStall,
    /// Per-worker deque depths stayed badly imbalanced (coefficient of
    /// variation above threshold): stealing is off or losing.
    DequeSkew,
    /// Weight-cache evictions outpaced hits: the working set no longer
    /// fits and the cache is churning instead of serving.
    CacheThrash,
    /// Prepared batches piled up ahead of execution: workers are the
    /// bottleneck, not the prepare stage.
    PrepareBacklog,
    /// A coordinator worker thread died to a panic (service degrades but
    /// survives — the fabric re-homed its queue).
    WorkerPanic,
}

impl Rule {
    /// Number of rules (sizes the per-rule counter array).
    pub const COUNT: usize = 5;

    /// All rules, in report order.
    pub const ALL: [Rule; Rule::COUNT] = [
        Rule::QueueStall,
        Rule::DequeSkew,
        Rule::CacheThrash,
        Rule::PrepareBacklog,
        Rule::WorkerPanic,
    ];

    /// Stable external name (the `rule` label of
    /// `adip_watchdog_events_total` and the `/statusz` key).
    pub fn name(self) -> &'static str {
        match self {
            Rule::QueueStall => "queue_stall",
            Rule::DequeSkew => "deque_skew",
            Rule::CacheThrash => "cache_thrash",
            Rule::PrepareBacklog => "prepare_backlog",
            Rule::WorkerPanic => "worker_panic",
        }
    }

    fn index(self) -> usize {
        match self {
            Rule::QueueStall => 0,
            Rule::DequeSkew => 1,
            Rule::CacheThrash => 2,
            Rule::PrepareBacklog => 3,
            Rule::WorkerPanic => 4,
        }
    }
}

/// Watchdog thresholds. The defaults are deliberately conservative —
/// a rule that cries wolf is worse than no rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WatchdogConfig {
    /// Consecutive breached sampler windows before an episode fires.
    pub windows: u32,
    /// Deque-skew coefficient (stddev/mean of per-worker deque depths)
    /// at or above which a window counts as breached.
    pub skew_threshold: f64,
    /// Prepared-batch backlog (gauge) at or above which a window counts
    /// as breached.
    pub backlog_threshold: u64,
}

impl Default for WatchdogConfig {
    fn default() -> WatchdogConfig {
        WatchdogConfig { windows: 3, skew_threshold: 1.25, backlog_threshold: 8 }
    }
}

/// One sampler window's digest — everything the rules look at. Produced
/// by `sampler::sample_tick`, or built directly by tests driving
/// synthetic episodes.
#[derive(Debug, Clone, Copy, Default)]
pub struct Observation {
    /// Requests completed during this window.
    pub completions_delta: u64,
    /// Injector depth at the end of the window (gauge).
    pub injector_depth: u64,
    /// Coefficient of variation of per-worker deque depths.
    pub deque_skew: f64,
    /// Weight-cache hits during this window.
    pub cache_hits_delta: u64,
    /// Weight-cache evictions during this window.
    pub cache_evictions_delta: u64,
    /// Prepared-batch backlog at the end of the window (gauge).
    pub prepared_depth: u64,
    /// Cumulative worker-panic counter at the end of the window.
    pub worker_panics: u64,
}

/// One fired watchdog event.
#[derive(Debug, Clone)]
pub struct WatchdogEvent {
    pub rule: Rule,
    /// Wall-clock milliseconds since the Unix epoch — watchdog events
    /// are operator-facing and must be correlatable with logs outside
    /// this process, so this is a deliberate (allowlisted) wall-clock
    /// read; everything hot-path uses monotonic `Instant`s.
    pub unix_ms: u64,
    /// Sampler tick number the event fired on (1-based).
    pub tick: u64,
    /// Human-readable context captured at fire time.
    pub detail: String,
}

/// Sustained-episode tracker: `observe` returns true exactly once per
/// episode — on the tick the breach count first reaches the window
/// threshold — and re-arms only after the condition fully clears.
#[derive(Debug, Default, Clone, Copy)]
struct Episode {
    consecutive: u32,
    active: bool,
}

impl Episode {
    fn observe(&mut self, breached: bool, windows: u32) -> bool {
        if !breached {
            *self = Episode::default();
            return false;
        }
        self.consecutive = self.consecutive.saturating_add(1);
        if self.consecutive >= windows && !self.active {
            self.active = true;
            return true;
        }
        false
    }
}

/// Cross-tick rule state, guarded by one mutex (only the sampler thread
/// observes; readers touch the atomics and the event ring instead).
#[derive(Debug, Default)]
struct WatchState {
    tick: u64,
    prev_injector: u64,
    prev_panics: u64,
    /// Episode trackers for the windowed rules, indexed like
    /// [`Rule::index`] (worker-panic is edge-triggered, not windowed).
    episodes: [Episode; 4],
}

/// The rule engine. One per telemetry tier, shared between the sampler
/// thread (writer via [`Watchdog::observe`]) and HTTP sessions (readers).
#[derive(Debug, Default)]
pub struct Watchdog {
    cfg: WatchdogConfig,
    /// Per-rule fire counters (`adip_watchdog_events_total{rule=...}`).
    fired: [AtomicU64; Rule::COUNT],
    /// Whether a queue-stall episode is currently active — feeds
    /// `/healthz` readiness.
    stall_active: AtomicBool,
    state: Mutex<WatchState>,
    events: Mutex<VecDeque<WatchdogEvent>>,
}

impl Watchdog {
    /// A watchdog with explicit thresholds.
    pub fn with_config(cfg: WatchdogConfig) -> Watchdog {
        Watchdog { cfg, ..Watchdog::default() }
    }

    /// Feed one sampler window. Returns the rules that fired on this
    /// tick (at most one firing per rule per episode).
    pub fn observe(&self, obs: &Observation) -> Vec<Rule> {
        let mut fired: Vec<(Rule, String)> = Vec::new();
        let tick;
        {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            st.tick += 1;
            tick = st.tick;
            // queue stall: depth held-or-rose while nothing completed.
            // `>= prev` (not `>`) so a full-and-wedged injector counts as
            // stalled even when producers are backpressured flat.
            let stall = obs.injector_depth > 0
                && obs.injector_depth >= st.prev_injector
                && obs.completions_delta == 0;
            if st.episodes[Rule::QueueStall.index()].observe(stall, self.cfg.windows) {
                fired.push((
                    Rule::QueueStall,
                    format!(
                        "injector depth {} with 0 completions for {} windows",
                        obs.injector_depth, self.cfg.windows
                    ),
                ));
            }
            self.stall_active
                .store(st.episodes[Rule::QueueStall.index()].active, Ordering::Release);

            let skew = obs.deque_skew >= self.cfg.skew_threshold;
            if st.episodes[Rule::DequeSkew.index()].observe(skew, self.cfg.windows) {
                fired.push((
                    Rule::DequeSkew,
                    format!(
                        "deque skew coefficient {:.2} >= {:.2} for {} windows",
                        obs.deque_skew, self.cfg.skew_threshold, self.cfg.windows
                    ),
                ));
            }

            let thrash = obs.cache_evictions_delta > 0
                && obs.cache_evictions_delta > obs.cache_hits_delta;
            if st.episodes[Rule::CacheThrash.index()].observe(thrash, self.cfg.windows) {
                fired.push((
                    Rule::CacheThrash,
                    format!(
                        "{} evictions vs {} hits per window for {} windows",
                        obs.cache_evictions_delta, obs.cache_hits_delta, self.cfg.windows
                    ),
                ));
            }

            let backlog = obs.prepared_depth >= self.cfg.backlog_threshold;
            if st.episodes[Rule::PrepareBacklog.index()].observe(backlog, self.cfg.windows) {
                fired.push((
                    Rule::PrepareBacklog,
                    format!(
                        "prepared backlog {} >= {} for {} windows",
                        obs.prepared_depth, self.cfg.backlog_threshold, self.cfg.windows
                    ),
                ));
            }

            // worker panic: edge-triggered on the cumulative counter —
            // every lost worker is its own episode, immediately.
            if obs.worker_panics > st.prev_panics {
                fired.push((
                    Rule::WorkerPanic,
                    format!(
                        "{} new worker panic(s), {} total",
                        obs.worker_panics - st.prev_panics,
                        obs.worker_panics
                    ),
                ));
            }
            st.prev_injector = obs.injector_depth;
            st.prev_panics = obs.worker_panics;
        }
        for (rule, detail) in &fired {
            self.record(*rule, tick, detail.clone());
        }
        fired.into_iter().map(|(r, _)| r).collect()
    }

    fn record(&self, rule: Rule, tick: u64, detail: String) {
        self.fired[rule.index()].fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        let event = WatchdogEvent { rule, unix_ms: wall_clock_unix_ms(), tick, detail };
        let mut ring = self.events.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() == EVENT_RING_CAP {
            ring.pop_front();
        }
        ring.push_back(event);
    }

    /// How many times `rule` has fired since start.
    pub fn fired(&self, rule: Rule) -> u64 {
        self.fired[rule.index()].load(Ordering::Relaxed) // relaxed-ok: stat read
    }

    /// Whether a queue-stall episode is active right now (feeds
    /// `/healthz` readiness: a stalled server is serving scrapes but not
    /// work).
    pub fn stall_active(&self) -> bool {
        self.stall_active.load(Ordering::Acquire)
    }

    /// The retained event tail, oldest first.
    pub fn recent_events(&self) -> Vec<WatchdogEvent> {
        self.events.lock().unwrap_or_else(|e| e.into_inner()).iter().cloned().collect()
    }

    /// Append the watchdog's Prometheus series to a `/metrics` body.
    /// This runs in the HTTP handler, *not* in `Metrics::render`, so the
    /// exposition the rest of the stack produces is bit-identical with
    /// telemetry off.
    pub fn render_prometheus(&self, s: &mut String) {
        let _ = writeln!(
            s,
            "# HELP adip_watchdog_events_total Watchdog rule firings since start.\n\
             # TYPE adip_watchdog_events_total counter"
        );
        for rule in Rule::ALL {
            let _ = writeln!(
                s,
                "adip_watchdog_events_total{{rule=\"{}\"}} {}",
                rule.name(),
                self.fired(rule)
            );
        }
    }
}

/// Wall-clock milliseconds since the Unix epoch, for operator-facing
/// event timestamps only (see [`WatchdogEvent::unix_ms`]). This module
/// is the lint allowlist for `SystemTime::now` — hot paths must use
/// monotonic `Instant`s (`wall-clock-containment`).
fn wall_clock_unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map_or(0, |d| d.as_millis() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stall_obs(depth: u64) -> Observation {
        Observation { injector_depth: depth, ..Observation::default() }
    }

    #[test]
    fn queue_stall_fires_exactly_once_per_sustained_episode() {
        let w = Watchdog::default(); // windows = 3
        // ramp: depth rising, completions flat — breach on every tick
        assert!(w.observe(&stall_obs(2)).is_empty());
        assert!(w.observe(&stall_obs(3)).is_empty());
        assert_eq!(w.observe(&stall_obs(3)), vec![Rule::QueueStall], "third window fires");
        assert!(w.stall_active());
        // the episode stays latched: more stalled windows add nothing
        for d in [4, 5, 6] {
            assert!(w.observe(&stall_obs(d)).is_empty(), "latched episode must not re-fire");
        }
        assert_eq!(w.fired(Rule::QueueStall), 1);
        // recovery: completions move — episode clears
        let recovered =
            Observation { completions_delta: 9, injector_depth: 1, ..Observation::default() };
        assert!(w.observe(&recovered).is_empty());
        assert!(!w.stall_active());
        // a second sustained stall is a new episode: fires once more
        assert!(w.observe(&stall_obs(5)).is_empty());
        assert!(w.observe(&stall_obs(5)).is_empty());
        assert_eq!(w.observe(&stall_obs(5)), vec![Rule::QueueStall]);
        assert_eq!(w.fired(Rule::QueueStall), 2);
    }

    #[test]
    fn dropping_injector_depth_is_not_a_stall() {
        let w = Watchdog::default();
        // depth falls every window (the fabric is draining, completions
        // just aren't attributed this window): never a breach
        for d in [9, 7, 5, 3, 2, 1] {
            assert!(w.observe(&stall_obs(d)).is_empty());
        }
        assert_eq!(w.fired(Rule::QueueStall), 0);
        assert!(!w.stall_active());
    }

    #[test]
    fn deque_skew_needs_sustained_windows() {
        let w = Watchdog::with_config(WatchdogConfig { windows: 2, ..WatchdogConfig::default() });
        let skewed = Observation {
            completions_delta: 1,
            deque_skew: 2.0,
            ..Observation::default()
        };
        let flat = Observation { completions_delta: 1, ..Observation::default() };
        assert!(w.observe(&skewed).is_empty(), "one skewed window is noise");
        assert!(w.observe(&flat).is_empty(), "a clear window resets the count");
        assert!(w.observe(&skewed).is_empty());
        assert_eq!(w.observe(&skewed), vec![Rule::DequeSkew]);
        assert_eq!(w.fired(Rule::DequeSkew), 1);
    }

    #[test]
    fn cache_thrash_compares_evictions_to_hits() {
        let w = Watchdog::with_config(WatchdogConfig { windows: 1, ..WatchdogConfig::default() });
        let healthy = Observation {
            completions_delta: 1,
            cache_hits_delta: 10,
            cache_evictions_delta: 2,
            ..Observation::default()
        };
        assert!(w.observe(&healthy).is_empty(), "hits outpacing evictions is healthy");
        let thrash = Observation {
            completions_delta: 1,
            cache_hits_delta: 1,
            cache_evictions_delta: 5,
            ..Observation::default()
        };
        assert_eq!(w.observe(&thrash), vec![Rule::CacheThrash]);
    }

    #[test]
    fn prepare_backlog_threshold() {
        let w = Watchdog::with_config(WatchdogConfig {
            windows: 1,
            backlog_threshold: 4,
            ..WatchdogConfig::default()
        });
        let light =
            Observation { completions_delta: 1, prepared_depth: 3, ..Observation::default() };
        assert!(w.observe(&light).is_empty());
        let heavy =
            Observation { completions_delta: 1, prepared_depth: 4, ..Observation::default() };
        assert_eq!(w.observe(&heavy), vec![Rule::PrepareBacklog]);
    }

    #[test]
    fn worker_panic_is_edge_triggered_per_panic() {
        let w = Watchdog::default();
        let calm = Observation { completions_delta: 1, ..Observation::default() };
        assert!(w.observe(&calm).is_empty());
        let one = Observation { completions_delta: 1, worker_panics: 1, ..Observation::default() };
        assert_eq!(w.observe(&one), vec![Rule::WorkerPanic], "first panic fires immediately");
        assert!(w.observe(&one).is_empty(), "steady count does not re-fire");
        let two = Observation { completions_delta: 1, worker_panics: 2, ..Observation::default() };
        assert_eq!(w.observe(&two), vec![Rule::WorkerPanic], "each new panic is an episode");
        assert_eq!(w.fired(Rule::WorkerPanic), 2);
        let ev = w.recent_events();
        assert_eq!(ev.len(), 2);
        assert!(ev[1].detail.contains("2 total"), "{:?}", ev[1].detail);
    }

    #[test]
    fn event_ring_is_bounded() {
        let w = Watchdog::default();
        for i in 0..(EVENT_RING_CAP as u64 + 10) {
            let obs = Observation {
                completions_delta: 1,
                worker_panics: i + 1,
                ..Observation::default()
            };
            assert_eq!(w.observe(&obs).len(), 1);
        }
        let ev = w.recent_events();
        assert_eq!(ev.len(), EVENT_RING_CAP, "ring keeps only the tail");
        assert_eq!(w.fired(Rule::WorkerPanic), EVENT_RING_CAP as u64 + 10, "counters never forget");
        // oldest events were shed; the tail is the most recent ones
        assert!(ev[0].tick > 1);
        assert!(ev.last().unwrap().detail.contains("total"));
        // ticks are monotone and timestamps are sane (post-2020 wall clock)
        assert!(ev.windows(2).all(|p| p[0].tick < p[1].tick));
        assert!(ev.iter().all(|e| e.unix_ms > 1_577_836_800_000));
    }

    #[test]
    fn prometheus_render_covers_every_rule() {
        let w = Watchdog::with_config(WatchdogConfig { windows: 1, ..WatchdogConfig::default() });
        let _ = w.observe(&stall_obs(1));
        let mut s = String::new();
        w.render_prometheus(&mut s);
        assert!(s.contains("# HELP adip_watchdog_events_total"));
        assert!(s.contains("# TYPE adip_watchdog_events_total counter"));
        assert!(s.contains("adip_watchdog_events_total{rule=\"queue_stall\"} 1"), "{s}");
        for rule in Rule::ALL {
            assert!(s.contains(&format!("rule=\"{}\"", rule.name())), "{s}");
        }
    }
}
