//! Energy integration (paper Fig. 10 model).
//!
//! The paper's energy evaluation is `E = P(arch, N) × t`: the post-PnR
//! power of the array at its operating point times the simulated execution
//! time. This reproduces the published per-model totals exactly (GPT-2
//! −62.8%, BERT +2.3%, BitNet +24.4% — see `engine` tests). An optional
//! per-byte DRAM term is provided for ablations beyond the paper's model.

use crate::arch::Architecture;
use crate::power::{adip_point, dip_point, ws_point};

/// Energy model for one architecture instance.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Architecture power at the operating point (W).
    pub power_w: f64,
    /// Clock frequency (Hz).
    pub freq_hz: f64,
    /// Optional DRAM energy per byte (J/B); 0 in the paper's model.
    pub dram_j_per_byte: f64,
}

impl EnergyModel {
    /// Model for an architecture at array size `n`, 1 GHz, paper's model
    /// (no explicit DRAM term).
    pub fn paper(arch: Architecture, n: usize) -> EnergyModel {
        let power_w = match arch {
            Architecture::Ws => ws_point(n).power_w,
            Architecture::Dip => dip_point(n).power_w,
            Architecture::Adip => adip_point(n).power_w,
        };
        EnergyModel { power_w, freq_hz: 1e9, dram_j_per_byte: 0.0 }
    }

    /// Energy for an execution of `cycles` moving `dram_bytes`.
    pub fn energy_joules(&self, cycles: u64, dram_bytes: u64) -> f64 {
        self.power_w * cycles as f64 / self.freq_hz + self.dram_j_per_byte * dram_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_models_use_calibrated_power() {
        let adip = EnergyModel::paper(Architecture::Adip, 64);
        assert!((adip.power_w - 1.45).abs() < 0.01);
        let dip = EnergyModel::paper(Architecture::Dip, 64);
        assert!((dip.power_w - 0.858).abs() < 1e-9);
        let ws = EnergyModel::paper(Architecture::Ws, 64);
        assert!((ws.power_w / dip.power_w - 1.25).abs() < 1e-9);
    }

    #[test]
    fn integration() {
        let m = EnergyModel { power_w: 2.0, freq_hz: 1e9, dram_j_per_byte: 0.0 };
        assert!((m.energy_joules(1_000_000, 0) - 2e-3).abs() < 1e-12);
        let with_dram = EnergyModel { dram_j_per_byte: 1e-12, ..m };
        assert!(with_dram.energy_joules(1_000_000, 1_000) > m.energy_joules(1_000_000, 1_000));
    }
}
