//! Workload-level evaluation engine — produces the Figs. 9/10/11 numbers.
//!
//! For each attention stage of a model, the engine composes the GEMM-level
//! analytical estimate ([`crate::analytical::estimate_gemm`], validated
//! cycle-for-cycle against the register-level simulators) with the
//! calibrated power model, yielding latency, energy and memory access per
//! stage and in total for WS / DiP / ADiP.

use crate::analytical::gemm::{estimate_gemm, MemoryPolicy};
use crate::arch::{ArchConfig, Architecture};
use crate::quant::PrecisionMode;
use crate::sim::energy::EnergyModel;
use crate::workload::{stages::attention_workloads, AttentionStage, StageWorkload, TransformerModel};

/// Simulation configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Array configuration (the paper evaluates 32×32).
    pub arch: ArchConfig,
    /// Clock (Hz).
    pub freq_hz: f64,
    /// Memory counting policy.
    pub memory: MemoryPolicy,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { arch: ArchConfig::default(), freq_hz: 1e9, memory: MemoryPolicy::default() }
    }
}

/// Evaluation result for one attention stage.
#[derive(Debug, Clone, Copy)]
pub struct StageResult {
    /// Stage evaluated.
    pub stage: AttentionStage,
    /// Mode it executed in on this architecture.
    pub mode: PrecisionMode,
    /// Total cycles across all instances/layers.
    pub cycles: u64,
    /// Wall-clock seconds at the configured frequency.
    pub seconds: f64,
    /// Energy (J).
    pub energy_j: f64,
    /// Memory traffic (bytes, paper policy).
    pub memory_bytes: u64,
    /// Useful operations.
    pub ops: u64,
}

/// Evaluation result for a whole model on one architecture.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Architecture evaluated.
    pub arch: Architecture,
    /// Model name.
    pub model: &'static str,
    /// Per-stage results (six stages).
    pub stages: Vec<StageResult>,
}

impl EvalResult {
    /// Total cycles.
    pub fn total_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.cycles).sum()
    }

    /// Total seconds.
    pub fn total_seconds(&self) -> f64 {
        self.stages.iter().map(|s| s.seconds).sum()
    }

    /// Total energy (J).
    pub fn total_energy_j(&self) -> f64 {
        self.stages.iter().map(|s| s.energy_j).sum()
    }

    /// Total memory traffic (bytes).
    pub fn total_memory_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.memory_bytes).sum()
    }

    /// Total ops.
    pub fn total_ops(&self) -> u64 {
        self.stages.iter().map(|s| s.ops).sum()
    }

    /// Achieved throughput in ops/s.
    pub fn achieved_ops_per_sec(&self) -> f64 {
        self.total_ops() as f64 / self.total_seconds()
    }

    /// Sum over projection (activation-to-weight) stages only.
    pub fn projection_cycles(&self) -> u64 {
        self.stages.iter().filter(|s| s.stage.is_projection()).map(|s| s.cycles).sum()
    }
}

/// Evaluate one stage workload on one architecture.
pub fn evaluate_stage(arch: Architecture, sw: &StageWorkload, cfg: &SimConfig) -> StageResult {
    let est = estimate_gemm(arch, &cfg.arch, sw.gemm, sw.mode, cfg.memory);
    let instances = sw.instances();
    let cycles = est.cycles * instances;
    let energy = EnergyModel::paper(arch, cfg.arch.n).energy_joules(cycles, 0);
    StageResult {
        stage: sw.stage,
        mode: est.mode,
        cycles,
        seconds: cycles as f64 / cfg.freq_hz,
        energy_j: energy,
        memory_bytes: est.memory_bytes * instances,
        ops: est.ops * instances,
    }
}

/// Evaluate a model's full attention workload on one architecture.
pub fn evaluate_model(arch: Architecture, model: &TransformerModel, cfg: &SimConfig) -> EvalResult {
    let stages =
        attention_workloads(model).iter().map(|sw| evaluate_stage(arch, sw, cfg)).collect();
    EvalResult { arch, model: model.name, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{bert_large, bitnet_1_58b, gpt2_medium};

    fn improvements(model: &TransformerModel) -> (f64, f64, f64) {
        let cfg = SimConfig::default();
        let dip = evaluate_model(Architecture::Dip, model, &cfg);
        let adip = evaluate_model(Architecture::Adip, model, &cfg);
        let latency = 1.0 - adip.total_cycles() as f64 / dip.total_cycles() as f64;
        let energy = 1.0 - adip.total_energy_j() / dip.total_energy_j();
        let memory = 1.0 - adip.total_memory_bytes() as f64 / dip.total_memory_bytes() as f64;
        (latency * 100.0, energy * 100.0, memory * 100.0)
    }

    #[test]
    fn fig9_total_latency_improvements() {
        // Paper: GPT-2 ~0%, BERT 40%, BitNet 53.6% vs DiP at 32×32.
        let (g, _, _) = improvements(&gpt2_medium());
        assert!(g.abs() < 0.1, "GPT-2 latency improvement {g}%");
        let (b, _, _) = improvements(&bert_large());
        assert!((b - 40.0).abs() < 0.15, "BERT latency improvement {b}%");
        let (n, _, _) = improvements(&bitnet_1_58b());
        assert!((n - 53.6).abs() < 0.15, "BitNet latency improvement {n}%");
    }

    #[test]
    fn fig10_total_energy_changes() {
        // Paper: GPT-2 −62.8% (overhead), BERT +2.3%, BitNet +24.4%.
        let (_, g, _) = improvements(&gpt2_medium());
        assert!((g + 62.8).abs() < 0.3, "GPT-2 energy change {g}%");
        let (_, b, _) = improvements(&bert_large());
        assert!((b - 2.3).abs() < 0.4, "BERT energy change {b}%");
        let (_, n, _) = improvements(&bitnet_1_58b());
        assert!((n - 24.4).abs() < 0.4, "BitNet energy change {n}%");
    }

    #[test]
    fn fig11_total_memory_savings() {
        // Paper: GPT-2 0%, BERT ~40%, BitNet ~53.6%.
        let (_, _, g) = improvements(&gpt2_medium());
        assert!(g.abs() < 0.1, "GPT-2 memory saving {g}%");
        let (_, _, b) = improvements(&bert_large());
        assert!((b - 40.0).abs() < 0.15, "BERT memory saving {b}%");
        let (_, _, n) = improvements(&bitnet_1_58b());
        assert!((n - 53.6).abs() < 0.15, "BitNet memory saving {n}%");
    }

    #[test]
    fn projection_stage_improvements_50_and_75_percent() {
        // Paper Fig. 9: projection stages improve 50% (BERT, 8b×4b) and
        // 75% (BitNet, 8b×2b).
        let cfg = SimConfig::default();
        for (model, want) in [(bert_large(), 50.0), (bitnet_1_58b(), 75.0)] {
            let dip = evaluate_model(Architecture::Dip, &model, &cfg);
            let adip = evaluate_model(Architecture::Adip, &model, &cfg);
            let imp =
                (1.0 - adip.projection_cycles() as f64 / dip.projection_cycles() as f64) * 100.0;
            assert!((imp - want).abs() < 0.1, "{}: {imp}%", model.name);
        }
    }

    #[test]
    fn act_act_energy_overhead_is_power_ratio() {
        // Activation-to-activation stages: same cycles, ADiP power ratio
        // (1.628 at 32×32) → ~62.8% energy overhead, no latency change.
        let cfg = SimConfig::default();
        let model = bitnet_1_58b();
        let dip = evaluate_model(Architecture::Dip, &model, &cfg);
        let adip = evaluate_model(Architecture::Adip, &model, &cfg);
        for (d, a) in dip.stages.iter().zip(&adip.stages) {
            if !d.stage.is_projection() {
                let cyc_ratio = a.cycles as f64 / d.cycles as f64;
                assert!((cyc_ratio - 1.0).abs() < 1e-3, "{}: cycles ×{cyc_ratio}", d.stage);
                let e_ratio = a.energy_j / d.energy_j;
                assert!((e_ratio - 1.628).abs() < 0.01, "{}: energy ×{e_ratio}", d.stage);
            }
        }
    }

    #[test]
    fn ws_total_latency_exceeds_dip() {
        let cfg = SimConfig::default();
        for model in TransformerModel::evaluated() {
            let ws = evaluate_model(Architecture::Ws, &model, &cfg);
            let dip = evaluate_model(Architecture::Dip, &model, &cfg);
            let ratio = ws.total_cycles() as f64 / dip.total_cycles() as f64;
            assert!(ratio > 1.4 && ratio < 2.0, "{}: WS/DiP {ratio}", model.name);
            // memory traffic identical
            assert_eq!(ws.total_memory_bytes(), dip.total_memory_bytes());
        }
    }

    #[test]
    fn totals_are_stage_sums() {
        let cfg = SimConfig::default();
        let r = evaluate_model(Architecture::Adip, &gpt2_medium(), &cfg);
        assert_eq!(r.stages.len(), 6);
        let sum: u64 = r.stages.iter().map(|s| s.cycles).sum();
        assert_eq!(r.total_cycles(), sum);
        assert_eq!(r.total_ops(), gpt2_medium().total_attention_ops());
        assert!(r.achieved_ops_per_sec() > 0.0);
    }
}
