//! Multi-bank SRAM / DRAM traffic accounting.
//!
//! Counts the off-array traffic of tile passes (Fig. 11's model: activation
//! tile reads + stationary carrier tile reads; psums on-chip; write-back
//! symmetric across architectures) and models the **multi-bank runtime
//! interleaving** used for activation-to-activation workloads: the paper
//! claims the online interleave of k dynamic tiles is re-scheduled across
//! multi-bank memories “with almost zero overhead” — true exactly when the
//! k concurrent tile streams land in distinct banks.

use crate::quant::PrecisionMode;

/// Cumulative traffic counters (bytes / events).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryCounters {
    /// Activation tile bytes read.
    pub act_read_bytes: u64,
    /// Stationary (packed weight) tile bytes read.
    pub weight_read_bytes: u64,
    /// Output tile bytes written (tracked; excluded from the paper total).
    pub output_write_bytes: u64,
    /// Tile-read events.
    pub tile_reads: u64,
    /// Bank-conflict stall cycles during runtime interleaving.
    pub conflict_cycles: u64,
}

impl MemoryCounters {
    /// The paper's Fig. 11 total: input traffic only.
    pub fn paper_total_bytes(&self) -> u64 {
        self.act_read_bytes + self.weight_read_bytes
    }

    /// Total including write-back (ablation).
    pub fn total_with_outputs(&self) -> u64 {
        self.paper_total_bytes() + self.output_write_bytes
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &MemoryCounters) {
        self.act_read_bytes += other.act_read_bytes;
        self.weight_read_bytes += other.weight_read_bytes;
        self.output_write_bytes += other.output_write_bytes;
        self.tile_reads += other.tile_reads;
        self.conflict_cycles += other.conflict_cycles;
    }
}

/// A multi-banked scratchpad with traffic counters.
#[derive(Debug, Clone)]
pub struct MemorySystem {
    /// Number of SRAM banks.
    pub banks: usize,
    counters: MemoryCounters,
}

impl MemorySystem {
    /// System with `banks` banks (the paper's design uses ≥4 so the 8b×2b
    /// runtime interleave never conflicts).
    pub fn new(banks: usize) -> MemorySystem {
        assert!(banks > 0);
        MemorySystem { banks, counters: MemoryCounters::default() }
    }

    /// Record one activation tile read (`n×n` int8).
    pub fn read_act_tile(&mut self, n: usize) {
        self.counters.act_read_bytes += (n * n) as u64;
        self.counters.tile_reads += 1;
    }

    /// Record one stationary tile read: the packed carrier is `n×n` bytes
    /// regardless of mode (k interleaved tiles at 8/k bits each).
    pub fn read_stationary_tile(&mut self, n: usize, _mode: PrecisionMode) {
        self.counters.weight_read_bytes += (n * n) as u64;
        self.counters.tile_reads += 1;
    }

    /// Record write-back of `k` output tiles, requantized to int8.
    pub fn write_output_tiles(&mut self, n: usize, k: usize) {
        self.counters.output_write_bytes += (n * n * k) as u64;
    }

    /// Bulk-record the traffic of a whole functionally-executed GEMM:
    /// `act_tile_reads` activation tiles, `stationary_tile_reads` packed
    /// carrier tiles and `output_tiles` written output tiles, all `n×n`
    /// bytes. Equivalent to the corresponding sequence of per-tile calls —
    /// the functional backend uses this so its counters match the
    /// tile-level schedule exactly without looping over tiles.
    pub fn record_gemm(
        &mut self,
        n: usize,
        act_tile_reads: u64,
        stationary_tile_reads: u64,
        output_tiles: u64,
    ) {
        let tile = (n * n) as u64;
        self.counters.act_read_bytes += act_tile_reads * tile;
        self.counters.weight_read_bytes += stationary_tile_reads * tile;
        self.counters.output_write_bytes += output_tiles * tile;
        self.counters.tile_reads += act_tile_reads + stationary_tile_reads;
    }

    /// Model a runtime interleave of `k` dynamic tile streams: each stream
    /// `i` is assigned bank `(base + i) % banks`. Returns the stall cycles
    /// added (0 when all streams land in distinct banks — the paper's
    /// “almost zero overhead” condition, which holds whenever
    /// `banks ≥ k`). With fewer banks, colliding streams serialize.
    pub fn runtime_interleave(&mut self, k: usize, tile_cycles: u64) -> u64 {
        let rounds = k.div_ceil(self.banks) as u64;
        let stall = (rounds - 1) * tile_cycles;
        self.counters.conflict_cycles += stall;
        stall
    }

    /// Snapshot of the counters.
    pub fn counters(&self) -> MemoryCounters {
        self.counters
    }

    /// Reset all counters.
    pub fn reset(&mut self) {
        self.counters = MemoryCounters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_accounting() {
        let mut m = MemorySystem::new(4);
        m.read_act_tile(32);
        m.read_stationary_tile(32, PrecisionMode::W2);
        m.write_output_tiles(32, 4);
        let c = m.counters();
        assert_eq!(c.act_read_bytes, 1024);
        assert_eq!(c.weight_read_bytes, 1024);
        assert_eq!(c.output_write_bytes, 4096);
        assert_eq!(c.paper_total_bytes(), 2048);
        assert_eq!(c.total_with_outputs(), 6144);
        assert_eq!(c.tile_reads, 2);
    }

    #[test]
    fn carrier_bytes_independent_of_mode() {
        // the packed stationary tile always costs N² bytes — this is the
        // source of the k× weight-traffic saving
        for mode in PrecisionMode::ALL {
            let mut m = MemorySystem::new(4);
            m.read_stationary_tile(16, mode);
            assert_eq!(m.counters().weight_read_bytes, 256);
        }
    }

    #[test]
    fn interleave_zero_overhead_with_enough_banks() {
        let mut m = MemorySystem::new(4);
        assert_eq!(m.runtime_interleave(4, 32), 0);
        assert_eq!(m.runtime_interleave(2, 32), 0);
        assert_eq!(m.counters().conflict_cycles, 0);
    }

    #[test]
    fn interleave_serializes_with_few_banks() {
        let mut m = MemorySystem::new(2);
        assert_eq!(m.runtime_interleave(4, 32), 32);
        let mut one = MemorySystem::new(1);
        assert_eq!(one.runtime_interleave(4, 32), 96);
    }

    #[test]
    fn record_gemm_equals_per_tile_calls() {
        let mut tile_by_tile = MemorySystem::new(4);
        for _ in 0..6 {
            tile_by_tile.read_act_tile(8);
        }
        for _ in 0..2 {
            tile_by_tile.read_stationary_tile(8, PrecisionMode::W4);
        }
        tile_by_tile.write_output_tiles(8, 3);
        let mut bulk = MemorySystem::new(4);
        bulk.record_gemm(8, 6, 2, 3);
        assert_eq!(bulk.counters(), tile_by_tile.counters());
    }

    #[test]
    fn merge_and_reset() {
        let mut a = MemorySystem::new(4);
        a.read_act_tile(8);
        let mut c = a.counters();
        c.merge(&a.counters());
        assert_eq!(c.act_read_bytes, 128);
        a.reset();
        assert_eq!(a.counters(), MemoryCounters::default());
    }
}
