//! Functional + timed co-simulation of quantized GEMMs.
//!
//! [`CoSim`] executes a real (integer) GEMM tile-by-tile through an
//! [`crate::arch::SystolicArray`] model: every pass produces the actual
//! psum tiles (bit-exact with the PE arithmetic) *and* advances the cycle,
//! energy and memory accounting. This is the execution backend behind the
//! coordinator and the end-to-end examples — the numbers and the numerics
//! come out of the same tile schedule.
//!
//! Two fusion shapes implement the paper's multi-matrix modes:
//!
//! * [`CoSim::run_gemm`] — single weight matrix; adjacent output-column
//!   tiles are interleaved (`k` j-tiles per stationary pass, Fig. 5(b)(c)).
//! * [`CoSim::run_gemm_set`] — several weight matrices sharing one input
//!   (Q/K/V — Fig. 5(d)): same-coordinate tiles of each matrix interleave.

use anyhow::{ensure, Result};

use crate::arch::{Architecture, FunctionalRun, SystolicArray, TilePass};
use crate::dataflow::{interleave_tiles, tiling::tile_grid, Mat};
use crate::quant::PrecisionMode;
use crate::sim::energy::EnergyModel;
use crate::sim::memory::{MemoryCounters, MemorySystem};

/// Result of a co-simulated GEMM (set).
#[derive(Debug, Clone)]
pub struct CoSimResult {
    /// Output matrices (one per weight matrix), exact integer psums.
    pub outputs: Vec<Mat>,
    /// Stationary-tile passes executed.
    pub passes: u64,
    /// Total cycles (fill/drain + steady streaming + interleave stalls).
    pub cycles: u64,
    /// Energy (J) over those cycles.
    pub energy_j: f64,
    /// Memory counters for the run.
    pub memory: MemoryCounters,
}

/// Co-simulator: one array instance + memory system + energy model.
pub struct CoSim<A: SystolicArray> {
    array: A,
    memory: MemorySystem,
    energy: EnergyModel,
}

impl<A: SystolicArray> CoSim<A> {
    /// Build a co-simulator around an array model with the paper's energy
    /// model and a 4-bank scratchpad.
    pub fn new(array: A) -> CoSim<A> {
        let energy = EnergyModel::paper(array.architecture(), array.n());
        CoSim { array, memory: MemorySystem::new(4), energy }
    }

    /// Access the underlying array model.
    pub fn array(&self) -> &A {
        &self.array
    }

    /// Execute `C = A · B` with `B` quantized for `mode`.
    ///
    /// `a` is `m×k` int8; `b` is `k×n` with entries in the mode's weight
    /// range. On ADiP, groups of `interleave_factor` adjacent output-column
    /// tiles share each activation-tile fetch. `runtime_interleave` marks
    /// activation-to-activation workloads whose preprocessing happens
    /// online via the multi-bank rescheduling.
    pub fn run_gemm(
        &mut self,
        a: &Mat,
        b: &Mat,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<CoSimResult> {
        ensure!(a.cols() == b.rows(), "inner dimension mismatch");
        if let Some(f) = self.array.as_functional() {
            let run = f.run_gemm(a, b, mode)?;
            return Ok(self.finish_functional(run, runtime_interleave));
        }
        let exec_mode = self.exec_mode(mode);
        let kf = if self.array.architecture() == Architecture::Adip {
            exec_mode.interleave_factor()
        } else {
            1
        };
        let n = self.array.n();
        let grid = tile_grid(a.rows(), a.cols(), b.cols(), n);
        let mut c = Mat::zeros(a.rows(), b.cols());

        let mut passes = 0u64;
        let mut steady_total = 0u64;
        let mut stall_total = 0u64;
        let mut fill: u64 = 0;
        let start_counters = self.memory.counters();

        // §Perf iteration 6: extract each activation tile once (it is
        // re-streamed for every output-column group; the memory counter
        // still charges one read per pass — the SRAM fetch is real, the
        // host-side re-extraction is not).
        let act_tiles: Vec<Mat> = (0..grid.tiles_m())
            .flat_map(|i| (0..grid.tiles_k()).map(move |kk| (i, kk)))
            .map(|(i, kk)| a.tile(i * n, kk * n, n, n))
            .collect();
        let act_tile = |i: usize, kk: usize| &act_tiles[i * grid.tiles_k() + kk];

        for jg in (0..grid.tiles_n()).step_by(kf) {
            let js: Vec<usize> = (jg..(jg + kf).min(grid.tiles_n())).collect();
            for kk in 0..grid.tiles_k() {
                // Build the stationary tile: adjacent j-tiles interleaved.
                let tiles: Vec<Mat> =
                    js.iter().map(|&j| b.tile(kk * n, j * n, n, n)).collect();
                let refs: Vec<&Mat> = tiles.iter().collect();
                let stationary = interleave_tiles(&refs, exec_mode)?;
                self.memory.read_stationary_tile(n, exec_mode);
                if runtime_interleave {
                    stall_total += self
                        .memory
                        .runtime_interleave(js.len(), self.array.steady_tile_cycles(exec_mode));
                }

                for i in 0..grid.tiles_m() {
                    let act = act_tile(i, kk);
                    self.memory.read_act_tile(n);
                    let pass: TilePass = self.array.tile_pass(act, &stationary)?;
                    fill = fill.max(pass.latency_cycles - pass.steady_cycles);
                    steady_total += pass.steady_cycles;
                    passes += 1;
                    for (s, out) in pass.outputs.iter().enumerate() {
                        c.accumulate(i * n, js[s] * n, out);
                    }
                    if kk == grid.tiles_k() - 1 {
                        self.memory.write_output_tiles(n, js.len());
                    }
                }
            }
        }

        let cycles = fill + steady_total + stall_total;
        let mut mem = self.memory.counters();
        // report only this run's deltas
        let mut delta = MemoryCounters::default();
        delta.act_read_bytes = mem.act_read_bytes - start_counters.act_read_bytes;
        delta.weight_read_bytes = mem.weight_read_bytes - start_counters.weight_read_bytes;
        delta.output_write_bytes = mem.output_write_bytes - start_counters.output_write_bytes;
        delta.tile_reads = mem.tile_reads - start_counters.tile_reads;
        delta.conflict_cycles = mem.conflict_cycles - start_counters.conflict_cycles;
        mem = delta;

        Ok(CoSimResult {
            outputs: vec![c],
            passes,
            cycles,
            energy_j: self.energy.energy_joules(cycles, 0),
            memory: mem,
        })
    }

    /// Execute a shared-input GEMM set `C_s = A · B_s` (Q/K/V-style):
    /// same-coordinate tiles of up to `interleave_factor` matrices share
    /// one stationary pass and one activation fetch per pass.
    pub fn run_gemm_set(
        &mut self,
        a: &Mat,
        bs: &[&Mat],
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<CoSimResult> {
        ensure!(!bs.is_empty(), "need at least one weight matrix");
        if let Some(f) = self.array.as_functional() {
            let run = f.run_gemm_set(a, bs, mode)?;
            return Ok(self.finish_functional(run, runtime_interleave));
        }
        let exec_mode = self.exec_mode(mode);
        let adip = self.array.architecture() == Architecture::Adip;
        let cap = if adip { exec_mode.interleave_factor() } else { 1 };
        // (sets larger than the interleave capacity are handled naturally:
        // the generalized slot list below chunks into capacity-sized
        // stationary groups)
        for b in bs {
            ensure!(
                b.rows() == bs[0].rows() && b.cols() == bs[0].cols(),
                "weight matrices must share a shape"
            );
            ensure!(a.cols() == b.rows(), "inner dimension mismatch");
        }

        if !adip || bs.len() == 1 {
            // No set fusion available: run each matrix separately.
            let mut outputs = Vec::new();
            let mut passes = 0;
            let mut cycles = 0;
            let mut energy = 0.0;
            let mut mem = MemoryCounters::default();
            for b in bs {
                let r = self.run_gemm(a, b, mode, runtime_interleave)?;
                outputs.extend(r.outputs);
                passes += r.passes;
                cycles += r.cycles;
                energy += r.energy_j;
                mem.merge(&r.memory);
            }
            return Ok(CoSimResult { outputs, passes, cycles, energy_j: energy, memory: mem });
        }

        let n = self.array.n();
        let grid = tile_grid(a.rows(), a.cols(), bs[0].cols(), n);
        let mut outs: Vec<Mat> = bs.iter().map(|b| Mat::zeros(a.rows(), b.cols())).collect();
        let start = self.memory.counters();
        let (mut passes, mut steady_total, mut stall_total, mut fill) = (0u64, 0u64, 0u64, 0u64);

        // Generalized stationary slots: every (source matrix, output-column
        // tile) pair is one interleave slot — a pass may mix matrices AND
        // adjacent j-tiles, so capacity is always filled (e.g. 3 Q/K/V
        // matrices with 4 j-tiles each pack into ceil(12/4) = 3 groups per
        // reduction step instead of 4).
        let slots: Vec<(usize, usize)> = (0..grid.tiles_n())
            .flat_map(|j| (0..bs.len()).map(move |s| (s, j)))
            .collect();
        for group in slots.chunks(cap) {
            for kk in 0..grid.tiles_k() {
                let tiles: Vec<Mat> =
                    group.iter().map(|&(s, j)| bs[s].tile(kk * n, j * n, n, n)).collect();
                let refs: Vec<&Mat> = tiles.iter().collect();
                let stationary = interleave_tiles(&refs, exec_mode)?;
                self.memory.read_stationary_tile(n, exec_mode);
                if runtime_interleave {
                    stall_total += self
                        .memory
                        .runtime_interleave(group.len(), self.array.steady_tile_cycles(exec_mode));
                }
                for i in 0..grid.tiles_m() {
                    let act = a.tile(i * n, kk * n, n, n);
                    self.memory.read_act_tile(n);
                    let pass = self.array.tile_pass(&act, &stationary)?;
                    fill = fill.max(pass.latency_cycles - pass.steady_cycles);
                    steady_total += pass.steady_cycles;
                    passes += 1;
                    for (slot, out) in group.iter().zip(&pass.outputs) {
                        outs[slot.0].accumulate(i * n, slot.1 * n, out);
                    }
                    if kk == grid.tiles_k() - 1 {
                        self.memory.write_output_tiles(n, group.len());
                    }
                }
            }
        }

        let cycles = fill + steady_total + stall_total;
        let end = self.memory.counters();
        let memory = MemoryCounters {
            act_read_bytes: end.act_read_bytes - start.act_read_bytes,
            weight_read_bytes: end.weight_read_bytes - start.weight_read_bytes,
            output_write_bytes: end.output_write_bytes - start.output_write_bytes,
            tile_reads: end.tile_reads - start.tile_reads,
            conflict_cycles: end.conflict_cycles - start.conflict_cycles,
        };
        Ok(CoSimResult {
            outputs: outs,
            passes,
            cycles,
            energy_j: self.energy.energy_joules(cycles, 0),
            memory,
        })
    }

    /// The mode the array actually executes (DiP/WS degrade to 8b×8b).
    fn exec_mode(&self, requested: PrecisionMode) -> PrecisionMode {
        if self.array.supports(requested) {
            requested
        } else {
            PrecisionMode::W8
        }
    }

    /// Turn a whole-GEMM functional run into a [`CoSimResult`]: record the
    /// bulk memory traffic, replay the runtime-interleave bank accounting
    /// (stall cycles + conflict counters, exactly as the tile-level
    /// schedule would incur them), and integrate energy.
    fn finish_functional(&mut self, run: FunctionalRun, runtime_interleave: bool) -> CoSimResult {
        let n = self.array.n();
        self.memory.record_gemm(n, run.passes, run.stationary_fetches, run.output_tiles);
        let mut stall_total = 0u64;
        if runtime_interleave {
            for &(fetches, size) in &run.interleave_groups {
                for _ in 0..fetches {
                    stall_total += self.memory.runtime_interleave(size, run.steady_cycles);
                }
            }
        }
        let cycles = run.cycles + stall_total;
        CoSimResult {
            memory: MemoryCounters {
                act_read_bytes: run.passes * (n * n) as u64,
                weight_read_bytes: run.stationary_fetches * (n * n) as u64,
                output_write_bytes: run.output_tiles * (n * n) as u64,
                tile_reads: run.passes + run.stationary_fetches,
                conflict_cycles: stall_total,
            },
            outputs: run.outputs,
            passes: run.passes,
            cycles,
            energy_j: self.energy.energy_joules(cycles, 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{AdipArray, ArchConfig, DipArray, WsArray};
    use crate::testutil::{check, Rng};

    fn adip(n: usize) -> CoSim<AdipArray> {
        CoSim::new(AdipArray::new(ArchConfig::with_n(n)))
    }

    #[test]
    fn gemm_outputs_exact_all_modes() {
        check(
            "cosim-gemm-exact",
            601,
            10,
            |rng| {
                let mode = *rng.choose(&PrecisionMode::ALL);
                let (m, k, n) = (1 + rng.below(20), 1 + rng.below(20), 1 + rng.below(40));
                (mode, Mat::random(rng, m, k, 8), Mat::random(rng, k, n, mode.weight_bits()))
            },
            |(mode, a, b)| {
                let mut sim = adip(8);
                let r = sim.run_gemm(a, b, *mode, false).map_err(|e| e.to_string())?;
                if r.outputs[0] == a.matmul(b) {
                    Ok(())
                } else {
                    Err("cosim output != reference".into())
                }
            },
        );
    }

    #[test]
    fn pass_counts_match_analytical_fusion() {
        let mut rng = Rng::seeded(603);
        let a = Mat::random(&mut rng, 64, 64, 8);
        let b = Mat::random(&mut rng, 64, 64, 2);
        // ADiP 8b×2b on 16×16: tiles 4×4×4; j-fusion /4 → 4·4·1 = 16 passes
        let mut sim = adip(16);
        let r = sim.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(r.passes, 16);
        // DiP: all 64 passes at 8b×8b
        let mut dsim = CoSim::new(DipArray::new(ArchConfig::with_n(16)));
        let rd = dsim.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(rd.passes, 64);
        assert_eq!(rd.outputs[0], r.outputs[0]);
        // ~4× cycle advantage
        let ratio = rd.cycles as f64 / r.cycles as f64;
        assert!(ratio > 3.7 && ratio <= 4.01, "ratio {ratio}");
        // ~4× memory advantage (paper input-traffic policy)
        let mratio =
            rd.memory.paper_total_bytes() as f64 / r.memory.paper_total_bytes() as f64;
        assert!((mratio - 4.0).abs() < 1e-9, "mem ratio {mratio}");
    }

    #[test]
    fn qkv_set_shares_input_and_matches_reference() {
        let mut rng = Rng::seeded(605);
        let x = Mat::random(&mut rng, 32, 32, 8);
        let wq = Mat::random(&mut rng, 32, 32, 2);
        let wk = Mat::random(&mut rng, 32, 32, 2);
        let wv = Mat::random(&mut rng, 32, 32, 2);
        let mut sim = adip(8);
        let r = sim.run_gemm_set(&x, &[&wq, &wk, &wv], PrecisionMode::W2, false).unwrap();
        assert_eq!(r.outputs.len(), 3);
        assert_eq!(r.outputs[0], x.matmul(&wq));
        assert_eq!(r.outputs[1], x.matmul(&wk));
        assert_eq!(r.outputs[2], x.matmul(&wv));
        // 3 matrices × 4 j-tiles = 12 slots → 3 capacity-4 groups per
        // reduction step: 3 · 4 (k) · 4 (m) = 48 passes
        assert_eq!(r.passes, 48);
        // DiP needs 3× the passes
        let mut dsim = CoSim::new(DipArray::new(ArchConfig::with_n(8)));
        let rd = dsim.run_gemm_set(&x, &[&wq, &wk, &wv], PrecisionMode::W2, false).unwrap();
        assert_eq!(rd.passes, 192);
        assert_eq!(rd.outputs, r.outputs);
    }

    #[test]
    fn ws_and_dip_agree_functionally() {
        let mut rng = Rng::seeded(607);
        let a = Mat::random(&mut rng, 24, 24, 8);
        let b = Mat::random(&mut rng, 24, 24, 8);
        let mut ws = CoSim::new(WsArray::new(ArchConfig::with_n(8)));
        let mut dip = CoSim::new(DipArray::new(ArchConfig::with_n(8)));
        let rw = ws.run_gemm(&a, &b, PrecisionMode::W8, false).unwrap();
        let rd = dip.run_gemm(&a, &b, PrecisionMode::W8, false).unwrap();
        assert_eq!(rw.outputs, rd.outputs);
        assert!(rw.cycles > rd.cycles, "WS {} vs DiP {}", rw.cycles, rd.cycles);
    }

    #[test]
    fn runtime_interleave_zero_overhead_with_default_banks() {
        let mut rng = Rng::seeded(609);
        let a = Mat::random(&mut rng, 16, 16, 8);
        let b = Mat::random(&mut rng, 16, 16, 2);
        let mut sim = adip(8);
        let with = sim.run_gemm(&a, &b, PrecisionMode::W2, true).unwrap();
        let mut sim2 = adip(8);
        let without = sim2.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(with.cycles, without.cycles, "4 banks cover the 8b×2b interleave");
        assert_eq!(with.memory.conflict_cycles, 0);
    }

    #[test]
    fn energy_scales_with_cycles() {
        let mut rng = Rng::seeded(611);
        let a = Mat::random(&mut rng, 32, 32, 8);
        let b = Mat::random(&mut rng, 32, 32, 8);
        let mut sim = adip(8);
        let r1 = sim.run_gemm(&a, &b, PrecisionMode::W8, false).unwrap();
        let expect = EnergyModel::paper(Architecture::Adip, 8).energy_joules(r1.cycles, 0);
        assert!((r1.energy_j - expect).abs() < 1e-15);
    }

    #[test]
    fn set_overflow_chunks_and_mismatch_rejects() {
        let mut rng = Rng::seeded(613);
        let a = Mat::random(&mut rng, 8, 8, 8);
        let bs: Vec<Mat> = (0..5).map(|_| Mat::random(&mut rng, 8, 8, 2)).collect();
        let refs: Vec<&Mat> = bs.iter().collect();
        let mut sim = adip(8);
        // 5 matrices exceed the 4-way interleave: chunked into 4 + 1
        let r = sim.run_gemm_set(&a, &refs, PrecisionMode::W2, false).unwrap();
        assert_eq!(r.outputs.len(), 5);
        assert_eq!(r.passes, 2);
        for (out, b) in r.outputs.iter().zip(&bs) {
            assert_eq!(*out, a.matmul(b));
        }
        let b = Mat::zeros(8, 8);
        let short = Mat::zeros(4, 8);
        assert!(sim.run_gemm_set(&a, &[&b, &short], PrecisionMode::W4, false).is_err());
        let none: Vec<&Mat> = vec![];
        assert!(sim.run_gemm_set(&a, &none, PrecisionMode::W8, false).is_err());
    }
}
