//! The cycle-accurate evaluation simulator (paper §V-B).
//!
//! “A cycle-accurate simulator is developed to evaluate the latency, energy
//! consumption, and memory access for WS, DiP, and ADiP architectures. The
//! simulator employs analytical models for WS and DiP architectures,
//! derived from the DiP work.”
//!
//! * [`engine`] — evaluates whole Transformer attention workloads per
//!   stage/architecture and produces the latency / energy / memory numbers
//!   behind Figs. 9, 10 and 11.
//! * [`cosim`] — functional + timed co-simulation: runs real quantized
//!   GEMMs tile-by-tile through the [`crate::arch`] models, producing both
//!   the numeric outputs and the cycle/energy/memory accounting in one
//!   pass. The coordinator's execution backend.
//! * [`memory`] — multi-bank SRAM / DRAM traffic counters, including the
//!   runtime-interleaving bank model for activation-to-activation
//!   workloads.
//! * [`energy`] — energy integration over cycles from the calibrated power
//!   model.

pub mod cosim;
pub mod energy;
pub mod engine;
pub mod memory;

pub use cosim::{CoSim, CoSimResult};
pub use energy::EnergyModel;
pub use engine::{evaluate_model, evaluate_stage, EvalResult, SimConfig, StageResult};
pub use memory::{MemoryCounters, MemorySystem};
