//! Per-ticket lifecycle tracing: a bounded, sharded, lock-free span
//! recorder for the whole admit → prepare → execute pipeline.
//!
//! Every stage a request passes through — submit/admit, batch formation
//! (with `batch_seq` and aging promotions), prepare, balance-fabric
//! residency, steal and coalesce decisions, shed/demotion verdicts,
//! per-core shard execution, reduce and split-back — can record a
//! [`SpanRecord`] against the request's ticket id. The records feed two
//! exports: a whole-run Chrome/Perfetto trace-event JSON dump
//! ([`Recorder::chrome_trace_json`], wired to `--trace-out <path>` on
//! `adip serve`/`adip trace`) and a per-ticket view
//! ([`Recorder::for_ticket`], surfaced as `Ticket::trace()`), so tests
//! and the CLI can assert on stage timings.
//!
//! # Ring layout
//!
//! Records land in [`OBS_SHARDS`] independent ring arrays (default
//! [`OBS_SHARD_CAP`] slots each), mirroring the sharded latency
//! reservoir of `coordinator/metrics.rs`: each thread is assigned a
//! shard round-robin on first use (thread-local cache), so concurrent
//! writers almost never contend on the same shard's `claimed` counter.
//! A slot is five `AtomicU64` words — ticket, start, duration, aux,
//! header — written payload-first with `Relaxed` stores and *published*
//! by a single `Release` store of the packed header word
//! (`seq+1 | worker | kind`; zero means "not yet published"). Readers
//! `Acquire`-load the header before touching the payload, so a snapshot
//! taken mid-write can never observe a torn record — it simply skips
//! slots whose publish store hasn't landed yet.
//!
//! The rings are **non-overwriting**: a writer claims a slot index with
//! one `fetch_add` on the shard's monotone `claimed` counter, and an
//! index past the end of the ring increments the global drop counter
//! instead of writing anywhere. The hot path therefore never blocks,
//! never spins, and never tears an already-published record; the cost
//! of a full ring is losing *new* events, observably
//! (`Recorder::dropped`, exported as `adip_trace_dropped_total`). The
//! invariant `snapshot().len() + dropped() == events recorded` is exact
//! once writers quiesce.
//!
//! # Sampling and the zero-overhead-when-off contract
//!
//! [`TraceMode`] is `Off` (default), `On`, or `Sample(n)` — trace every
//! `n`-th ticket (`ticket % n == 0`). The mode lives in one `AtomicU64`;
//! when tracing is off (or a ticket is sampled out), every recording
//! entry point is a single `Relaxed` load plus a branch — no clock
//! reads, no allocation (the rings themselves are only allocated by
//! [`Recorder::enable`], so a never-enabled recorder costs a pointer).
//! Tracing never influences scheduling, outputs, or simulated
//! accounting: the differential axis in
//! `rust/tests/integration_pipeline.rs` holds outputs and
//! cycles/passes/memory/energy bit-exact across off/on/sampled, and
//! `rust/benches/bench_obs.rs` bounds the wall-clock overhead
//! (≤5% saturated throughput fully sampled, ≤1% at 1/16).

use std::cell::Cell;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Shard count of the recorder (same rationale as the latency
/// reservoir's 16 shards: comfortably more than the worker count).
pub const OBS_SHARDS: usize = 16;
/// Default slots per shard (65536 records per run before drops).
pub const OBS_SHARD_CAP: usize = 4096;

/// Virtual lane (Chrome-trace `tid`) of the submitting client threads.
pub const LANE_CLIENT: u32 = 0;
/// Virtual lane of the router (batch formation, shed/promote verdicts).
pub const LANE_ROUTER: u32 = 1;

/// Virtual lane of worker `w` (prepare/fabric/execute/shard/reduce).
pub fn lane_worker(w: usize) -> u32 {
    2 + w as u32
}

/// What to trace. Default `Off`; `Sample(n)` traces every `n`-th ticket.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// Tracing disabled: the recording fast path is one relaxed atomic
    /// load and a branch.
    #[default]
    Off,
    /// Trace every ticket.
    On,
    /// Trace tickets with `ticket % n == 0` (n ≥ 2).
    Sample(u32),
}

impl TraceMode {
    /// Pack into the recorder's atomic word (0 off, 1 on, n≥2 sample).
    fn word(self) -> u64 {
        match self {
            TraceMode::Off => 0,
            TraceMode::On => 1,
            TraceMode::Sample(n) => u64::from(n.max(2)),
        }
    }

    fn from_word(w: u64) -> TraceMode {
        match w {
            0 => TraceMode::Off,
            1 => TraceMode::On,
            n => TraceMode::Sample(n as u32),
        }
    }
}

impl fmt::Display for TraceMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceMode::Off => f.write_str("off"),
            TraceMode::On => f.write_str("on"),
            TraceMode::Sample(n) => write!(f, "sample={n}"),
        }
    }
}

impl FromStr for TraceMode {
    type Err = String;

    fn from_str(s: &str) -> Result<TraceMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(TraceMode::Off),
            "on" => Ok(TraceMode::On),
            other => match other.strip_prefix("sample=") {
                Some(n) => match n.parse::<u32>() {
                    Ok(0) => Err("sample rate must be >= 1".into()),
                    Ok(1) => Ok(TraceMode::On),
                    Ok(n) => Ok(TraceMode::Sample(n)),
                    Err(_) => Err(format!("bad sample rate {n:?}")),
                },
                None => Err(format!("unknown trace mode {other:?} (off|on|sample=N)")),
            },
        }
    }
}

/// Lifecycle stage of one record. The discriminants are stable (packed
/// into the slot header) and start at 1 so a zero header always means
/// "unpublished slot".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// Instant: the client admitted the request (aux = priority rank).
    Submit = 1,
    /// Span: admission-queue wait, enqueue → batch formation (router lane).
    Queue = 2,
    /// Instant: the router formed the batch (aux = `batch_seq`).
    BatchForm = 3,
    /// Instant: the aging rule promoted this request one class.
    Promote = 4,
    /// Instant: the shedding policy failed this request fast.
    Shed = 5,
    /// Instant: the shedding policy demoted this request to Background.
    Demote = 6,
    /// Span: host-side preparation (fingerprinting) of the batch.
    Prepare = 7,
    /// Span: residency on the balance fabric, push → worker pop.
    Fabric = 8,
    /// Instant: the batch was stolen (aux = victim<<32 | thief).
    Steal = 9,
    /// Instant: this ticket led a coalesced pass (aux = member count).
    Coalesce = 10,
    /// Instant: this ticket joined a coalesced pass (aux = leader id).
    CoalesceMember = 11,
    /// Span: batch execution on the worker's cluster (aux = `batch_seq`).
    Execute = 12,
    /// Span: one shard dispatched to a cluster core (aux = shard seq).
    Shard = 13,
    /// Span: the cluster reduce/reassembly step.
    Reduce = 14,
    /// Span: splitting a coalesced pass back per member (aux = leader id).
    SplitBack = 15,
    /// Instant: the outcome was sent back to the ticket.
    Complete = 16,
    /// Instant: the request was cancelled (aux: 0 = requested by the
    /// client, 1 = honored by the router, 2 = honored by the prepare
    /// stage, 3 = honored by a worker at fabric pop).
    Cancel = 17,
}

impl SpanKind {
    /// Decode a header byte; `None` for an unknown discriminant (a
    /// future-versioned or corrupt slot is skipped, never misread).
    pub fn from_u8(v: u8) -> Option<SpanKind> {
        use SpanKind::*;
        Some(match v {
            1 => Submit,
            2 => Queue,
            3 => BatchForm,
            4 => Promote,
            5 => Shed,
            6 => Demote,
            7 => Prepare,
            8 => Fabric,
            9 => Steal,
            10 => Coalesce,
            11 => CoalesceMember,
            12 => Execute,
            13 => Shard,
            14 => Reduce,
            15 => SplitBack,
            16 => Complete,
            17 => Cancel,
            _ => return None,
        })
    }

    /// Stable lower-snake name (Chrome-trace event name, CLI output).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Queue => "queue",
            SpanKind::BatchForm => "batch_form",
            SpanKind::Promote => "promote",
            SpanKind::Shed => "shed",
            SpanKind::Demote => "demote",
            SpanKind::Prepare => "prepare",
            SpanKind::Fabric => "fabric",
            SpanKind::Steal => "steal",
            SpanKind::Coalesce => "coalesce",
            SpanKind::CoalesceMember => "coalesce_member",
            SpanKind::Execute => "execute",
            SpanKind::Shard => "shard",
            SpanKind::Reduce => "reduce",
            SpanKind::SplitBack => "split_back",
            SpanKind::Complete => "complete",
            SpanKind::Cancel => "cancel",
        }
    }
}

/// One decoded trace record. `start_ns` is relative to the recorder's
/// enable instant; `dur_ns == 0` marks an instant event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// The request id the record belongs to.
    pub ticket: u64,
    /// Lifecycle stage.
    pub kind: SpanKind,
    /// Virtual lane ([`LANE_CLIENT`], [`LANE_ROUTER`], [`lane_worker`]).
    pub worker: u32,
    /// Nanoseconds since the recorder was enabled.
    pub start_ns: u64,
    /// Span duration in nanoseconds (0 for instant events).
    pub dur_ns: u64,
    /// Kind-specific payload (see [`SpanKind`] docs).
    pub aux: u64,
    /// Global publication sequence (total order across shards).
    pub seq: u64,
}

/// One ring slot: payload words stored `Relaxed`, then published by a
/// `Release` store of the packed header (`(seq+1)<<24 | worker<<8 | kind`).
#[derive(Debug, Default)]
struct Slot {
    ticket: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    aux: AtomicU64,
    header: AtomicU64,
}

/// One non-overwriting ring: a monotone claim counter over a fixed slot
/// array. `claimed` keeps counting past the end — the overflow is the
/// shard's share of the drop counter.
#[derive(Debug)]
struct Shard {
    claimed: AtomicU64,
    slots: Vec<Slot>,
}

impl Shard {
    fn with_capacity(cap: usize) -> Shard {
        Shard { claimed: AtomicU64::new(0), slots: (0..cap).map(|_| Slot::default()).collect() }
    }
}

#[derive(Debug, Default)]
struct RecorderInner {
    /// 0 = off, 1 = on, n ≥ 2 = sample every n-th ticket. The only word
    /// the disabled fast path touches.
    mode: AtomicU64,
    /// Time zero of every `start_ns` (set by the first `enable`).
    epoch: OnceLock<Instant>,
    /// The rings; allocated by `enable`, never before.
    shards: OnceLock<Vec<Shard>>,
    /// Events lost to full rings (never blocks the hot path).
    dropped: AtomicU64,
    /// Global publication sequence.
    seq: AtomicU64,
}

/// Cheap, cloneable handle onto one trace store. A default recorder is
/// disabled and unallocated; [`Recorder::enable`] flips it on for every
/// clone (they share the store through the `Arc`).
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    inner: Arc<RecorderInner>,
}

fn pack_header(seq: u64, worker: u32, kind: SpanKind) -> u64 {
    ((seq + 1) << 24) | (u64::from(worker & 0xffff) << 8) | kind as u64
}

/// Round-robin thread → shard assignment, cached thread-locally (the
/// same scheme as the metrics latency reservoir).
fn my_shard(n: usize) -> usize {
    static NEXT_SHARD: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SHARD.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) as usize; // relaxed-ok: round-robin shard pick; exactness not required
            s.set(v);
        }
        v % n
    })
}

impl Recorder {
    /// Enable tracing at `mode` with the default ring capacity. A
    /// no-op for `TraceMode::Off` (nothing is allocated).
    pub fn enable(&self, mode: TraceMode) {
        self.enable_bounded(mode, OBS_SHARD_CAP);
    }

    /// [`Recorder::enable`] with an explicit per-shard slot count —
    /// lets tests exercise the full-ring drop path deterministically.
    /// The rings are allocated once; a second call only updates the mode.
    pub fn enable_bounded(&self, mode: TraceMode, slots_per_shard: usize) {
        if mode == TraceMode::Off {
            self.inner.mode.store(0, Ordering::Release);
            return;
        }
        self.inner.epoch.get_or_init(Instant::now);
        self.inner
            .shards
            .get_or_init(|| {
                (0..OBS_SHARDS).map(|_| Shard::with_capacity(slots_per_shard)).collect()
            });
        self.inner.mode.store(mode.word(), Ordering::Release);
    }

    /// The current mode.
    pub fn mode(&self) -> TraceMode {
        TraceMode::from_word(self.inner.mode.load(Ordering::Relaxed)) // relaxed-ok: mode word is self-contained; rings were published by enable()'s Release
    }

    /// Whether records for `ticket` are being kept. **The** disabled
    /// fast path: one relaxed load plus a branch.
    #[inline]
    pub fn enabled_for(&self, ticket: u64) -> bool {
        match self.inner.mode.load(Ordering::Relaxed) { // relaxed-ok: mode word is self-contained (the disabled fast path)
            0 => false,
            1 => true,
            n => ticket % n == 0,
        }
    }

    /// Record an instant event (duration 0) timestamped now.
    #[inline]
    pub fn event(&self, kind: SpanKind, ticket: u64, lane: u32, aux: u64) {
        if !self.enabled_for(ticket) {
            return;
        }
        let Some(&epoch) = self.inner.epoch.get() else { return };
        let start_ns = Instant::now().saturating_duration_since(epoch).as_nanos() as u64;
        self.record(kind, ticket, lane, start_ns, 0, aux);
    }

    /// Record a span that started at `start` and ends now.
    #[inline]
    pub fn span_since(&self, kind: SpanKind, ticket: u64, lane: u32, start: Instant, aux: u64) {
        if !self.enabled_for(ticket) {
            return;
        }
        self.span_at(kind, ticket, lane, start, start.elapsed(), aux);
    }

    /// Record a span with an explicit start instant and duration.
    #[inline]
    pub fn span_at(
        &self,
        kind: SpanKind,
        ticket: u64,
        lane: u32,
        start: Instant,
        dur: Duration,
        aux: u64,
    ) {
        if !self.enabled_for(ticket) {
            return;
        }
        let Some(&epoch) = self.inner.epoch.get() else { return };
        let start_ns = start.saturating_duration_since(epoch).as_nanos() as u64;
        self.record(kind, ticket, lane, start_ns, dur.as_nanos() as u64, aux);
    }

    /// Claim a slot and publish one record (see the module docs for the
    /// memory-ordering contract). Full shard → count a drop, touch
    /// nothing else.
    fn record(&self, kind: SpanKind, ticket: u64, lane: u32, start_ns: u64, dur_ns: u64, aux: u64) {
        let Some(shards) = self.inner.shards.get() else { return };
        let shard = &shards[my_shard(shards.len())];
        let idx = shard.claimed.fetch_add(1, Ordering::Relaxed) as usize; // relaxed-ok: slot claim: RMW uniqueness; publication is the header Release below
        if idx >= shard.slots.len() {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            return;
        }
        let seq = self.inner.seq.fetch_add(1, Ordering::Relaxed); // relaxed-ok: seq allocation: RMW uniqueness; ordering comes from the header publish
        let slot = &shard.slots[idx];
        // relaxed-ok: payload words; the header Release store below publishes them
        slot.ticket.store(ticket, Ordering::Relaxed);
        slot.start_ns.store(start_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.aux.store(aux, Ordering::Relaxed);
        slot.header.store(pack_header(seq, lane, kind), Ordering::Release);
    }

    /// Events lost to full rings.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed) // relaxed-ok: stat read
    }

    /// Decode every published record, sorted by `(start_ns, seq)`. Safe
    /// to call while writers are active: claimed-but-unpublished slots
    /// are skipped (their publish store hasn't landed), published slots
    /// are immutable.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let Some(shards) = self.inner.shards.get() else { return Vec::new() };
        let mut out = Vec::new();
        for shard in shards {
            let n = (shard.claimed.load(Ordering::Relaxed) as usize).min(shard.slots.len()); // relaxed-ok: claimed bound; unpublished slots are filtered by the header Acquire
            for slot in &shard.slots[..n] {
                let header = slot.header.load(Ordering::Acquire);
                if header == 0 {
                    continue; // claimed, not yet published
                }
                let Some(kind) = SpanKind::from_u8((header & 0xff) as u8) else { continue };
                out.push(SpanRecord {
                    ticket: slot.ticket.load(Ordering::Relaxed), // relaxed-ok: payload word; ordered by the header Acquire above
                    kind,
                    worker: ((header >> 8) & 0xffff) as u32,
                    start_ns: slot.start_ns.load(Ordering::Relaxed), // relaxed-ok: payload word; ordered by the header Acquire above
                    dur_ns: slot.dur_ns.load(Ordering::Relaxed), // relaxed-ok: payload word; ordered by the header Acquire above
                    aux: slot.aux.load(Ordering::Relaxed), // relaxed-ok: payload word; ordered by the header Acquire above
                    seq: (header >> 24) - 1,
                });
            }
        }
        out.sort_by_key(|r| (r.start_ns, r.seq));
        out
    }

    /// All records of one ticket, in `(start_ns, seq)` order — the
    /// backing of `Ticket::trace()`.
    pub fn for_ticket(&self, ticket: u64) -> Vec<SpanRecord> {
        let mut v = self.snapshot();
        v.retain(|r| r.ticket == ticket);
        v
    }

    /// Export every published record as Chrome/Perfetto trace-event
    /// JSON (`chrome://tracing`, <https://ui.perfetto.dev>): complete
    /// (`"X"`) events for spans, thread-scoped instant (`"i"`) events
    /// for markers, one process with a named thread per lane.
    pub fn chrome_trace_json(&self) -> String {
        let records = self.snapshot();
        let mut lanes: Vec<u32> = records.iter().map(|r| r.worker).collect();
        lanes.sort_unstable();
        lanes.dedup();
        let mut out = String::with_capacity(64 + records.len() * 96);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        for lane in lanes {
            let name = match lane {
                LANE_CLIENT => "client".to_string(),
                LANE_ROUTER => "router".to_string(),
                w => format!("worker-{}", w - 2),
            };
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{lane},\
                 \"args\":{{\"name\":\"{name}\"}}}}"
            ));
        }
        for r in &records {
            if !first {
                out.push(',');
            }
            first = false;
            let ts = r.start_ns as f64 / 1e3;
            let args = format!(
                "{{\"ticket\":{},\"aux\":{},\"seq\":{}}}",
                r.ticket, r.aux, r.seq
            );
            if r.dur_ns > 0 {
                out.push_str(&format!(
                    "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{ts:.3},\
                     \"dur\":{:.3},\"args\":{args}}}",
                    r.kind.name(),
                    r.worker,
                    r.dur_ns as f64 / 1e3,
                ));
            } else {
                out.push_str(&format!(
                    "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\"tid\":{},\
                     \"ts\":{ts:.3},\"args\":{args}}}",
                    r.kind.name(),
                    r.worker,
                ));
            }
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_mode_parses_and_displays() {
        assert_eq!("off".parse::<TraceMode>().unwrap(), TraceMode::Off);
        assert_eq!("on".parse::<TraceMode>().unwrap(), TraceMode::On);
        assert_eq!("sample=16".parse::<TraceMode>().unwrap(), TraceMode::Sample(16));
        assert_eq!("sample=1".parse::<TraceMode>().unwrap(), TraceMode::On, "1/1 == on");
        assert!("sample=0".parse::<TraceMode>().is_err());
        assert!("sample=x".parse::<TraceMode>().is_err());
        assert!("loud".parse::<TraceMode>().is_err());
        for m in [TraceMode::Off, TraceMode::On, TraceMode::Sample(4)] {
            assert_eq!(m.to_string().parse::<TraceMode>().unwrap(), m);
            assert_eq!(TraceMode::from_word(m.word()), m);
        }
        assert_eq!(TraceMode::default(), TraceMode::Off);
    }

    #[test]
    fn disabled_recorder_keeps_nothing() {
        let r = Recorder::default();
        assert_eq!(r.mode(), TraceMode::Off);
        assert!(!r.enabled_for(0));
        r.event(SpanKind::Submit, 1, LANE_CLIENT, 0);
        r.span_since(SpanKind::Execute, 1, 2, Instant::now(), 0);
        assert!(r.snapshot().is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn sampling_selects_every_nth_ticket() {
        let r = Recorder::default();
        r.enable(TraceMode::Sample(4));
        assert_eq!(r.mode(), TraceMode::Sample(4));
        for id in 1..=16u64 {
            r.event(SpanKind::Submit, id, LANE_CLIENT, 0);
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        assert!(snap.iter().all(|s| s.ticket % 4 == 0));
    }

    #[test]
    fn records_decode_in_order_and_filter_by_ticket() {
        let r = Recorder::default();
        r.enable(TraceMode::On);
        let t0 = Instant::now();
        r.event(SpanKind::Submit, 7, LANE_CLIENT, 2);
        r.span_at(SpanKind::Execute, 7, lane_worker(0), t0, Duration::from_micros(50), 9);
        r.event(SpanKind::Submit, 8, LANE_CLIENT, 0);
        let seven = r.for_ticket(7);
        assert_eq!(seven.len(), 2);
        assert_eq!(seven[0].kind, SpanKind::Submit);
        assert_eq!(seven[0].aux, 2);
        assert_eq!(seven[1].kind, SpanKind::Execute);
        assert_eq!(seven[1].worker, lane_worker(0));
        assert_eq!(seven[1].dur_ns, 50_000);
        let all = r.snapshot();
        assert_eq!(all.len(), 3);
        assert!(all.windows(2).all(|w| (w[0].start_ns, w[0].seq) <= (w[1].start_ns, w[1].seq)));
        // seqs are unique across the run
        let mut seqs: Vec<u64> = all.iter().map(|s| s.seq).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), 3);
    }

    #[test]
    fn full_ring_drops_exactly_and_never_blocks() {
        let r = Recorder::default();
        r.enable_bounded(TraceMode::On, 8);
        // single thread -> single shard: 20 records into 8 slots
        for i in 0..20u64 {
            r.event(SpanKind::Submit, i, LANE_CLIENT, i);
        }
        let snap = r.snapshot();
        assert_eq!(snap.len() as u64 + r.dropped(), 20, "every event kept or counted");
        assert_eq!(snap.len(), 8, "ring is non-overwriting");
        assert_eq!(r.dropped(), 12);
        // published records are the first 8, intact
        for s in &snap {
            assert_eq!(s.aux, s.ticket);
        }
    }

    /// Satellite: multi-writer stress — 4 producers × 1k events against
    /// deliberately tiny rings, with a scraper snapshotting throughout.
    /// Zero torn records (payload must match its self-describing aux),
    /// and the drop counter is exact once writers quiesce.
    #[test]
    fn multi_writer_stress_no_torn_records_exact_drops() {
        const WRITERS: u64 = 4;
        const EVENTS: u64 = 1000;
        let r = Recorder::default();
        r.enable_bounded(TraceMode::On, 64);
        let stop = Arc::new(AtomicU64::new(0));
        std::thread::scope(|scope| {
            let scraper = {
                let r = r.clone();
                let stop = stop.clone();
                scope.spawn(move || {
                    let mut seen = 0usize;
                    while stop.load(Ordering::Relaxed) == 0 {
                        for s in r.snapshot() {
                            assert_eq!(s.aux, s.ticket.wrapping_mul(3), "torn record {s:?}");
                            assert_eq!(s.kind, SpanKind::Execute);
                            seen += 1;
                        }
                    }
                    seen
                })
            };
            for w in 0..WRITERS {
                let r = r.clone();
                scope.spawn(move || {
                    let t0 = Instant::now();
                    for i in 0..EVENTS {
                        let ticket = w * 100_000 + i;
                        r.span_at(
                            SpanKind::Execute,
                            ticket,
                            lane_worker(w as usize),
                            t0,
                            Duration::from_nanos(i),
                            ticket.wrapping_mul(3),
                        );
                    }
                });
            }
            // writers join at scope end only after this: give the
            // scraper real concurrent traffic, then stop it
            std::thread::sleep(Duration::from_millis(10));
            stop.store(1, Ordering::Relaxed);
            assert!(scraper.join().unwrap() < usize::MAX);
        });
        let snap = r.snapshot();
        assert_eq!(
            snap.len() as u64 + r.dropped(),
            WRITERS * EVENTS,
            "claim/drop accounting must be exact after quiesce"
        );
        assert!(r.dropped() > 0, "tiny rings must overflow under this load");
        for s in &snap {
            assert_eq!(s.aux, s.ticket.wrapping_mul(3), "torn record {s:?}");
        }
        // publication seqs are unique
        let mut seqs: Vec<u64> = snap.iter().map(|s| s.seq).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), snap.len());
    }

    #[test]
    fn chrome_trace_json_is_well_formed() {
        let r = Recorder::default();
        r.enable(TraceMode::On);
        let t0 = Instant::now();
        r.event(SpanKind::Submit, 1, LANE_CLIENT, 0);
        r.span_at(SpanKind::Execute, 1, lane_worker(0), t0, Duration::from_micros(3), 1);
        let json = r.chrome_trace_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"ph\":\"M\""), "thread-name metadata present");
        assert!(json.contains("\"name\":\"client\""));
        assert!(json.contains("\"name\":\"worker-0\""));
        assert!(json.contains("\"ph\":\"X\""), "complete event for the span");
        assert!(json.contains("\"ph\":\"i\""), "instant event for the marker");
        assert!(json.contains("\"dur\":3.000"));
        for key in ["\"name\"", "\"ph\"", "\"pid\"", "\"tid\"", "\"ts\""] {
            assert!(json.contains(key), "required trace-event key {key}");
        }
        // an empty recorder still exports a loadable document
        let empty = Recorder::default();
        assert_eq!(empty.chrome_trace_json(), "{\"traceEvents\":[]}");
    }

    #[test]
    fn enable_off_is_a_no_op_and_reenable_updates_mode() {
        let r = Recorder::default();
        r.enable(TraceMode::Off);
        assert!(r.inner.shards.get().is_none(), "off allocates nothing");
        r.enable(TraceMode::On);
        r.event(SpanKind::Submit, 1, LANE_CLIENT, 0);
        r.enable(TraceMode::Off);
        r.event(SpanKind::Submit, 2, LANE_CLIENT, 0);
        assert_eq!(r.snapshot().len(), 1, "records survive a later disable");
    }
}
