//! Float → low-precision quantization schemes used by the workloads.
//!
//! The evaluation models (paper §V-B) use:
//! * **int8** symmetric per-tensor for all activations (and GPT-2 weights),
//! * **int4** symmetric per-tensor for BERT-large weights,
//! * **ternary absmean** (the BitNet-1.58B scheme [11, 37]) for BitNet
//!   weights, stored in the 2-bit fields of the 8b×2b mode.

use super::types::{clamp_to, value_range};

/// A quantized tensor: integer values + a single symmetric scale such that
/// `float ≈ value × scale`.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantTensor {
    /// Integer values, row-major.
    pub values: Vec<i32>,
    /// Symmetric dequantization scale.
    pub scale: f32,
    /// Bit-width of `values` (2, 4 or 8).
    pub bits: u32,
    /// Rows of the (2-D) tensor.
    pub rows: usize,
    /// Columns of the (2-D) tensor.
    pub cols: usize,
}

impl QuantTensor {
    /// Element at `(r, c)` (row-major).
    pub fn get(&self, r: usize, c: usize) -> i32 {
        self.values[r * self.cols + c]
    }

    /// Dequantized float value at `(r, c)`.
    pub fn get_f32(&self, r: usize, c: usize) -> f32 {
        self.get(r, c) as f32 * self.scale
    }
}

/// Symmetric per-tensor quantization to `bits` bits: scale = max(|x|) /
/// qmax, values = round(x / scale) clamped to range. A zero tensor gets
/// scale 1.0.
pub fn quantize_symmetric(data: &[f32], rows: usize, cols: usize, bits: u32) -> QuantTensor {
    assert_eq!(data.len(), rows * cols, "shape mismatch");
    let (_, qmax) = value_range(bits);
    let absmax = data.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let scale = if absmax == 0.0 { 1.0 } else { absmax / qmax as f32 };
    let values = data
        .iter()
        .map(|&v| clamp_to((v / scale).round() as i32, bits))
        .collect();
    QuantTensor { values, scale, bits, rows, cols }
}

/// BitNet-1.58B ternary quantization (absmean): scale = mean(|x|), values =
/// round(x / scale) clamped to {−1, 0, 1}. The ternary values fit the 2-bit
/// fields of the 8b×2b mode with headroom (the 2-bit range is −2..1).
pub fn ternary_absmean(data: &[f32], rows: usize, cols: usize) -> QuantTensor {
    assert_eq!(data.len(), rows * cols, "shape mismatch");
    let absmean = if data.is_empty() {
        1.0
    } else {
        let s: f32 = data.iter().map(|v| v.abs()).sum();
        let m = s / data.len() as f32;
        if m == 0.0 {
            1.0
        } else {
            m
        }
    };
    let values = data
        .iter()
        .map(|&v| ((v / absmean).round() as i32).clamp(-1, 1))
        .collect();
    QuantTensor { values, scale: absmean, bits: 2, rows, cols }
}

/// Dequantize back to floats (row-major).
pub fn dequantize(t: &QuantTensor) -> Vec<f32> {
    t.values.iter().map(|&v| v as f32 * t.scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn symmetric_int8_roundtrip_error_bounded() {
        let mut rng = Rng::seeded(7);
        let data: Vec<f32> = (0..256).map(|_| rng.f32_range(-3.0, 3.0)).collect();
        let q = quantize_symmetric(&data, 16, 16, 8);
        let deq = dequantize(&q);
        let max_abs = data.iter().fold(0f32, |m, &v| m.max(v.abs()));
        for (orig, back) in data.iter().zip(&deq) {
            assert!((orig - back).abs() <= q.scale * 0.5 + 1e-6, "orig={orig} back={back}");
        }
        // scale reconstructs the max value
        assert!((q.scale * 127.0 - max_abs).abs() < 1e-4);
    }

    #[test]
    fn symmetric_values_in_range() {
        let mut rng = Rng::seeded(11);
        for bits in [2u32, 4, 8] {
            let data: Vec<f32> = (0..64).map(|_| rng.f32_range(-10.0, 10.0)).collect();
            let q = quantize_symmetric(&data, 8, 8, bits);
            let (lo, hi) = value_range(bits);
            assert!(q.values.iter().all(|&v| (lo..=hi).contains(&v)));
            assert_eq!(q.bits, bits);
        }
    }

    #[test]
    fn zero_tensor_gets_unit_scale() {
        let q = quantize_symmetric(&[0.0; 16], 4, 4, 8);
        assert_eq!(q.scale, 1.0);
        assert!(q.values.iter().all(|&v| v == 0));
    }

    #[test]
    fn ternary_values_are_ternary() {
        let mut rng = Rng::seeded(3);
        let data: Vec<f32> = (0..128).map(|_| rng.f32_range(-2.0, 2.0)).collect();
        let q = ternary_absmean(&data, 8, 16);
        assert!(q.values.iter().all(|&v| (-1..=1).contains(&v)));
        assert_eq!(q.bits, 2);
        // absmean scale is the mean absolute value
        let expect: f32 = data.iter().map(|v| v.abs()).sum::<f32>() / 128.0;
        assert!((q.scale - expect).abs() < 1e-6);
    }

    #[test]
    fn accessors() {
        let q = QuantTensor { values: (0..6).collect(), scale: 0.5, bits: 8, rows: 2, cols: 3 };
        assert_eq!(q.get(1, 2), 5);
        assert_eq!(q.get_f32(1, 0), 1.5);
    }
}
