//! Bit-packing of 4-/2-bit weights into 8-bit memory carriers.
//!
//! ADiP stores interleaved low-precision weights in the same 8-bit
//! stationary registers used for full-precision weights (paper §III:
//! “the weight register stores a single weight value … or 2 and 4
//! interleaved values encoded in 4-bit or 2-bit”). Packing order is
//! little-endian in the byte: element 0 occupies the least-significant
//! field. This is also the layout the L1 Pallas kernel consumes
//! (`python/compile/kernels/adip_matmul.py` uses the identical convention —
//! checked by the golden-vector cross test).

use super::types::value_range;

/// Pack two signed 4-bit values (`-8..=7`) into one byte; `vals[0]` in the
/// low nibble.
pub fn pack_int4(vals: [i32; 2]) -> u8 {
    let (lo, hi) = value_range(4);
    for v in vals {
        assert!((lo..=hi).contains(&v), "{v} out of int4 range");
    }
    ((vals[0] as u8) & 0x0F) | (((vals[1] as u8) & 0x0F) << 4)
}

/// Unpack one byte into two signed 4-bit values; inverse of [`pack_int4`].
pub fn unpack_int4(b: u8) -> [i32; 2] {
    [sign_extend((b & 0x0F) as i32, 4), sign_extend(((b >> 4) & 0x0F) as i32, 4)]
}

/// Pack four signed 2-bit values (`-2..=1`) into one byte; `vals[0]` in the
/// lowest 2-bit field.
pub fn pack_int2(vals: [i32; 4]) -> u8 {
    let (lo, hi) = value_range(2);
    let mut b = 0u8;
    for (i, v) in vals.into_iter().enumerate() {
        assert!((lo..=hi).contains(&v), "{v} out of int2 range");
        b |= ((v as u8) & 0b11) << (2 * i);
    }
    b
}

/// Unpack one byte into four signed 2-bit values; inverse of [`pack_int2`].
pub fn unpack_int2(b: u8) -> [i32; 4] {
    [
        sign_extend((b & 0b11) as i32, 2),
        sign_extend(((b >> 2) & 0b11) as i32, 2),
        sign_extend(((b >> 4) & 0b11) as i32, 2),
        sign_extend(((b >> 6) & 0b11) as i32, 2),
    ]
}

/// Sign-extend the low `bits` bits of `v`.
pub fn sign_extend(v: i32, bits: u32) -> i32 {
    let shift = 32 - bits;
    (v << shift) >> shift
}

/// Pack a slice of int4 values (length must be even) into bytes.
pub fn pack_int4_slice(vals: &[i32]) -> Vec<u8> {
    assert!(vals.len() % 2 == 0, "int4 slice length must be even");
    vals.chunks_exact(2).map(|c| pack_int4([c[0], c[1]])).collect()
}

/// Pack a slice of int2 values (length must be a multiple of 4) into bytes.
pub fn pack_int2_slice(vals: &[i32]) -> Vec<u8> {
    assert!(vals.len() % 4 == 0, "int2 slice length must be multiple of 4");
    vals.chunks_exact(4)
        .map(|c| pack_int2([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Unpack a byte slice into int4 values.
pub fn unpack_int4_slice(bytes: &[u8]) -> Vec<i32> {
    bytes.iter().flat_map(|&b| unpack_int4(b)).collect()
}

/// Unpack a byte slice into int2 values.
pub fn unpack_int2_slice(bytes: &[u8]) -> Vec<i32> {
    bytes.iter().flat_map(|&b| unpack_int2(b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int4_roundtrip_exhaustive() {
        for a in -8..=7 {
            for b in -8..=7 {
                assert_eq!(unpack_int4(pack_int4([a, b])), [a, b]);
            }
        }
    }

    #[test]
    fn int2_roundtrip_exhaustive() {
        for a in -2..=1 {
            for b in -2..=1 {
                for c in -2..=1 {
                    for d in -2..=1 {
                        assert_eq!(unpack_int2(pack_int2([a, b, c, d])), [a, b, c, d]);
                    }
                }
            }
        }
    }

    #[test]
    fn slice_roundtrips() {
        let v4: Vec<i32> = (-8..8).collect();
        assert_eq!(unpack_int4_slice(&pack_int4_slice(&v4)), v4);
        let v2: Vec<i32> = (0..64).map(|i| (i % 4) - 2).collect();
        assert_eq!(unpack_int2_slice(&pack_int2_slice(&v2)), v2);
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend(0b11, 2), -1);
        assert_eq!(sign_extend(0b10, 2), -2);
        assert_eq!(sign_extend(0b01, 2), 1);
        assert_eq!(sign_extend(0xF, 4), -1);
        assert_eq!(sign_extend(0x8, 4), -8);
        assert_eq!(sign_extend(0x7, 4), 7);
    }

    #[test]
    #[should_panic]
    fn pack_int2_rejects_out_of_range() {
        pack_int2([2, 0, 0, 0]);
    }
}
