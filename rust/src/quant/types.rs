//! Precision-mode types shared across the whole stack.

use std::fmt;
use std::str::FromStr;

/// Operand precision mode of the reconfigurable PE / ADiP array.
///
/// The first operand (input activation) is always 8-bit; the second operand
/// (stationary weight) is 8, 4 or 2 bits (paper §III). The mode determines
/// how many *distinct weight matrices* are interleaved into one stationary
/// tile and therefore the per-PE parallelism:
///
/// | mode  | weight bits | interleaved matrices `k` | PE latency (M=16) | ops/cycle/PE |
/// |-------|-------------|--------------------------|-------------------|--------------|
/// | 8b×8b | 8           | 1                        | 1                 | 2            |
/// | 8b×4b | 4           | 2                        | 1                 | 4            |
/// | 8b×2b | 2           | 4 (3 for Q/K/V)          | 1                 | 8            |
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PrecisionMode {
    /// Symmetric single-matrix multiplication, 8-bit × 8-bit.
    W8,
    /// Asymmetric multi-matrix multiplication, 8-bit × 4-bit (2 matrices).
    W4,
    /// Asymmetric multi-matrix multiplication, 8-bit × 2-bit (≤4 matrices).
    W2,
}

impl PrecisionMode {
    /// All modes, in descending weight width.
    pub const ALL: [PrecisionMode; 3] = [PrecisionMode::W8, PrecisionMode::W4, PrecisionMode::W2];

    /// Activation (first operand) bit-width — fixed at 8 in ADiP.
    pub const fn act_bits(self) -> u32 {
        8
    }

    /// Weight (second operand) bit-width.
    pub const fn weight_bits(self) -> u32 {
        match self {
            PrecisionMode::W8 => 8,
            PrecisionMode::W4 => 4,
            PrecisionMode::W2 => 2,
        }
    }

    /// Maximum number of distinct weight matrices interleaved into one
    /// stationary tile (the *interleave factor* of Fig. 5).
    pub const fn interleave_factor(self) -> usize {
        match self {
            PrecisionMode::W8 => 1,
            PrecisionMode::W4 => 2,
            PrecisionMode::W2 => 4,
        }
    }

    /// Throughput gain over the 8b×8b baseline (Table I: 1×/2×/4×).
    pub const fn throughput_gain(self) -> u32 {
        self.interleave_factor() as u32
    }

    /// Number of 2-bit weight subwords per weight value.
    pub const fn weight_subwords(self) -> u32 {
        self.weight_bits() / 2
    }

    /// MAC operations (1 multiply + 1 add = 2 ops) per PE per cycle once the
    /// pipeline is full, for the selected 16-multiplier PE (paper §IV).
    pub const fn ops_per_pe_cycle(self) -> u64 {
        2 * self.interleave_factor() as u64
    }

    /// Pick the mode that fits a given weight bit-width (≤2 → W2, ≤4 → W4,
    /// otherwise W8).
    pub fn for_weight_bits(bits: u32) -> PrecisionMode {
        if bits <= 2 {
            PrecisionMode::W2
        } else if bits <= 4 {
            PrecisionMode::W4
        } else {
            PrecisionMode::W8
        }
    }

    /// Canonical lower-case name used by the CLI / config files.
    pub const fn name(self) -> &'static str {
        match self {
            PrecisionMode::W8 => "8x8",
            PrecisionMode::W4 => "8x4",
            PrecisionMode::W2 => "8x2",
        }
    }
}

impl fmt::Display for PrecisionMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PrecisionMode::W8 => "8b×8b",
            PrecisionMode::W4 => "8b×4b",
            PrecisionMode::W2 => "8b×2b",
        };
        f.write_str(s)
    }
}

impl FromStr for PrecisionMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "8x8" | "8b8b" | "8bx8b" | "w8" | "int8" | "8" => Ok(PrecisionMode::W8),
            "8x4" | "8b4b" | "8bx4b" | "w4" | "int4" | "4" => Ok(PrecisionMode::W4),
            "8x2" | "8b2b" | "8bx2b" | "w2" | "int2" | "2" | "ternary" => Ok(PrecisionMode::W2),
            other => Err(format!(
                "unknown precision mode {other:?} (expected one of 8x8, 8x4, 8x2)"
            )),
        }
    }
}

/// Inclusive signed value range of a two's-complement integer of `bits` bits.
///
/// `bits` must be in `1..=8`. 2-bit → (−2, 1); 4-bit → (−8, 7); 8-bit →
/// (−128, 127).
pub fn value_range(bits: u32) -> (i32, i32) {
    assert!((1..=8).contains(&bits), "unsupported bit-width {bits}");
    let hi = (1i32 << (bits - 1)) - 1;
    (-(hi + 1), hi)
}

/// Clamp `v` into the signed range of `bits` bits.
pub fn clamp_to(v: i32, bits: u32) -> i32 {
    let (lo, hi) = value_range(bits);
    v.clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_widths_and_factors() {
        assert_eq!(PrecisionMode::W8.weight_bits(), 8);
        assert_eq!(PrecisionMode::W4.weight_bits(), 4);
        assert_eq!(PrecisionMode::W2.weight_bits(), 2);
        assert_eq!(PrecisionMode::W8.interleave_factor(), 1);
        assert_eq!(PrecisionMode::W4.interleave_factor(), 2);
        assert_eq!(PrecisionMode::W2.interleave_factor(), 4);
        for m in PrecisionMode::ALL {
            assert_eq!(m.act_bits(), 8);
            assert_eq!(m.weight_subwords() * 2, m.weight_bits());
            assert_eq!(m.ops_per_pe_cycle(), 2 * m.throughput_gain() as u64);
        }
    }

    #[test]
    fn mode_parsing_roundtrip() {
        for m in PrecisionMode::ALL {
            assert_eq!(m.name().parse::<PrecisionMode>().unwrap(), m);
        }
        assert_eq!("ternary".parse::<PrecisionMode>().unwrap(), PrecisionMode::W2);
        assert!("16x16".parse::<PrecisionMode>().is_err());
    }

    #[test]
    fn for_weight_bits_picks_narrowest_fit() {
        assert_eq!(PrecisionMode::for_weight_bits(1), PrecisionMode::W2);
        assert_eq!(PrecisionMode::for_weight_bits(2), PrecisionMode::W2);
        assert_eq!(PrecisionMode::for_weight_bits(3), PrecisionMode::W4);
        assert_eq!(PrecisionMode::for_weight_bits(4), PrecisionMode::W4);
        assert_eq!(PrecisionMode::for_weight_bits(5), PrecisionMode::W8);
        assert_eq!(PrecisionMode::for_weight_bits(8), PrecisionMode::W8);
    }

    #[test]
    fn ranges() {
        assert_eq!(value_range(2), (-2, 1));
        assert_eq!(value_range(4), (-8, 7));
        assert_eq!(value_range(8), (-128, 127));
        assert_eq!(clamp_to(5, 2), 1);
        assert_eq!(clamp_to(-5, 2), -2);
        assert_eq!(clamp_to(5, 4), 5);
    }

    #[test]
    #[should_panic]
    fn range_rejects_wide() {
        value_range(9);
    }
}
