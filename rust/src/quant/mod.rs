//! Precision modes, subword arithmetic, packing and quantization.
//!
//! ADiP keeps activations at 8 bits and adapts the *weight* precision
//! (8b×8b, 8b×4b, 8b×2b — paper §III/§IV). Reduced weight precision is
//! traded for **multi-matrix multiplication with a shared input matrix**:
//! a 4-bit mode interleaves 2 weight matrices, a 2-bit mode interleaves up
//! to 4 (or 3 for the Q/K/V variant of Fig. 5(d)) into one stationary tile.
//!
//! This module is the numeric substrate for everything above it:
//!
//! * [`types`] — [`PrecisionMode`] and value-range helpers.
//! * [`subword`] — radix-4 (2-bit) signed subword decomposition, the exact
//!   arithmetic performed by the reconfigurable PE’s 16 2-bit multipliers.
//! * [`packing`] — bit-packing of 4-/2-bit weights into 8-bit carriers, as
//!   stored in the stationary weight registers and in memory.
//! * [`quantize`] — float → int8/int4/int2 symmetric quantization and the
//!   BitNet-1.58B ternary (absmean) scheme.

pub mod packing;
pub mod quantize;
pub mod subword;
pub mod types;

pub use packing::{pack_int2, pack_int4, unpack_int2, unpack_int4};
pub use quantize::{dequantize, quantize_symmetric, ternary_absmean, QuantTensor};
pub use subword::{decompose_radix4, recompose_radix4, subword_product};
pub use types::{clamp_to, value_range, PrecisionMode};
