//! Radix-4 (2-bit) signed subword decomposition.
//!
//! The reconfigurable PE (paper §III, Fig. 3(a)) builds a full-precision
//! product out of 2-bit × 2-bit partial products — the divide-and-conquer
//! decomposition of [27]. An 8-bit two's-complement value decomposes as
//!
//! ```text
//! a = a₃·4³ + a₂·4² + a₁·4 + a₀
//! ```
//!
//! where the *top* subword `a₃ ∈ {−2..1}` is signed and the lower subwords
//! `a₀..a₂ ∈ {0..3}` are unsigned. With this convention the shift-add
//! recombination of partial products is exact for any signed operand pair,
//! which is what lets the PE share plain shifters/accumulators per column
//! without per-PE sign fix-ups.

/// Decompose a signed value of `bits` bits (2, 4 or 8) into `bits / 2`
/// radix-4 subwords, least-significant first. The final subword is signed
/// (−2..1), the rest unsigned (0..3).
pub fn decompose_radix4(v: i32, bits: u32) -> Vec<i32> {
    assert!(bits == 2 || bits == 4 || bits == 8, "unsupported width {bits}");
    let (lo, hi) = super::types::value_range(bits);
    assert!(
        (lo..=hi).contains(&v),
        "{v} out of range for {bits}-bit ({lo}..={hi})"
    );
    let n = (bits / 2) as usize;
    let mut out = Vec::with_capacity(n);
    // Work on the unsigned two's-complement image, then sign-correct the
    // top subword.
    let mask = (1u32 << bits) - 1;
    let u = (v as u32) & mask;
    for i in 0..n {
        let limb = ((u >> (2 * i)) & 0b11) as i32;
        if i == n - 1 {
            // top subword: interpret as signed 2-bit
            out.push(if limb >= 2 { limb - 4 } else { limb });
        } else {
            out.push(limb);
        }
    }
    out
}

/// Precomputed radix-4 decomposition of every 8-bit value, indexed by the
/// unsigned byte image (`(v as u8) as usize`). Hot-path replacement for
/// [`decompose_radix4`] in the PE model (§Perf iteration 2): avoids the
/// per-MAC `Vec` allocation.
pub static RADIX4_I8: [[i8; 4]; 256] = {
    let mut table = [[0i8; 4]; 256];
    let mut u = 0usize;
    while u < 256 {
        let mut i = 0;
        while i < 4 {
            let limb = ((u >> (2 * i)) & 0b11) as i8;
            table[u][i] = if i == 3 && limb >= 2 { limb - 4 } else { limb };
            i += 1;
        }
        u += 1;
    }
    table
};

/// Recompose radix-4 subwords (least-significant first) into a value.
/// Inverse of [`decompose_radix4`].
pub fn recompose_radix4(subwords: &[i32]) -> i32 {
    subwords
        .iter()
        .enumerate()
        .map(|(i, &s)| s << (2 * i))
        .sum()
}

/// One 2-bit × 2-bit multiplier of the PE: multiplies a (possibly signed)
/// activation subword by a (possibly signed) weight subword. Plain integer
/// product — the hardware unit is a 3-bit signed multiplier; the model only
/// asserts the operands are in subword range.
pub fn subword_product(a_sub: i32, w_sub: i32) -> i32 {
    debug_assert!((-2..=3).contains(&a_sub), "activation subword {a_sub} out of range");
    debug_assert!((-2..=3).contains(&w_sub), "weight subword {w_sub} out of range");
    a_sub * w_sub
}

/// Full product of `a` (8-bit) × `w` (`w_bits`-bit) computed exclusively via
/// 2-bit subword products and shift-adds — the arithmetic identity the PE
/// implements. Used as the specification in tests: must equal `a * w`.
pub fn product_via_subwords(a: i32, w: i32, w_bits: u32) -> i32 {
    let a_subs = decompose_radix4(a, 8);
    let w_subs = decompose_radix4(w, w_bits);
    let mut acc = 0i32;
    for (j, &aj) in a_subs.iter().enumerate() {
        for (k, &wk) in w_subs.iter().enumerate() {
            acc += subword_product(aj, wk) << (2 * (j + k));
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_recompose_roundtrip_exhaustive() {
        for bits in [2u32, 4, 8] {
            let (lo, hi) = crate::quant::value_range(bits);
            for v in lo..=hi {
                let subs = decompose_radix4(v, bits);
                assert_eq!(subs.len(), (bits / 2) as usize);
                for (i, &s) in subs.iter().enumerate() {
                    if i + 1 == subs.len() {
                        assert!((-2..=1).contains(&s), "top subword {s}");
                    } else {
                        assert!((0..=3).contains(&s), "low subword {s}");
                    }
                }
                assert_eq!(recompose_radix4(&subs), v, "roundtrip of {v} ({bits}b)");
            }
        }
    }

    #[test]
    fn subword_product_matches_direct_product_exhaustive() {
        // Exhaustive over all 8-bit × {2,4,8}-bit operand pairs: the PE's
        // shift-add decomposition is exactly the integer product.
        for w_bits in [2u32, 4, 8] {
            let (wlo, whi) = crate::quant::value_range(w_bits);
            for a in -128..=127 {
                for w in wlo..=whi {
                    assert_eq!(
                        product_via_subwords(a, w, w_bits),
                        a * w,
                        "a={a} w={w} bits={w_bits}"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn decompose_rejects_out_of_range() {
        decompose_radix4(9, 4);
    }

    #[test]
    fn lut_matches_decompose_exhaustive() {
        for v in -128i32..=127 {
            let want = decompose_radix4(v, 8);
            let got = RADIX4_I8[(v as u8) as usize];
            for i in 0..4 {
                assert_eq!(got[i] as i32, want[i], "v={v} sub={i}");
            }
        }
    }
}
