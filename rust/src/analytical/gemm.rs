//! GEMM-level analytical estimates: cycles, passes, ops and memory traffic
//! for a full `M×K·K×N` multiplication on each architecture.
//!
//! The estimate mirrors how the paper's evaluation composes: Algorithm 1
//! tiles the GEMM into array-sized stationary tiles; ADiP additionally
//! groups up to `k = interleave_factor` weight tiles that share the same
//! activation tile (adjacent output-column tiles of a single GEMM, or
//! Q/K/V tiles of separate GEMMs) into one pass.
//!
//! **Memory model** (matches §V-B / Fig. 11): counted traffic is the
//! *input* traffic per pass — one activation tile (8-bit) plus one
//! stationary tile (8-bit carrier, holding `k` interleaved low-precision
//! tiles). Psums stay on-chip; output write-back is identical across the
//! three architectures and attributed to the next stage's activation reads
//! (set [`MemoryPolicy::count_outputs`] to include it explicitly — one
//! tile per output block, matching the co-simulator's write-back counter).

use crate::arch::{ArchConfig, Architecture, SharedColumnUnit};
use crate::dataflow::tiling::tile_grid;
use crate::quant::PrecisionMode;

/// Shape of a GEMM `A(m×k) · B(k×n)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    /// Rows of A / C.
    pub m: usize,
    /// Inner (reduction) dimension.
    pub k: usize,
    /// Columns of B / C.
    pub n: usize,
}

impl GemmShape {
    /// Construct a shape.
    pub fn new(m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { m, k, n }
    }

    /// Total operations (2 ops per MAC).
    pub fn ops(&self) -> u64 {
        2 * self.m as u64 * self.k as u64 * self.n as u64
    }
}

/// What the memory counter includes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryPolicy {
    /// Count output-tile write-back (off in the paper's Fig. 11 model).
    pub count_outputs: bool,
}

impl Default for MemoryPolicy {
    fn default() -> Self {
        MemoryPolicy { count_outputs: false }
    }
}

/// Analytical estimate for one GEMM on one architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmEstimate {
    /// Architecture evaluated.
    pub arch: Architecture,
    /// Precision mode executed (DiP/WS always run 8b×8b).
    pub mode: PrecisionMode,
    /// Stationary-tile passes.
    pub passes: u64,
    /// Total latency in cycles (one fill/drain + steady streaming).
    pub cycles: u64,
    /// Useful operations (2 ops/MAC over the logical GEMM).
    pub ops: u64,
    /// Off-array memory traffic in bytes (activation + stationary reads,
    /// plus write-back when [`MemoryPolicy::count_outputs`] is set).
    pub memory_bytes: u64,
    /// Activation-tile read bytes (one `N²` tile per pass). Broken out so
    /// the cluster estimator can apply its broadcast attribution rule
    /// (shared-input traffic counted once across cores).
    pub act_read_bytes: u64,
    /// Stationary (packed weight carrier) tile read bytes.
    pub weight_read_bytes: u64,
    /// Output tile write-back bytes (always tracked; included in
    /// `memory_bytes` only per [`MemoryPolicy::count_outputs`]).
    pub output_write_bytes: u64,
}

impl GemmEstimate {
    /// Achieved ops/cycle.
    pub fn ops_per_cycle(&self) -> f64 {
        self.ops as f64 / self.cycles as f64
    }
}

/// Per-pass fill/drain overhead and steady interval for an architecture.
fn pass_cycles(arch: Architecture, cfg: &ArchConfig, mode: PrecisionMode) -> (u64, u64) {
    let n = cfg.n as u64;
    let s = cfg.mac_stages;
    match arch {
        Architecture::Ws => (3 * n + s - 3, 2 * n - 1),
        Architecture::Dip => (2 * n + s - 2, n),
        Architecture::Adip => {
            let e = SharedColumnUnit.pipeline_stages(mode);
            let pe_lat = ((mode.act_bits() * mode.weight_bits()) as u64)
                .div_ceil((cfg.multipliers * 4) as u64);
            (n * pe_lat + n + s + e - 2, n * pe_lat)
        }
    }
}

/// Estimate one GEMM. `requested_mode` is the weight precision of the
/// workload; DiP/WS execute it as 8b×8b (no gain), ADiP runs it natively
/// and fuses `interleave_factor` adjacent weight tiles per pass.
pub fn estimate_gemm(
    arch: Architecture,
    cfg: &ArchConfig,
    shape: GemmShape,
    requested_mode: PrecisionMode,
    policy: MemoryPolicy,
) -> GemmEstimate {
    let mode = match arch {
        Architecture::Adip => requested_mode,
        _ => PrecisionMode::W8,
    };
    let grid = tile_grid(shape.m, shape.k, shape.n, cfg.n);
    let weight_tiles = (grid.tiles_k() * grid.tiles_n()) as u64;
    let act_tiles_per_weight = grid.tiles_m() as u64;

    // ADiP fuses k adjacent output-column weight tiles per stationary pass.
    let fused_groups = match arch {
        Architecture::Adip => {
            (grid.tiles_n().div_ceil(mode.interleave_factor()) * grid.tiles_k()) as u64
        }
        _ => weight_tiles,
    };
    let passes = fused_groups * act_tiles_per_weight;

    let (tile_latency, steady) = pass_cycles(arch, cfg, mode);
    // One pipeline fill/drain for the GEMM; passes stream back-to-back.
    let cycles = (tile_latency - steady) + passes * steady;

    // Input traffic: one activation tile (N² bytes, 8-bit) per pass, plus
    // one stationary carrier tile (N² bytes — k interleaved tiles at 8/k
    // bits) per stationary group (the weight stays resident across the
    // tiles_m activation passes that reuse it). Matches the co-simulator's
    // counters exactly; the ADiP/DiP ratio is 1/k either way.
    let tile_bytes = (cfg.n * cfg.n) as u64;
    let act_read_bytes = passes * tile_bytes;
    let weight_read_bytes = fused_groups * tile_bytes;
    // Output tiles, requantized to 8-bit, written once per output block
    // after the last reduction step — identical across architectures and
    // exactly the co-simulator's write-back counter.
    let output_write_bytes = (grid.tiles_m() * grid.tiles_n()) as u64 * tile_bytes;
    let mut memory_bytes = act_read_bytes + weight_read_bytes;
    if policy.count_outputs {
        memory_bytes += output_write_bytes;
    }

    GemmEstimate {
        arch,
        mode,
        passes,
        cycles,
        ops: shape.ops(),
        memory_bytes,
        act_read_bytes,
        weight_read_bytes,
        output_write_bytes,
    }
}

/// Estimate a shared-input GEMM *set* `C_s = A · B_s` of `set_size`
/// equally-shaped weight matrices (the paper's asymmetric multi-matrix
/// mode, Fig. 5(d)).
///
/// Mirrors the co-simulator's generalized slot packing: on ADiP every
/// (source matrix, output-column tile) pair is one interleave slot, slots
/// are chunked into `interleave_factor`-sized stationary groups, and the
/// whole set pays one pipeline fill. Architectures without interleaving
/// (and singleton sets) execute the matrices independently, so their cost
/// is `set_size ×` the single-GEMM estimate — including one fill each.
pub fn estimate_gemm_set(
    arch: Architecture,
    cfg: &ArchConfig,
    shape: GemmShape,
    set_size: usize,
    requested_mode: PrecisionMode,
    policy: MemoryPolicy,
) -> GemmEstimate {
    assert!(set_size > 0, "set must contain at least one matrix");
    let single = estimate_gemm(arch, cfg, shape, requested_mode, policy);
    if arch != Architecture::Adip || set_size == 1 {
        return GemmEstimate {
            passes: single.passes * set_size as u64,
            cycles: single.cycles * set_size as u64,
            ops: single.ops * set_size as u64,
            memory_bytes: single.memory_bytes * set_size as u64,
            act_read_bytes: single.act_read_bytes * set_size as u64,
            weight_read_bytes: single.weight_read_bytes * set_size as u64,
            output_write_bytes: single.output_write_bytes * set_size as u64,
            ..single
        };
    }

    let mode = requested_mode;
    let grid = tile_grid(shape.m, shape.k, shape.n, cfg.n);
    let cap = mode.interleave_factor();
    let slots = grid.tiles_n() * set_size;
    let groups = (slots.div_ceil(cap) * grid.tiles_k()) as u64;
    let passes = groups * grid.tiles_m() as u64;

    let (tile_latency, steady) = pass_cycles(arch, cfg, mode);
    let cycles = (tile_latency - steady) + passes * steady;

    let tile_bytes = (cfg.n * cfg.n) as u64;
    let act_read_bytes = passes * tile_bytes;
    let weight_read_bytes = groups * tile_bytes;
    let output_write_bytes = (grid.tiles_m() * slots) as u64 * tile_bytes;
    let mut memory_bytes = act_read_bytes + weight_read_bytes;
    if policy.count_outputs {
        memory_bytes += output_write_bytes;
    }

    GemmEstimate {
        arch,
        mode,
        passes,
        cycles,
        ops: shape.ops() * set_size as u64,
        memory_bytes,
        act_read_bytes,
        weight_read_bytes,
        output_write_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ArchConfig {
        ArchConfig::with_n(32)
    }

    #[test]
    fn ops_counting() {
        assert_eq!(GemmShape::new(2, 3, 4).ops(), 48);
    }

    #[test]
    fn adip_w8_matches_dip_within_fill() {
        // GPT-2-style 8-bit workload: ADiP incurs no (meaningful) latency
        // overhead vs DiP — only the 3-stage column-unit fill per GEMM.
        let shape = GemmShape::new(1024, 1024, 1024);
        let d = estimate_gemm(
            Architecture::Dip,
            &cfg(),
            shape,
            PrecisionMode::W8,
            MemoryPolicy::default(),
        );
        let a = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W8,
            MemoryPolicy::default(),
        );
        assert_eq!(a.passes, d.passes);
        let overhead = a.cycles as f64 / d.cycles as f64 - 1.0;
        assert!(overhead.abs() < 1e-4, "overhead {overhead}");
        assert_eq!(a.memory_bytes, d.memory_bytes);
    }

    #[test]
    fn adip_quantized_gains_2x_and_4x() {
        let shape = GemmShape::new(1024, 1024, 1024);
        let d = estimate_gemm(
            Architecture::Dip,
            &cfg(),
            shape,
            PrecisionMode::W4,
            MemoryPolicy::default(),
        );
        let a4 = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W4,
            MemoryPolicy::default(),
        );
        let a2 = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        assert!((d.cycles as f64 / a4.cycles as f64 - 2.0).abs() < 1e-3);
        assert!((d.cycles as f64 / a2.cycles as f64 - 4.0).abs() < 1e-3);
        // memory efficiency gains match (Fig. 11: tile accesses ÷ k)
        assert!((d.memory_bytes as f64 / a4.memory_bytes as f64 - 2.0).abs() < 1e-9);
        assert!((d.memory_bytes as f64 / a2.memory_bytes as f64 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ws_slower_than_dip() {
        let shape = GemmShape::new(512, 512, 512);
        let w = estimate_gemm(
            Architecture::Ws,
            &cfg(),
            shape,
            PrecisionMode::W8,
            MemoryPolicy::default(),
        );
        let d = estimate_gemm(
            Architecture::Dip,
            &cfg(),
            shape,
            PrecisionMode::W8,
            MemoryPolicy::default(),
        );
        let ratio = w.cycles as f64 / d.cycles as f64;
        assert!(ratio > 1.9 && ratio < 2.0, "WS/DiP = {ratio}");
        // identical memory traffic (same tile reads)
        assert_eq!(w.memory_bytes, d.memory_bytes);
    }

    #[test]
    fn ragged_shapes_round_up() {
        let shape = GemmShape::new(33, 65, 97); // none divisible by 32
        let a = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        // tiles: m=2, k=3, n=4 → fused groups = ceil(4/4)*3 = 3; passes = 6
        assert_eq!(a.passes, 6);
    }

    #[test]
    fn output_counting_policy() {
        let shape = GemmShape::new(64, 64, 64);
        let without = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        let with = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy { count_outputs: true },
        );
        assert!(with.memory_bytes > without.memory_bytes);
    }

    #[test]
    fn set_estimate_packs_slots_and_degrades_elsewhere() {
        let cfg = ArchConfig::with_n(8);
        let shape = GemmShape::new(32, 32, 32); // 4×4×4 tiles at n=8
        // ADiP 8b×2b, 3 matrices: 12 slots → 3 groups × 4 k × 4 m = 48
        let a = estimate_gemm_set(
            Architecture::Adip,
            &cfg,
            shape,
            3,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        assert_eq!(a.passes, 48);
        assert_eq!(a.mode, PrecisionMode::W2);
        assert_eq!(a.ops, 3 * shape.ops());
        // singleton set degenerates to the single-GEMM estimate
        let one = estimate_gemm_set(
            Architecture::Adip,
            &cfg,
            shape,
            1,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        let single = estimate_gemm(
            Architecture::Adip,
            &cfg,
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        assert_eq!(one, single);
        // DiP: three independent 8b×8b runs (fill paid per run)
        let d = estimate_gemm_set(
            Architecture::Dip,
            &cfg,
            shape,
            3,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        let d1 = estimate_gemm(
            Architecture::Dip,
            &cfg,
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        assert_eq!(d.passes, 3 * d1.passes);
        assert_eq!(d.cycles, 3 * d1.cycles);
        assert_eq!(d.memory_bytes, 3 * d1.memory_bytes);
        assert_eq!(d.mode, PrecisionMode::W8);
    }

    #[test]
    #[should_panic]
    fn set_estimate_rejects_empty_sets() {
        estimate_gemm_set(
            Architecture::Adip,
            &cfg(),
            GemmShape::new(8, 8, 8),
            0,
            PrecisionMode::W8,
            MemoryPolicy::default(),
        );
    }

    #[test]
    fn ops_per_cycle_sane() {
        let shape = GemmShape::new(4096, 4096, 4096);
        let a = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        // close to peak 8·N² = 8192 ops/cycle for 32×32 at 8b×2b
        assert!(a.ops_per_cycle() > 8000.0, "{}", a.ops_per_cycle());
        assert!(a.ops_per_cycle() <= 8192.0);
    }
}
