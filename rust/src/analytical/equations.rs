//! Paper Eqs. (1)–(3) and the Fig. 2 / Fig. 4 series generators.

use crate::quant::PrecisionMode;

/// Eq. (1): reconfigurable-PE latency in cycles.
///
/// `Latency_PE = ceil( (1/M) · (OW₁·OW₂ / MW²) )`
///
/// * `m` — number of 2-bit multipliers,
/// * `mw` — multiplier operand width (bits),
/// * `ow1`, `ow2` — operand bit-widths (multiples of `mw`).
pub fn pe_latency(m: u32, mw: u32, ow1: u32, ow2: u32) -> u64 {
    assert!(m > 0 && mw > 0, "M and MW must be positive");
    ((ow1 * ow2) as u64).div_ceil((m * mw * mw) as u64)
}

/// Eq. (2): ADiP single-tile latency in cycles.
///
/// `Latency_ADiP = N·ceil((1/M)(OW₁·OW₂/MW²)) + N + S + E − 2`
pub fn adip_latency(n: u64, m: u32, mw: u32, ow1: u32, ow2: u32, s: u64, e: u64) -> u64 {
    n * pe_latency(m, mw, ow1, ow2) + n + s + e - 2
}

/// Eq. (3): ADiP throughput in operations per cycle (multiply-and-add
/// counted as 2 ops), for one `N×N` tile pass.
///
/// `T = 2 · ceil(M·MW²/(OW₁·OW₂)) · N³ / Latency_ADiP`
///
/// The ceil term is the per-PE parallelism (number of weight matrices
/// resolved per MAC cycle): 1, 2 and 4 for 8b×8b, 8b×4b, 8b×2b at M = 16.
pub fn adip_throughput_ops_per_cycle(
    n: u64,
    m: u32,
    mw: u32,
    ow1: u32,
    ow2: u32,
    s: u64,
    e: u64,
) -> f64 {
    let parallelism = ((m * mw * mw) as u64).div_ceil((ow1 * ow2) as u64);
    let ops = 2 * parallelism * n * n * n;
    ops as f64 / adip_latency(n, m, mw, ow1, ow2, s, e) as f64
}

/// One bar of Fig. 2: PE latency for a multiplier count and mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fig2Row {
    /// Number of 2-bit multipliers (`M`).
    pub multipliers: u32,
    /// Operand configuration.
    pub mode: PrecisionMode,
    /// Eq. (1) latency in cycles.
    pub latency: u64,
}

/// The full Fig. 2 series: `M ∈ {2, 4, 8, 16}` × all modes.
pub fn fig2_series() -> Vec<Fig2Row> {
    let mut out = Vec::new();
    for &m in &[2u32, 4, 8, 16] {
        for mode in PrecisionMode::ALL {
            out.push(Fig2Row {
                multipliers: m,
                mode,
                latency: pe_latency(m, 2, mode.act_bits(), mode.weight_bits()),
            });
        }
    }
    out
}

/// One point of Fig. 4: ADiP latency + throughput at an array size/mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fig4Row {
    /// Array size `N`.
    pub n: u64,
    /// Operand configuration.
    pub mode: PrecisionMode,
    /// Eq. (2) latency (cycles).
    pub latency: u64,
    /// Eq. (3) throughput (ops/cycle).
    pub throughput_ops_per_cycle: f64,
    /// Eq. (3) throughput at 1 GHz, in TOPS.
    pub throughput_tops_at_1ghz: f64,
}

/// The full Fig. 4 series: `N ∈ {4, 8, 16, 32, 64}` × all modes, with the
/// selected design point `M = 16` and the default pipeline depths
/// (`S = 1`; `E` per mode from the shared column unit).
pub fn fig4_series() -> Vec<Fig4Row> {
    let unit = crate::arch::SharedColumnUnit;
    let mut out = Vec::new();
    for &n in &[4u64, 8, 16, 32, 64] {
        for mode in PrecisionMode::ALL {
            let (s, e) = (1, unit.pipeline_stages(mode));
            let ops = adip_throughput_ops_per_cycle(n, 16, 2, 8, mode.weight_bits(), s, e);
            out.push(Fig4Row {
                n,
                mode,
                latency: adip_latency(n, 16, 2, 8, mode.weight_bits(), s, e),
                throughput_ops_per_cycle: ops,
                throughput_tops_at_1ghz: ops * 1e9 / 1e12,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{AdipArray, ArchConfig, SystolicArray};

    #[test]
    fn eq1_fig2_values() {
        // The Fig. 2 bars: latency halves with M, floors at 1 cycle.
        let series = fig2_series();
        let get = |m: u32, mode: PrecisionMode| {
            series.iter().find(|r| r.multipliers == m && r.mode == mode).unwrap().latency
        };
        assert_eq!(get(2, PrecisionMode::W8), 8);
        assert_eq!(get(4, PrecisionMode::W8), 4);
        assert_eq!(get(8, PrecisionMode::W8), 2);
        assert_eq!(get(16, PrecisionMode::W8), 1);
        assert_eq!(get(8, PrecisionMode::W4), 1); // stabilizes at 8 mults
        assert_eq!(get(4, PrecisionMode::W2), 1); // stabilizes at 4 mults
        // gap narrows to one cycle at M = 16 (paper §III)
        assert_eq!(get(16, PrecisionMode::W8), get(16, PrecisionMode::W2));
    }

    #[test]
    fn eq2_matches_array_model() {
        // The closed form and the AdipArray implementation agree.
        for n in [4usize, 8, 16, 32, 64] {
            let arr = AdipArray::new(ArchConfig::with_n(n));
            for mode in PrecisionMode::ALL {
                let e = crate::arch::SharedColumnUnit.pipeline_stages(mode);
                assert_eq!(
                    adip_latency(n as u64, 16, 2, 8, mode.weight_bits(), 1, e),
                    arr.tile_latency(mode),
                    "n={n} mode={mode}"
                );
            }
        }
    }

    #[test]
    fn eq3_throughput_gains_approach_2x_4x() {
        // Fig. 4(b): at large N the quantized modes deliver 2× / 4×.
        let t8 = adip_throughput_ops_per_cycle(64, 16, 2, 8, 8, 1, 3);
        let t4 = adip_throughput_ops_per_cycle(64, 16, 2, 8, 4, 1, 2);
        let t2 = adip_throughput_ops_per_cycle(64, 16, 2, 8, 2, 1, 0);
        assert!((t4 / t8 - 2.0).abs() < 0.04, "t4/t8 = {}", t4 / t8);
        // slightly above 4×: the 8b×2b column unit bypass also saves the
        // E-stage fill of the 8b×8b path
        assert!((t2 / t8 - 4.0).abs() < 0.11, "t2/t8 = {}", t2 / t8);
    }

    #[test]
    fn eq3_peak_tops_at_64() {
        // Steady-state peaks (paper abstract: 8.192/16.384/32.768 TOPS at
        // 64×64, 1 GHz). Eq. (3) includes fill/drain of a single tile, so
        // the single-tile numbers sit slightly below peak; the steady-state
        // ops/cycle equal the abstract's figures exactly.
        let arr = AdipArray::new(ArchConfig::with_n(64));
        assert_eq!(arr.peak_ops_per_cycle(PrecisionMode::W8), 8192);
        assert_eq!(arr.peak_ops_per_cycle(PrecisionMode::W4), 16384);
        assert_eq!(arr.peak_ops_per_cycle(PrecisionMode::W2), 32768);
        // Eq. (3) at N=64 approaches the peak within the fill overhead.
        let t8 = adip_throughput_ops_per_cycle(64, 16, 2, 8, 8, 1, 3);
        assert!(t8 / 8192.0 > 0.49 && t8 <= 8192.0, "single-tile t8 = {t8}");
    }

    #[test]
    fn fig4_series_is_complete_and_monotone() {
        let series = fig4_series();
        assert_eq!(series.len(), 15);
        // throughput grows with N for every mode
        for mode in PrecisionMode::ALL {
            let tp: Vec<f64> = series
                .iter()
                .filter(|r| r.mode == mode)
                .map(|r| r.throughput_ops_per_cycle)
                .collect();
            assert!(tp.windows(2).all(|w| w[1] > w[0]), "mode {mode}: {tp:?}");
        }
    }

    #[test]
    #[should_panic]
    fn eq1_rejects_zero_multipliers() {
        pe_latency(0, 2, 8, 8);
    }

    /// Golden: the paper abstract's 64×64 peaks — 8.192 / 16.384 / 32.768
    /// TOPS at 1 GHz for 8b×8b / 8b×4b / 8b×2b — exactly.
    #[test]
    fn golden_64x64_peak_tops() {
        let arr = AdipArray::new(ArchConfig::with_n(64));
        let tops = |mode| arr.peak_ops_per_cycle(mode) as f64 * 1e9 / 1e12;
        assert_eq!(tops(PrecisionMode::W8), 8.192);
        assert_eq!(tops(PrecisionMode::W4), 16.384);
        assert_eq!(tops(PrecisionMode::W2), 32.768);
        // and in raw ops/cycle
        assert_eq!(arr.peak_ops_per_cycle(PrecisionMode::W8), 8_192);
        assert_eq!(arr.peak_ops_per_cycle(PrecisionMode::W4), 16_384);
        assert_eq!(arr.peak_ops_per_cycle(PrecisionMode::W2), 32_768);
    }

    /// Golden: WS / DiP / ADiP latency ordering from the paper's tables.
    /// Per tile: WS (3N−2) > DiP (2N−1) ≥ ADiP-by-mode; per GEMM: ADiP's
    /// quantized modes gain 2×/4× over DiP while its 8-bit mode pays only
    /// the constant column-unit fill, and WS trails everything.
    #[test]
    fn golden_ws_dip_adip_latency_ordering() {
        use crate::analytical::gemm::{estimate_gemm, GemmShape, MemoryPolicy};
        use crate::arch::{Architecture, DipArray, WsArray};

        for n in [8usize, 16, 32, 64] {
            let cfg = ArchConfig::with_n(n);
            let (ws, dip, adip) = (WsArray::new(cfg), DipArray::new(cfg), AdipArray::new(cfg));
            // single-tile ordering
            let wsl = ws.tile_latency(PrecisionMode::W8);
            let dipl = dip.tile_latency(PrecisionMode::W8);
            assert!(wsl > dipl, "n={n}: WS {wsl} !> DiP {dipl}");
            assert_eq!(wsl - dipl, n as u64 - 1, "n={n}: FIFO saving");
            // ADiP narrows monotonically with weight width (E shrinks)
            let a8 = adip.tile_latency(PrecisionMode::W8);
            let a4 = adip.tile_latency(PrecisionMode::W4);
            let a2 = adip.tile_latency(PrecisionMode::W2);
            assert!(a8 > a4 && a4 > a2, "n={n}: {a8}/{a4}/{a2}");
            assert_eq!(a2, dipl, "n={n}: 8b×2b bypass equals DiP's tile latency");

            // GEMM-level ordering (paper Fig. 9 structure)
            let shape = GemmShape::new(8 * n, 8 * n, 8 * n);
            let est = |arch, mode| {
                estimate_gemm(arch, &cfg, shape, mode, MemoryPolicy::default()).cycles
            };
            let w8 = est(Architecture::Ws, PrecisionMode::W8);
            let d8 = est(Architecture::Dip, PrecisionMode::W8);
            let a8 = est(Architecture::Adip, PrecisionMode::W8);
            let a4 = est(Architecture::Adip, PrecisionMode::W4);
            let a2 = est(Architecture::Adip, PrecisionMode::W2);
            assert!(w8 > d8, "n={n}: WS {w8} !> DiP {d8}");
            assert!(d8 > a4 && a4 > a2, "n={n}: quantized ordering {d8}/{a4}/{a2}");
            // 8-bit ADiP trails DiP only by the constant E-stage fill
            assert!(a8 >= d8 && a8 - d8 <= 3, "n={n}: ADiP W8 {a8} vs DiP {d8}");
        }
    }
}
