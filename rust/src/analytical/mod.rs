//! Closed-form analytical models — paper Eqs. (1)–(3) plus the WS/DiP
//! baseline equivalents and GEMM-level estimates.
//!
//! These are the models the paper's own cycle-accurate simulator "employs
//! … for WS and DiP architectures, derived from the DiP work" (§V-B). The
//! register-level simulators in [`crate::arch::cycle_sim`] validate them
//! cycle-for-cycle; [`crate::sim`] applies them per-workload.

pub mod cluster;
pub mod equations;
pub mod gemm;
pub mod utilization;

pub use cluster::{
    estimate_cluster, estimate_coalesced, CoalescedEstimate, CoalescedMember, ClusterEstimate,
};
pub use equations::{
    adip_latency, adip_throughput_ops_per_cycle, fig2_series, fig4_series, pe_latency, Fig2Row,
    Fig4Row,
};
pub use gemm::{estimate_gemm, estimate_gemm_set, GemmEstimate, GemmShape};
pub use utilization::{effective_gain, qkv_sweep, slot_utilization, FusionPolicy};
