//! Closed-form cluster latency / throughput estimates.
//!
//! States, in closed form, exactly what the cluster execution path
//! ([`crate::cluster::ClusterScheduler`]) measures: each shard of the
//! partition is an independent [`estimate_gemm_set`] at the shard's
//! sub-shape, and the shard estimates combine under the reducer's
//! attribution rules (latency = max over cores **plus** the explicit
//! K-split reduce term of [`crate::cluster::reducer::reduce_cycles`],
//! passes/energy-like quantities = sum, shared-input traffic counted once
//! on broadcast splits). Because PR 1's differential suite proves the
//! functional backend equals `estimate_gemm_set` per GEMM, the cluster
//! equality holds by construction — and
//! `rust/tests/integration_cluster.rs` asserts it case by case anyway.

use crate::arch::{ArchConfig, Architecture};
use crate::cluster::partitioner::{partition, ClusterConfig};
use crate::cluster::reducer::reduce_cycles;
use crate::cluster::ShardSplit;
use crate::quant::PrecisionMode;

use super::gemm::{estimate_gemm_set, GemmEstimate, GemmShape, MemoryPolicy};

/// Closed-form estimate for one GEMM set sharded across a cluster.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterEstimate {
    /// Split dimension used.
    pub split: ShardSplit,
    /// Shards (= cores actually used; ≤ configured cores).
    pub shards: usize,
    /// Per-shard estimates, in plan order.
    pub per_core: Vec<GemmEstimate>,
    /// Cluster latency: max over cores (cores run concurrently) plus
    /// [`ClusterEstimate::reduce_cycles`].
    pub cycles: u64,
    /// Latency of the K-split accumulate-reduce (0 for M/N splits and
    /// single-shard plans); already included in `cycles`.
    pub reduce_cycles: u64,
    /// Total stationary passes across the cluster.
    pub passes: u64,
    /// Useful operations of the whole logical GEMM set.
    pub ops: u64,
    /// Activation read bytes (broadcast splits count the stream once).
    pub act_read_bytes: u64,
    /// Stationary (weight carrier) read bytes, summed over cores.
    pub weight_read_bytes: u64,
    /// Output write-back bytes, summed over cores.
    pub output_write_bytes: u64,
    /// Paper-policy memory total (activation + weight reads, plus
    /// write-back when the policy counts outputs).
    pub memory_bytes: u64,
}

impl ClusterEstimate {
    /// End-to-end latency speedup over a single-core estimate.
    pub fn speedup_vs(&self, single: &GemmEstimate) -> f64 {
        single.cycles as f64 / self.cycles as f64
    }

    /// Parallel efficiency: speedup divided by the cores used (1.0 =
    /// perfect linear scaling at this shard granularity).
    pub fn parallel_efficiency(&self, single: &GemmEstimate) -> f64 {
        self.speedup_vs(single) / self.shards as f64
    }

    /// Cluster-wide achieved throughput in ops/cycle (whole-GEMM ops over
    /// the gating core's latency).
    pub fn ops_per_cycle(&self) -> f64 {
        self.ops as f64 / self.cycles as f64
    }
}

/// Estimate a shared-input GEMM set of `set_size` matrices sharded across
/// `cluster`. `set_size == 1` is the single-GEMM case. The partition is
/// the same tile-aligned plan the cluster scheduler executes, so the
/// functional cluster path must (and does) match this estimate exactly.
pub fn estimate_cluster(
    arch: Architecture,
    cfg: &ArchConfig,
    shape: GemmShape,
    set_size: usize,
    requested_mode: PrecisionMode,
    cluster: &ClusterConfig,
    policy: MemoryPolicy,
) -> ClusterEstimate {
    assert!(set_size > 0, "set must contain at least one matrix");
    let plans = partition(shape.m, shape.k, shape.n, cfg.n, cluster);
    let per_core: Vec<GemmEstimate> = plans
        .iter()
        .map(|p| {
            let (m, k, n) = p.shape();
            estimate_gemm_set(arch, cfg, GemmShape::new(m, k, n), set_size, requested_mode, policy)
        })
        .collect();

    let reduce = reduce_cycles(cluster.split, plans.len(), shape.m, shape.n, set_size, cfg.n);
    let cycles = per_core.iter().map(|e| e.cycles).max().unwrap_or(0) + reduce;
    let passes = per_core.iter().map(|e| e.passes).sum();
    let ops = per_core.iter().map(|e| e.ops).sum();
    let act_read_bytes = if cluster.split.broadcasts_activations() {
        per_core.iter().map(|e| e.act_read_bytes).max().unwrap_or(0)
    } else {
        per_core.iter().map(|e| e.act_read_bytes).sum()
    };
    let weight_read_bytes = per_core.iter().map(|e| e.weight_read_bytes).sum();
    let output_write_bytes = per_core.iter().map(|e| e.output_write_bytes).sum();
    let mut memory_bytes = act_read_bytes + weight_read_bytes;
    if policy.count_outputs {
        memory_bytes += output_write_bytes;
    }

    ClusterEstimate {
        split: cluster.split,
        shards: plans.len(),
        per_core,
        cycles,
        reduce_cycles: reduce,
        passes,
        ops,
        act_read_bytes,
        weight_read_bytes,
        output_write_bytes,
        memory_bytes,
    }
}

/// Closed-form estimate for one **coalesced** pass: member batches with
/// identical weight sets stacked along `M` and executed as one
/// shared-input cluster run (see `balance/coalescer.rs`), with the pass's
/// accounting attributed back per member by row share.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoalescedEstimate {
    /// The whole stacked pass: a plain cluster estimate at shape
    /// `(Σ rows, k, n)`.
    pub total: ClusterEstimate,
    /// Per-member attributed accounting, in stacking order.
    pub members: Vec<CoalescedMember>,
}

/// One member's row-share slice of a coalesced pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoalescedMember {
    /// Activation rows this member contributed.
    pub rows: usize,
    /// Cycles attributed (row share of the pass, rounded to nearest).
    pub cycles: u64,
    /// Passes attributed (row share, rounded to nearest).
    pub passes: u64,
    /// Activation read bytes attributed (row share, truncated).
    pub act_read_bytes: u64,
    /// Weight read bytes attributed (row share, truncated).
    pub weight_read_bytes: u64,
    /// Output write-back bytes attributed (row share, truncated).
    pub output_write_bytes: u64,
}

/// Estimate a coalesced pass: `member_rows[i]` activation rows per member,
/// all multiplying the same `set_size`-matrix weight set of shape
/// `k × n_cols` in `requested_mode`, sharded across `cluster`.
///
/// The per-member attribution uses **exactly** the arithmetic of
/// `balance::split_back` (the helpers are shared), so the functional
/// serving path's per-ticket accounting equals this estimate by
/// construction — `rust/tests/integration_balance.rs` asserts it case by
/// case. The win the estimate exposes: the stacked pass runs
/// `ceil(Σm / n)` activation tile rows against each stationary weight
/// tile instead of `Σ ceil(mᵢ / n)`, so skinny (decode-shaped) members
/// amortize fill/drain and re-load the weight tiles once per pass rather
/// than once per request.
#[allow(clippy::too_many_arguments)] // mirrors estimate_cluster + the member split
pub fn estimate_coalesced(
    arch: Architecture,
    cfg: &ArchConfig,
    member_rows: &[usize],
    k: usize,
    n_cols: usize,
    set_size: usize,
    requested_mode: PrecisionMode,
    cluster: &ClusterConfig,
    policy: MemoryPolicy,
) -> CoalescedEstimate {
    use crate::balance::split_back::{row_share_bytes, row_share_cycles};
    assert!(!member_rows.is_empty(), "a coalesced pass needs at least one member");
    let m_total: usize = member_rows.iter().sum();
    let total = estimate_cluster(
        arch,
        cfg,
        GemmShape::new(m_total, k, n_cols),
        set_size,
        requested_mode,
        cluster,
        policy,
    );
    let members = member_rows
        .iter()
        .map(|&rows| CoalescedMember {
            rows,
            cycles: row_share_cycles(total.cycles, rows, m_total),
            passes: row_share_cycles(total.passes, rows, m_total),
            act_read_bytes: row_share_bytes(total.act_read_bytes, rows, m_total),
            weight_read_bytes: row_share_bytes(total.weight_read_bytes, rows, m_total),
            output_write_bytes: row_share_bytes(total.output_write_bytes, rows, m_total),
        })
        .collect();
    CoalescedEstimate { total, members }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytical::estimate_gemm;

    fn cfg() -> ArchConfig {
        ArchConfig::with_n(32)
    }

    #[test]
    fn single_core_cluster_degenerates_to_gemm_estimate() {
        let shape = GemmShape::new(256, 256, 256);
        let single = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        let c = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            shape,
            1,
            PrecisionMode::W2,
            &ClusterConfig::default(),
            MemoryPolicy::default(),
        );
        assert_eq!(c.shards, 1);
        assert_eq!(c.cycles, single.cycles);
        assert_eq!(c.passes, single.passes);
        assert_eq!(c.ops, single.ops);
        assert_eq!(c.memory_bytes, single.memory_bytes);
    }

    #[test]
    fn m_split_scales_near_linearly_on_even_shards() {
        let shape = GemmShape::new(256, 256, 256);
        let single = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W2,
            MemoryPolicy::default(),
        );
        for cores in [2usize, 4, 8] {
            let c = estimate_cluster(
                Architecture::Adip,
                &cfg(),
                shape,
                1,
                PrecisionMode::W2,
                &ClusterConfig::with_cores(cores),
                MemoryPolicy::default(),
            );
            assert_eq!(c.shards, cores, "256 rows = 8 tiles shard {cores} ways");
            let s = c.speedup_vs(&single);
            // per-shard fill overhead keeps it just under linear
            assert!(s > 0.9 * cores as f64 && s <= cores as f64, "cores={cores} speedup={s}");
            assert!(c.parallel_efficiency(&single) > 0.9);
            // same total tile passes, same total weight traffic × cores
            assert_eq!(c.passes, single.passes);
        }
    }

    #[test]
    fn n_split_counts_broadcast_activations_once() {
        let shape = GemmShape::new(128, 128, 256);
        let cluster = ClusterConfig::with_cores(4).with_split(ShardSplit::N);
        let c = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            shape,
            1,
            PrecisionMode::W8,
            &cluster,
            MemoryPolicy::default(),
        );
        assert_eq!(c.shards, 4);
        let act_sum: u64 = c.per_core.iter().map(|e| e.act_read_bytes).sum();
        let act_max = c.per_core.iter().map(|e| e.act_read_bytes).max().unwrap();
        assert_eq!(c.act_read_bytes, act_max, "broadcast stream counted once");
        assert!(act_sum > act_max);
        // weight slices are disjoint: they sum to the single-core total
        let single = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W8,
            MemoryPolicy::default(),
        );
        assert_eq!(c.weight_read_bytes, single.weight_read_bytes);
    }

    #[test]
    fn k_split_keeps_total_ops_and_sums_partial_writebacks() {
        let shape = GemmShape::new(64, 256, 64);
        let cluster = ClusterConfig::with_cores(4).with_split(ShardSplit::K);
        let c = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            shape,
            1,
            PrecisionMode::W4,
            &cluster,
            MemoryPolicy::default(),
        );
        assert_eq!(c.shards, 4);
        assert_eq!(c.ops, shape.ops(), "disjoint K slices cover the GEMM");
        let single = estimate_gemm(
            Architecture::Adip,
            &cfg(),
            shape,
            PrecisionMode::W4,
            MemoryPolicy::default(),
        );
        // each core drains a full-size partial product
        assert_eq!(c.output_write_bytes, 4 * single.output_write_bytes);
        // the accumulate-reduce is charged explicitly: 3 extra partials ×
        // (2 × 2 output tiles at n = 32)
        assert_eq!(c.reduce_cycles, 3 * 2 * 2);
        let gating = c.per_core.iter().map(|e| e.cycles).max().unwrap();
        assert_eq!(c.cycles, gating + c.reduce_cycles);
        assert!(c.cycles < single.cycles, "reduce cost must not erase the K-split win here");
    }

    #[test]
    fn only_k_splits_pay_the_reduce_term() {
        let shape = GemmShape::new(256, 256, 256);
        for (split, expect_reduce) in
            [(ShardSplit::M, false), (ShardSplit::N, false), (ShardSplit::K, true)]
        {
            let c = estimate_cluster(
                Architecture::Adip,
                &cfg(),
                shape,
                1,
                PrecisionMode::W2,
                &ClusterConfig::with_cores(4).with_split(split),
                MemoryPolicy::default(),
            );
            assert_eq!(c.shards, 4, "{split}");
            assert_eq!(c.reduce_cycles > 0, expect_reduce, "{split}");
        }
        // degenerate single-shard K plan: nothing to reduce
        let one = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            GemmShape::new(256, 32, 256), // one K tile at n = 32
            1,
            PrecisionMode::W2,
            &ClusterConfig::with_cores(4).with_split(ShardSplit::K),
            MemoryPolicy::default(),
        );
        assert_eq!(one.shards, 1);
        assert_eq!(one.reduce_cycles, 0);
    }

    #[test]
    fn coalesced_estimate_is_the_stacked_cluster_estimate_split_by_rows() {
        // two skinny decode-shaped members against one shared weight set
        let (k, n_cols) = (256usize, 256usize);
        let members = [8usize, 24];
        let est = estimate_coalesced(
            Architecture::Adip,
            &cfg(),
            &members,
            k,
            n_cols,
            2,
            PrecisionMode::W2,
            &ClusterConfig::default(),
            MemoryPolicy::default(),
        );
        let stacked = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            GemmShape::new(32, k, n_cols),
            2,
            PrecisionMode::W2,
            &ClusterConfig::default(),
            MemoryPolicy::default(),
        );
        assert_eq!(est.total, stacked, "the pass is a plain stacked estimate");
        assert_eq!(est.members.len(), 2);
        // row-share attribution sums back to the pass (within rounding)
        let cyc: u64 = est.members.iter().map(|m| m.cycles).sum();
        assert!(cyc.abs_diff(stacked.cycles) <= 1, "{cyc} vs {}", stacked.cycles);
        assert!(est.members[1].cycles > est.members[0].cycles, "3x the rows, bigger share");
    }

    #[test]
    fn coalescing_skinny_members_beats_solo_passes() {
        // the data-reuse win in closed form: one stacked pass loads the
        // stationary weight set once; two solo passes load it twice
        let (k, n_cols) = (256usize, 128usize);
        let solo = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            GemmShape::new(8, k, n_cols),
            1,
            PrecisionMode::W2,
            &ClusterConfig::default(),
            MemoryPolicy::default(),
        );
        let co = estimate_coalesced(
            Architecture::Adip,
            &cfg(),
            &[8, 8],
            k,
            n_cols,
            1,
            PrecisionMode::W2,
            &ClusterConfig::default(),
            MemoryPolicy::default(),
        );
        assert!(
            co.total.cycles < 2 * solo.cycles,
            "stacked {} vs 2 solo {}",
            co.total.cycles,
            2 * solo.cycles
        );
        assert!(
            co.total.weight_read_bytes < 2 * solo.weight_read_bytes,
            "weights loaded once per pass, not once per request"
        );
        assert_eq!(co.total.passes, solo.passes, "8+8 rows still fit one tile row");
    }

    #[test]
    fn unshardable_dimension_caps_the_shard_count() {
        let shape = GemmShape::new(32, 512, 512); // one M tile at n=32
        let c = estimate_cluster(
            Architecture::Adip,
            &cfg(),
            shape,
            1,
            PrecisionMode::W8,
            &ClusterConfig::with_cores(8),
            MemoryPolicy::default(),
        );
        assert_eq!(c.shards, 1);
    }
}
