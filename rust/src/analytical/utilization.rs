//! Array-utilization analysis — the quantitative case for the Fig. 5(d)
//! Q/K/V variant.
//!
//! The paper motivates the 3-way interleave with core under-utilization
//! “when the core utilization is limited by the ratio between the head
//! size and the ADiP core size”. This module computes stationary-slot
//! utilization for a projection workload as a function of head size `d_k`,
//! array size `N` and fusion policy, quantifying exactly when multi-matrix
//! fusion recovers the idle capacity.

use crate::quant::PrecisionMode;

/// How weight tiles are packed into stationary passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionPolicy {
    /// One weight matrix per pass (DiP-style; narrow modes waste slots).
    None,
    /// Adjacent output-column tiles of one matrix share a pass (Fig. 5(b)/(c)).
    ColumnTiles,
    /// Column tiles of *multiple* matrices sharing an input may mix in one
    /// pass (Fig. 5(d) generalized — what `sim::cosim::run_gemm_set` does).
    MultiMatrix {
        /// Number of weight matrices sharing the input (e.g. 3 for Q/K/V).
        set: usize,
    },
}

/// Utilization of the stationary interleave capacity for a projection of
/// output width `out_cols` (per matrix) on an `n×n` array in `mode`.
///
/// Returns a value in `(0, 1]`: fraction of stationary slots carrying real
/// weight tiles, averaged over the passes of one reduction step.
pub fn slot_utilization(
    mode: PrecisionMode,
    n: usize,
    out_cols: usize,
    policy: FusionPolicy,
) -> f64 {
    assert!(n > 0 && out_cols > 0);
    let cap = mode.interleave_factor();
    let tiles_n = out_cols.div_ceil(n);
    let (slots_used, passes) = match policy {
        FusionPolicy::None => (tiles_n, tiles_n * cap), // 1 slot of `cap` per pass
        FusionPolicy::ColumnTiles => {
            let passes = tiles_n.div_ceil(cap);
            (tiles_n, passes * cap)
        }
        FusionPolicy::MultiMatrix { set } => {
            assert!(set >= 1);
            let total = tiles_n * set;
            let passes = total.div_ceil(cap);
            (total, passes * cap)
        }
    };
    slots_used as f64 / passes as f64
}

/// Effective throughput gain over 8b×8b for a projection workload under a
/// policy: the mode's ideal gain × the slot utilization.
pub fn effective_gain(mode: PrecisionMode, n: usize, out_cols: usize, policy: FusionPolicy) -> f64 {
    mode.throughput_gain() as f64 * slot_utilization(mode, n, out_cols, policy)
}

/// One row of the utilization report: the Q/K/V head-projection case.
#[derive(Debug, Clone, Copy)]
pub struct UtilizationRow {
    /// Head size (output width per matrix).
    pub d_k: usize,
    /// Array size.
    pub n: usize,
    /// Utilization without fusion.
    pub solo: f64,
    /// Utilization with column-tile fusion only.
    pub column: f64,
    /// Utilization with 3-way Q/K/V multi-matrix fusion.
    pub qkv: f64,
}

/// Sweep head sizes for the 8b×2b mode at an array size — the Fig. 5(d)
/// under-utilization regime appears when `d_k ≤ n` (a single column tile).
pub fn qkv_sweep(n: usize, head_sizes: &[usize]) -> Vec<UtilizationRow> {
    head_sizes
        .iter()
        .map(|&d_k| UtilizationRow {
            d_k,
            n,
            solo: slot_utilization(PrecisionMode::W2, n, d_k, FusionPolicy::None),
            column: slot_utilization(PrecisionMode::W2, n, d_k, FusionPolicy::ColumnTiles),
            qkv: slot_utilization(PrecisionMode::W2, n, d_k, FusionPolicy::MultiMatrix { set: 3 }),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, Architecture};
    use crate::dataflow::Mat;
    use crate::quant::PrecisionMode;
    use crate::sim::CoSim;
    use crate::testutil::Rng;

    #[test]
    fn no_fusion_wastes_capacity_in_narrow_modes() {
        // 8b×2b with one matrix per pass: 1 of 4 slots used.
        assert_eq!(slot_utilization(PrecisionMode::W2, 32, 128, FusionPolicy::None), 0.25);
        assert_eq!(slot_utilization(PrecisionMode::W4, 32, 128, FusionPolicy::None), 0.5);
        assert_eq!(slot_utilization(PrecisionMode::W8, 32, 128, FusionPolicy::None), 1.0);
    }

    #[test]
    fn column_fusion_saturates_wide_outputs() {
        // 4 column tiles fill the 4 slots exactly
        assert_eq!(slot_utilization(PrecisionMode::W2, 32, 128, FusionPolicy::ColumnTiles), 1.0);
        // a single column tile (d_k = n) cannot: 1/4
        assert_eq!(slot_utilization(PrecisionMode::W2, 32, 32, FusionPolicy::ColumnTiles), 0.25);
    }

    #[test]
    fn qkv_fusion_recovers_head_limited_utilization() {
        // d_k = n: solo/column = 25%, 3-way Q/K/V = 75% (paper Fig. 5(d))
        let rows = qkv_sweep(32, &[32]);
        let r = rows[0];
        assert_eq!(r.solo, 0.25);
        assert_eq!(r.column, 0.25);
        assert_eq!(r.qkv, 0.75);
        // effective gains: 1× vs 3× over 8b×8b
        assert_eq!(
            effective_gain(PrecisionMode::W2, 32, 32, FusionPolicy::ColumnTiles),
            1.0
        );
        assert_eq!(
            effective_gain(PrecisionMode::W2, 32, 32, FusionPolicy::MultiMatrix { set: 3 }),
            3.0
        );
    }

    #[test]
    fn utilization_matches_cosim_pass_counts() {
        // analytical slot utilization must predict the co-simulator's pass
        // counts: passes = slots_used / (cap × utilization)
        let mut rng = Rng::seeded(71);
        let n = 8;
        let d_k = 8; // one column tile per matrix
        let x = Mat::random(&mut rng, 16, 16, 8);
        let ws: Vec<Mat> = (0..3).map(|_| Mat::random(&mut rng, 16, d_k, 2)).collect();
        let refs: Vec<&Mat> = ws.iter().collect();
        let mut sim =
            CoSim::new(crate::arch::build_array(Architecture::Adip, ArchConfig::with_n(n)));
        let fused = sim.run_gemm_set(&x, &refs, PrecisionMode::W2, false).unwrap();
        // 3 slots in 1 group × tiles_k(2) × tiles_m(2) = 4 passes
        assert_eq!(fused.passes, 4);
        let mut solo_passes = 0;
        for w in &ws {
            let mut s =
                CoSim::new(crate::arch::build_array(Architecture::Adip, ArchConfig::with_n(n)));
            solo_passes += s.run_gemm(&x, w, PrecisionMode::W2, false).unwrap().passes;
        }
        assert_eq!(solo_passes, 12);
        let predicted =
            slot_utilization(PrecisionMode::W2, n, d_k, FusionPolicy::MultiMatrix { set: 3 })
                / slot_utilization(PrecisionMode::W2, n, d_k, FusionPolicy::ColumnTiles);
        assert_eq!(solo_passes as f64 / fused.passes as f64, predicted);
    }

    #[test]
    fn sweep_monotone_in_head_size() {
        let rows = qkv_sweep(32, &[32, 64, 128, 256]);
        for w in rows.windows(2) {
            assert!(w[1].column >= w[0].column);
        }
        // wide heads saturate even without set fusion
        assert_eq!(rows.last().unwrap().column, 1.0);
    }
}
