//! Weight-tile result cache: skip re-executing shards whose stationary
//! weights (and activation) were already run through a core.
//!
//! Transformer serving repeats the same projection weights every layer
//! invocation; when the *same request* recurs (identical activation too —
//! re-served prompts, replayed traces, retries), the shard's outputs are
//! already known and re-execution is pure waste. The cache is keyed by
//! the `(weight-tile fingerprint, precision mode, runtime-interleave
//! flag)` triple *extended with the activation fingerprint*: the
//! cluster's bit-exactness invariant requires a hit to reproduce the
//! uncached outputs exactly, so a weight match under a different
//! activation is simply a miss that occupies its own entry. (Folding the
//! activation into the key — rather than qualifying a weights-only entry
//! — also keeps M-split shards distinct: their weight slices are
//! identical full copies of `B` and only their activation slices differ.)
//!
//! **Accounting rule:** a hit contributes *zero* simulated cycles, energy
//! and memory traffic — the execution is skipped entirely — and is
//! reported through the `cache_hits` / `cache_misses` / `cache_evictions`
//! counters (surfaced in [`crate::coordinator::Metrics`]). A cold cache is
//! therefore accounting-neutral: misses change nothing, so the cluster's
//! analytical-estimate equality holds whenever no hit occurs.
//!
//! Fingerprints are 128-bit (two independently-seeded FNV-1a streams over
//! dimensions + elements). A collision would violate bit-exactness; at
//! ~2⁻¹²⁸ per pair this is accepted and documented rather than re-verified.

use std::collections::HashMap;

use crate::dataflow::Mat;
use crate::quant::PrecisionMode;
use crate::sim::CoSimResult;

/// Weight-cache configuration (`capacity` entries; 0 disables the cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheConfig {
    /// Maximum live entries before LRU eviction; 0 = caching off.
    pub capacity: usize,
}

impl CacheConfig {
    /// Whether the cache is enabled at all.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }
}

/// Cumulative cache counters (monotonic; diff snapshots for per-run deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that missed (unknown weights, activation, or mode).
    pub misses: u64,
    /// Live entries removed under LRU capacity pressure.
    pub evictions: u64,
    /// Current live entries.
    pub entries: usize,
}

impl CacheStats {
    /// `self - earlier`, for per-run deltas (entries carried as-is).
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            entries: self.entries,
        }
    }
}

/// 128-bit fingerprint over a list of matrices (dims + every element).
pub fn fingerprint(mats: &[&Mat]) -> u128 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut lo = OFFSET;
    let mut hi = OFFSET ^ 0x9e37_79b9_7f4a_7c15; // independent second stream
    let mut mix = |v: u64| {
        lo = (lo ^ v).wrapping_mul(PRIME);
        hi = (hi ^ v.rotate_left(23)).wrapping_mul(PRIME);
    };
    for m in mats {
        mix(m.rows() as u64);
        mix(m.cols() as u64);
        for &v in m.as_slice() {
            mix(v as u32 as u64);
        }
    }
    ((hi as u128) << 64) | lo as u128
}

/// Fold per-operand fingerprints into one order-sensitive set fingerprint
/// (128-bit FNV-1a over the element fingerprints). Lets callers memoize
/// the per-matrix hashes — e.g. the cluster scheduler hashes a borrowed
/// full weight set once per run instead of once per shard.
pub fn combine_fingerprints<I: IntoIterator<Item = u128>>(fps: I) -> u128 {
    const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;
    let mut h = OFFSET;
    for fp in fps {
        h ^= fp;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Cache key: the stationary weight set of one shard, as executed, plus
/// the activation fingerprint that makes a hit bit-exact by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct WeightKey {
    weight_fp: u128,
    act_fp: u128,
    mode: PrecisionMode,
    runtime_interleave: bool,
}

struct Entry {
    result: CoSimResult,
    stamp: u64,
}

/// LRU map from weight-tile fingerprints to shard execution results.
pub struct WeightCache {
    cfg: CacheConfig,
    map: HashMap<WeightKey, Entry>,
    clock: u64,
    stats: CacheStats,
}

impl WeightCache {
    /// Empty cache under `cfg`.
    pub fn new(cfg: CacheConfig) -> WeightCache {
        WeightCache { cfg, map: HashMap::new(), clock: 0, stats: CacheStats::default() }
    }

    /// Whether lookups can ever hit.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Cumulative counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats { entries: self.map.len(), ..self.stats }
    }

    /// Look up a shard execution. A hit returns the cached result (outputs
    /// are bit-exact by key construction) and counts `hits`; any miss
    /// counts `misses`.
    pub fn lookup(
        &mut self,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Option<CoSimResult> {
        if !self.enabled() {
            return None;
        }
        let key = WeightKey { weight_fp, act_fp, mode, runtime_interleave };
        self.clock += 1;
        match self.map.get_mut(&key) {
            Some(e) => {
                e.stamp = self.clock;
                self.stats.hits += 1;
                Some(e.result.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert the result of an executed shard, evicting the
    /// least-recently-used entries while over capacity.
    pub fn insert(
        &mut self,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
        result: CoSimResult,
    ) {
        if !self.enabled() {
            return;
        }
        let key = WeightKey { weight_fp, act_fp, mode, runtime_interleave };
        self.clock += 1;
        // A same-key insert (duplicate shards in one run, all probed before
        // any executes) replaces a bit-identical result — not an eviction.
        self.map.insert(key, Entry { result, stamp: self.clock });
        while self.map.len() > self.cfg.capacity {
            // O(capacity) victim scan — accepted: capacities are small
            // (≤ ~512) and the scan is dwarfed by the operand hashing a
            // miss already paid; revisit with an ordered index if
            // capacities grow.
            let lru = *self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k)
                .expect("non-empty over-capacity map");
            self.map.remove(&lru);
            self.stats.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MemoryCounters;
    use crate::testutil::Rng;

    fn result(cycles: u64) -> CoSimResult {
        CoSimResult {
            outputs: vec![Mat::zeros(2, 2)],
            passes: 1,
            cycles,
            energy_j: 1e-9,
            memory: MemoryCounters::default(),
        }
    }

    #[test]
    fn fingerprint_discriminates_content_and_shape() {
        let mut rng = Rng::seeded(41);
        let a = Mat::random(&mut rng, 6, 6, 8);
        let mut b = a.clone();
        b.set(3, 3, b.get(3, 3) ^ 1);
        assert_ne!(fingerprint(&[&a]), fingerprint(&[&b]));
        let flat = Mat::zeros(4, 9);
        let tall = Mat::zeros(9, 4);
        assert_ne!(fingerprint(&[&flat]), fingerprint(&[&tall]));
        // order of matrices matters (Q/K/V are distinct slots)
        assert_ne!(fingerprint(&[&a, &flat]), fingerprint(&[&flat, &a]));
        assert_eq!(fingerprint(&[&a]), fingerprint(&[&a.clone()]));
    }

    #[test]
    fn hit_requires_matching_activation() {
        let mut c = WeightCache::new(CacheConfig { capacity: 4 });
        c.insert(1, 100, PrecisionMode::W2, false, result(10));
        assert!(c.lookup(1, 100, PrecisionMode::W2, false).is_some());
        assert!(c.lookup(1, 200, PrecisionMode::W2, false).is_none(), "other activation");
        assert!(c.lookup(1, 100, PrecisionMode::W4, false).is_none(), "other mode");
        assert!(c.lookup(1, 100, PrecisionMode::W2, true).is_none(), "other interleave");
        assert!(c.lookup(2, 100, PrecisionMode::W2, false).is_none(), "other weights");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 4, 1));
    }

    #[test]
    fn lru_eviction_under_capacity_pressure() {
        let mut c = WeightCache::new(CacheConfig { capacity: 2 });
        c.insert(1, 1, PrecisionMode::W8, false, result(1));
        c.insert(2, 1, PrecisionMode::W8, false, result(2));
        assert!(c.lookup(1, 1, PrecisionMode::W8, false).is_some()); // touch 1: 2 is now LRU
        c.insert(3, 1, PrecisionMode::W8, false, result(3));
        assert_eq!(c.stats().evictions, 1);
        assert!(c.lookup(2, 1, PrecisionMode::W8, false).is_none(), "2 evicted as LRU");
        assert!(c.lookup(1, 1, PrecisionMode::W8, false).is_some());
        // same weights under a new activation occupy their own entry
        // (bit-exactness: the activation is part of the key), evicting the
        // LRU entry (3) — the old (1, act 1) result still hits
        c.insert(1, 9, PrecisionMode::W8, false, result(4));
        assert_eq!(c.stats().evictions, 2);
        assert!(c.lookup(1, 9, PrecisionMode::W8, false).is_some());
        assert!(c.lookup(1, 1, PrecisionMode::W8, false).is_some());
        assert!(c.lookup(3, 1, PrecisionMode::W8, false).is_none(), "3 evicted as LRU");
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn identical_weight_slices_with_distinct_activations_coexist() {
        // The M-split shape: every shard's weight slice is the same full
        // copy of B (equal weight_fp) while activation slices differ —
        // each shard must get its own entry, not displace its siblings.
        let mut c = WeightCache::new(CacheConfig { capacity: 8 });
        c.insert(7, 100, PrecisionMode::W2, false, result(1));
        c.insert(7, 200, PrecisionMode::W2, false, result(2));
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.stats().entries, 2);
        assert!(c.lookup(7, 100, PrecisionMode::W2, false).is_some());
        assert!(c.lookup(7, 200, PrecisionMode::W2, false).is_some());
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn disabled_cache_never_stores() {
        let mut c = WeightCache::new(CacheConfig::default());
        assert!(!c.enabled());
        c.insert(1, 1, PrecisionMode::W8, false, result(1));
        assert!(c.lookup(1, 1, PrecisionMode::W8, false).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (0, 0, 0, 0));
    }

    #[test]
    fn combined_fingerprints_are_order_sensitive() {
        let (a, b) = (1u128 << 100 | 7, 9u128 << 60 | 3);
        assert_ne!(combine_fingerprints([a, b]), combine_fingerprints([b, a]));
        assert_ne!(combine_fingerprints([a]), combine_fingerprints([a, a]));
        assert_eq!(combine_fingerprints([a, b]), combine_fingerprints([a, b]));
    }

    #[test]
    fn stats_delta() {
        let mut c = WeightCache::new(CacheConfig { capacity: 2 });
        c.insert(1, 1, PrecisionMode::W8, false, result(1));
        let before = c.stats();
        assert!(c.lookup(1, 1, PrecisionMode::W8, false).is_some());
        assert!(c.lookup(9, 1, PrecisionMode::W8, false).is_none());
        let d = c.stats().delta_since(&before);
        assert_eq!((d.hits, d.misses), (1, 1));
    }
}
