//! Weight-tile result cache: skip re-executing shards whose stationary
//! weights (and activation) were already run through a core.
//!
//! Transformer serving repeats the same projection weights every layer
//! invocation; when the *same request* recurs (identical activation too —
//! re-served prompts, replayed traces, retries), the shard's outputs are
//! already known and re-execution is pure waste. The cache is keyed by
//! the `(weight-tile fingerprint, precision mode, runtime-interleave
//! flag)` triple *extended with the activation fingerprint*: the
//! cluster's bit-exactness invariant requires a hit to reproduce the
//! uncached outputs exactly, so a weight match under a different
//! activation is simply a miss that occupies its own entry. (Folding the
//! activation into the key — rather than qualifying a weights-only entry
//! — also keeps M-split shards distinct: their weight slices are
//! identical full copies of `B` and only their activation slices differ.)
//!
//! # Cross-worker sharing
//!
//! [`SharedWeightCache`] shares one logical store — split into
//! fingerprint-routed, independently-locked [`WeightCache`] shards at
//! useful capacities — so *several* cluster schedulers — e.g. every
//! worker of one [`crate::coordinator::Coordinator`] — can reuse each
//! other's entries: sibling workers stop re-executing identical
//! projection tiles one of them already computed. Each attached scheduler registers for an
//! owner id; entries remember which owner inserted them, and a hit on
//! another owner's entry is additionally counted as a `shared_hit`
//! (surfaced as `adip_weight_cache_shared_hits_total`). Sharing cannot
//! change results: a hit is bit-exact by key construction regardless of
//! which worker computed the entry.
//!
//! **Accounting rule:** a hit contributes *zero* simulated cycles, energy
//! and memory traffic — the execution is skipped entirely — and is
//! reported through the `cache_hits` / `cache_misses` / `cache_evictions`
//! counters (surfaced in [`crate::coordinator::Metrics`]). A cold cache is
//! therefore accounting-neutral: misses change nothing, so the cluster's
//! analytical-estimate equality holds whenever no hit occurs.
//!
//! Fingerprints are 128-bit (two independently-seeded FNV-1a streams over
//! dimensions + elements). A collision would violate bit-exactness; at
//! ~2⁻¹²⁸ per pair this is accepted and documented rather than re-verified.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError, TryLockError};

use crate::dataflow::Mat;
use crate::quant::PrecisionMode;
use crate::sim::CoSimResult;

/// Weight-cache configuration (`capacity` entries; 0 disables the cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheConfig {
    /// Maximum live entries before LRU eviction; 0 = caching off.
    pub capacity: usize,
    /// Eviction-protection window in **lookups**: an entry that was hit
    /// within the last `protect` lookups cannot be evicted by a
    /// *different* owner's insert (0 = plain LRU). This is the
    /// eviction-aware admission policy for the shared store: one worker's
    /// streaming trace (endless one-shot inserts) cannot flush a sibling's
    /// hot projection tiles — when every other entry is protected, the
    /// streamer's own newest entry is the eviction victim, i.e. the
    /// insert is effectively refused admission. An owner always remains
    /// free to evict its *own* entries, so a single-owner cache degrades
    /// to plain LRU and the protection can never deadlock capacity.
    pub protect: usize,
}

impl CacheConfig {
    /// Whether the cache is enabled at all.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }
}

/// Cumulative cache counters (monotonic; diff snapshots for per-run deltas).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Subset of `hits` served from an entry a *different* owner (sibling
    /// worker on the same shared store) inserted.
    pub shared_hits: u64,
    /// Lookups that missed (unknown weights, activation, or mode).
    pub misses: u64,
    /// Live entries removed under LRU capacity pressure.
    pub evictions: u64,
    /// Current live entries.
    pub entries: usize,
}

impl CacheStats {
    /// `self - earlier`, for per-run deltas (entries carried as-is).
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            shared_hits: self.shared_hits - earlier.shared_hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            entries: self.entries,
        }
    }
}

/// 128-bit fingerprint over a list of matrices (dims + every element).
pub fn fingerprint(mats: &[&Mat]) -> u128 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut lo = OFFSET;
    let mut hi = OFFSET ^ 0x9e37_79b9_7f4a_7c15; // independent second stream
    let mut mix = |v: u64| {
        lo = (lo ^ v).wrapping_mul(PRIME);
        hi = (hi ^ v.rotate_left(23)).wrapping_mul(PRIME);
    };
    for m in mats {
        mix(m.rows() as u64);
        mix(m.cols() as u64);
        for &v in m.as_slice() {
            mix(v as u32 as u64);
        }
    }
    ((hi as u128) << 64) | lo as u128
}

/// Fold per-operand fingerprints into one order-sensitive set fingerprint
/// (128-bit FNV-1a over the element fingerprints). Lets callers memoize
/// the per-matrix hashes — e.g. the cluster scheduler hashes a borrowed
/// full weight set once per run instead of once per shard.
pub fn combine_fingerprints<I: IntoIterator<Item = u128>>(fps: I) -> u128 {
    const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;
    let mut h = OFFSET;
    for fp in fps {
        h ^= fp;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Cache key: the stationary weight set of one shard, as executed, plus
/// the activation fingerprint that makes a hit bit-exact by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct WeightKey {
    weight_fp: u128,
    act_fp: u128,
    mode: PrecisionMode,
    runtime_interleave: bool,
}

struct Entry {
    /// `Arc`'d so a hit hands back a cheap handle: the deep copy of the
    /// outputs (needed because hits mutate accounting to zero) happens in
    /// the *caller*, outside the shared store's mutex — sibling workers
    /// never serialize behind each other's result copies.
    result: Arc<CoSimResult>,
    stamp: u64,
    /// Which registered owner (scheduler) inserted this entry — a hit by
    /// any *other* owner is a shared (cross-worker) hit.
    owner: u64,
    /// Value of the store's lookup counter when this entry was last hit
    /// (0 = never). Drives the cross-owner eviction protection window.
    last_hit_lookup: u64,
}

/// LRU map from weight-tile fingerprints to shard execution results.
pub struct WeightCache {
    cfg: CacheConfig,
    map: HashMap<WeightKey, Entry>,
    clock: u64,
    /// Lookup calls served so far (the protection window's time base —
    /// distinct from `clock`, which also advances on inserts).
    lookups: u64,
    stats: CacheStats,
}

impl WeightCache {
    /// Empty cache under `cfg`.
    pub fn new(cfg: CacheConfig) -> WeightCache {
        WeightCache { cfg, map: HashMap::new(), clock: 0, lookups: 0, stats: CacheStats::default() }
    }

    /// Whether lookups can ever hit.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Cumulative counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats { entries: self.map.len(), ..self.stats }
    }

    /// Look up a shard execution for `requester`. A hit returns a handle
    /// to the cached result (outputs are bit-exact by key construction)
    /// plus whether the entry was inserted by a different owner (a
    /// cross-worker hit), and counts `hits`/`shared_hits`; any miss counts
    /// `misses`.
    pub fn lookup(
        &mut self,
        requester: u64,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Option<(Arc<CoSimResult>, bool)> {
        if !self.enabled() {
            return None;
        }
        let key = WeightKey { weight_fp, act_fp, mode, runtime_interleave };
        self.clock += 1;
        self.lookups += 1;
        match self.map.get_mut(&key) {
            Some(e) => {
                e.stamp = self.clock;
                e.last_hit_lookup = self.lookups;
                self.stats.hits += 1;
                let cross_owner = e.owner != requester;
                if cross_owner {
                    self.stats.shared_hits += 1;
                }
                Some((e.result.clone(), cross_owner))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert the result of a shard `owner` executed, evicting the
    /// least-recently-used entries while over capacity. Returns how many
    /// evictions this insert caused (for the inserter's local accounting).
    pub fn insert(
        &mut self,
        owner: u64,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
        result: CoSimResult,
    ) -> u64 {
        if !self.enabled() {
            return 0;
        }
        let key = WeightKey { weight_fp, act_fp, mode, runtime_interleave };
        self.clock += 1;
        // A same-key insert (duplicate shards in one run, all probed before
        // any executes — or sibling workers racing on one request) replaces
        // a bit-identical result — not an eviction.
        self.map.insert(
            key,
            Entry { result: Arc::new(result), stamp: self.clock, owner, last_hit_lookup: 0 },
        );
        let mut evicted = 0;
        while self.map.len() > self.cfg.capacity {
            // O(capacity) victim scan — accepted: capacities are small
            // (≤ ~512) and the scan is dwarfed by the operand hashing a
            // miss already paid; revisit with an ordered index if
            // capacities grow.
            //
            // Eviction-aware admission: a *sibling's* entry hit within the
            // last `protect` lookups is off-limits to this owner's insert.
            // The just-inserted entry is always a candidate (it is our own
            // and has never been hit), so when everything else is
            // protected, the newcomer itself is the LRU-by-stamp victim —
            // the insert refuses admission rather than flushing hot tiles.
            let protect = self.cfg.protect as i64;
            let lookups = self.lookups;
            let lru = *self
                .map
                .iter()
                .filter(|(_, e)| {
                    !(protect > 0
                        && e.owner != owner
                        && e.last_hit_lookup > 0
                        && (lookups as i64 - e.last_hit_lookup as i64) < protect)
                })
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k)
                .expect("the inserter's own fresh entry is always evictable");
            self.map.remove(&lru);
            self.stats.evictions += 1;
            evicted += 1;
        }
        evicted
    }
}

/// Lock shards a [`SharedWeightCache`] splits into once its capacity
/// reaches [`MIN_SHARDED_CAPACITY`] (power of two — the router masks
/// fingerprint bits).
pub const CACHE_SHARDS: usize = 8;

/// Smallest total capacity worth sharding: below this a single shard
/// keeps behavior (one LRU, one protect window) byte-identical to the
/// historical unsharded store, and per-shard capacities stay ≥ 8 above
/// it.
pub const MIN_SHARDED_CAPACITY: usize = 64;

/// One weight-cache store shared by any number of cluster schedulers.
///
/// Cloning the handle shares the underlying store. Each scheduler calls
/// [`SharedWeightCache::register`] once to obtain its owner id; the store
/// then distinguishes a worker re-hitting its own entries from a worker
/// reusing a sibling's (`shared_hits`). All operations take a lock for
/// the duration of one map access only — shard execution never holds it.
///
/// # Lock sharding
///
/// At capacity ≥ [`MIN_SHARDED_CAPACITY`] the store splits into
/// [`CACHE_SHARDS`] independently-locked [`WeightCache`]s, routed by
/// fingerprint bits (`(weight_fp ^ act_fp) & (shards-1)`): concurrent
/// workers probing *different* tiles no longer serialize on one mutex.
/// A key always routes to the same shard, so hit/miss behavior is
/// unchanged; LRU and the protect window become per-shard (capacity is
/// divided evenly). Below the threshold there is exactly one shard and
/// the store behaves byte-identically to the historical unsharded one.
/// Contended acquisitions are counted in [`SharedWeightCache::lock_waits`]
/// (surfaced as `adip_weight_cache_lock_waits_total`).
#[derive(Clone)]
pub struct SharedWeightCache {
    cfg: CacheConfig,
    shards: Arc<Vec<Mutex<WeightCache>>>,
    next_id: Arc<AtomicU64>,
    lock_waits: Arc<AtomicU64>,
}

impl SharedWeightCache {
    /// A fresh store under `cfg` (capacity 0 = caching off).
    pub fn new(cfg: CacheConfig) -> SharedWeightCache {
        let shard_count = if cfg.capacity >= MIN_SHARDED_CAPACITY { CACHE_SHARDS } else { 1 };
        let shard_cfg = CacheConfig { capacity: cfg.capacity / shard_count, ..cfg };
        SharedWeightCache {
            cfg,
            shards: Arc::new(
                (0..shard_count).map(|_| Mutex::new(WeightCache::new(shard_cfg))).collect(),
            ),
            next_id: Arc::new(AtomicU64::new(0)),
            lock_waits: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Whether lookups can ever hit.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Allocate a unique owner id for one attaching scheduler.
    pub fn register(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) // relaxed-ok: id allocation: RMW uniqueness only
    }

    /// Global counters across every attached scheduler, aggregated over
    /// all lock shards.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for shard in self.shards.iter() {
            let s = self.lock(shard).stats();
            total.hits += s.hits;
            total.shared_hits += s.shared_hits;
            total.misses += s.misses;
            total.evictions += s.evictions;
            total.entries += s.entries;
        }
        total
    }

    /// Current live entries across all lock shards.
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|shard| self.lock(shard).map.len()).sum()
    }

    /// How many independently-locked shards this store runs.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shards currently holding at least one entry (occupancy gauge —
    /// routing spread made observable).
    pub fn occupied_shards(&self) -> usize {
        self.shards.iter().filter(|shard| !self.lock(shard).map.is_empty()).count()
    }

    /// Cumulative lock acquisitions that found a shard lock held and had
    /// to wait (the store's contention signal).
    pub fn lock_waits(&self) -> u64 {
        self.lock_waits.load(Ordering::Relaxed) // relaxed-ok: stat read
    }

    /// The shard a key routes to — pure function of the key, so a hit
    /// can never be missed by looking in the wrong shard.
    fn shard_for(&self, weight_fp: u128, act_fp: u128) -> &Mutex<WeightCache> {
        &self.shards[((weight_fp ^ act_fp) as usize) & (self.shards.len() - 1)]
    }

    /// [`WeightCache::lookup`] under the key's shard lock. The returned
    /// handle lets the caller deep-copy (and re-account) the result
    /// *after* the lock is released.
    pub fn lookup(
        &self,
        requester: u64,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Option<(Arc<CoSimResult>, bool)> {
        self.lock(self.shard_for(weight_fp, act_fp)).lookup(
            requester,
            weight_fp,
            act_fp,
            mode,
            runtime_interleave,
        )
    }

    /// [`WeightCache::insert`] under the key's shard lock.
    pub fn insert(
        &self,
        owner: u64,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
        result: CoSimResult,
    ) -> u64 {
        self.lock(self.shard_for(weight_fp, act_fp)).insert(
            owner,
            weight_fp,
            act_fp,
            mode,
            runtime_interleave,
            result,
        )
    }

    fn lock<'a>(&self, shard: &'a Mutex<WeightCache>) -> std::sync::MutexGuard<'a, WeightCache> {
        match shard.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                // contended: count the wait, then block like before
                self.lock_waits.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
                shard.lock().unwrap_or_else(PoisonError::into_inner)
            }
            // Cache operations never panic mid-mutation; recover the
            // guard rather than poisoning every sibling worker if one
            // ever does.
            Err(TryLockError::Poisoned(e)) => e.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MemoryCounters;
    use crate::testutil::Rng;

    /// Owner id used where cross-owner attribution is not under test.
    const ME: u64 = 0;

    fn result(cycles: u64) -> CoSimResult {
        CoSimResult {
            outputs: vec![Mat::zeros(2, 2)],
            passes: 1,
            cycles,
            energy_j: 1e-9,
            memory: MemoryCounters::default(),
        }
    }

    #[test]
    fn fingerprint_discriminates_content_and_shape() {
        let mut rng = Rng::seeded(41);
        let a = Mat::random(&mut rng, 6, 6, 8);
        let mut b = a.clone();
        b.set(3, 3, b.get(3, 3) ^ 1);
        assert_ne!(fingerprint(&[&a]), fingerprint(&[&b]));
        let flat = Mat::zeros(4, 9);
        let tall = Mat::zeros(9, 4);
        assert_ne!(fingerprint(&[&flat]), fingerprint(&[&tall]));
        // order of matrices matters (Q/K/V are distinct slots)
        assert_ne!(fingerprint(&[&a, &flat]), fingerprint(&[&flat, &a]));
        assert_eq!(fingerprint(&[&a]), fingerprint(&[&a.clone()]));
    }

    #[test]
    fn hit_requires_matching_activation() {
        let mut c = WeightCache::new(CacheConfig { capacity: 4, ..Default::default() });
        c.insert(ME, 1, 100, PrecisionMode::W2, false, result(10));
        assert!(c.lookup(ME, 1, 100, PrecisionMode::W2, false).is_some());
        assert!(c.lookup(ME, 1, 200, PrecisionMode::W2, false).is_none(), "other activation");
        assert!(c.lookup(ME, 1, 100, PrecisionMode::W4, false).is_none(), "other mode");
        assert!(c.lookup(ME, 1, 100, PrecisionMode::W2, true).is_none(), "other interleave");
        assert!(c.lookup(ME, 2, 100, PrecisionMode::W2, false).is_none(), "other weights");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 4, 1));
        assert_eq!(s.shared_hits, 0, "own entry: not a shared hit");
    }

    #[test]
    fn cross_owner_hits_are_counted_as_shared() {
        let mut c = WeightCache::new(CacheConfig { capacity: 4, ..Default::default() });
        c.insert(7, 1, 1, PrecisionMode::W2, false, result(5));
        let (_, cross) = c.lookup(7, 1, 1, PrecisionMode::W2, false).unwrap();
        assert!(!cross, "owner re-hits its own entry");
        let (res, cross) = c.lookup(9, 1, 1, PrecisionMode::W2, false).unwrap();
        assert!(cross, "sibling hit is a shared hit");
        assert_eq!(res.cycles, 5, "shared hits return the identical result");
        let s = c.stats();
        assert_eq!((s.hits, s.shared_hits), (2, 1));
    }

    #[test]
    fn lru_eviction_under_capacity_pressure() {
        let mut c = WeightCache::new(CacheConfig { capacity: 2, ..Default::default() });
        assert_eq!(c.insert(ME, 1, 1, PrecisionMode::W8, false, result(1)), 0);
        assert_eq!(c.insert(ME, 2, 1, PrecisionMode::W8, false, result(2)), 0);
        assert!(c.lookup(ME, 1, 1, PrecisionMode::W8, false).is_some()); // touch 1: 2 is now LRU
        assert_eq!(c.insert(ME, 3, 1, PrecisionMode::W8, false, result(3)), 1);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.lookup(ME, 2, 1, PrecisionMode::W8, false).is_none(), "2 evicted as LRU");
        assert!(c.lookup(ME, 1, 1, PrecisionMode::W8, false).is_some());
        // same weights under a new activation occupy their own entry
        // (bit-exactness: the activation is part of the key), evicting the
        // LRU entry (3) — the old (1, act 1) result still hits
        assert_eq!(c.insert(ME, 1, 9, PrecisionMode::W8, false, result(4)), 1);
        assert_eq!(c.stats().evictions, 2);
        assert!(c.lookup(ME, 1, 9, PrecisionMode::W8, false).is_some());
        assert!(c.lookup(ME, 1, 1, PrecisionMode::W8, false).is_some());
        assert!(c.lookup(ME, 3, 1, PrecisionMode::W8, false).is_none(), "3 evicted as LRU");
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn identical_weight_slices_with_distinct_activations_coexist() {
        // The M-split shape: every shard's weight slice is the same full
        // copy of B (equal weight_fp) while activation slices differ —
        // each shard must get its own entry, not displace its siblings.
        let mut c = WeightCache::new(CacheConfig { capacity: 8, ..Default::default() });
        c.insert(ME, 7, 100, PrecisionMode::W2, false, result(1));
        c.insert(ME, 7, 200, PrecisionMode::W2, false, result(2));
        assert_eq!(c.stats().evictions, 0);
        assert_eq!(c.stats().entries, 2);
        assert!(c.lookup(ME, 7, 100, PrecisionMode::W2, false).is_some());
        assert!(c.lookup(ME, 7, 200, PrecisionMode::W2, false).is_some());
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn protect_window_shields_siblings_hot_entries_from_streaming() {
        // Owner A's hot entry (hit recently) must survive owner B's
        // streaming inserts: B's own newest entries become the victims
        // (admission effectively refused), so A keeps hitting.
        let mut c = WeightCache::new(CacheConfig { capacity: 2, protect: 100 });
        c.insert(1, 10, 1, PrecisionMode::W2, false, result(1));
        assert!(c.lookup(1, 10, 1, PrecisionMode::W2, false).is_some(), "warm A's entry");
        for i in 0..20u128 {
            // B streams one-shot entries; each lookup misses, each insert
            // overflows capacity
            assert!(c.lookup(2, 100 + i, 1, PrecisionMode::W2, false).is_none());
            c.insert(2, 100 + i, 1, PrecisionMode::W2, false, result(2));
        }
        assert!(
            c.lookup(1, 10, 1, PrecisionMode::W2, false).is_some(),
            "A's hot entry must not be flushed by B's stream"
        );
        assert_eq!(c.stats().entries, 2);
        // ... but an entry that was never hit has no protection
        let mut plain = WeightCache::new(CacheConfig { capacity: 2, protect: 100 });
        plain.insert(1, 10, 1, PrecisionMode::W2, false, result(1));
        for i in 0..3u128 {
            plain.insert(2, 100 + i, 1, PrecisionMode::W2, false, result(2));
        }
        assert!(plain.lookup(1, 10, 1, PrecisionMode::W2, false).is_none(), "never-hit: plain LRU");
    }

    #[test]
    fn protect_window_expires_after_w_lookups() {
        let mut c = WeightCache::new(CacheConfig { capacity: 2, protect: 4 });
        c.insert(1, 10, 1, PrecisionMode::W2, false, result(1));
        assert!(c.lookup(1, 10, 1, PrecisionMode::W2, false).is_some());
        // push the hit out of the 4-lookup window with unrelated misses
        for i in 0..6u128 {
            assert!(c.lookup(2, 500 + i, 1, PrecisionMode::W2, false).is_none());
        }
        c.insert(2, 100, 1, PrecisionMode::W2, false, result(2));
        c.insert(2, 101, 1, PrecisionMode::W2, false, result(2));
        assert!(
            c.lookup(1, 10, 1, PrecisionMode::W2, false).is_none(),
            "protection lapsed: the stale entry evicts normally"
        );
    }

    #[test]
    fn protect_never_blocks_an_owners_own_evictions() {
        // single owner: protection must degrade to plain LRU
        let mut c = WeightCache::new(CacheConfig { capacity: 2, protect: 1000 });
        c.insert(ME, 1, 1, PrecisionMode::W2, false, result(1));
        assert!(c.lookup(ME, 1, 1, PrecisionMode::W2, false).is_some());
        c.insert(ME, 2, 1, PrecisionMode::W2, false, result(2));
        c.insert(ME, 3, 1, PrecisionMode::W2, false, result(3));
        assert_eq!(c.stats().entries, 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.lookup(ME, 3, 1, PrecisionMode::W2, false).is_some(), "newest admitted");
    }

    #[test]
    fn disabled_cache_never_stores() {
        let mut c = WeightCache::new(CacheConfig::default());
        assert!(!c.enabled());
        assert_eq!(c.insert(ME, 1, 1, PrecisionMode::W8, false, result(1)), 0);
        assert!(c.lookup(ME, 1, 1, PrecisionMode::W8, false).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (0, 0, 0, 0));
    }

    #[test]
    fn combined_fingerprints_are_order_sensitive() {
        let (a, b) = (1u128 << 100 | 7, 9u128 << 60 | 3);
        assert_ne!(combine_fingerprints([a, b]), combine_fingerprints([b, a]));
        assert_ne!(combine_fingerprints([a]), combine_fingerprints([a, a]));
        assert_eq!(combine_fingerprints([a, b]), combine_fingerprints([a, b]));
    }

    #[test]
    fn stats_delta() {
        let mut c = WeightCache::new(CacheConfig { capacity: 2, ..Default::default() });
        c.insert(ME, 1, 1, PrecisionMode::W8, false, result(1));
        let before = c.stats();
        assert!(c.lookup(ME, 1, 1, PrecisionMode::W8, false).is_some());
        assert!(c.lookup(ME, 9, 1, PrecisionMode::W8, false).is_none());
        let d = c.stats().delta_since(&before);
        assert_eq!((d.hits, d.misses), (1, 1));
    }

    #[test]
    fn shared_store_clones_share_entries_and_ids_stay_unique() {
        let store = SharedWeightCache::new(CacheConfig { capacity: 4, ..Default::default() });
        let a = store.register();
        let b = store.clone().register();
        assert_ne!(a, b, "every attached scheduler gets its own owner id");
        assert!(store.enabled());
        store.insert(a, 1, 1, PrecisionMode::W2, false, result(3));
        let (res, cross) = store.clone().lookup(b, 1, 1, PrecisionMode::W2, false).unwrap();
        assert!(cross);
        assert_eq!(res.cycles, 3);
        assert_eq!(store.entries(), 1);
        let s = store.stats();
        assert_eq!((s.hits, s.shared_hits, s.misses), (1, 1, 0));
        assert!(!SharedWeightCache::new(CacheConfig::default()).enabled());
    }

    #[test]
    fn store_shards_by_capacity_threshold() {
        // small capacities: one shard — byte-identical to the historical
        // unsharded store (one LRU, one protect window)
        assert_eq!(
            SharedWeightCache::new(CacheConfig { capacity: 4, ..Default::default() })
                .shard_count(),
            1
        );
        assert_eq!(
            SharedWeightCache::new(CacheConfig { capacity: 63, ..Default::default() })
                .shard_count(),
            1
        );
        let store = SharedWeightCache::new(CacheConfig { capacity: 64, ..Default::default() });
        assert_eq!(store.shard_count(), CACHE_SHARDS);
        assert_eq!(store.occupied_shards(), 0);
        assert_eq!(store.lock_waits(), 0);
    }

    #[test]
    fn sharded_store_routes_consistently_and_aggregates_stats() {
        let store = SharedWeightCache::new(CacheConfig { capacity: 64, ..Default::default() });
        let me = store.register();
        // spray keys evenly across shards (consecutive weight
        // fingerprints walk the shard mask); every insert must be found
        // again (consistent routing) and totals aggregate across shards
        for i in 0..32u128 {
            store.insert(me, i, 0, PrecisionMode::W2, false, result(i as u64));
        }
        assert_eq!(store.entries(), 32);
        for i in 0..32u128 {
            let (res, cross) = store.lookup(me, i, 0, PrecisionMode::W2, false).unwrap();
            assert_eq!(res.cycles, i as u64);
            assert!(!cross);
        }
        assert!(store.lookup(me, 777, 0, PrecisionMode::W2, false).is_none());
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (32, 1, 32));
        assert_eq!(store.occupied_shards(), CACHE_SHARDS);
    }

    #[test]
    fn sharded_store_counts_contended_lock_acquisitions() {
        use std::sync::atomic::AtomicBool;
        let store = SharedWeightCache::new(CacheConfig { capacity: 64, ..Default::default() });
        let me = store.register();
        store.insert(me, 1, 1, PrecisionMode::W2, false, result(1));
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let hammer = |store: SharedWeightCache, stop: &AtomicBool| {
                move || {
                    while !stop.load(Ordering::Relaxed) {
                        // all threads probe the same key → same shard
                        let _ = store.lookup(me, 1, 1, PrecisionMode::W2, false);
                    }
                }
            };
            let workers: Vec<_> =
                (0..4).map(|_| scope.spawn(hammer(store.clone(), &stop))).collect();
            // spin until contention is observed (bounded by test timeout)
            while store.lock_waits() == 0 {
                std::hint::spin_loop();
            }
            stop.store(true, Ordering::Relaxed);
            for w in workers {
                w.join().unwrap();
            }
        });
        assert!(store.lock_waits() > 0);
    }
}
