//! Shard reducer: reassembles per-core shard results into the logical
//! GEMM-set result and aggregates cluster accounting.
//!
//! **Output assembly** is bit-exact by construction: M/N shards own
//! disjoint blocks of `C` and are placed at their offsets; K shards
//! produce full-size partial products that are accumulated
//! (`i32` addition — exact and order-independent, so the reduce order
//! never affects the result).
//!
//! **Accounting attribution** (the rules the analytical cluster estimator
//! in [`crate::analytical::cluster`] mirrors exactly):
//!
//! * cluster latency `cycles` = **max** over cores (cores run
//!   concurrently; the slowest shard gates the answer) **plus** the
//!   explicit K-split reduce term of [`reduce_cycles`]: the cross-core
//!   accumulate of partial products is real work — an `N×N` adder array
//!   merges one partial tile per cycle, `(S-1)` merges per output tile.
//!   M/N splits write disjoint output blocks and pay no reduce step.
//! * `passes`, `energy` = **sum** over cores (every executed pass burns
//!   real energy on its core).
//! * memory traffic = **sum** over cores, except that a broadcast split
//!   ([`ShardSplit::broadcasts_activations`]) counts the shared activation
//!   stream **once**: the same tiles are multicast to every core, so the
//!   cluster's activation read bytes are the maximum any single core
//!   consumes, not the sum. Weight and output traffic always sum (shards
//!   own disjoint weights/outputs; K shards each drain a full partial).
//! * `tile_reads` is recomputed from the combined byte counters (every
//!   read event in this stack moves exactly one `N²`-byte tile).

use crate::dataflow::Mat;
use crate::sim::cosim::CoSimResult;
use crate::sim::memory::MemoryCounters;

use super::partitioner::{ShardPlan, ShardSplit};

/// Latency of the K-split's cross-core accumulate-reduce, in cycles.
///
/// `S` K-shards each drain a full-size `M×N` partial product per weight
/// matrix; folding them into the final output takes `S-1` elementwise
/// merges per output tile. The reduce engine is modeled as an `N×N`
/// (`array_n²`) adder array consuming one partial tile per cycle — as wide
/// as the array's own datapath, and far cheaper than its MACs — so:
///
/// ```text
/// reduce = (S-1) · ⌈M/N⌉ · ⌈N_c/N⌉ · set_size        (K split, S > 1)
///        = 0                                          (otherwise)
/// ```
///
/// This term was previously modeled as free (a documented gap); it is now
/// charged identically by [`crate::analytical::cluster::estimate_cluster`]
/// and the functional cluster path, so their exact equality still holds.
/// It depends only on the plan shape — never on cache hits — because the
/// reassembly happens even when every shard was served from the cache.
pub fn reduce_cycles(
    split: ShardSplit,
    shards: usize,
    m: usize,
    n: usize,
    set_size: usize,
    array_n: usize,
) -> u64 {
    if split != ShardSplit::K || shards <= 1 {
        return 0;
    }
    let tiles = m.div_ceil(array_n) as u64 * n.div_ceil(array_n) as u64 * set_size as u64;
    (shards as u64 - 1) * tiles
}

/// Assemble per-shard outputs into one full-shape output per source
/// matrix. `shard_outputs[i]` are the outputs of `plans[i]` (one `Mat` per
/// weight matrix, in set order).
pub fn assemble_outputs(
    m: usize,
    n: usize,
    set_size: usize,
    plans: &[ShardPlan],
    shard_outputs: &[Vec<Mat>],
) -> Vec<Mat> {
    assert_eq!(plans.len(), shard_outputs.len(), "one output set per shard");
    let mut outs = vec![Mat::zeros(m, n); set_size];
    for (plan, shard) in plans.iter().zip(shard_outputs) {
        assert_eq!(shard.len(), set_size, "shard output arity");
        for (out, tile) in outs.iter_mut().zip(shard) {
            // disjoint M/N blocks land on zeros (place); K partials add up
            out.accumulate(plan.rows.start, plan.cols.start, tile);
        }
    }
    outs
}

/// Combine per-shard accounting into cluster totals per the attribution
/// rules above. `tile_bytes` is `N²` (the uniform tile size every read
/// event moves). Returns `(cycles, passes, energy_j, memory)`.
pub fn combine_accounting(
    split: ShardSplit,
    shards: &[&CoSimResult],
    tile_bytes: u64,
) -> (u64, u64, f64, MemoryCounters) {
    let cycles = shards.iter().map(|s| s.cycles).max().unwrap_or(0);
    let passes = shards.iter().map(|s| s.passes).sum();
    let energy_j = shards.iter().map(|s| s.energy_j).sum();
    let act_read_bytes = if split.broadcasts_activations() {
        shards.iter().map(|s| s.memory.act_read_bytes).max().unwrap_or(0)
    } else {
        shards.iter().map(|s| s.memory.act_read_bytes).sum()
    };
    let weight_read_bytes = shards.iter().map(|s| s.memory.weight_read_bytes).sum();
    let output_write_bytes = shards.iter().map(|s| s.memory.output_write_bytes).sum();
    let conflict_cycles = shards.iter().map(|s| s.memory.conflict_cycles).sum();
    let memory = MemoryCounters {
        act_read_bytes,
        weight_read_bytes,
        output_write_bytes,
        tile_reads: (act_read_bytes + weight_read_bytes) / tile_bytes.max(1),
        conflict_cycles,
    };
    (cycles, passes, energy_j, memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partitioner::{partition, ClusterConfig};
    use crate::testutil::Rng;

    fn res(cycles: u64, act: u64, weight: u64) -> CoSimResult {
        CoSimResult {
            outputs: vec![],
            passes: cycles / 2,
            cycles,
            energy_j: cycles as f64 * 1e-9,
            memory: MemoryCounters {
                act_read_bytes: act,
                weight_read_bytes: weight,
                output_write_bytes: 64,
                tile_reads: (act + weight) / 64,
                conflict_cycles: 1,
            },
        }
    }

    #[test]
    fn m_split_assembly_matches_reference() {
        let mut rng = Rng::seeded(43);
        let a = Mat::random(&mut rng, 40, 24, 8);
        let b = Mat::random(&mut rng, 24, 16, 4);
        let plans = partition(40, 24, 16, 8, &ClusterConfig::with_cores(3));
        let shard_outputs: Vec<Vec<Mat>> = plans
            .iter()
            .map(|p| {
                let asl = a.tile(p.rows.start, p.inner.start, p.rows.len(), p.inner.len());
                let bsl = b.tile(p.inner.start, p.cols.start, p.inner.len(), p.cols.len());
                vec![asl.matmul(&bsl)]
            })
            .collect();
        let outs = assemble_outputs(40, 16, 1, &plans, &shard_outputs);
        assert_eq!(outs[0], a.matmul(&b));
    }

    #[test]
    fn k_split_partials_accumulate_exactly() {
        let mut rng = Rng::seeded(45);
        let a = Mat::random(&mut rng, 12, 50, 8);
        let b = Mat::random(&mut rng, 50, 20, 2);
        let plans =
            partition(12, 50, 20, 8, &ClusterConfig::with_cores(4).with_split(ShardSplit::K));
        assert!(plans.len() > 1);
        let shard_outputs: Vec<Vec<Mat>> = plans
            .iter()
            .map(|p| {
                let asl = a.tile(p.rows.start, p.inner.start, p.rows.len(), p.inner.len());
                let bsl = b.tile(p.inner.start, p.cols.start, p.inner.len(), p.cols.len());
                vec![asl.matmul(&bsl)]
            })
            .collect();
        let outs = assemble_outputs(12, 20, 1, &plans, &shard_outputs);
        assert_eq!(outs[0], a.matmul(&b));
    }

    #[test]
    fn accounting_rules_max_sum_and_broadcast() {
        let a = res(100, 1024, 256);
        let b = res(60, 512, 256);
        let (cycles, passes, energy, mem) =
            combine_accounting(ShardSplit::M, &[&a, &b], 64);
        assert_eq!(cycles, 100);
        assert_eq!(passes, 80);
        assert!((energy - 160e-9).abs() < 1e-18);
        assert_eq!(mem.act_read_bytes, 1536, "M-split sums activations");
        assert_eq!(mem.weight_read_bytes, 512);
        assert_eq!(mem.output_write_bytes, 128);
        assert_eq!(mem.tile_reads, (1536 + 512) / 64);
        assert_eq!(mem.conflict_cycles, 2);
        let (_, _, _, bmem) = combine_accounting(ShardSplit::N, &[&a, &b], 64);
        assert_eq!(bmem.act_read_bytes, 1024, "N-split counts the broadcast once");
        assert_eq!(bmem.weight_read_bytes, 512);
    }

    #[test]
    fn reduce_term_charged_only_for_multi_shard_k_splits() {
        // 3 extra partials × (⌈100/32⌉ · ⌈64/32⌉ tiles) × 2 matrices
        assert_eq!(reduce_cycles(ShardSplit::K, 4, 100, 64, 2, 32), 3 * 4 * 2 * 2);
        assert_eq!(reduce_cycles(ShardSplit::K, 1, 100, 64, 2, 32), 0, "single shard");
        assert_eq!(reduce_cycles(ShardSplit::M, 4, 100, 64, 2, 32), 0, "disjoint blocks");
        assert_eq!(reduce_cycles(ShardSplit::N, 4, 100, 64, 2, 32), 0, "disjoint blocks");
        // tile-rounded, not element-exact: a 1×1 output still costs a merge
        assert_eq!(reduce_cycles(ShardSplit::K, 2, 1, 1, 1, 32), 1);
    }
}
