//! Shard reducer: reassembles per-core shard results into the logical
//! GEMM-set result and aggregates cluster accounting.
//!
//! **Output assembly** is bit-exact by construction: M/N shards own
//! disjoint blocks of `C` and are placed at their offsets; K shards
//! produce full-size partial products that are accumulated
//! (`i32` addition — exact and order-independent, so the reduce order
//! never affects the result).
//!
//! **Accounting attribution** (the rules the analytical cluster estimator
//! in [`crate::analytical::cluster`] mirrors exactly):
//!
//! * cluster latency `cycles` = **max** over cores (cores run
//!   concurrently; the slowest shard gates the answer). The K-split's
//!   final accumulate is modeled as free — partial psums drain through the
//!   same write-back path the single-core schedule uses.
//! * `passes`, `energy` = **sum** over cores (every executed pass burns
//!   real energy on its core).
//! * memory traffic = **sum** over cores, except that a broadcast split
//!   ([`ShardSplit::broadcasts_activations`]) counts the shared activation
//!   stream **once**: the same tiles are multicast to every core, so the
//!   cluster's activation read bytes are the maximum any single core
//!   consumes, not the sum. Weight and output traffic always sum (shards
//!   own disjoint weights/outputs; K shards each drain a full partial).
//! * `tile_reads` is recomputed from the combined byte counters (every
//!   read event in this stack moves exactly one `N²`-byte tile).

use crate::dataflow::Mat;
use crate::sim::cosim::CoSimResult;
use crate::sim::memory::MemoryCounters;

use super::partitioner::{ShardPlan, ShardSplit};

/// Assemble per-shard outputs into one full-shape output per source
/// matrix. `shard_outputs[i]` are the outputs of `plans[i]` (one `Mat` per
/// weight matrix, in set order).
pub fn assemble_outputs(
    m: usize,
    n: usize,
    set_size: usize,
    plans: &[ShardPlan],
    shard_outputs: &[Vec<Mat>],
) -> Vec<Mat> {
    assert_eq!(plans.len(), shard_outputs.len(), "one output set per shard");
    let mut outs = vec![Mat::zeros(m, n); set_size];
    for (plan, shard) in plans.iter().zip(shard_outputs) {
        assert_eq!(shard.len(), set_size, "shard output arity");
        for (out, tile) in outs.iter_mut().zip(shard) {
            // disjoint M/N blocks land on zeros (place); K partials add up
            out.accumulate(plan.rows.start, plan.cols.start, tile);
        }
    }
    outs
}

/// Combine per-shard accounting into cluster totals per the attribution
/// rules above. `tile_bytes` is `N²` (the uniform tile size every read
/// event moves). Returns `(cycles, passes, energy_j, memory)`.
pub fn combine_accounting(
    split: ShardSplit,
    shards: &[&CoSimResult],
    tile_bytes: u64,
) -> (u64, u64, f64, MemoryCounters) {
    let cycles = shards.iter().map(|s| s.cycles).max().unwrap_or(0);
    let passes = shards.iter().map(|s| s.passes).sum();
    let energy_j = shards.iter().map(|s| s.energy_j).sum();
    let act_read_bytes = if split.broadcasts_activations() {
        shards.iter().map(|s| s.memory.act_read_bytes).max().unwrap_or(0)
    } else {
        shards.iter().map(|s| s.memory.act_read_bytes).sum()
    };
    let weight_read_bytes = shards.iter().map(|s| s.memory.weight_read_bytes).sum();
    let output_write_bytes = shards.iter().map(|s| s.memory.output_write_bytes).sum();
    let conflict_cycles = shards.iter().map(|s| s.memory.conflict_cycles).sum();
    let memory = MemoryCounters {
        act_read_bytes,
        weight_read_bytes,
        output_write_bytes,
        tile_reads: (act_read_bytes + weight_read_bytes) / tile_bytes.max(1),
        conflict_cycles,
    };
    (cycles, passes, energy_j, memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partitioner::{partition, ClusterConfig};
    use crate::testutil::Rng;

    fn res(cycles: u64, act: u64, weight: u64) -> CoSimResult {
        CoSimResult {
            outputs: vec![],
            passes: cycles / 2,
            cycles,
            energy_j: cycles as f64 * 1e-9,
            memory: MemoryCounters {
                act_read_bytes: act,
                weight_read_bytes: weight,
                output_write_bytes: 64,
                tile_reads: (act + weight) / 64,
                conflict_cycles: 1,
            },
        }
    }

    #[test]
    fn m_split_assembly_matches_reference() {
        let mut rng = Rng::seeded(43);
        let a = Mat::random(&mut rng, 40, 24, 8);
        let b = Mat::random(&mut rng, 24, 16, 4);
        let plans = partition(40, 24, 16, 8, &ClusterConfig::with_cores(3));
        let shard_outputs: Vec<Vec<Mat>> = plans
            .iter()
            .map(|p| {
                let asl = a.tile(p.rows.start, p.inner.start, p.rows.len(), p.inner.len());
                let bsl = b.tile(p.inner.start, p.cols.start, p.inner.len(), p.cols.len());
                vec![asl.matmul(&bsl)]
            })
            .collect();
        let outs = assemble_outputs(40, 16, 1, &plans, &shard_outputs);
        assert_eq!(outs[0], a.matmul(&b));
    }

    #[test]
    fn k_split_partials_accumulate_exactly() {
        let mut rng = Rng::seeded(45);
        let a = Mat::random(&mut rng, 12, 50, 8);
        let b = Mat::random(&mut rng, 50, 20, 2);
        let plans =
            partition(12, 50, 20, 8, &ClusterConfig::with_cores(4).with_split(ShardSplit::K));
        assert!(plans.len() > 1);
        let shard_outputs: Vec<Vec<Mat>> = plans
            .iter()
            .map(|p| {
                let asl = a.tile(p.rows.start, p.inner.start, p.rows.len(), p.inner.len());
                let bsl = b.tile(p.inner.start, p.cols.start, p.inner.len(), p.cols.len());
                vec![asl.matmul(&bsl)]
            })
            .collect();
        let outs = assemble_outputs(12, 20, 1, &plans, &shard_outputs);
        assert_eq!(outs[0], a.matmul(&b));
    }

    #[test]
    fn accounting_rules_max_sum_and_broadcast() {
        let a = res(100, 1024, 256);
        let b = res(60, 512, 256);
        let (cycles, passes, energy, mem) =
            combine_accounting(ShardSplit::M, &[&a, &b], 64);
        assert_eq!(cycles, 100);
        assert_eq!(passes, 80);
        assert!((energy - 160e-9).abs() < 1e-18);
        assert_eq!(mem.act_read_bytes, 1536, "M-split sums activations");
        assert_eq!(mem.weight_read_bytes, 512);
        assert_eq!(mem.output_write_bytes, 128);
        assert_eq!(mem.tile_reads, (1536 + 512) / 64);
        assert_eq!(mem.conflict_cycles, 2);
        let (_, _, _, bmem) = combine_accounting(ShardSplit::N, &[&a, &b], 64);
        assert_eq!(bmem.act_read_bytes, 1024, "N-split counts the broadcast once");
        assert_eq!(bmem.weight_read_bytes, 512);
    }
}
