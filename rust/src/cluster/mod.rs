//! Cluster execution subsystem — shard one GEMM across a mesh of array
//! cores, with a persistent worker pool and a shareable weight-tile cache.
//!
//! The paper evaluates a single `N×N` ADiP array; its follow-up many-core
//! work (D-Legion) shows the scaling win comes from ganging many such
//! arrays. This layer adds that system level: a pool of `P` simulated
//! cores executes one large GEMM — or a shared-input multi-matrix set —
//! as tile-aligned shards, and the shards are merged back into the exact
//! single-core result.
//!
//! * [`partitioner`] — [`ShardSplit`] (M / N / K) and tile-aligned,
//!   balanced shard plans; [`ClusterConfig`] (cores, split, cache,
//!   [`PoolMode`]) threaded through
//!   [`crate::coordinator::CoordinatorConfig`].
//! * [`scheduler`] — [`ClusterScheduler`]: pipelined shard ingress
//!   (slice → fingerprint → cache probe → dispatch, one shard at a time)
//!   feeding either the persistent worker pool or the legacy spawn-per-run
//!   engine, then reduce.
//! * [`reducer`] — output reassembly, the accounting attribution rules and
//!   the explicit K-split [`reducer::reduce_cycles`] term.
//! * [`weight_cache`] — result cache keyed by (weight-tile fingerprint,
//!   precision mode), activation-qualified for bit-exactness;
//!   [`SharedWeightCache`] lets every worker of one coordinator share one
//!   store (cross-worker reuse, counted as `shared_hits`).
//!
//! # Pool / pipeline design
//!
//! The host-side analogue of keeping all `N×N` PEs busy is keeping all `P`
//! cores busy *across* GEMMs, not just within one. Two mechanisms:
//!
//! 1. **Persistent workers** ([`PoolMode::Persistent`], the default).
//!    Each core lives on a long-lived worker thread that pops shard jobs
//!    off a shared queue; consecutive invocations reuse warm workers
//!    instead of paying a `std::thread::scope` spawn/join barrier per
//!    GEMM. Shutdown (dropping the scheduler) closes the queue, drains
//!    already-queued shards and joins the workers; a worker that panics
//!    mid-shard replies with an error first (the submitter can never
//!    hang), then rebuilds its core and keeps serving. The legacy
//!    spawn-per-run engine ([`PoolMode::PerRun`]) is retained as the
//!    benchmark baseline and produces bit-identical runs.
//! 2. **Pipelined shard ingress.** Shard `i` is sliced, fingerprinted,
//!    cache-probed and *immediately* dispatched before shard `i+1` is even
//!    sliced — so host-side operand preparation (partition/quantize) of
//!    later shards overlaps execution of earlier ones. Jobs own their
//!    operands (`Arc<Mat>`): split-dimension slices are owned tiles, and a
//!    full-extent operand is shared through one `Arc` created at most once
//!    per run (free on the coordinator path, whose requests already carry
//!    `Arc<Mat>`s — see `run_gemm_set_shared`).
//!
//! # Sharding invariants
//!
//! 1. **Bit-exactness.** A cluster run's outputs equal the single-core
//!    run's outputs — and therefore the `i32` reference GEMM — for every
//!    split × core count × precision × batch mode × backend × pool mode.
//!    M/N shards own disjoint output blocks; K shards produce full-size
//!    partial products reduced by exact `i32` accumulation
//!    (order-independent, so out-of-order pool completions cannot matter).
//!    Cache hits replay previously computed outputs under a key that
//!    includes the activation fingerprint, so a hit cannot change results
//!    — not even a `shared_hit` on an entry a sibling worker computed.
//!    `rust/tests/integration_cluster.rs` enforces all of this — per the
//!    repo's backend policy the cluster path *extends* the differential
//!    suite, it does not bypass it.
//! 2. **Accounting attribution.** Cluster latency (`cycles`) is the
//!    maximum over cores plus the explicit K-split reduce term
//!    ([`reducer::reduce_cycles`]: one `N×N` adder-array merge per partial
//!    output tile — previously a documented modeled-as-free gap); passes
//!    and energy are sums; memory traffic is a sum except that a broadcast
//!    split (N: every core streams the same activation tiles) counts the
//!    shared-input traffic once
//!    ([`ShardSplit::broadcasts_activations`]). The closed forms in
//!    [`crate::analytical::cluster`] state the same rules over
//!    [`crate::analytical::estimate_gemm_set`] per shard, and the
//!    functional path must match them *exactly* (tested). Accounting is
//!    engine-independent: pool and spawn-per-run runs are bit-identical.
//! 3. **Cache keying.** Entries are keyed by (weight-set fingerprint,
//!    precision mode, runtime-interleave flag) extended with the
//!    activation fingerprint — a hit is bit-exact by key construction,
//!    and M-split shards (identical weight slices, distinct activation
//!    slices) occupy distinct entries. Hits contribute zero simulated
//!    cycles/energy/memory (execution skipped; the K-split reduce term is
//!    still charged — reassembly is real) and are surfaced as
//!    `cache_hits`/`cache_misses`/`cache_evictions`/`cache_shared_hits`
//!    in [`crate::coordinator::Metrics`]. A cold cache is
//!    accounting-neutral, which is what keeps invariant 2 testable.
//!    Entries carry the owner id of the scheduler that inserted them;
//!    under a coordinator-wide [`SharedWeightCache`] a hit on a sibling's
//!    entry is a `shared_hit` (the cross-worker reuse the shared store
//!    exists for). Admission is eviction-aware: with
//!    [`CacheConfig::protect`] set (`--cache-protect`), an insert cannot
//!    evict a *sibling's* entry hit within the last `protect` lookups —
//!    when everything else is protected the inserter's own fresh entry is
//!    the victim, so one worker's streaming trace cannot flush the other
//!    workers' hot projection tiles (and protection can never block an
//!    owner's own LRU churn).

pub mod partitioner;
pub mod reducer;
pub mod scheduler;
pub mod weight_cache;

pub use partitioner::{partition, ClusterConfig, PoolMode, ShardPlan, ShardSplit};
pub use reducer::{assemble_outputs, combine_accounting, reduce_cycles};
pub use scheduler::{ClusterRun, ClusterScheduler, PoolStats, PreparedFingerprints};
pub use weight_cache::{fingerprint, CacheConfig, CacheStats, SharedWeightCache, WeightCache};
