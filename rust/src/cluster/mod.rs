//! Cluster execution subsystem — shard one GEMM across a mesh of array
//! cores, with a shared weight-tile cache.
//!
//! The paper evaluates a single `N×N` ADiP array; its follow-up many-core
//! work (D-Legion) shows the scaling win comes from ganging many such
//! arrays. This layer adds that system level: a pool of `P` simulated
//! cores executes one large GEMM — or a shared-input multi-matrix set —
//! as tile-aligned shards, and the shards are merged back into the exact
//! single-core result.
//!
//! * [`partitioner`] — [`ShardSplit`] (M / N / K) and tile-aligned,
//!   balanced shard plans; [`ClusterConfig`] threaded through
//!   [`crate::coordinator::CoordinatorConfig`].
//! * [`scheduler`] — [`ClusterScheduler`]: cache probe → concurrent shard
//!   execution on a pool of [`crate::coordinator::CoreScheduler`] workers
//!   (one host thread per shard) → reduce.
//! * [`reducer`] — output reassembly and the accounting attribution rules.
//! * [`weight_cache`] — result cache keyed by (weight-tile fingerprint,
//!   precision mode), activation-qualified for bit-exactness.
//!
//! # Sharding invariants
//!
//! 1. **Bit-exactness.** A cluster run's outputs equal the single-core
//!    run's outputs — and therefore the `i32` reference GEMM — for every
//!    split × core count × precision × batch mode × backend. M/N shards
//!    own disjoint output blocks; K shards produce full-size partial
//!    products reduced by exact `i32` accumulation (order-independent).
//!    Cache hits replay previously computed outputs under a key that
//!    includes the activation fingerprint, so a hit cannot change results.
//!    `rust/tests/integration_cluster.rs` enforces all of this — per the
//!    repo's backend policy the cluster path *extends* the differential
//!    suite, it does not bypass it.
//! 2. **Accounting attribution.** Cluster latency (`cycles`) is the
//!    maximum over cores; passes and energy are sums; memory traffic is a
//!    sum except that a broadcast split (N: every core streams the same
//!    activation tiles) counts the shared-input traffic once
//!    ([`ShardSplit::broadcasts_activations`]). The K-split's final
//!    accumulate is modeled as free. The closed forms in
//!    [`crate::analytical::cluster`] state the same rules over
//!    [`crate::analytical::estimate_gemm_set`] per shard, and the
//!    functional path must match them *exactly* (tested).
//! 3. **Cache keying.** Entries are keyed by (weight-set fingerprint,
//!    precision mode, runtime-interleave flag) extended with the
//!    activation fingerprint — a hit is bit-exact by key construction,
//!    and M-split shards (identical weight slices, distinct activation
//!    slices) occupy distinct entries. Hits contribute zero simulated
//!    cycles/energy/memory (execution skipped) and are surfaced as
//!    `cache_hits`/`cache_misses`/`cache_evictions` in
//!    [`crate::coordinator::Metrics`]. A cold cache is
//!    accounting-neutral, which is what keeps invariant 2 testable.

pub mod partitioner;
pub mod reducer;
pub mod scheduler;
pub mod weight_cache;

pub use partitioner::{partition, ClusterConfig, ShardPlan, ShardSplit};
pub use reducer::{assemble_outputs, combine_accounting};
pub use scheduler::{ClusterRun, ClusterScheduler};
pub use weight_cache::{fingerprint, CacheConfig, CacheStats, WeightCache};
