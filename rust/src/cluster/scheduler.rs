//! The cluster shard scheduler: dispatches shard plans to a pool of
//! [`CoreScheduler`] workers and reduces their results.
//!
//! One [`ClusterScheduler`] owns `P` simulated array cores (each a
//! [`CoreScheduler`] on the configured `Backend` — the backend policy of
//! `rust/src/arch/mod.rs` applies unchanged: functional serves, the cycle
//! simulator stays golden). A GEMM (or shared-input multi-matrix set) is
//! partitioned by [`super::partitioner::partition`], each shard is probed
//! against the [`super::weight_cache::WeightCache`] and, on a miss,
//! executed on its own core — concurrently, on host threads, one thread
//! per shard — then the [`super::reducer`] reassembles outputs and
//! aggregates accounting.
//!
//! The degenerate single-shard case (1 core, or a split dimension with one
//! tile) skips slicing and reduction entirely and is byte-identical to a
//! bare [`CoreScheduler`] run — which is what keeps the coordinator's
//! default configuration (1 cluster core per worker) behavior-neutral.

use std::borrow::Cow;

use anyhow::{anyhow, ensure, Result};

use crate::arch::{Architecture, Backend};
use crate::coordinator::scheduler::{attribute_members, CoreScheduler, MemberResult};
use crate::coordinator::select_mode;
use crate::coordinator::MatmulRequest;
use crate::dataflow::Mat;
use crate::quant::PrecisionMode;
use crate::sim::cosim::CoSimResult;

use super::partitioner::{partition, ClusterConfig};
use super::reducer::{assemble_outputs, combine_accounting};
use super::weight_cache::{combine_fingerprints, fingerprint, CacheStats, WeightCache};

/// Result of one cluster execution: the logical (reduced) co-sim result
/// plus the shard-level breakdown.
#[derive(Debug, Clone)]
pub struct ClusterRun {
    /// Reduced outputs + aggregated accounting (cluster latency = max over
    /// cores; passes/energy/memory combined per the reducer's rules).
    pub result: CoSimResult,
    /// Shards executed (≤ configured cores; 1 when the GEMM cannot shard).
    pub shards: usize,
    /// Simulated cycles per shard, in plan order (0 for cache hits).
    pub per_core_cycles: Vec<u64>,
    /// Weight-cache activity during this run (all zero when disabled).
    pub cache: CacheStats,
}

/// One shard's operands, ready for a core. Only the split dimension is
/// actually sliced (copied); ranges covering a full extent borrow the
/// original matrix — an M-split does not clone the weight set per core,
/// an N/K-split does not clone the activation matrix per core.
struct ShardJob<'x> {
    a: Cow<'x, Mat>,
    bs: Vec<Cow<'x, Mat>>,
}

/// Borrow `m` when the requested window is the whole matrix; otherwise
/// extract the (clipped, hence exact) tile.
fn slice_or_borrow<'x>(
    m: &'x Mat,
    r0: usize,
    c0: usize,
    rows: usize,
    cols: usize,
) -> Cow<'x, Mat> {
    if r0 == 0 && c0 == 0 && rows == m.rows() && cols == m.cols() {
        Cow::Borrowed(m)
    } else {
        Cow::Owned(m.tile(r0, c0, rows, cols))
    }
}

/// Outcome of the cache probe for one shard.
enum Probe {
    /// Served from the cache (outputs reused, accounting zeroed).
    Hit(CoSimResult),
    /// Must execute; insert under these fingerprints afterwards.
    Miss(Option<(u128, u128)>),
}

/// Pool of `P` array cores + the shared weight-tile cache.
pub struct ClusterScheduler {
    cores: Vec<CoreScheduler>,
    cfg: ClusterConfig,
    cache: WeightCache,
    n: usize,
}

impl ClusterScheduler {
    /// Build a cluster of `cfg.effective_cores()` cores, each simulating
    /// `arch` at size `n` on `backend`.
    pub fn new(arch: Architecture, n: usize, backend: Backend, cfg: ClusterConfig) -> ClusterScheduler {
        let cores = (0..cfg.effective_cores())
            .map(|_| CoreScheduler::with_backend(arch, n, backend))
            .collect();
        ClusterScheduler { cores, cfg, cache: WeightCache::new(cfg.cache), n }
    }

    /// Cluster configuration.
    pub fn cluster_config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Which architecture the cores simulate.
    pub fn architecture(&self) -> Architecture {
        self.cores[0].architecture()
    }

    /// Which execution backend the cores run on.
    pub fn backend(&self) -> Backend {
        self.cores[0].backend()
    }

    /// Cumulative weight-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Execute `C = A · B` across the cluster.
    pub fn run_gemm(
        &mut self,
        a: &Mat,
        b: &Mat,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<ClusterRun> {
        self.run_gemm_set(a, &[b], mode, runtime_interleave)
    }

    /// Execute a shared-input GEMM set `C_s = A · B_s` across the cluster:
    /// partition per the configured split, serve shards from the weight
    /// cache where possible, run the misses concurrently (one core per
    /// shard), and reduce.
    pub fn run_gemm_set(
        &mut self,
        a: &Mat,
        bs: &[&Mat],
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<ClusterRun> {
        ensure!(!bs.is_empty(), "need at least one weight matrix");
        for b in bs {
            ensure!(
                b.rows() == bs[0].rows() && b.cols() == bs[0].cols(),
                "weight matrices must share a shape"
            );
            ensure!(a.cols() == b.rows(), "inner dimension mismatch");
        }
        let (m, k, nc) = (a.rows(), a.cols(), bs[0].cols());
        let plans = partition(m, k, nc, self.n, &self.cfg);
        let stats0 = self.cache.stats();

        // Degenerate single shard: no slicing, no reduction — identical to
        // a bare core run (plus an optional cache probe on the full set).
        if plans.len() == 1 && plans[0].covers(m, k, nc) {
            let probe = if self.cache.enabled() {
                let weight_fp = combine_fingerprints(bs.iter().map(|b| fingerprint(&[*b])));
                let act_fp = fingerprint(&[a]);
                self.probe_with(weight_fp, act_fp, mode, runtime_interleave)
            } else {
                Probe::Miss(None)
            };
            let result = match probe {
                Probe::Hit(res) => res,
                Probe::Miss(key) => {
                    let res = self.cores[0].run_set(a, bs, mode, runtime_interleave)?;
                    self.store(key, mode, runtime_interleave, &res);
                    res
                }
            };
            let cycles = result.cycles;
            return Ok(ClusterRun {
                result,
                shards: 1,
                per_core_cycles: vec![cycles],
                cache: self.cache.stats().delta_since(&stats0),
            });
        }

        // Slice operands per shard plan (split dimension only; full
        // extents are borrowed, not copied).
        let jobs: Vec<ShardJob<'_>> = plans
            .iter()
            .map(|p| ShardJob {
                a: slice_or_borrow(a, p.rows.start, p.inner.start, p.rows.len(), p.inner.len()),
                bs: bs
                    .iter()
                    .map(|b| {
                        slice_or_borrow(b, p.inner.start, p.cols.start, p.inner.len(), p.cols.len())
                    })
                    .collect(),
            })
            .collect();

        // Probe the cache (sequentially — the cache is shared state).
        // Per-matrix fingerprints of *borrowed* operands are memoized by
        // address, so e.g. an M-split hashes the shared full weight set
        // once per run, not once per shard.
        let mut memo: std::collections::HashMap<usize, u128> = std::collections::HashMap::new();
        let mut fp_of = |c: &Cow<'_, Mat>| -> u128 {
            match c {
                Cow::Borrowed(m) => *memo
                    .entry(*m as *const Mat as usize)
                    .or_insert_with(|| fingerprint(&[*m])),
                Cow::Owned(m) => fingerprint(&[m]),
            }
        };
        let mut slots: Vec<Option<CoSimResult>> = Vec::with_capacity(jobs.len());
        let mut hit: Vec<bool> = Vec::with_capacity(jobs.len());
        let mut keys: Vec<Option<(u128, u128)>> = Vec::with_capacity(jobs.len());
        for job in &jobs {
            let probe = if self.cache.enabled() {
                let act_fp = fp_of(&job.a);
                let weight_fp = combine_fingerprints(job.bs.iter().map(&mut fp_of));
                self.probe_with(weight_fp, act_fp, mode, runtime_interleave)
            } else {
                Probe::Miss(None)
            };
            match probe {
                Probe::Hit(res) => {
                    slots.push(Some(res));
                    hit.push(true);
                    keys.push(None);
                }
                Probe::Miss(key) => {
                    slots.push(None);
                    hit.push(false);
                    keys.push(key);
                }
            }
        }

        // Execute the misses concurrently, one core per shard (shard count
        // never exceeds the core count, so the pairing is 1:1). A single
        // miss runs inline — no point paying a thread spawn for it.
        let misses: Vec<usize> = (0..jobs.len()).filter(|&i| !hit[i]).collect();
        if misses.len() == 1 {
            let only = misses[0];
            let job = &jobs[only];
            let refs: Vec<&Mat> = job.bs.iter().map(|c| &**c).collect();
            let res = self.cores[0]
                .run_set(&job.a, &refs, mode, runtime_interleave)
                .map_err(|e| anyhow!("shard {only}: {e:#}"))?;
            self.store(keys[only], mode, runtime_interleave, &res);
            slots[only] = Some(res);
        } else if !misses.is_empty() {
            let executed: Vec<(usize, Result<CoSimResult>)> = std::thread::scope(|scope| {
                let mut cores = self.cores.iter_mut();
                let handles: Vec<_> = misses
                    .iter()
                    .map(|&i| {
                        let core = cores.next().expect("shards <= cores");
                        let job = &jobs[i];
                        let h = scope.spawn(move || {
                            let refs: Vec<&Mat> = job.bs.iter().map(|c| &**c).collect();
                            core.run_set(&job.a, &refs, mode, runtime_interleave)
                        });
                        (i, h)
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|(i, h)| (i, h.join().expect("shard worker panicked")))
                    .collect()
            });
            for (i, res) in executed {
                let res = res.map_err(|e| anyhow!("shard {i}: {e:#}"))?;
                self.store(keys[i], mode, runtime_interleave, &res);
                slots[i] = Some(res);
            }
        }

        let shard_results: Vec<CoSimResult> =
            slots.into_iter().map(|s| s.expect("all shards resolved")).collect();
        let per_core_cycles: Vec<u64> = shard_results.iter().map(|r| r.cycles).collect();

        // Reduce outputs + accounting. Cache hits already carry zeroed
        // accounting (see `probe_with`), but the broadcast `max` rule must
        // see only *executed* shards, so hits are masked out of the combine.
        let executed_refs: Vec<&CoSimResult> = shard_results
            .iter()
            .zip(&hit)
            .filter(|(_, &h)| !h)
            .map(|(r, _)| r)
            .collect();
        let tile_bytes = (self.n * self.n) as u64;
        let (cycles, passes, energy_j, memory) =
            combine_accounting(self.cfg.split, &executed_refs, tile_bytes);
        let shard_outputs: Vec<Vec<Mat>> =
            shard_results.into_iter().map(|r| r.outputs).collect();
        let outputs = assemble_outputs(m, nc, bs.len(), &plans, &shard_outputs);

        Ok(ClusterRun {
            result: CoSimResult { outputs, passes, cycles, energy_j, memory },
            shards: plans.len(),
            per_core_cycles,
            cache: self.cache.stats().delta_since(&stats0),
        })
    }

    /// Execute a batch of fused requests (all sharing `members[0].a`)
    /// across the cluster — the same contract as
    /// [`CoreScheduler::execute_batch`], with identical per-member
    /// attribution, so the coordinator's worker loop can use either.
    pub fn execute_batch(
        &mut self,
        members: &[&MatmulRequest],
        runtime_interleave: bool,
    ) -> Result<Vec<MemberResult>> {
        assert!(!members.is_empty());
        let first = members[0];
        let mode = select_mode(first.weight_bits, first.act_act);
        let bs: Vec<&Mat> = members.iter().flat_map(|m| m.bs.iter().map(|b| b.as_ref())).collect();
        let run = self.run_gemm_set(&first.a, &bs, mode, runtime_interleave)?;
        Ok(attribute_members(members, &run.result))
    }

    /// Probe the cache under precomputed fingerprints (the caller derives
    /// `weight_fp` via [`combine_fingerprints`] over per-matrix
    /// fingerprints so borrowed operands can be memoized).
    fn probe_with(
        &mut self,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Probe {
        match self.cache.lookup(weight_fp, act_fp, mode, runtime_interleave) {
            Some(mut res) => {
                // a hit skips execution: outputs reused, accounting zeroed
                res.passes = 0;
                res.cycles = 0;
                res.energy_j = 0.0;
                res.memory = Default::default();
                Probe::Hit(res)
            }
            None => Probe::Miss(Some((weight_fp, act_fp))),
        }
    }

    fn store(
        &mut self,
        key: Option<(u128, u128)>,
        mode: PrecisionMode,
        runtime_interleave: bool,
        res: &CoSimResult,
    ) {
        if let Some((weight_fp, act_fp)) = key {
            self.cache.insert(weight_fp, act_fp, mode, runtime_interleave, res.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partitioner::ShardSplit;
    use crate::testutil::Rng;
    use std::sync::Arc;

    fn cluster(cores: usize, split: ShardSplit, n: usize) -> ClusterScheduler {
        ClusterScheduler::new(
            Architecture::Adip,
            n,
            Backend::Functional,
            ClusterConfig::with_cores(cores).with_split(split),
        )
    }

    #[test]
    fn sharded_gemm_bit_exact_across_splits() {
        let mut rng = Rng::seeded(51);
        let a = Mat::random(&mut rng, 48, 40, 8);
        let b = Mat::random(&mut rng, 40, 32, 2);
        let want = a.matmul(&b);
        for split in ShardSplit::ALL {
            let mut c = cluster(3, split, 8);
            let run = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
            assert_eq!(run.result.outputs[0], want, "{split}");
            assert!(run.shards > 1, "{split}: expected sharding");
            assert_eq!(run.per_core_cycles.len(), run.shards);
            assert_eq!(
                run.result.cycles,
                *run.per_core_cycles.iter().max().unwrap(),
                "{split}: cluster latency = max over cores"
            );
        }
    }

    #[test]
    fn single_core_cluster_matches_bare_core() {
        let mut rng = Rng::seeded(53);
        let a = Mat::random(&mut rng, 24, 24, 8);
        let b1 = Mat::random(&mut rng, 24, 24, 4);
        let b2 = Mat::random(&mut rng, 24, 24, 4);
        let mut one = cluster(1, ShardSplit::M, 8);
        let mut core = CoreScheduler::with_backend(Architecture::Adip, 8, Backend::Functional);
        let cr = one.run_gemm_set(&a, &[&b1, &b2], PrecisionMode::W4, false).unwrap();
        let sr = core.run_set(&a, &[&b1, &b2], PrecisionMode::W4, false).unwrap();
        assert_eq!(cr.result.outputs, sr.outputs);
        assert_eq!(cr.result.cycles, sr.cycles);
        assert_eq!(cr.result.passes, sr.passes);
        assert_eq!(cr.result.memory, sr.memory);
        assert_eq!(cr.shards, 1);
    }

    #[test]
    fn execute_batch_attribution_matches_core_scheduler() {
        let mut rng = Rng::seeded(55);
        let a = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let reqs: Vec<MatmulRequest> = (0..2)
            .map(|i| MatmulRequest {
                id: i,
                input_id: 1,
                a: a.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, 16, 16, 2))],
                weight_bits: 2,
                act_act: false,
                tag: String::new(),
            })
            .collect();
        let refs: Vec<&MatmulRequest> = reqs.iter().collect();
        let mut c = cluster(1, ShardSplit::M, 8);
        let mut core = CoreScheduler::new(Architecture::Adip, 8);
        let from_cluster = c.execute_batch(&refs, false).unwrap();
        let from_core = core.execute_batch(&refs, false).unwrap();
        for (x, y) in from_cluster.iter().zip(&from_core) {
            assert_eq!(x.outputs, y.outputs);
            assert_eq!(x.metrics.cycles, y.metrics.cycles);
            assert_eq!(x.metrics.passes, y.metrics.passes);
            assert_eq!(x.metrics.batched, y.metrics.batched);
        }
    }

    #[test]
    fn repeated_run_hits_cache_and_reports_zero_cycles() {
        let mut rng = Rng::seeded(57);
        let a = Mat::random(&mut rng, 64, 32, 8);
        let b = Mat::random(&mut rng, 32, 32, 2);
        let mut c = ClusterScheduler::new(
            Architecture::Adip,
            8,
            Backend::Functional,
            ClusterConfig::with_cores(2).with_cache(32),
        );
        let cold = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(cold.cache.hits, 0);
        assert!(cold.cache.misses > 0);
        assert!(cold.result.cycles > 0);
        let warm = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(warm.result.outputs, cold.result.outputs, "hits must be bit-exact");
        assert_eq!(warm.cache.hits, cold.cache.misses, "every shard served from cache");
        assert_eq!(warm.result.cycles, 0, "fully cached run skips execution");
        assert_eq!(warm.result.memory, Default::default());
        // different activation, same weights: misses into fresh entries
        let a2 = Mat::random(&mut rng, 64, 32, 8);
        let other = c.run_gemm(&a2, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(other.cache.hits, 0);
        assert_eq!(other.result.outputs[0], a2.matmul(&b));
    }

    #[test]
    fn rejects_malformed_sets_like_a_single_core() {
        let a = Mat::zeros(16, 16);
        let short = Mat::zeros(8, 16);
        let mut c = cluster(2, ShardSplit::M, 8);
        let none: Vec<&Mat> = vec![];
        assert!(c.run_gemm_set(&a, &none, PrecisionMode::W8, false).is_err());
        assert!(c.run_gemm(&a, &short, PrecisionMode::W8, false).is_err());
        assert!(c
            .run_gemm_set(&a, &[&a, &short], PrecisionMode::W8, false)
            .is_err());
    }
}
