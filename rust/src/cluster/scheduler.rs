//! The cluster shard scheduler: a persistent pool of per-core workers fed
//! by a shard queue, plus the reducer that folds their results back into
//! one logical run.
//!
//! One [`ClusterScheduler`] owns `P` simulated array cores (each a
//! [`CoreScheduler`] on the configured `Backend` — the backend policy of
//! `rust/src/arch/mod.rs` applies unchanged: functional serves, the cycle
//! simulator stays golden). A GEMM (or shared-input multi-matrix set) is
//! partitioned by [`super::partitioner::partition`], each shard is probed
//! against the [`super::weight_cache::SharedWeightCache`] and, on a miss,
//! executed on a core; the [`super::reducer`] then reassembles outputs and
//! aggregates accounting (including the K-split reduce-step latency).
//!
//! # Execution engines ([`PoolMode`])
//!
//! * [`PoolMode::Persistent`] (default) — long-lived worker threads, one
//!   per core, each owning its `CoreScheduler`, fed by a shared shard
//!   queue. Consecutive invocations reuse warm workers (no spawn/join
//!   barrier per GEMM), and ingress is **pipelined**: the caller slices,
//!   fingerprints and cache-probes shard `i+1` while shards `≤ i` are
//!   already executing. A worker that panics mid-shard reports the shard
//!   as an error (never a hang — the reply is sent before recovery) and
//!   rebuilds its core; dropping the scheduler closes the queue, drains
//!   any queued shards and joins the workers. A **single-core** cluster
//!   has nothing to overlap, so it spawns no pool threads and executes
//!   inline (identical to the per-run engine).
//! * [`PoolMode::PerRun`] — the legacy spawn-per-run engine: scoped
//!   threads spawned per miss, joined before the run returns (a single
//!   miss runs inline). Kept as the baseline the persistent pool is
//!   benchmarked against (`bench_cluster`'s warm-pool gate).
//!
//! Both engines execute the identical shard jobs through
//! [`CoreScheduler::run_set`], so outputs and accounting are bit-identical
//! across pool modes — `rust/tests/integration_cluster.rs` asserts it.
//!
//! The degenerate single-shard case (1 core, or a split dimension with one
//! tile) skips slicing and reduction entirely and is byte-identical to a
//! bare [`CoreScheduler`] run — which is what keeps the coordinator's
//! default configuration (1 cluster core per worker) behavior-neutral.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::arch::{ArchConfig, Architecture, Backend};
use crate::coordinator::scheduler::{attribute_members, CoreScheduler, MemberResult};
use crate::coordinator::select_mode;
use crate::coordinator::MatmulRequest;
use crate::dataflow::Mat;
use crate::obs::{Recorder, SpanKind};
use crate::quant::PrecisionMode;
use crate::sim::cosim::CoSimResult;

use super::partitioner::{partition, ClusterConfig, PoolMode};
use super::reducer::{assemble_outputs, combine_accounting, reduce_cycles};
use super::weight_cache::{combine_fingerprints, fingerprint, CacheStats, SharedWeightCache};

/// Result of one cluster execution: the logical (reduced) co-sim result
/// plus the shard-level breakdown.
#[derive(Debug, Clone)]
pub struct ClusterRun {
    /// Reduced outputs + aggregated accounting (cluster latency = max over
    /// cores plus the reduce-step term; passes/energy/memory combined per
    /// the reducer's rules).
    pub result: CoSimResult,
    /// Shards executed (≤ configured cores; 1 when the GEMM cannot shard).
    pub shards: usize,
    /// Simulated cycles per shard, in plan order (0 for cache hits).
    pub per_core_cycles: Vec<u64>,
    /// This scheduler's weight-cache activity during this run (all zero
    /// when disabled; hits against siblings' entries count `shared_hits`).
    pub cache: CacheStats,
}

/// Cumulative persistent-pool counters (monotonic except `workers`; diff
/// snapshots via [`PoolStats::delta_since`] for per-batch metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    /// Persistent worker threads in the pool (0 in per-run mode).
    pub workers: usize,
    /// Shards handed to pool workers.
    pub dispatched: u64,
    /// Total seconds shards spent queued before a worker picked them up.
    pub queue_wait_s: f64,
    /// Shard executions that panicked (the worker recovered and rebuilt
    /// its core; the shard surfaced as an error to the submitter).
    pub worker_panics: u64,
}

impl PoolStats {
    /// `self - earlier`, for per-batch deltas (`workers` carried as-is).
    pub fn delta_since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            workers: self.workers,
            dispatched: self.dispatched - earlier.dispatched,
            queue_wait_s: (self.queue_wait_s - earlier.queue_wait_s).max(0.0),
            worker_panics: self.worker_panics - earlier.worker_panics,
        }
    }
}

/// What a pool worker executes for one shard.
enum ShardWork {
    /// A real shard: one shared-input GEMM set on a core.
    Run { a: Arc<Mat>, bs: Vec<Arc<Mat>>, mode: PrecisionMode, runtime_interleave: bool },
    /// Test hook: panic inside the worker (exercises panic recovery).
    #[cfg(test)]
    Panic,
}

/// One queued shard job: owned operands plus the reply channel.
struct ShardJob {
    seq: usize,
    submitted: Instant,
    work: ShardWork,
    reply: Sender<ShardDone>,
}

/// A completed (or failed) shard, keyed back to its plan slot.
struct ShardDone {
    seq: usize,
    result: Result<CoSimResult, String>,
}

/// A miss gathered for the per-run (spawn) engine.
struct PendingShard {
    seq: usize,
    a: Arc<Mat>,
    bs: Vec<Arc<Mat>>,
}

/// Atomic counters shared between the pool's workers and the scheduler.
#[derive(Default)]
struct PoolCounters {
    dispatched: AtomicU64,
    queue_wait_ns: AtomicU64,
    panics: AtomicU64,
}

/// Persistent worker pool: `P` long-lived threads, each owning one
/// [`CoreScheduler`], popping shard jobs off a shared queue.
struct WorkerPool {
    /// Job ingress; `None` once shutdown has begun.
    tx: Option<Sender<ShardJob>>,
    handles: Vec<JoinHandle<()>>,
    counters: Arc<PoolCounters>,
    workers: usize,
}

impl WorkerPool {
    fn new(arch: Architecture, core_cfg: ArchConfig, workers: usize) -> WorkerPool {
        let (tx, rx) = channel::<ShardJob>();
        let rx = Arc::new(Mutex::new(rx));
        let counters = Arc::new(PoolCounters::default());
        let handles = (0..workers)
            .map(|w| {
                let rx = rx.clone();
                let counters = counters.clone();
                std::thread::Builder::new()
                    .name(format!("adip-cluster-core-{w}"))
                    .spawn(move || worker_main(arch, core_cfg, rx, counters))
                    .expect("spawn cluster pool worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), handles, counters, workers }
    }

    /// Enqueue one shard. A send can only fail once every worker has died;
    /// the job's reply sender is dropped with it, so the collector sees a
    /// disconnect (an error), never a hang.
    fn submit(&self, job: ShardJob) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(job);
        }
    }

    fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.workers,
            // relaxed-ok: stat reads; a point-in-time report tolerates tearing
            dispatched: self.counters.dispatched.load(Ordering::Relaxed),
            queue_wait_s: self.counters.queue_wait_ns.load(Ordering::Relaxed) as f64 / 1e9,
            worker_panics: self.counters.panics.load(Ordering::Relaxed),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the queue lets each worker drain the jobs already queued
        // (mpsc receivers keep yielding buffered messages after the sender
        // drops) and then exit; join makes shutdown deterministic.
        self.tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Body of one persistent pool worker: own a core, pop shards, execute,
/// reply. A panicking shard is converted into an error reply *before* the
/// core is rebuilt, so the submitter can never be left waiting.
fn worker_main(
    arch: Architecture,
    core_cfg: ArchConfig,
    rx: Arc<Mutex<Receiver<ShardJob>>>,
    counters: Arc<PoolCounters>,
) {
    let mut core = CoreScheduler::with_config(arch, core_cfg);
    loop {
        // Hold the queue lock only for the pop — execution must not block
        // the sibling workers' ingress.
        let job = {
            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
            guard.recv()
        };
        let Ok(job) = job else {
            return; // queue closed and drained: clean shutdown
        };
        counters.dispatched.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        counters
            .queue_wait_ns
            .fetch_add(job.submitted.elapsed().as_nanos() as u64, Ordering::Relaxed); // relaxed-ok: stat counter
        let outcome = catch_unwind(AssertUnwindSafe(|| match &job.work {
            ShardWork::Run { a, bs, mode, runtime_interleave } => {
                let refs: Vec<&Mat> = bs.iter().map(|b| b.as_ref()).collect();
                core.run_set(a, &refs, *mode, *runtime_interleave).map_err(|e| format!("{e:#}"))
            }
            #[cfg(test)]
            ShardWork::Panic => panic!("injected shard panic (test hook)"),
        }));
        let (result, panicked) = match outcome {
            Ok(r) => (r, false),
            Err(_) => (Err("shard worker panicked".to_string()), true),
        };
        let _ = job.reply.send(ShardDone { seq: job.seq, result });
        if panicked {
            counters.panics.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter; pool replacement is signalled separately
            // The interrupted core may hold torn mid-run state; rebuild it
            // so the worker keeps serving subsequent shards correctly.
            core = CoreScheduler::with_config(arch, core_cfg);
        }
    }
}

/// The execution engine behind a cluster (see the module docs).
enum Engine {
    /// Legacy spawn-per-run: scoped threads over scheduler-owned cores.
    PerRun { cores: Vec<CoreScheduler> },
    /// Persistent worker pool (cores owned by the worker threads).
    Pool(WorkerPool),
}

/// Full-extent operand fingerprints computed ahead of execution — the
/// coordinator's prepare stage hashes a batch's operands on its own stage
/// thread and hands them here, so the execute path (worker hot loop)
/// never re-hashes what preparation already covered. Harmless to omit:
/// the scheduler memoizes and computes on demand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PreparedFingerprints {
    /// Fingerprint of the (full) activation matrix.
    pub act: u128,
    /// Per-weight-matrix fingerprints, in operand order.
    pub weights: Vec<u128>,
}

/// One run's operand views plus lazily created shared (`Arc`) handles and
/// memoized full-extent fingerprints.
///
/// Pool workers outlive any one run, so jobs must own their operands:
/// sliced tiles are owned `Mat`s wrapped in fresh `Arc`s, while an operand
/// used at its full extent is shared through a single `Arc` — created at
/// most once per run (callers that already hold `Arc<Mat>` operands, like
/// the coordinator's request path, pre-populate it for free). The same
/// create-at-most-once rule applies to operand fingerprints, which the
/// coordinator's prepare stage can pre-populate via
/// [`PreparedFingerprints`].
struct Operands<'x> {
    a: &'x Mat,
    bs: Vec<&'x Mat>,
    a_arc: Option<Arc<Mat>>,
    bs_arc: Vec<Option<Arc<Mat>>>,
    a_fp: Option<u128>,
    bs_fp: Vec<Option<u128>>,
}

impl<'x> Operands<'x> {
    fn borrowed(a: &'x Mat, bs: &[&'x Mat]) -> Operands<'x> {
        Operands {
            a,
            bs: bs.to_vec(),
            a_arc: None,
            bs_arc: vec![None; bs.len()],
            a_fp: None,
            bs_fp: vec![None; bs.len()],
        }
    }

    fn shared(a: &'x Arc<Mat>, bs: &[&'x Arc<Mat>]) -> Operands<'x> {
        Operands {
            a: a.as_ref(),
            bs: bs.iter().map(|b| b.as_ref()).collect(),
            a_arc: Some(Arc::clone(a)),
            bs_arc: bs.iter().map(|b| Some(Arc::clone(b))).collect(),
            a_fp: None,
            bs_fp: vec![None; bs.len()],
        }
    }

    /// Adopt fingerprints computed ahead of execution. Ignored (falls
    /// back to on-demand hashing) if the operand count does not line up.
    /// Callers are trusted to have hashed *these* operands — the entry
    /// points taking [`PreparedFingerprints`] are crate-internal (the
    /// coordinator's prepare stage), and debug builds re-verify, because
    /// a value mismatch would mis-key the weight cache.
    fn adopt_fps(&mut self, fps: &PreparedFingerprints) {
        if fps.weights.len() == self.bs.len() {
            debug_assert_eq!(fps.act, fingerprint(&[self.a]), "stale activation fingerprint");
            debug_assert!(
                fps.weights
                    .iter()
                    .zip(&self.bs)
                    .all(|(&f, b)| f == fingerprint(&[*b])),
                "stale weight fingerprints"
            );
            self.a_fp = Some(fps.act);
            self.bs_fp = fps.weights.iter().map(|&f| Some(f)).collect();
        }
    }

    /// Shared handle to the full activation matrix (cloned at most once).
    fn share_a(&mut self) -> Arc<Mat> {
        let view = self.a;
        Arc::clone(self.a_arc.get_or_insert_with(|| Arc::new(view.clone())))
    }

    /// Shared handle to full weight matrix `j` (cloned at most once).
    fn share_b(&mut self, j: usize) -> Arc<Mat> {
        let view = self.bs[j];
        Arc::clone(self.bs_arc[j].get_or_insert_with(|| Arc::new(view.clone())))
    }

    /// Fingerprint of the full activation matrix (hashed at most once).
    fn act_fp(&mut self) -> u128 {
        let view = self.a;
        *self.a_fp.get_or_insert_with(|| fingerprint(&[view]))
    }

    /// Fingerprint of full weight matrix `j` (hashed at most once).
    fn weight_fp(&mut self, j: usize) -> u128 {
        let view = self.bs[j];
        *self.bs_fp[j].get_or_insert_with(|| fingerprint(&[view]))
    }

    /// Combined fingerprint of the full weight set.
    fn weight_set_fp(&mut self) -> u128 {
        let fps: Vec<u128> = (0..self.bs.len()).map(|j| self.weight_fp(j)).collect();
        combine_fingerprints(fps)
    }
}

/// Outcome of the cache probe for one shard.
enum Probe {
    /// Served from the cache (outputs reused, accounting zeroed).
    Hit(CoSimResult),
    /// Must execute; insert under these fingerprints afterwards.
    Miss(Option<(u128, u128)>),
}

/// Pool of `P` array cores + the (shareable) weight-tile cache.
pub struct ClusterScheduler {
    engine: Engine,
    cfg: ClusterConfig,
    cache: SharedWeightCache,
    /// This scheduler's identity in the shared store (cross-owner hits
    /// are what `shared_hits` counts).
    cache_id: u64,
    /// Cache activity caused by *this* scheduler (the shared store also
    /// keeps global counters; per-worker metrics need the local view).
    local_cache: CacheStats,
    arch: Architecture,
    backend: Backend,
    n: usize,
    /// Lifecycle-trace sink (disabled by default — a bare scheduler
    /// records nothing). The coordinator's worker loop installs its
    /// metrics recorder + worker lane via [`ClusterScheduler::set_trace`].
    trace: Recorder,
    trace_lane: u32,
    /// Ticket the next run's shard/reduce spans are attributed to
    /// (stamped per batch by the worker loop; 0 for direct use).
    trace_ticket: u64,
}

impl ClusterScheduler {
    /// Build a cluster of `cfg.effective_cores()` cores, each simulating
    /// `arch` at size `n` on `backend`, with a private weight-cache store.
    pub fn new(
        arch: Architecture,
        n: usize,
        backend: Backend,
        cfg: ClusterConfig,
    ) -> ClusterScheduler {
        let cache = SharedWeightCache::new(cfg.cache);
        ClusterScheduler::with_shared_cache(arch, n, backend, cfg, cache)
    }

    /// Build a cluster whose weight cache is an existing shared store —
    /// the coordinator hands every server worker the same store so
    /// siblings reuse each other's projection tiles. The store's own
    /// capacity governs; `cfg.cache` is ignored in this constructor.
    pub fn with_shared_cache(
        arch: Architecture,
        n: usize,
        backend: Backend,
        cfg: ClusterConfig,
        cache: SharedWeightCache,
    ) -> ClusterScheduler {
        // A single-core cluster has nothing to overlap: every run is one
        // shard, so spinning up a pool thread would only add a queue hop
        // to the coordinator's default hot path. Run it inline (the
        // per-run engine with one core spawns no threads at all).
        let core_cfg = ArchConfig::with_n(n)
            .with_backend(backend)
            .with_kernel(cfg.kernel)
            .with_kernel_threads(cfg.kernel_threads);
        let engine = match cfg.pool {
            PoolMode::Persistent if cfg.effective_cores() > 1 => {
                Engine::Pool(WorkerPool::new(arch, core_cfg, cfg.effective_cores()))
            }
            _ => Engine::PerRun {
                cores: (0..cfg.effective_cores())
                    .map(|_| CoreScheduler::with_config(arch, core_cfg))
                    .collect(),
            },
        };
        let cache_id = cache.register();
        ClusterScheduler {
            engine,
            cfg,
            cache,
            cache_id,
            local_cache: CacheStats::default(),
            arch,
            backend,
            n,
            trace: Recorder::default(),
            trace_lane: 0,
            trace_ticket: 0,
        }
    }

    /// Install a lifecycle-trace recorder and the lane (thread track) this
    /// scheduler's shard/reduce spans render under. Observability only —
    /// the recorder never influences partitioning, caching or execution.
    pub(crate) fn set_trace(&mut self, rec: Recorder, lane: u32) {
        self.trace = rec;
        self.trace_lane = lane;
    }

    /// Attribute the next run's shard/reduce spans to this ticket (the
    /// coordinator worker stamps the batch leader's request id).
    pub(crate) fn set_trace_ticket(&mut self, id: u64) {
        self.trace_ticket = id;
    }

    /// Cluster configuration.
    pub fn cluster_config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Which architecture the cores simulate.
    pub fn architecture(&self) -> Architecture {
        self.arch
    }

    /// Which execution backend the cores run on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// This scheduler's cumulative weight-cache counters (`entries`
    /// reflects the — possibly shared — store).
    pub fn cache_stats(&self) -> CacheStats {
        CacheStats { entries: self.cache.entries(), ..self.local_cache }
    }

    /// Handle to the weight-cache store (global counters, sharing).
    pub fn shared_cache(&self) -> SharedWeightCache {
        self.cache.clone()
    }

    /// Cumulative persistent-pool counters (all zero in per-run mode).
    pub fn pool_stats(&self) -> PoolStats {
        match &self.engine {
            Engine::Pool(pool) => pool.stats(),
            Engine::PerRun { .. } => PoolStats::default(),
        }
    }

    /// Execute `C = A · B` across the cluster.
    pub fn run_gemm(
        &mut self,
        a: &Mat,
        b: &Mat,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<ClusterRun> {
        self.run_gemm_set(a, &[b], mode, runtime_interleave)
    }

    /// Execute a shared-input GEMM set `C_s = A · B_s` across the cluster:
    /// partition per the configured split, serve shards from the weight
    /// cache where possible, run the misses on the engine's cores, and
    /// reduce. Full-extent operands are copied into shared handles at most
    /// once per run; callers that already hold `Arc<Mat>` operands should
    /// use [`ClusterScheduler::run_gemm_set_shared`] to avoid even that.
    pub fn run_gemm_set(
        &mut self,
        a: &Mat,
        bs: &[&Mat],
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<ClusterRun> {
        let ops = Operands::borrowed(a, bs);
        self.run_inner(ops, mode, runtime_interleave)
    }

    /// [`ClusterScheduler::run_gemm_set`] over operands that are already
    /// shared handles (the coordinator's request path) — zero operand
    /// copies beyond the split-dimension slices.
    pub fn run_gemm_set_shared(
        &mut self,
        a: &Arc<Mat>,
        bs: &[&Arc<Mat>],
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<ClusterRun> {
        let ops = Operands::shared(a, bs);
        self.run_inner(ops, mode, runtime_interleave)
    }

    /// [`ClusterScheduler::run_gemm_set_shared`] with operand
    /// fingerprints computed ahead of execution (the coordinator's
    /// prepare stage): the cache probe reuses them instead of re-hashing
    /// on the worker's execute path. `fps = None` degrades gracefully to
    /// on-demand hashing, so results and accounting are identical either
    /// way (the fingerprints are a pure function of the operands).
    /// Crate-internal: supplying fingerprints of *different* operands
    /// would mis-key the weight cache, so only the trusted prepare stage
    /// gets to pass them (debug builds re-verify).
    pub(crate) fn run_gemm_set_prepared(
        &mut self,
        a: &Arc<Mat>,
        bs: &[&Arc<Mat>],
        mode: PrecisionMode,
        runtime_interleave: bool,
        fps: Option<&PreparedFingerprints>,
    ) -> Result<ClusterRun> {
        let mut ops = Operands::shared(a, bs);
        if let Some(f) = fps {
            ops.adopt_fps(f);
        }
        self.run_inner(ops, mode, runtime_interleave)
    }

    fn run_inner(
        &mut self,
        mut ops: Operands<'_>,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<ClusterRun> {
        ensure!(!ops.bs.is_empty(), "need at least one weight matrix");
        for b in &ops.bs {
            ensure!(
                b.rows() == ops.bs[0].rows() && b.cols() == ops.bs[0].cols(),
                "weight matrices must share a shape"
            );
            ensure!(ops.a.cols() == b.rows(), "inner dimension mismatch");
        }
        let (m, k, nc) = (ops.a.rows(), ops.a.cols(), ops.bs[0].cols());
        let plans = partition(m, k, nc, self.n, &self.cfg);
        let stats0 = self.cache_stats();

        // Degenerate single shard: no slicing, no reduction — identical to
        // a bare core run (plus an optional cache probe on the full set).
        if plans.len() == 1 && plans[0].covers(m, k, nc) {
            let probe = if self.cache.enabled() {
                let weight_fp = ops.weight_set_fp();
                let act_fp = ops.act_fp();
                self.probe_with(weight_fp, act_fp, mode, runtime_interleave)
            } else {
                Probe::Miss(None)
            };
            let result = match probe {
                Probe::Hit(res) => res,
                Probe::Miss(key) => {
                    let t0 = Instant::now();
                    let res = self.exec_whole(&mut ops, mode, runtime_interleave)?;
                    self.trace.span_since(
                        SpanKind::Shard,
                        self.trace_ticket,
                        self.trace_lane,
                        t0,
                        0,
                    );
                    self.store(key, mode, runtime_interleave, &res);
                    res
                }
            };
            let cycles = result.cycles;
            return Ok(ClusterRun {
                result,
                shards: 1,
                per_core_cycles: vec![cycles],
                cache: self.cache_stats().delta_since(&stats0),
            });
        }

        // Pipelined shard ingress: slice → fingerprint → cache probe →
        // dispatch, one shard at a time, so warm pool workers execute
        // shard i while the caller prepares shard i+1. (Per-run mode
        // gathers the misses and fans out scoped threads at the end — the
        // legacy barrier semantics kept for comparison.) Fingerprints of
        // full-extent operands are memoized per run, so e.g. an M-split
        // hashes the shared weight set once, not once per shard.
        let (done_tx, done_rx) = channel::<ShardDone>();
        let mut slots: Vec<Option<CoSimResult>> = vec![None; plans.len()];
        let mut hit = vec![false; plans.len()];
        let mut keys: Vec<Option<(u128, u128)>> = vec![None; plans.len()];
        let mut pending: Vec<PendingShard> = Vec::new();
        let mut submitted = 0usize;
        // Dispatch instants keyed by plan slot — Shard spans cover
        // dispatch → completion (queue wait + execution); hits record none.
        let mut dispatched_at: Vec<Option<Instant>> = vec![None; plans.len()];
        for (i, p) in plans.iter().enumerate() {
            let a_full =
                p.rows.start == 0 && p.inner.start == 0 && p.rows.len() == m && p.inner.len() == k;
            let b_full =
                p.inner.start == 0 && p.cols.start == 0 && p.inner.len() == k && p.cols.len() == nc;
            let a_slice = (!a_full)
                .then(|| ops.a.tile(p.rows.start, p.inner.start, p.rows.len(), p.inner.len()));
            let b_slices: Option<Vec<Mat>> = (!b_full).then(|| {
                ops.bs
                    .iter()
                    .map(|b| b.tile(p.inner.start, p.cols.start, p.inner.len(), p.cols.len()))
                    .collect()
            });

            let probe = if self.cache.enabled() {
                let act_fp = match &a_slice {
                    Some(t) => fingerprint(&[t]),
                    None => ops.act_fp(),
                };
                let weight_fp = match &b_slices {
                    Some(ts) => combine_fingerprints(ts.iter().map(|t| fingerprint(&[t]))),
                    None => ops.weight_set_fp(),
                };
                self.probe_with(weight_fp, act_fp, mode, runtime_interleave)
            } else {
                Probe::Miss(None)
            };

            match probe {
                Probe::Hit(res) => {
                    slots[i] = Some(res);
                    hit[i] = true;
                }
                Probe::Miss(key) => {
                    keys[i] = key;
                    let a_sh = match a_slice {
                        Some(t) => Arc::new(t),
                        None => ops.share_a(),
                    };
                    let bs_sh: Vec<Arc<Mat>> = match b_slices {
                        Some(ts) => ts.into_iter().map(Arc::new).collect(),
                        None => (0..ops.bs.len()).map(|j| ops.share_b(j)).collect(),
                    };
                    let now = Instant::now();
                    dispatched_at[i] = Some(now);
                    match &mut self.engine {
                        Engine::Pool(pool) => {
                            pool.submit(ShardJob {
                                seq: i,
                                submitted: now,
                                work: ShardWork::Run {
                                    a: a_sh,
                                    bs: bs_sh,
                                    mode,
                                    runtime_interleave,
                                },
                                reply: done_tx.clone(),
                            });
                            submitted += 1;
                        }
                        Engine::PerRun { .. } => {
                            pending.push(PendingShard { seq: i, a: a_sh, bs: bs_sh })
                        }
                    }
                }
            }
        }
        // Drop our reply sender: the collector below must see a disconnect
        // (not a hang) if any in-flight job is lost with a dead worker.
        drop(done_tx);

        // Per-run engine: fan out the gathered misses (inline when single).
        if !pending.is_empty() {
            let executed = match &mut self.engine {
                Engine::PerRun { cores } => run_pending(cores, &pending, mode, runtime_interleave),
                Engine::Pool(_) => unreachable!("pending shards only accumulate in per-run mode"),
            };
            for (seq, res) in executed {
                let res = res.map_err(|e| anyhow!("shard {seq}: {e:#}"))?;
                if let Some(t0) = dispatched_at[seq] {
                    self.trace.span_since(
                        SpanKind::Shard,
                        self.trace_ticket,
                        self.trace_lane,
                        t0,
                        seq as u64,
                    );
                }
                self.store(keys[seq], mode, runtime_interleave, &res);
                slots[seq] = Some(res);
            }
        }
        // Pool engine: collect completions (arrival order is irrelevant —
        // results are keyed back to their plan slots).
        for _ in 0..submitted {
            match done_rx.recv() {
                Ok(d) => {
                    let res = d.result.map_err(|e| anyhow!("shard {}: {e}", d.seq))?;
                    if let Some(t0) = dispatched_at[d.seq] {
                        self.trace.span_since(
                            SpanKind::Shard,
                            self.trace_ticket,
                            self.trace_lane,
                            t0,
                            d.seq as u64,
                        );
                    }
                    self.store(keys[d.seq], mode, runtime_interleave, &res);
                    slots[d.seq] = Some(res);
                }
                Err(_) => return Err(anyhow!("cluster worker pool disconnected")),
            }
        }

        let shard_results: Vec<CoSimResult> =
            slots.into_iter().map(|s| s.expect("all shards resolved")).collect();
        let per_core_cycles: Vec<u64> = shard_results.iter().map(|r| r.cycles).collect();

        // Reduce outputs + accounting. Cache hits already carry zeroed
        // accounting (see `probe_with`), but the broadcast `max` rule must
        // see only *executed* shards, so hits are masked out of the combine.
        let t_reduce = Instant::now();
        let executed_refs: Vec<&CoSimResult> = shard_results
            .iter()
            .zip(&hit)
            .filter(|(_, &h)| !h)
            .map(|(r, _)| r)
            .collect();
        let tile_bytes = (self.n * self.n) as u64;
        let (exec_cycles, passes, energy_j, memory) =
            combine_accounting(self.cfg.split, &executed_refs, tile_bytes);
        // The K-split's cross-core accumulate is charged explicitly (it
        // used to be modeled as free). It depends only on the plan shape,
        // so warm (fully cached) K-split runs still pay for reassembly.
        let cycles = exec_cycles
            + reduce_cycles(self.cfg.split, plans.len(), m, nc, ops.bs.len(), self.n);
        let shard_outputs: Vec<Vec<Mat>> =
            shard_results.into_iter().map(|r| r.outputs).collect();
        let outputs = assemble_outputs(m, nc, ops.bs.len(), &plans, &shard_outputs);
        self.trace.span_since(
            SpanKind::Reduce,
            self.trace_ticket,
            self.trace_lane,
            t_reduce,
            plans.len() as u64,
        );

        Ok(ClusterRun {
            result: CoSimResult { outputs, passes, cycles, energy_j, memory },
            shards: plans.len(),
            per_core_cycles,
            cache: self.cache_stats().delta_since(&stats0),
        })
    }

    /// Execute a batch of fused requests (all sharing `members[0].a`)
    /// across the cluster — the same contract as
    /// [`CoreScheduler::execute_batch`], with identical per-member
    /// attribution, so the coordinator's worker loop can use either.
    pub fn execute_batch(
        &mut self,
        members: &[&MatmulRequest],
        runtime_interleave: bool,
    ) -> Result<Vec<MemberResult>> {
        assert!(!members.is_empty());
        let first = members[0];
        let mode = select_mode(first.weight_bits, first.act_act);
        self.execute_batch_prepared(members, mode, runtime_interleave, None)
    }

    /// [`ClusterScheduler::execute_batch`] with the prepare stage's work
    /// already done: the precision mode was selected and the operand
    /// fingerprints were hashed off the execute path. This is the
    /// coordinator worker's entry point in the three-stage
    /// admit → prepare → execute pipeline (crate-internal — see
    /// [`ClusterScheduler::run_gemm_set_prepared`]).
    pub(crate) fn execute_batch_prepared(
        &mut self,
        members: &[&MatmulRequest],
        mode: PrecisionMode,
        runtime_interleave: bool,
        fps: Option<&PreparedFingerprints>,
    ) -> Result<Vec<MemberResult>> {
        assert!(!members.is_empty());
        let first = members[0];
        let bs: Vec<&Arc<Mat>> = members.iter().flat_map(|m| m.bs.iter()).collect();
        let run = self.run_gemm_set_prepared(&first.a, &bs, mode, runtime_interleave, fps)?;
        Ok(attribute_members(members, &run.result))
    }

    /// Execute the whole (unsharded) GEMM set on one core.
    fn exec_whole(
        &mut self,
        ops: &mut Operands<'_>,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Result<CoSimResult> {
        match &mut self.engine {
            Engine::PerRun { cores } => cores[0].run_set(ops.a, &ops.bs, mode, runtime_interleave),
            Engine::Pool(pool) => {
                let (reply, done) = channel();
                let a = ops.share_a();
                let bs: Vec<Arc<Mat>> = (0..ops.bs.len()).map(|j| ops.share_b(j)).collect();
                pool.submit(ShardJob {
                    seq: 0,
                    submitted: Instant::now(),
                    work: ShardWork::Run { a, bs, mode, runtime_interleave },
                    reply,
                });
                match done.recv() {
                    Ok(d) => d.result.map_err(|e| anyhow!("shard 0: {e}")),
                    Err(_) => Err(anyhow!("cluster worker pool disconnected")),
                }
            }
        }
    }

    /// Probe the cache under precomputed fingerprints. Callers must check
    /// [`SharedWeightCache::enabled`] first — a disabled cache stays
    /// silent in both the local and the global counters.
    fn probe_with(
        &mut self,
        weight_fp: u128,
        act_fp: u128,
        mode: PrecisionMode,
        runtime_interleave: bool,
    ) -> Probe {
        match self.cache.lookup(self.cache_id, weight_fp, act_fp, mode, runtime_interleave) {
            Some((cached, cross_owner)) => {
                self.local_cache.hits += 1;
                if cross_owner {
                    self.local_cache.shared_hits += 1;
                }
                // a hit skips execution: outputs reused, accounting zeroed
                // (the deep copy happens here, outside the store's mutex)
                let mut res = (*cached).clone();
                res.passes = 0;
                res.cycles = 0;
                res.energy_j = 0.0;
                res.memory = Default::default();
                Probe::Hit(res)
            }
            None => {
                self.local_cache.misses += 1;
                Probe::Miss(Some((weight_fp, act_fp)))
            }
        }
    }

    fn store(
        &mut self,
        key: Option<(u128, u128)>,
        mode: PrecisionMode,
        runtime_interleave: bool,
        res: &CoSimResult,
    ) {
        if let Some((weight_fp, act_fp)) = key {
            self.local_cache.evictions += self.cache.insert(
                self.cache_id,
                weight_fp,
                act_fp,
                mode,
                runtime_interleave,
                res.clone(),
            );
        }
    }

    /// Test hook: push a panicking job through the persistent pool and
    /// return what the submitter observes.
    #[cfg(test)]
    fn inject_panic_for_test(&mut self) -> Result<CoSimResult, String> {
        match &mut self.engine {
            Engine::Pool(pool) => {
                let (reply, done) = channel();
                pool.submit(ShardJob {
                    seq: 0,
                    submitted: Instant::now(),
                    work: ShardWork::Panic,
                    reply,
                });
                done.recv().expect("pool must reply, not hang").result
            }
            Engine::PerRun { .. } => panic!("panic injection requires the persistent pool"),
        }
    }
}

/// Execute the per-run engine's gathered misses: scoped threads, one core
/// per shard (shard count never exceeds the core count, so the pairing is
/// 1:1); a single miss runs inline — no point paying a thread spawn for it.
fn run_pending(
    cores: &mut [CoreScheduler],
    pending: &[PendingShard],
    mode: PrecisionMode,
    runtime_interleave: bool,
) -> Vec<(usize, Result<CoSimResult>)> {
    if pending.len() == 1 {
        let p = &pending[0];
        let refs: Vec<&Mat> = p.bs.iter().map(|b| b.as_ref()).collect();
        return vec![(p.seq, cores[0].run_set(&p.a, &refs, mode, runtime_interleave))];
    }
    std::thread::scope(|scope| {
        let mut cores = cores.iter_mut();
        let handles: Vec<_> = pending
            .iter()
            .map(|p| {
                let core = cores.next().expect("shards <= cores");
                let h = scope.spawn(move || {
                    let refs: Vec<&Mat> = p.bs.iter().map(|b| b.as_ref()).collect();
                    core.run_set(&p.a, &refs, mode, runtime_interleave)
                });
                (p.seq, h)
            })
            .collect();
        handles
            .into_iter()
            .map(|(s, h)| (s, h.join().expect("shard worker panicked")))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partitioner::ShardSplit;
    use crate::testutil::Rng;

    fn cluster(cores: usize, split: ShardSplit, n: usize) -> ClusterScheduler {
        ClusterScheduler::new(
            Architecture::Adip,
            n,
            Backend::Functional,
            ClusterConfig::with_cores(cores).with_split(split),
        )
    }

    #[test]
    fn sharded_gemm_bit_exact_across_splits() {
        let mut rng = Rng::seeded(51);
        let a = Mat::random(&mut rng, 48, 40, 8);
        let b = Mat::random(&mut rng, 40, 32, 2);
        let want = a.matmul(&b);
        for split in ShardSplit::ALL {
            let mut c = cluster(3, split, 8);
            let run = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
            assert_eq!(run.result.outputs[0], want, "{split}");
            assert!(run.shards > 1, "{split}: expected sharding");
            assert_eq!(run.per_core_cycles.len(), run.shards);
            let reduce = reduce_cycles(split, run.shards, 48, 32, 1, 8);
            assert_eq!(
                run.result.cycles,
                *run.per_core_cycles.iter().max().unwrap() + reduce,
                "{split}: cluster latency = max over cores + reduce step"
            );
        }
    }

    #[test]
    fn pool_modes_agree_field_by_field() {
        let mut rng = Rng::seeded(52);
        let a = Mat::random(&mut rng, 40, 24, 8);
        let b1 = Mat::random(&mut rng, 24, 32, 2);
        let b2 = Mat::random(&mut rng, 24, 32, 2);
        for split in ShardSplit::ALL {
            let cfg = ClusterConfig::with_cores(3).with_split(split);
            let mut pool = ClusterScheduler::new(
                Architecture::Adip,
                8,
                Backend::Functional,
                cfg.with_pool(PoolMode::Persistent),
            );
            let mut spawn = ClusterScheduler::new(
                Architecture::Adip,
                8,
                Backend::Functional,
                cfg.with_pool(PoolMode::PerRun),
            );
            let rp = pool.run_gemm_set(&a, &[&b1, &b2], PrecisionMode::W2, false).unwrap();
            let rs = spawn.run_gemm_set(&a, &[&b1, &b2], PrecisionMode::W2, false).unwrap();
            assert_eq!(rp.result.outputs, rs.result.outputs, "{split}");
            assert_eq!(rp.result.cycles, rs.result.cycles, "{split}");
            assert_eq!(rp.result.passes, rs.result.passes, "{split}");
            assert_eq!(rp.result.memory, rs.result.memory, "{split}");
            assert_eq!(rp.per_core_cycles, rs.per_core_cycles, "{split}");
            assert!(pool.pool_stats().dispatched > 0);
            assert_eq!(spawn.pool_stats(), PoolStats::default());
        }
    }

    #[test]
    fn single_core_cluster_matches_bare_core() {
        let mut rng = Rng::seeded(53);
        let a = Mat::random(&mut rng, 24, 24, 8);
        let b1 = Mat::random(&mut rng, 24, 24, 4);
        let b2 = Mat::random(&mut rng, 24, 24, 4);
        let mut one = cluster(1, ShardSplit::M, 8);
        let mut core = CoreScheduler::with_backend(Architecture::Adip, 8, Backend::Functional);
        let cr = one.run_gemm_set(&a, &[&b1, &b2], PrecisionMode::W4, false).unwrap();
        let sr = core.run_set(&a, &[&b1, &b2], PrecisionMode::W4, false).unwrap();
        assert_eq!(cr.result.outputs, sr.outputs);
        assert_eq!(cr.result.cycles, sr.cycles);
        assert_eq!(cr.result.passes, sr.passes);
        assert_eq!(cr.result.memory, sr.memory);
        assert_eq!(cr.shards, 1);
    }

    #[test]
    fn execute_batch_attribution_matches_core_scheduler() {
        let mut rng = Rng::seeded(55);
        let a = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let reqs: Vec<MatmulRequest> = (0..2)
            .map(|i| MatmulRequest {
                id: i,
                input_id: 1,
                a: a.clone(),
                bs: vec![Arc::new(Mat::random(&mut rng, 16, 16, 2))],
                weight_bits: 2,
                act_act: false,
                tag: String::new(),
            })
            .collect();
        let refs: Vec<&MatmulRequest> = reqs.iter().collect();
        let mut c = cluster(1, ShardSplit::M, 8);
        let mut core = CoreScheduler::new(Architecture::Adip, 8);
        let from_cluster = c.execute_batch(&refs, false).unwrap();
        let from_core = core.execute_batch(&refs, false).unwrap();
        for (x, y) in from_cluster.iter().zip(&from_core) {
            assert_eq!(x.outputs, y.outputs);
            assert_eq!(x.metrics.cycles, y.metrics.cycles);
            assert_eq!(x.metrics.passes, y.metrics.passes);
            assert_eq!(x.metrics.batched, y.metrics.batched);
        }
    }

    #[test]
    fn repeated_run_hits_cache_and_reports_zero_cycles() {
        let mut rng = Rng::seeded(57);
        let a = Mat::random(&mut rng, 64, 32, 8);
        let b = Mat::random(&mut rng, 32, 32, 2);
        let mut c = ClusterScheduler::new(
            Architecture::Adip,
            8,
            Backend::Functional,
            ClusterConfig::with_cores(2).with_cache(32),
        );
        let cold = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(cold.cache.hits, 0);
        assert!(cold.cache.misses > 0);
        assert!(cold.result.cycles > 0);
        let warm = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(warm.result.outputs, cold.result.outputs, "hits must be bit-exact");
        assert_eq!(warm.cache.hits, cold.cache.misses, "every shard served from cache");
        assert_eq!(warm.cache.shared_hits, 0, "own entries are not shared hits");
        assert_eq!(warm.result.cycles, 0, "fully cached M-split run skips execution");
        assert_eq!(warm.result.memory, Default::default());
        // different activation, same weights: misses into fresh entries
        let a2 = Mat::random(&mut rng, 64, 32, 8);
        let other = c.run_gemm(&a2, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(other.cache.hits, 0);
        assert_eq!(other.result.outputs[0], a2.matmul(&b));
    }

    #[test]
    fn warm_pool_repeat_invocations_stay_bit_exact() {
        let mut rng = Rng::seeded(59);
        let a = Mat::random(&mut rng, 48, 32, 8);
        let b = Mat::random(&mut rng, 32, 40, 4);
        let mut core = CoreScheduler::with_backend(Architecture::Adip, 8, Backend::Functional);
        let fresh = core.run_set(&a, &[&b], PrecisionMode::W4, false).unwrap();
        let mut mesh = cluster(4, ShardSplit::M, 8);
        for round in 0..4 {
            let run = mesh.run_gemm(&a, &b, PrecisionMode::W4, false).unwrap();
            assert_eq!(run.result.outputs, fresh.outputs, "round {round}");
            assert_eq!(run.result.passes, fresh.passes, "round {round}");
        }
        let stats = mesh.pool_stats();
        assert_eq!(stats.workers, 4);
        assert_eq!(stats.dispatched, 4 * 4, "4 shards per round, 4 rounds, no respawn");
        assert_eq!(stats.worker_panics, 0);
    }

    #[test]
    fn panicked_worker_surfaces_error_and_pool_recovers() {
        let mut c = cluster(2, ShardSplit::M, 8);
        let err = c.inject_panic_for_test().unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert_eq!(c.pool_stats().worker_panics, 1);
        // the pool rebuilt the panicked core and keeps serving correctly
        let mut rng = Rng::seeded(61);
        let a = Mat::random(&mut rng, 32, 16, 8);
        let b = Mat::random(&mut rng, 16, 16, 2);
        let run = c.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(run.result.outputs[0], a.matmul(&b));
    }

    #[test]
    fn dropping_the_pool_drains_queued_shards() {
        let mut rng = Rng::seeded(63);
        let a = Arc::new(Mat::random(&mut rng, 16, 16, 8));
        let b = Arc::new(Mat::random(&mut rng, 16, 16, 2));
        let pool = WorkerPool::new(Architecture::Adip, ArchConfig::with_n(8), 1);
        let (reply, done) = channel();
        for seq in 0..6 {
            pool.submit(ShardJob {
                seq,
                submitted: Instant::now(),
                work: ShardWork::Run {
                    a: a.clone(),
                    bs: vec![b.clone()],
                    mode: PrecisionMode::W2,
                    runtime_interleave: false,
                },
                reply: reply.clone(),
            });
        }
        drop(reply);
        // Dropping the pool closes the queue and joins the worker — which
        // must first drain every queued shard.
        drop(pool);
        let results: Vec<ShardDone> = done.iter().collect();
        assert_eq!(results.len(), 6, "all queued shards answered before join");
        for d in results {
            assert_eq!(d.result.unwrap().outputs[0], a.matmul(&b));
        }
    }

    #[test]
    fn shared_cache_serves_sibling_schedulers() {
        let mut rng = Rng::seeded(65);
        let a = Mat::random(&mut rng, 32, 16, 8);
        let b = Mat::random(&mut rng, 16, 16, 2);
        let store = SharedWeightCache::new(crate::cluster::CacheConfig {
            capacity: 16,
            ..Default::default()
        });
        let cfg = ClusterConfig::with_cores(1).with_cache(16);
        let mut first = ClusterScheduler::with_shared_cache(
            Architecture::Adip,
            8,
            Backend::Functional,
            cfg,
            store.clone(),
        );
        let mut second = ClusterScheduler::with_shared_cache(
            Architecture::Adip,
            8,
            Backend::Functional,
            cfg,
            store.clone(),
        );
        let cold = first.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(cold.cache.misses, 1);
        // the sibling never executed this GEMM, yet hits the shared entry
        let warm = second.run_gemm(&a, &b, PrecisionMode::W2, false).unwrap();
        assert_eq!(warm.cache.hits, 1);
        assert_eq!(warm.cache.shared_hits, 1, "hit on a sibling's entry");
        assert_eq!(warm.result.outputs, cold.result.outputs, "byte-identical reuse");
        assert_eq!(warm.result.cycles, 0);
        let global = store.stats();
        assert_eq!((global.hits, global.misses), (1, 1));
        assert_eq!(global.shared_hits, 1);
    }

    #[test]
    fn prepared_fingerprints_are_equivalent_to_on_demand_hashing() {
        // a run that adopted prepared fingerprints must populate the
        // cache under the same keys an unprepared run computes itself
        let mut rng = Rng::seeded(71);
        let a = Arc::new(Mat::random(&mut rng, 48, 32, 8));
        let b = Arc::new(Mat::random(&mut rng, 32, 32, 2));
        for cores in [1usize, 2] {
            let mut c = ClusterScheduler::new(
                Architecture::Adip,
                8,
                Backend::Functional,
                ClusterConfig::with_cores(cores).with_cache(16),
            );
            let fps = PreparedFingerprints {
                act: fingerprint(&[a.as_ref()]),
                weights: vec![fingerprint(&[b.as_ref()])],
            };
            let cold = c
                .run_gemm_set_prepared(&a, &[&b], PrecisionMode::W2, false, Some(&fps))
                .unwrap();
            assert_eq!(cold.result.outputs[0], a.matmul(&b), "{cores} cores");
            assert!(cold.cache.misses > 0);
            // the same GEMM *without* prepared fingerprints must hit
            // every entry the prepared run inserted
            let warm = c.run_gemm_set_shared(&a, &[&b], PrecisionMode::W2, false).unwrap();
            assert_eq!(warm.result.outputs, cold.result.outputs, "{cores} cores");
            assert_eq!(warm.cache.hits, cold.cache.misses, "{cores} cores: keys must agree");
            // mismatched operand counts degrade to on-demand hashing
            // rather than mis-keying the cache
            let stale = PreparedFingerprints { act: fps.act, weights: vec![fps.weights[0]; 3] };
            let again = c
                .run_gemm_set_prepared(&a, &[&b], PrecisionMode::W2, false, Some(&stale))
                .unwrap();
            assert_eq!(again.cache.hits, cold.cache.misses, "{cores} cores");
        }
    }

    #[test]
    fn rejects_malformed_sets_like_a_single_core() {
        let a = Mat::zeros(16, 16);
        let short = Mat::zeros(8, 16);
        let mut c = cluster(2, ShardSplit::M, 8);
        let none: Vec<&Mat> = vec![];
        assert!(c.run_gemm_set(&a, &none, PrecisionMode::W8, false).is_err());
        assert!(c.run_gemm(&a, &short, PrecisionMode::W8, false).is_err());
        assert!(c
            .run_gemm_set(&a, &[&a, &short], PrecisionMode::W8, false)
            .is_err());
    }
}
